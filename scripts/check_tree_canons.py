"""Guard: the tree-template compilation route is frozen.

Computes a SHA-256 digest over the canonical stage sequences
(:func:`repro.plan.ir.template_canon_sequence`) of every paper tree
template and compares it against the committed digest in
``scripts/tree_canons.sha256``.  The canon sequence IS the schedule
identity (plan equality and the engine cache key both reduce to it), so
any refactor that perturbs how trees compile — e.g. the bag-stage
generalization growing new code paths — trips this guard BEFORE counts
can drift.

Usage::

    PYTHONPATH=src python scripts/check_tree_canons.py           # verify
    PYTHONPATH=src python scripts/check_tree_canons.py --update  # re-pin

Only re-pin when a tree-schedule change is intentional; note it in the
commit message.
"""

from __future__ import annotations

import argparse
import hashlib
import os
import sys

sys.path.insert(
    0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
)

from repro.core.templates import PAPER_TEMPLATES  # noqa: E402
from repro.plan.ir import template_canon_sequence  # noqa: E402

DIGEST_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)), "tree_canons.sha256")


def current_digest() -> str:
    payload = []
    for name in sorted(PAPER_TEMPLATES):
        canons = template_canon_sequence(PAPER_TEMPLATES[name])
        payload.append(f"{name}: {canons!r}")
    return hashlib.sha256("\n".join(payload).encode()).hexdigest()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--update", action="store_true", help="re-pin the committed digest"
    )
    args = ap.parse_args(argv)
    digest = current_digest()
    if args.update:
        with open(DIGEST_PATH, "w") as fh:
            fh.write(digest + "\n")
        print(f"tree canon digest re-pinned: {digest}")
        return 0
    try:
        with open(DIGEST_PATH) as fh:
            committed = fh.read().strip()
    except FileNotFoundError:
        print(
            f"no committed digest at {DIGEST_PATH} — run with --update to pin",
            file=sys.stderr,
        )
        return 1
    if digest != committed:
        print(
            "tree-template canonical schedules CHANGED:\n"
            f"  committed: {committed}\n"
            f"  current:   {digest}\n"
            "Tree plans must stay byte-identical across refactors; if this "
            "change is intentional, re-pin with --update and say so in the "
            "commit message.",
            file=sys.stderr,
        )
        return 1
    print(f"tree canon digest OK ({len(PAPER_TEMPLATES)} templates): {digest}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
