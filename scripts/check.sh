#!/usr/bin/env bash
# CI / local gate: tier-1 test suite + a ~30s benchmark smoke + a
# multi-device smoke of the engine's mesh backend (4 virtual host devices).
#
#   bash scripts/check.sh
#
# Works without optional dev deps (hypothesis): the suite installs a
# fixed-seed fallback when the real package is missing.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== smoke: batched engine vs per-coloring loop =="
python -m benchmarks.bench_counting --quick

echo "== smoke: mesh backend on 4 virtual devices =="
XLA_FLAGS="--xla_force_host_platform_device_count=4" python - <<'PY'
import jax, numpy as np
from repro.core import CountingEngine, get_template, rmat_graph

g = rmat_graph(300, 1500, seed=2)
t = get_template("u6")
mesh = jax.make_mesh((4,), ("dev",))
colors = np.random.default_rng(0).integers(0, t.k, size=g.n)
local = float(CountingEngine(g, [t], backend="edges").raw_counts(colors)[0])
dist = float(
    CountingEngine(g, [t], backend="mesh", mesh=mesh, column_batch=8).raw_counts(colors)[0]
)
rel = abs(dist - local) / max(abs(local), 1e-9)
assert rel < 1e-5, (dist, local)
print(f"mesh smoke: {len(jax.devices())} devices, rel err {rel:.2e} -> OK")
PY

echo "check.sh: all green"
