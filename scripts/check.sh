#!/usr/bin/env bash
# CI / local gate: tier-1 test suite + a ~30s benchmark smoke.
#
#   bash scripts/check.sh
#
# Works without optional dev deps (hypothesis): the suite installs a
# fixed-seed fallback when the real package is missing.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== smoke: batched engine vs per-coloring loop =="
python -m benchmarks.bench_counting --quick

echo "check.sh: all green"
