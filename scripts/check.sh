#!/usr/bin/env bash
# CI / local gate: lint, the tier-1 test suite split into a fast lane
# (-m "not slow and not concurrency and not chaos"), a concurrency lane
# (the async front-end scheduler tests, -m concurrency, under a per-test
# timeout so a deadlock fails fast instead of hanging CI), a chaos lane
# (the seeded fault-injection suite, -m chaos, under a fixed
# REPRO_FAULT_SEED so the failure schedule replays exactly), and a slow
# lane (the multi-process mesh subprocess tests, -m slow), a ~30s
# benchmark smoke, the plan-inspector smoke, an async front-end load
# smoke, a watchdog kill smoke, an autotuner smoke (tune rmat2k u5-1,
# cached pickup, bit-exact vs heuristic, <=5% slower bar), and a
# multi-device smoke of the engine's mesh backend (4 virtual devices).
#
#   bash scripts/check.sh
#
# Works without optional dev deps (hypothesis, pytest-timeout, pyflakes):
# the suite installs a fixed-seed hypothesis fallback plus a SIGALRM
# timeout fallback, and the lint stage degrades to stdlib compileall.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== lint: pyflakes (or stdlib compile-all when absent) =="
if python -c "import pyflakes" 2>/dev/null; then
  python -m pyflakes src/repro tests benchmarks
else
  python -m compileall -q src/repro tests benchmarks
fi

echo "== tier-1 (fast lane): pytest -m 'not slow and not concurrency and not chaos' =="
python -m pytest -x -q -m "not slow and not concurrency and not chaos"

echo "== tier-1 (concurrency lane): front-end scheduler tests under a per-test timeout =="
# --timeout is honored by pytest-timeout when installed, else by the
# conftest SIGALRM fallback — either way a scheduler deadlock dies loudly
python -m pytest -x -q -m concurrency --timeout=300

echo "== tier-1 (chaos lane): seeded fault injection, fixed REPRO_FAULT_SEED =="
# one pinned seed => one replayable failure schedule for the whole lane
# (includes the watchdog kill test: a scheduler thread killed by a clock
# fault must fail every in-flight future within one watchdog interval)
REPRO_FAULT_SEED=0 python -m pytest -x -q -m chaos --timeout=300

echo "== tier-1 (slow lane): mesh/subprocess tests, pytest -m slow =="
python -m pytest -x -q -m slow

echo "== guard: tree-template canonical schedules frozen =="
python scripts/check_tree_canons.py

echo "== smoke: plan inspector CLI =="
python -m repro.plan u6 --graph rmat:300:1500:2 | tee /tmp/plan_inspect.out >/dev/null
grep -q "liveness peak" /tmp/plan_inspect.out
grep -q "fusion slack" /tmp/plan_inspect.out
python -m repro.plan --template triangle --template square | tee /tmp/plan_bag.out >/dev/null
grep -q "bag stages" /tmp/plan_bag.out
grep -q "decomposition widths" /tmp/plan_bag.out
echo "plan inspector: schedule + cost verdict + bag schedules printed -> OK"

echo "== smoke: non-tree (bag) counting — triangle end-to-end =="
python - <<'PY'
import numpy as np
from repro.core import CountingEngine, rmat_graph
from repro.core.counting import brute_force_colorful
from repro.core.templates import get_template, graph_automorphisms

g = rmat_graph(64, 400, seed=4)  # small enough to brute-force
t = get_template("triangle")
eng = CountingEngine(g, [t], backend="edges")
colors = np.random.default_rng(0).integers(0, 3, size=(4, g.n))
nonzero = 0
for c in colors:
    raw = float(eng.raw_counts(c)[0])
    exact = brute_force_colorful(g, t, c) * graph_automorphisms(t)
    assert abs(raw - exact) <= 1e-5 * max(1.0, exact), (raw, exact)
    nonzero += exact > 0
assert nonzero, "all colorings missed — graph too sparse for the smoke"
print(f"triangle smoke: {len(colors)} colorings exact vs brute force -> OK")
PY

echo "== smoke: batched engine vs per-coloring loop (+ rmat8k cliff row) =="
python -m benchmarks.bench_counting --quick

echo "== smoke: fused SpMM+eMA equality (pure-JAX backends + interpret-mode Pallas) =="
python - <<'PY'
import numpy as np, jax.numpy as jnp
from functools import partial
from repro.core import (
    CountingEngine, build_counting_plan, count_colorful_vectorized,
    get_template, rmat_graph, spmm_edges,
)

g = rmat_graph(220, 900, seed=11)
for tname in ("u5-2", "u6"):
    t = get_template(tname)
    plan = build_counting_plan(t)
    colors = np.random.default_rng(1).integers(0, t.k, size=g.n)
    # legacy two-pass reference (materializes the aggregate product)
    ref = float(count_colorful_vectorized(
        plan, jnp.asarray(colors),
        partial(spmm_edges, jnp.asarray(g.src), jnp.asarray(g.dst), g.n),
    ))
    for backend, kw in (
        ("edges", {}), ("sell", {}), ("dense", {}),
        ("blocked", dict(interpret=True, block_size=128)),  # fused Pallas kernel
    ):
        got = float(CountingEngine(g, [t], backend=backend, **kw).raw_counts(colors)[0])
        rel = abs(got - ref) / max(abs(ref), 1e-9)
        assert rel < 1e-5, (tname, backend, got, ref)
    print(f"fused smoke {tname}: all backends == two-pass reference -> OK")
PY

echo "== guard: chunk picker must not shrink below the seed bench chunks =="
python - <<'PY'
from repro.core import CountingEngine, get_template, rmat_graph

# seed values recorded for the u5-u7 rmat2k bench configs (PR 1/2 era, the
# two-pass memory model); the fused model must only ever pick larger chunks
SEED_CHUNKS = {"u5-1": 20, "u5-2": 22, "u6": 10, "u7": 5}
g = rmat_graph(2048, 20_000, seed=1)
for tname, seed_chunk in SEED_CHUNKS.items():
    eng = CountingEngine(g, [get_template(tname)])
    ok = eng.chunk_size > seed_chunk if tname in ("u6", "u7") else eng.chunk_size >= seed_chunk
    assert ok, f"{tname}: chunk {eng.chunk_size} fell below seed {seed_chunk}"
    print(f"chunk guard {tname}: {eng.chunk_size} (seed {seed_chunk}) -> OK")
PY

echo "== smoke: CountingService (concurrent queries, warm cache, adaptive stop) =="
python - <<'PY'
import numpy as np
from repro.core import rmat_graph
from repro.serve import CountingService

svc = CountingService(chunk_size=8)
svc.register_graph("a", rmat_graph(300, 1500, seed=2))
svc.register_graph("b", rmat_graph(260, 1100, seed=3))

# two concurrent queries on different graphs share the admission loop
qa = svc.submit("a", "u5-1", iterations=8, seed=1)
qb = svc.submit("b", "u6", iterations=8, seed=2)
svc.run()
assert qa.done and qb.done
assert {qa.engine_key, qb.engine_key} == set(svc.stats()["launches_by_key"])

# cached re-query: same key, zero new jit compilations
engine = svc.engine(qa.engine_key)
traces = engine.trace_count
qc = svc.submit("a", "u5-1", iterations=5, seed=9)
svc.run()
assert svc.engine(qc.engine_key) is engine and engine.trace_count == traces
hits = svc.stats()["cache"]["hits"]
assert hits >= 1, svc.stats()["cache"]

# adaptive stop fires before the budget
qd = svc.submit("a", "u5-1", epsilon=0.1, delta=0.1, iterations=512, seed=0)
svc.run()
assert qd.done and qd.iterations < 512 and qd.result()[0].converged
print(
    f"service smoke: 2 graphs, warm re-query 0 new traces, adaptive stopped "
    f"at {qd.iterations}/512 -> OK"
)
PY

echo "== smoke: async front-end under load (32 queries, 2 tenants) =="
python - <<'PY'
from benchmarks.bench_service import frontend_load

stats = frontend_load(record_row=False)
# symmetric tenants through the round-robin admission ring: per-tenant
# mean latencies must stay within a small factor of each other
assert stats["fairness"] < 4.0, f"tenant fairness ratio {stats['fairness']:.2f}"
assert stats["queries"] >= 32, stats
print(
    f"frontend load smoke: {stats['queries']} queries / 2 tenants, "
    f"p50 {stats['p50_us']:.0f}us p99 {stats['p99_us']:.0f}us, "
    f"{stats['qps']:.1f} q/s, fairness {stats['fairness']:.2f} -> OK"
)
PY

echo "== smoke: autotuner (tune rmat2k u5-1 -> cached pickup, bit-exact, not slower) =="
TUNE_CACHE="/tmp/repro_tune_smoke_$$.json"
rm -f "$TUNE_CACHE"
REPRO_TUNE_CACHE="$TUNE_CACHE" python -m repro.tune u5-1 \
  --graph rmat:2048:20000:1 --top-n 3 --probes 3
REPRO_TUNE_CACHE="$TUNE_CACHE" python - <<'PY'
import os, time
import jax
import numpy as np
from repro.core import CountingEngine, rmat_graph
from repro.core.templates import get_template
from repro.serve import CountingService

g = rmat_graph(2048, 20_000, seed=1)

# a fresh service under the default REPRO_TUNE=cached picks the tuned
# config up from the cache the CLI just wrote
svc = CountingService()
svc.register_graph("rmat2k", g)
q = svc.submit("rmat2k", "u5-1", iterations=6, seed=7)
svc.run()
tuned = svc.engine(q.engine_key)
d = tuned.describe()["backend"]
assert d["source"] == "tuned", d
print(f"tuner smoke: fresh service resolved backend={d['name']} source=tuned")

# cached re-query: same engine object, zero new jit programs
traces = tuned.trace_count
q2 = svc.submit("rmat2k", "u5-1", iterations=4, seed=8)
svc.run()
assert svc.engine(q2.engine_key) is tuned and tuned.trace_count == traces
print("tuner smoke: warm re-query reused the tuned engine, 0 new traces")

# REPRO_TUNE=off: the untuned heuristic engine — counts must agree exactly
os.environ["REPRO_TUNE"] = "off"
heur = CountingEngine(g, [get_template("u5-1")])
assert heur.describe()["backend"]["source"] == "heuristic", heur.describe()
for cseed in range(3):
    colors = np.random.default_rng(cseed).integers(0, 5, size=g.n)
    rt = np.asarray(tuned.raw_counts(colors))
    rh = np.asarray(heur.raw_counts(colors))
    assert np.array_equal(rt, rh), (cseed, rt, rh)
et = tuned.estimate(iterations=4, seed=11)[0].mean
eh = heur.estimate(iterations=4, seed=11)[0].mean
assert et == eh, (et, eh)
print("tuner smoke: tuned counts == heuristic counts (bit-exact)")

# the acceptance bar: tuned must not run >5% slower than the heuristic
# (interleaved timed launches so host-load drift hits both sides)
kt = jax.random.split(jax.random.PRNGKey(0), tuned.chunk_size)
kh = jax.random.split(jax.random.PRNGKey(0), heur.chunk_size)
tuned.count_keys_chunk(kt)
heur.count_keys_chunk(kh)
t_us, h_us = [], []
for _ in range(9):
    t0 = time.perf_counter()
    heur.count_keys_chunk(kh)
    h_us.append((time.perf_counter() - t0) / heur.chunk_size)
    t0 = time.perf_counter()
    tuned.count_keys_chunk(kt)
    t_us.append((time.perf_counter() - t0) / tuned.chunk_size)
ratio = float(np.median(h_us) / np.median(t_us))
assert ratio >= 0.95, f"tuned config {1/ratio:.2f}x SLOWER than heuristic"
print(f"tuner smoke: heuristic/tuned per-coloring ratio {ratio:.2f} -> OK")
PY
rm -f "$TUNE_CACHE"

echo "== smoke: mesh backend on 4 virtual devices =="
XLA_FLAGS="--xla_force_host_platform_device_count=4" python - <<'PY'
import jax, numpy as np
from repro.core import CountingEngine, get_template, rmat_graph

g = rmat_graph(300, 1500, seed=2)
t = get_template("u6")
mesh = jax.make_mesh((4,), ("dev",))
colors = np.random.default_rng(0).integers(0, t.k, size=g.n)
local = float(CountingEngine(g, [t], backend="edges").raw_counts(colors)[0])
dist = float(
    CountingEngine(g, [t], backend="mesh", mesh=mesh, column_batch=8).raw_counts(colors)[0]
)
rel = abs(dist - local) / max(abs(local), 1e-9)
assert rel < 1e-5, (dist, local)
print(f"mesh smoke: {len(jax.devices())} devices, rel err {rel:.2e} -> OK")
PY

echo "== smoke: pipelined ring collectives (4 virtual devices, bit-exact A/B) =="
XLA_FLAGS="--xla_force_host_platform_device_count=4" python - <<'PY'
import os
os.environ.pop("REPRO_MESH_COMM", None)  # modes are explicit below
import jax, numpy as np
from repro.core import CountingEngine, get_template, rmat_graph

g = rmat_graph(400, 2000, seed=5)
t = get_template("u7")
mesh = jax.make_mesh((4,), ("dev",))
colors = np.random.default_rng(1).integers(0, t.k, size=g.n)
keys = jax.random.split(jax.random.PRNGKey(3), 4)
kw = dict(backend="mesh", mesh=mesh, column_batch=8, chunk_size=2)
block = CountingEngine(g, [t], mesh_comm="blocking", **kw)
ring = CountingEngine(g, [t], mesh_comm="pipelined", **kw)
# the ring must be BIT-exact against blocking, not merely close: both
# modes fold the same per-src-shard bucket partial sums in the same order
assert np.array_equal(
    np.asarray(block.raw_counts(colors)), np.asarray(ring.raw_counts(colors))
)
assert np.array_equal(
    np.asarray(block.count_keys(keys)), np.asarray(ring.count_keys(keys))
)
comm = ring.describe()["comm"]
assert comm["mode"] == "pipelined" and comm["collective_dispatches"] == 4
sched = comm["schedule"][0]
# the modeled overlap is informational at smoke scale (tiny working set,
# single physical core) — printed, not gated
print(
    "ring smoke: pipelined == blocking bit-exact on 4 devices; "
    f"stage0 wire {sched['wire_bytes']}B, modeled overlap "
    f"{sched['overlap_efficiency']:.2f} -> OK"
)
PY

echo "check.sh: all green"
