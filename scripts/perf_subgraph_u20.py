"""Perf driver — mesh collectives A/B (blocking vs pipelined ring).

A real runnable benchmark over virtual host devices::

    python scripts/perf_subgraph_u20.py --devices 4
    python scripts/perf_subgraph_u20.py --devices 8 --comm pipelined
    python scripts/perf_subgraph_u20.py --devices 4 --template u20 --static

Per comm mode it records, on a ``--devices``-shard 1-D mesh:

* measured wall-clock per coloring (interleaved A/B when ``--comm both``,
  so machine drift hits both arms equally);
* **measured overlap efficiency** — the fraction of the comm model's
  predicted wire time the ring actually hid,
  ``clip((t_blocking - t_pipelined) / predicted_comm_us, 0, 1)``;
* **per-shard byte fraction** — the pipelined transient footprint over the
  blocking one (two ring slots vs the full all-gathered batch);
* the resolved per-stage ``CommSchedule`` (``describe()["comm"]``).

``--static`` skips execution and reports the compile-time memory /
HLO-collective analysis instead (the original single-pod static mode,
kept for the u20-at-512-devices paper cell where running is not the
point).  Output JSON -> ``results/perf/subgraph_u20.json``.
"""

import argparse
import json
import os
import sys
import time


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--devices", type=int, default=4,
                    help="virtual host devices / mesh shards (default 4)")
    ap.add_argument("--comm", choices=("blocking", "pipelined", "both"),
                    default="both", help="which collective scheme(s) to run")
    ap.add_argument("--template", default="u12",
                    help="template to count (default u12; u20 for the "
                    "paper cell — slow when executing)")
    ap.add_argument("--n", type=int, default=4096, help="graph vertices")
    ap.add_argument("--edges", type=int, default=32768, help="graph edges")
    ap.add_argument("--column-batch", type=int, default=64)
    ap.add_argument("--chunk-size", type=int, default=4)
    ap.add_argument("--iters", type=int, default=12,
                    help="colorings measured (chunks = iters / chunk-size)")
    ap.add_argument("--repeats", type=int, default=3,
                    help="interleaved A/B rounds; per-arm time is the min")
    ap.add_argument("--static", action="store_true",
                    help="compile-only memory/HLO analysis at 512 devices "
                    "(the original paper-cell mode; no execution)")
    ap.add_argument("--out", default="results/perf/subgraph_u20.json")
    return ap.parse_args(argv)


def _engine(args, g, t, mesh, comm):
    from repro.core import CountingEngine

    return CountingEngine(
        g, [t], backend="mesh", mesh=mesh, column_batch=args.column_batch,
        chunk_size=args.chunk_size, mesh_comm=comm,
    )


def _measure_us_per_coloring(engine, keys, repeats):
    """Min wall-clock us/coloring over ``repeats`` timed runs (warm)."""
    engine.count_keys(keys)  # warmup: compile + operand transfer
    best = float("inf")
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        engine.count_keys(keys)
        best = min(best, time.perf_counter() - t0)
    return best * 1e6 / keys.shape[0]


def run_ab(args):
    import jax
    import numpy as np

    from repro.core import get_template, rmat_graph

    g = rmat_graph(args.n, args.edges, seed=7)
    t = get_template(args.template)
    mesh = jax.make_mesh((args.devices,), ("dev",))
    keys = jax.random.split(jax.random.PRNGKey(0), args.iters)

    modes = ("blocking", "pipelined") if args.comm == "both" else (args.comm,)
    engines = {m: _engine(args, g, t, mesh, m) for m in modes}
    for m, eng in engines.items():
        eng.count_keys(keys)  # both arms warm before any timing

    # interleaved A/B: alternate arms each round so drift cancels
    times = {m: float("inf") for m in modes}
    for _ in range(max(1, args.repeats)):
        for m in modes:
            t0 = time.perf_counter()
            engines[m].count_keys(keys)
            times[m] = min(times[m], time.perf_counter() - t0)
    us = {m: times[m] * 1e6 / args.iters for m in modes}

    out = {
        "cell": f"subgraph2vec/{args.template}/{args.devices}dev",
        "devices": args.devices,
        "template": args.template,
        "graph": {"n": g.n, "edges": g.num_undirected},
        "column_batch": args.column_batch,
        "chunk_size": args.chunk_size,
        "iters": args.iters,
    }
    for m in modes:
        eng = engines[m]
        comm = eng.backend_impl.describe_comm()
        out[m] = {
            "us_per_coloring": us[m],
            "comm": comm,
            "transient_elements_per_shard": eng.backend_impl.transient_elements(),
        }
    if len(modes) == 2:
        b, p = engines["blocking"], engines["pipelined"]
        # counts must be BIT-exact across the arms — the A/B is meaningless
        # if the arms compute different things
        cb = np.asarray(b.count_keys(keys[:2]))
        cp = np.asarray(p.count_keys(keys[:2]))
        assert np.array_equal(cb, cp), "pipelined != blocking counts"
        predicted_comm_us = sum(
            s["comm_us"] for s in out["pipelined"]["comm"]["schedule"]
        )
        hidden_us = max(0.0, us["blocking"] - us["pipelined"])
        out["ratio_pipelined_vs_blocking"] = (
            us["pipelined"] / us["blocking"] if us["blocking"] else None
        )
        out["measured_overlap_efficiency"] = (
            min(1.0, hidden_us / predicted_comm_us) if predicted_comm_us else 0.0
        )
        out["per_shard_byte_fraction"] = (
            out["pipelined"]["transient_elements_per_shard"]
            / max(1, out["blocking"]["transient_elements_per_shard"])
        )
        out["bit_exact"] = True
    return out


def run_static(args):
    """The original compile-only paper cell: resident bytes + HLO
    collective bytes for loop vs streamed eMA at 512 devices."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro import compat
    from repro.configs.registry import SUBGRAPH_SHAPES
    from repro.core import build_counting_plan
    from repro.core.colorsets import binom
    from repro.core.distributed import (
        distributed_input_specs,
        make_distributed_count_fn,
    )
    from repro.core.templates import PAPER_TEMPLATES
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import collective_wire_bytes

    mesh = make_production_mesh()
    shape = [s for s in SUBGRAPH_SHAPES if s.name == "rmat1m_u20"][0]
    k = shape.params["k"]
    plan = build_counting_plan(PAPER_TEMPLATES["u20"])
    n_shards = mesh.devices.size
    n = shape.params["n_vertices"]
    n_padded = ((n + n_shards - 1) // n_shards) * n_shards
    e_directed = 2 * shape.params["n_edges"]
    edges_per_shard = ((int(e_directed / n_shards * 1.2) + 7) // 8) * 8
    rows = n_padded // n_shards
    b_traffic = sum(
        2.0 * rows * binom(k, t.m_p) * 4 for t in plan.tables if t is not None
    )
    out = {
        "cell": "subgraph2vec/rmat1m_u20/single",
        "analytic_B_roundtrip_bytes_per_device": b_traffic,
    }
    for mode in ("loop", "streamed"):
        print(f"compiling {mode}...")
        fn = make_distributed_count_fn(
            plan, mesh, n_padded, edges_per_shard,
            column_batch=128, ema_mode=mode,
        )
        specs = distributed_input_specs(n_padded, mesh.devices.size,
                                        edges_per_shard)
        every = tuple(mesh.axis_names)
        in_sh = tuple(NamedSharding(mesh, P(every)) for _ in specs)
        with compat.set_mesh(mesh):
            compiled = jax.jit(fn, in_shardings=in_sh).lower(*specs).compile()
        ms = compiled.memory_analysis()
        resident = ms.argument_size_in_bytes + ms.temp_size_in_bytes + max(
            ms.output_size_in_bytes - ms.alias_size_in_bytes, 0
        )
        coll, counts = collective_wire_bytes(compiled.as_text())
        out[mode] = {
            "mode": mode,
            "resident_bytes_per_device": float(resident),
            "temp_bytes": float(ms.temp_size_in_bytes),
            "collective_bytes": float(coll),
            "collective_counts": counts,
            "fits_16GB": bool(resident < 16e9),
        }
        print(json.dumps(out[mode], indent=1))
    return out


def main(argv=None):
    args = parse_args(argv)
    # XLA_FLAGS must be set before jax imports — which is why every import
    # of jax/repro in this script is function-local
    devices = 512 if args.static else args.devices
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={devices}"
    ).strip()
    out = run_static(args) if args.static else run_ab(args)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    summary = {k: v for k, v in out.items() if not isinstance(v, dict)}
    for m in ("blocking", "pipelined"):
        if m in out and isinstance(out[m], dict) and "us_per_coloring" in out[m]:
            summary[f"{m}_us_per_coloring"] = out[m]["us_per_coloring"]
    print(json.dumps(summary, indent=1))
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
