import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb — paper core (subgraph2vec x rmat1m_u20, single pod).

Baseline  = paper-faithful Algorithm 5 (batched SpMM -> materialized B -> eMA).
Optimized = streamed eMA (beyond paper): per-batch SpMM output consumed
immediately; B never exists.

Records per variant: resident bytes/device (memory_analysis), collective
bytes (HLO parse), analytic HBM-traffic delta.  Output JSON ->
results/perf/subgraph_u20.json.
"""

import json

import jax
import numpy as np
from repro import compat

from repro.configs.registry import SUBGRAPH_SHAPES
from repro.core import build_counting_plan
from repro.core.colorsets import binom
from repro.core.distributed import distributed_input_specs, make_distributed_count_fn
from repro.core.templates import PAPER_TEMPLATES
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import collective_wire_bytes
from jax.sharding import NamedSharding, PartitionSpec as P


def compile_variant(mesh, plan, n_padded, edges_per_shard, mode, column_batch=128):
    # the engine's mesh-backend compute core: split tables are built once
    # inside the builder and closure-captured (jit constants)
    fn = make_distributed_count_fn(
        plan, mesh, n_padded, edges_per_shard,
        column_batch=column_batch,
        ema_mode=mode,
    )
    specs = distributed_input_specs(n_padded, mesh.devices.size, edges_per_shard)
    every = tuple(mesh.axis_names)
    in_sh = tuple(NamedSharding(mesh, P(every)) for _ in specs)
    with compat.set_mesh(mesh):
        compiled = jax.jit(fn, in_shardings=in_sh).lower(*specs).compile()
    ms = compiled.memory_analysis()
    resident = ms.argument_size_in_bytes + ms.temp_size_in_bytes + max(
        ms.output_size_in_bytes - ms.alias_size_in_bytes, 0
    )
    coll, counts = collective_wire_bytes(compiled.as_text())
    return {
        "mode": mode,
        "resident_bytes_per_device": float(resident),
        "temp_bytes": float(ms.temp_size_in_bytes),
        "collective_bytes": float(coll),
        "collective_counts": counts,
        "fits_16GB": bool(resident < 16e9),
    }


def main():
    mesh = make_production_mesh()
    shape = [s for s in SUBGRAPH_SHAPES if s.name == "rmat1m_u20"][0]
    k = shape.params["k"]
    plan = build_counting_plan(PAPER_TEMPLATES["u20"])
    n_shards = mesh.devices.size
    n = shape.params["n_vertices"]
    n_padded = ((n + n_shards - 1) // n_shards) * n_shards
    e_directed = 2 * shape.params["n_edges"]
    edges_per_shard = ((int(e_directed / n_shards * 1.2) + 7) // 8) * 8
    rows = n_padded // n_shards

    # analytic HBM saving: B write+read per stage = 2 * rows * C_p * 4 bytes
    b_traffic = sum(
        2.0 * rows * binom(k, t.m_p) * 4 for t in plan.tables if t is not None
    )

    out = {"cell": "subgraph2vec/rmat1m_u20/single", "analytic_B_roundtrip_bytes_per_device": b_traffic}
    for mode in ("loop", "streamed"):
        print(f"compiling {mode}...")
        out[mode] = compile_variant(mesh, plan, n_padded, edges_per_shard, mode)
        print(json.dumps(out[mode], indent=1))
    os.makedirs("results/perf", exist_ok=True)
    with open("results/perf/subgraph_u20.json", "w") as f:
        json.dump(out, f, indent=1)
    print("wrote results/perf/subgraph_u20.json")


if __name__ == "__main__":
    main()
