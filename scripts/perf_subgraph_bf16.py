import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf iteration 3 (paper core): bf16 compressed all-gathers on u20."""

import json
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs.registry import SUBGRAPH_SHAPES
from repro.core import build_counting_plan
from repro.core.distributed import distributed_input_specs, make_distributed_count_fn
from repro.core.templates import PAPER_TEMPLATES
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import collective_wire_bytes

mesh = make_production_mesh()
shape = [s for s in SUBGRAPH_SHAPES if s.name == "rmat1m_u20"][0]
plan = build_counting_plan(PAPER_TEMPLATES["u20"])
n_shards = mesh.devices.size
n_padded = ((shape.params["n_vertices"] + n_shards - 1) // n_shards) * n_shards
e_directed = 2 * shape.params["n_edges"]
edges_per_shard = ((int(e_directed / n_shards * 1.2) + 7) // 8) * 8

out = {"cell": "subgraph2vec/rmat1m_u20/single/streamed"}
for name, gd in (("fp32_gather", None), ("bf16_gather", jnp.bfloat16)):
    # split tables are built once inside the builder (jit constants)
    fn = make_distributed_count_fn(plan, mesh, n_padded, edges_per_shard,
                                   column_batch=128, ema_mode="streamed", gather_dtype=gd)
    specs = distributed_input_specs(n_padded, n_shards, edges_per_shard)
    every = tuple(mesh.axis_names)
    in_sh = tuple(NamedSharding(mesh, P(every)) for _ in specs)
    with compat.set_mesh(mesh):
        compiled = jax.jit(fn, in_shardings=in_sh).lower(*specs).compile()
    ms = compiled.memory_analysis()
    resident = ms.argument_size_in_bytes + ms.temp_size_in_bytes + max(
        ms.output_size_in_bytes - ms.alias_size_in_bytes, 0)
    coll, counts = collective_wire_bytes(compiled.as_text())
    out[name] = {"collective_bytes": float(coll), "resident_bytes": float(resident),
                 "collective_s_at_50GBs": coll / 50e9}
    print(name, json.dumps(out[name]))
os.makedirs("results/perf", exist_ok=True)
json.dump(out, open("results/perf/subgraph_u20_bf16.json", "w"), indent=1)
print("wrote results/perf/subgraph_u20_bf16.json")
