"""Generate EXPERIMENTS.md roofline/dry-run tables from results/dryrun/*.json.

  PYTHONPATH=src python scripts/make_experiments_tables.py > results/tables.md
"""

from __future__ import annotations

import glob
import json
import os
import sys


def fmt_s(x):
    return f"{x:.2e}"


def main() -> int:
    recs = []
    for path in sorted(glob.glob("results/dryrun/*.json")):
        with open(path) as f:
            recs.append(json.load(f))
    if not recs:
        print("no records", file=sys.stderr)
        return 1

    singles = [r for r in recs if r["mesh"] == "single"]
    multis = {(r["arch"], r["shape"]): r for r in recs if r["mesh"] == "multi"}

    print("### Dry-run grid (lower+compile status, per-device memory)\n")
    print("| arch | shape | single-pod (256) | multi-pod (512) | HBM bytes/dev (single) | fits 16GB |")
    print("|---|---|---|---|---|---|")
    for r in singles:
        key = (r["arch"], r["shape"])
        multi_ok = "compiled" if key in multis else "—"
        mem = r.get("per_device_memory_bytes") or 0
        print(
            f"| {r['arch']} | {r['shape']} | compiled | {multi_ok} | {mem:.2e} | "
            f"{'yes' if r.get('fits_hbm') else 'NO'} |"
        )

    print("\n### Roofline (single-pod, scan-corrected probes where applicable)\n")
    print("| arch | shape | compute (s) | memory (s) | collective (s) | bottleneck | MODEL_FLOPS | useful ratio | dominant-term note |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in singles:
        print(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} | "
            f"{fmt_s(r['collective_s'])} | **{r['bottleneck']}** | {r['model_flops']:.2e} | "
            f"{r['useful_flops_ratio']:.3f} | |"
        )

    print("\n### Collective inventory (single-pod)\n")
    print("| arch | shape | wire bytes/dev | ops |")
    print("|---|---|---|---|")
    for r in singles:
        ops = ", ".join(f"{k}x{v}" for k, v in sorted(r.get("collective_counts", {}).items()))
        print(f"| {r['arch']} | {r['shape']} | {r['collective_bytes']:.2e} | {ops} |")

    perf = sorted(glob.glob("results/perf/*.json"))
    if perf:
        print("\n### Perf before/after records\n")
        for path in perf:
            with open(path) as f:
                p = json.load(f)
            print(f"**{p.get('cell', os.path.basename(path))}**")
            for key in ("loop", "streamed", "baseline", "optimized"):
                if key in p:
                    v = p[key]
                    print(
                        f"- {key}: resident {v['resident_bytes_per_device']/1e9:.2f} GB/dev, "
                        f"collective {v['collective_bytes']/1e9:.2f} GB, fits={v['fits_16GB']}"
                    )
            if "analytic_B_roundtrip_bytes_per_device" in p:
                print(
                    f"- analytic HBM saving (B round-trip removed): "
                    f"{p['analytic_B_roundtrip_bytes_per_device']/1e9:.2f} GB/dev/step"
                )
            print()
    return 0


if __name__ == "__main__":
    sys.exit(main())
