"""Multi-tenant serving demo: the CountingService end to end.

Registers two graphs, then drives three tenant workloads through one
service instance:

1. concurrent fixed-N queries on the same (graph, template) key — their
   colorings merge into shared chunk launches;
2. a warm repeat query — cache hit, zero new jit compilations;
3. an adaptive (epsilon, delta) query — stops at its CI target instead of
   the blind ``required_iterations`` bound.

Run:  PYTHONPATH=src python examples/counting_service.py
"""

import logging

from repro.core import rmat_graph
from repro.core.estimator import required_iterations
from repro.serve import CountingService

logging.basicConfig(level=logging.INFO, format="%(name)s: %(message)s")


def main() -> None:
    svc = CountingService(max_engines=4)
    svc.register_graph("social", rmat_graph(2048, 20_000, seed=0))
    svc.register_graph("ppin", rmat_graph(500, 4_000, seed=7))

    # -- 1: concurrent tenants share launches ------------------------------
    tenants = [svc.submit("social", "u5-1", iterations=16, seed=s) for s in range(3)]
    ppin_q = svc.submit("ppin", ["path6", "star6", "u6"], iterations=16, seed=0)
    svc.run()
    for i, q in enumerate(tenants):
        print(f"tenant {i}: u5-1 ~= {q.result()[0].mean:.4g} ({q.iterations} iters)")
    for est in ppin_q.result():
        print(f"ppin {est.template}: ~= {est.mean:.4g}")

    # -- 2: warm repeat query — no recompilation ---------------------------
    engine = svc.engine(tenants[0].engine_key)
    before = engine.trace_count
    repeat = svc.submit("social", "u5-1", iterations=24, seed=99)
    svc.run()
    print(
        f"warm repeat: {repeat.result()[0].mean:.4g} "
        f"(new compilations: {engine.trace_count - before})"
    )

    # -- 3: adaptive accuracy target ---------------------------------------
    adaptive = svc.submit("social", "u5-1", epsilon=0.01, delta=0.05, seed=1)
    svc.run()
    est = adaptive.result()[0]
    blind = required_iterations(5, 0.01, 0.05)
    print(
        f"adaptive: {est.mean:.4g} +- {est.halfwidth:.3g} "
        f"(converged={est.converged}, {adaptive.iterations} iters vs "
        f"blind bound {blind})"
    )

    stats = svc.stats()
    print(
        f"service: {stats['queries_completed']} queries, "
        f"{stats['launches']} launches, cache {stats['cache']}"
    )


if __name__ == "__main__":
    main()
