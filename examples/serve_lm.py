"""Serving example: batched request serving with the continuous-batching-lite
engine (prefill into slots + joint decode; deliverable (b) serving driver).

  PYTHONPATH=src python examples/serve_lm.py
"""

import numpy as np

import jax

from repro.configs.base import LMConfig
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = LMConfig(
        name="serve-demo", n_layers=4, d_model=256, n_heads=8, n_kv_heads=2,
        d_head=32, d_ff=1024, vocab_size=4096, dtype="float32", remat=False,
        attn_q_chunk=64, scan_layers=False,
    )
    params = T.init_params(jax.random.PRNGKey(7), cfg)
    engine = ServeEngine(cfg, params, max_batch=4, max_len=96)

    rng = np.random.default_rng(0)
    requests = [
        Request(uid=i, prompt=rng.integers(1, cfg.vocab_size, size=int(l)).astype(np.int32),
                max_new_tokens=12)
        for i, l in enumerate(rng.integers(4, 24, size=10))
    ]
    print(f"serving {len(requests)} requests on a {engine.max_batch}-slot pool...")
    engine.run(requests)
    for req in requests:
        assert req.done and len(req.generated) == 12
        print(f"  req {req.uid}: prompt_len={len(req.prompt)} -> {req.generated}")
    print("OK — all requests served to completion with continuous batching")


if __name__ == "__main__":
    main()
