"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps with
the full production substrate (AdamW + cosine schedule, checkpointing,
straggler watchdog, deterministic resumable data stream).

  PYTHONPATH=src python examples/train_lm.py [--steps 300]

On the CPU container this takes a few minutes; pass --tiny for a quick run.
"""

import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.data.pipeline import token_batches
from repro.models import transformer as T
from repro.train.loop import LoopConfig, TrainLoop
from repro.train.optimizer import adamw_init, adamw_update, clip_by_global_norm, linear_warmup_cosine


def make_config(tiny: bool) -> LMConfig:
    if tiny:
        return LMConfig(
            name="lm-tiny", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
            d_head=32, d_ff=512, vocab_size=2048, dtype="float32", remat=False,
            attn_q_chunk=128, scan_layers=False,
        )
    # ~100M params: 12L x 512d, GQA 8/4, vocab 32k
    return LMConfig(
        name="lm-100m", n_layers=12, d_model=512, n_heads=8, n_kv_heads=4,
        d_head=64, d_ff=2048, vocab_size=32768, dtype="float32", remat=False,
        attn_q_chunk=256, scan_layers=True,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--tiny", action="store_true")
    args = ap.parse_args()

    cfg = make_config(args.tiny)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {cfg.name} — {n_params / 1e6:.1f}M parameters")

    lr_fn = linear_warmup_cosine(3e-4, warmup=20, total_steps=args.steps)
    state = {"params": params, "opt": adamw_init(params), "step": jnp.zeros((), jnp.int32)}

    @jax.jit
    def train_step(state, batch):
        tokens, labels = batch
        loss, grads = jax.value_and_grad(T.loss_fn)(state["params"], cfg, tokens, labels)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt = adamw_update(grads, state["opt"], state["params"], lr_fn(state["step"]))
        return (
            {"params": params, "opt": opt, "step": state["step"] + 1},
            {"loss": loss, "gnorm": gnorm},
        )

    def data_factory(start):
        return token_batches(cfg, args.batch, args.seq_len, seed=0, start_step=start)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        loop = TrainLoop(
            LoopConfig(total_steps=args.steps, ckpt_dir=ckpt_dir, ckpt_every=100,
                       log_every=max(args.steps // 20, 1)),
            train_step,
            data_factory,
            state,
        )
        loop.run()
    hist = loop.metrics_history
    print(f"loss: {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f} over {args.steps} steps")
    assert hist[-1]["loss"] < hist[0]["loss"], "training did not reduce loss"
    print("OK")


if __name__ == "__main__":
    main()
