"""Quickstart: count tree-like subgraphs in a synthetic network.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (
    CountingEngine,
    brute_force_embeddings,
    estimate_embeddings,
    get_template,
    rmat_graph,
)


def main():
    # An RMAT network (the paper's synthetic family) and a 7-vertex treelet.
    graph = rmat_graph(n=2048, num_edges=20_000, seed=0)
    template = get_template("u7")
    print(f"graph: {graph.n} vertices, {graph.num_undirected} edges, "
          f"avg degree {graph.avg_degree:.1f}")
    print(f"template: {template.name} (k={template.k})")

    # SUBGRAPH2VEC color-coding estimate: the CountingEngine picks the SpMM
    # backend from graph statistics and runs all colorings batched in one jit
    # (a chunk of colorings fused into the M-matrix column dimension).
    engine = CountingEngine(graph, [template])
    print(f"engine: backend={engine.backend} chunk_size={engine.chunk_size} "
          f"peak_columns={engine.peak_columns()}")
    result = engine.estimate(iterations=24, seed=1)[0]
    print(f"estimated embeddings: {result.mean:.4g}  "
          f"(std over colorings {result.std:.3g}, {result.iterations} iterations)")

    # Exact validation on a smaller instance (brute force is exponential).
    small = rmat_graph(n=64, num_edges=300, seed=3)
    t_small = get_template("u5-2")
    exact = brute_force_embeddings(small, t_small)
    est = estimate_embeddings(small, t_small, iterations=400, seed=2)
    rel = abs(est.mean - exact) / max(exact, 1e-9)
    print(f"small-graph validation: exact={exact:.0f} estimate={est.mean:.1f} "
          f"rel_err={rel:.2%}")


if __name__ == "__main__":
    main()
