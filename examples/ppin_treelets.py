"""Fig 1 analog: compare treelet distributions across PPIN-like networks.

The paper compares five protein-protein interaction networks by the
normalized frequencies of 9-vertex treelets.  Real PPIN files are not
bundled; this example synthesizes networks with the published vertex/edge
statistics (Table II: Ecoli, Worm, Yeast) and shows the comparison pipeline:
count several treelet shapes per network -> normalize -> distribution
distance.

  PYTHONPATH=src python examples/ppin_treelets.py
"""

import numpy as np

from repro.core import (
    CountingEngine,
    Template,
    erdos_renyi_graph,
    rmat_graph,
)

# Reduced treelet family (the paper uses 47 9-vertex treelets; we use
# 5 six-vertex ones so the example runs in seconds on CPU).
TREELETS = [
    Template("t6-path", ((0, 1), (1, 2), (2, 3), (3, 4), (4, 5))),
    Template("t6-star", ((0, 1), (0, 2), (0, 3), (0, 4), (0, 5))),
    Template("t6-y", ((0, 1), (1, 2), (2, 3), (2, 4), (4, 5))),
    Template("t6-chair", ((0, 1), (1, 2), (2, 3), (1, 4), (4, 5))),
    Template("t6-cross", ((0, 1), (1, 2), (1, 3), (1, 4), (4, 5))),
]

# Table II statistics (vertices, edges) — synthetic stand-ins.
NETWORKS = {
    "Ecoli": (1474, 6896, "rmat"),
    "Worm1": (1239, 1736, "er"),
    "Yeast1": (1622, 9070, "rmat"),
    "Yeast2": (1536, 2925, "er"),
}


def treelet_distribution(graph, iterations=12, seed=0):
    # ONE engine counts all five treelets per coloring: the leaf one-hot and
    # every coinciding passive sub-template (shared canonical form) is
    # computed once, and the same colorings serve every template.
    engine = CountingEngine(graph, TREELETS)
    results = engine.estimate(iterations=iterations, seed=seed)
    counts = [max(r.mean, 0.0) for r in results]
    total = sum(counts) or 1.0
    return np.array([c / total for c in counts])


def main():
    dists = {}
    for name, (n, e, kind) in NETWORKS.items():
        g = rmat_graph(n, e, seed=hash(name) % 997) if kind == "rmat" else erdos_renyi_graph(n, e, seed=hash(name) % 997)
        dists[name] = treelet_distribution(g)
        row = " ".join(f"{x:.3f}" for x in dists[name])
        print(f"{name:8s} treelet distribution: [{row}]")

    print("\npairwise L1 distribution distances (Fig 1 comparison):")
    names = list(dists)
    for i, a in enumerate(names):
        for b in names[i + 1:]:
            d = float(np.abs(dists[a] - dists[b]).sum())
            print(f"  {a} vs {b}: {d:.3f}")


if __name__ == "__main__":
    main()
