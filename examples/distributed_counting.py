"""Distributed SUBGRAPH2VEC through the CountingEngine mesh backend.

Runs the engine's ``mesh`` backend (vertex 1-D partition + column-batched
all-gather SpMM + streamed eMA under ``shard_map``) on a multi-device host
mesh and cross-checks against the single-device local engine.

  PYTHONPATH=src python examples/distributed_counting.py

The device count comes from ``XLA_FLAGS`` (8 virtual host devices by
default; set ``--xla_force_host_platform_device_count=N`` to change it).
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np

from repro.core import CountingEngine, get_template, rmat_graph


def main():
    mesh = jax.make_mesh((len(jax.devices()),), ("dev",))
    print(f"mesh: {dict(mesh.shape)} = {mesh.devices.size} devices")

    graph = rmat_graph(2048, 20_000, seed=11)
    template = get_template("u7")

    # The mesh backend shards the graph once (degree-balanced row partition),
    # builds the split tables once, and runs chunks of colorings batched
    # through the column-batched all-gather SpMM + streamed eMA.
    engine = CountingEngine(
        graph,
        [template],
        backend="mesh",
        mesh=mesh,
        column_batch=16,
        balance_degrees=True,
    )
    sharded = engine.backend_impl.sharded
    print(
        f"graph: {graph.n} vertices; {sharded.edges_per_shard} edges/shard "
        f"(degree-balanced); chunk_size={engine.chunk_size} "
        f"column_batch={engine.backend_impl.column_batch}"
    )

    result = engine.estimate(iterations=8, seed=0)[0]
    print(
        f"distributed estimate: {result.mean:.4g} "
        f"(std over colorings {result.std:.3g}, {result.iterations} iterations)"
    )

    # cross-check one fixed coloring against the single-device local engine
    colors = np.random.default_rng(0).integers(0, template.k, size=graph.n)
    local = CountingEngine(graph, [template], backend="edges")
    raw_mesh = float(engine.raw_counts(colors)[0])
    raw_local = float(local.raw_counts(colors)[0])
    rel = abs(raw_mesh - raw_local) / max(abs(raw_local), 1e-9)
    print(f"mesh vs local engine: {raw_mesh:.6g} vs {raw_local:.6g} (rel err {rel:.2e})")
    assert rel < 1e-5


if __name__ == "__main__":
    main()
