"""Distributed SUBGRAPH2VEC on an 8-device host mesh (Fig 13 structure).

Runs the shard_map DP (vertex 1-D partition + column-batched all-gather
SpMM) and cross-checks against the single-device count.

  PYTHONPATH=src python examples/distributed_counting.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from repro import compat

from repro.core import (
    build_counting_plan,
    count_colorful_vectorized,
    get_template,
    normalize_count,
    rmat_graph,
    spmm_edges,
)
from repro.core.distributed import make_distributed_count_fn, plan_tables, shard_graph


def main():
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    print(f"mesh: {dict(mesh.shape)} = {mesh.devices.size} devices")

    graph = rmat_graph(4096, 40_000, seed=11)
    template = get_template("u7")
    plan = build_counting_plan(template)
    sharded = shard_graph(graph, mesh.devices.size, balance_degrees=True)
    print(f"graph: {graph.n} vertices; {sharded.edges_per_shard} edges/shard (degree-balanced)")

    count_fn = make_distributed_count_fn(
        plan, mesh, sharded.n_padded, sharded.edges_per_shard, column_batch=16
    )
    rng = np.random.default_rng(0)
    # NB: shard_graph(balance_degrees=True) relabels vertices; colors are iid
    # so any assignment is valid for the estimate.
    colors = jnp.asarray(rng.integers(0, template.k, size=sharded.n_padded))

    with compat.set_mesh(mesh):
        raw = count_fn(
            colors,
            jnp.asarray(sharded.src),
            jnp.asarray(sharded.dst_local),
            jnp.asarray(sharded.edge_mask),
            plan_tables(plan),
        )
        est = float(normalize_count(raw, plan))
    print(f"distributed colorful-count estimate (1 coloring): {est:.4g}")

    # single-device reference over the same coloring (identity labeling)
    plain = shard_graph(graph, mesh.devices.size)  # no relabel
    with compat.set_mesh(mesh):
        raw_plain = count_fn(
            colors,
            jnp.asarray(plain.src),
            jnp.asarray(plain.dst_local),
            jnp.asarray(plain.edge_mask),
            plan_tables(plan),
        )
    ref = float(
        count_colorful_vectorized(
            plan,
            colors[: graph.n],
            partial(spmm_edges, jnp.asarray(graph.src), jnp.asarray(graph.dst), graph.n),
        )
    )
    rel = abs(float(raw_plain) - ref) / max(abs(ref), 1e-9)
    print(f"distributed vs single-device: {float(raw_plain):.6g} vs {ref:.6g} (rel err {rel:.2e})")
    assert rel < 1e-5


if __name__ == "__main__":
    main()
