"""Shared benchmark utilities: timing, CSV emission."""

from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax

ROWS: List[Tuple[str, float, str]] = []


def record(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Best-of-N wall time in microseconds (after jit warmup)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def emit_header() -> None:
    print("name,us_per_call,derived")
