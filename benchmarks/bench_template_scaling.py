"""Fig 12 analog: template-size scaling — peak live M-matrix columns and
bytes as the template grows (the distributed system's memory-extension
argument), plus measured wall time per template on the CPU host.

Non-tree rows: width-2 graphlets (triangle / square / diamond) ride the
bag pipeline through a ``CountingEngine`` — the tree-only
``count_colorful_vectorized`` cannot run them — and report the
element-level liveness peak (a bag state over ``r`` axes is ``n**r``
rows wide, so columns alone understate the footprint)."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build_counting_plan, count_colorful_vectorized, get_template, rmat_graph, spmm_edges
from .common import record, time_fn


def run() -> None:
    g = rmat_graph(1024, 10_000, seed=9)
    spmm = partial(spmm_edges, jnp.asarray(g.src), jnp.asarray(g.dst), g.n)
    rng = np.random.default_rng(2)
    for tname in ["u5-1", "u7", "u10", "u12"]:
        t = get_template(tname)
        plan = build_counting_plan(t)
        peak_cols = plan.peak_columns()
        colors = jnp.asarray(rng.integers(0, t.k, size=g.n))
        fn = jax.jit(lambda c, p=plan, s=spmm: count_colorful_vectorized(p, c, s))
        us = time_fn(fn, colors, iters=2)
        bytes_1m = peak_cols * 1_000_000 * 4
        record(
            f"fig12/template_scaling/{tname}",
            us,
            f"peak_cols={peak_cols};bytes_at_1M_vertices={bytes_1m / 1e9:.1f}GB",
        )

    # non-tree (bag-compiled) graphlets: engine path, element-level peak
    from repro.core.engine import CountingEngine

    for tname in ["triangle", "square", "diamond"]:
        t = get_template(tname)
        eng = CountingEngine(g, t, backend="edges")
        colors = jnp.asarray(rng.integers(0, t.k, size=(1, g.n)))
        fn = jax.jit(eng.backend_impl.counts_for_colors)
        us = time_fn(fn, colors, iters=2)
        peak_el = eng.plan_ir.peak_elements(g.n)
        width = eng.plan_ir.decomposition_widths[0]
        record(
            f"fig12/template_scaling/{tname}",
            us,
            f"tw={width};peak_elements={peak_el};"
            f"bytes={peak_el * 4 / 1e6:.1f}MB",
        )
