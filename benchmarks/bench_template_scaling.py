"""Fig 12 analog: template-size scaling — peak live M-matrix columns and
bytes as the template grows (the distributed system's memory-extension
argument), plus measured wall time per template on the CPU host."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build_counting_plan, count_colorful_vectorized, get_template, rmat_graph, spmm_edges
from .common import record, time_fn


def run() -> None:
    g = rmat_graph(1024, 10_000, seed=9)
    spmm = partial(spmm_edges, jnp.asarray(g.src), jnp.asarray(g.dst), g.n)
    rng = np.random.default_rng(2)
    for tname in ["u5-1", "u7", "u10", "u12"]:
        t = get_template(tname)
        plan = build_counting_plan(t)
        peak_cols = plan.peak_columns()
        colors = jnp.asarray(rng.integers(0, t.k, size=g.n))
        fn = jax.jit(lambda c, p=plan, s=spmm: count_colorful_vectorized(p, c, s))
        us = time_fn(fn, colors, iters=2)
        bytes_1m = peak_cols * 1_000_000 * 4
        record(
            f"fig12/template_scaling/{tname}",
            us,
            f"peak_cols={peak_cols};bytes_at_1M_vertices={bytes_1m / 1e9:.1f}GB",
        )
