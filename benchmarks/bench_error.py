"""Fig 14 analog: fp32 vectorized vs fp64 traversal relative error per
coloring (the paper reports ~1e-6 relative differences from fp reassociation;
exact arithmetic would make the two identical)."""

from __future__ import annotations

from functools import partial

import jax.numpy as jnp
import numpy as np

from repro.core import (
    build_counting_plan,
    count_colorful_traversal,
    count_colorful_vectorized,
    get_template,
    rmat_graph,
    spmm_edges,
)
from .common import record


def run() -> None:
    g = rmat_graph(1024, 10_000, seed=3)
    spmm = partial(spmm_edges, jnp.asarray(g.src), jnp.asarray(g.dst), g.n)
    rng = np.random.default_rng(1)
    for tname in ["u5-1", "u6", "u7"]:
        t = get_template(tname)
        plan = build_counting_plan(t)
        errs = []
        for it in range(5):
            colors = rng.integers(0, t.k, size=g.n)
            ref = count_colorful_traversal(plan, g, colors)  # numpy fp64
            vec = float(count_colorful_vectorized(plan, jnp.asarray(colors), spmm))
            errs.append(abs(vec - ref) / max(abs(ref), 1e-12))
        record(f"fig14/{tname}/rel_error", 0.0, f"max_rel_err={max(errs):.2e}")
        assert max(errs) < 1e-5, f"Fig14 bound violated: {max(errs)}"
