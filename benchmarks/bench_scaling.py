"""Fig 13 analog: strong scaling of distributed SUBGRAPH2VEC.

The container exposes one physical core, so wall-time across host-device
counts measures dispatch overhead, not hardware scaling; the meaningful
strong-scaling evidence on this host is the **per-shard resource scaling**
extracted from the compiled artifact at mesh sizes 1/2/4/8:

* per-shard M-matrix bytes (the paper's Fig 12 memory-extension claim),
* per-shard HLO flops (compute splits linearly),
* all-gather wire bytes (the communication the column batching bounds).

Runs in a subprocess (needs its own XLA_FLAGS device count).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

from .common import record

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, time
import jax, jax.numpy as jnp, numpy as np
from repro import compat
from repro.core import CountingEngine, get_template, rmat_graph
from repro.launch.roofline import collective_wire_bytes

g = rmat_graph(16384, 160_000, seed=7)
t = get_template("u7")
colors = jnp.asarray(np.random.default_rng(0).integers(0, t.k, size=(1, g.n)))
out = []
for n_dev in (1, 2, 4, 8):
    mesh = jax.make_mesh((n_dev,), ("data",))
    # the engine's mesh backend: one-coloring chunk for the per-shard probe
    eng = CountingEngine(g, [t], backend="mesh", mesh=mesh, column_batch=8,
                         ema_mode="loop", chunk_size=1)
    with compat.set_mesh(mesh):
        jitted = jax.jit(eng.backend_impl.counts_for_colors)
        compiled = jitted.lower(colors).compile()
        val = float(jitted(colors)[0, 0])
        t0 = time.perf_counter(); jax.block_until_ready(jitted(colors)); dt = time.perf_counter() - t0
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # JAX 0.4.x returns [dict]
        ca = ca[0] if ca else {}
    coll, _ = collective_wire_bytes(compiled.as_text())
    out.append({
        "devices": n_dev,
        "wall_s": dt,
        "flops_per_shard": ca.get("flops", 0.0),
        "bytes_per_shard": ca.get("bytes accessed", 0.0),
        "collective_bytes": coll,
        "count": val,
    })
print("RESULT " + json.dumps(out))
"""


def run() -> None:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD], capture_output=True, text=True, env=env, timeout=900
    )
    line = next(l for l in proc.stdout.splitlines() if l.startswith("RESULT "))
    data = json.loads(line[len("RESULT "):])
    base = data[0]
    counts = [d["count"] for d in data]
    spread = (max(counts) - min(counts)) / max(abs(counts[0]), 1e-9)
    # fp32 reassociation across mesh sizes (the paper's Fig 14 effect)
    assert spread < 1e-5, f"count drifted beyond fp tolerance: {counts}"
    for d in data:
        record(
            f"fig13/strong_scaling/{d['devices']}dev",
            d["wall_s"] * 1e6,
            f"flops_per_shard_frac={d['flops_per_shard'] / max(base['flops_per_shard'], 1):.3f};"
            f"bytes_per_shard_frac={d['bytes_per_shard'] / max(base['bytes_per_shard'], 1):.3f}",
        )
