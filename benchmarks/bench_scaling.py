"""Fig 13 analog: strong scaling of distributed SUBGRAPH2VEC.

The container exposes one physical core, so wall-time across host-device
counts measures dispatch overhead, not hardware scaling; the meaningful
strong-scaling evidence on this host is the **per-shard resource scaling**
extracted from the compiled artifact at mesh sizes 1/2/4/8:

* per-shard M-matrix bytes (the paper's Fig 12 memory-extension claim),
* per-shard HLO flops (compute splits linearly),
* all-gather wire bytes (the communication the column batching bounds).

The ``fig13/ring`` row family is the wall-clock half: an interleaved A/B
of ``mesh_comm="blocking"`` vs ``"pipelined"`` (same process, same graph,
alternating arms) through the ``scripts/perf_subgraph_u20.py`` driver, at a
working-set size where the all-gathered column buffer falls out of cache
but the ring's two circulating slices do not.  Each row records the
pipelined us/coloring plus ``ratio=`` (pipelined/blocking, < 1.0 is a ring
win), ``per_shard_byte_frac`` (transient footprint of the ring arm as a
fraction of blocking's), and ``overlap_eff`` (measured fraction of the
modeled wire time hidden).

Runs in a subprocess (needs its own XLA_FLAGS device count).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile

from .common import record

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, time
import jax, jax.numpy as jnp, numpy as np
from repro import compat
from repro.core import CountingEngine, get_template, rmat_graph
from repro.launch.roofline import collective_wire_bytes

g = rmat_graph(16384, 160_000, seed=7)
t = get_template("u7")
colors = jnp.asarray(np.random.default_rng(0).integers(0, t.k, size=(1, g.n)))
out = []
for n_dev in (1, 2, 4, 8):
    mesh = jax.make_mesh((n_dev,), ("data",))
    # the engine's mesh backend: one-coloring chunk for the per-shard probe
    eng = CountingEngine(g, [t], backend="mesh", mesh=mesh, column_batch=8,
                         ema_mode="loop", chunk_size=1)
    with compat.set_mesh(mesh):
        jitted = jax.jit(eng.backend_impl.counts_for_colors)
        compiled = jitted.lower(colors).compile()
        val = float(jitted(colors)[0, 0])
        t0 = time.perf_counter(); jax.block_until_ready(jitted(colors)); dt = time.perf_counter() - t0
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # JAX 0.4.x returns [dict]
        ca = ca[0] if ca else {}
    coll, _ = collective_wire_bytes(compiled.as_text())
    out.append({
        "devices": n_dev,
        "wall_s": dt,
        "flops_per_shard": ca.get("flops", 0.0),
        "bytes_per_shard": ca.get("bytes accessed", 0.0),
        "collective_bytes": coll,
        "count": val,
    })
print("RESULT " + json.dumps(out))
"""


def run() -> None:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD], capture_output=True, text=True, env=env, timeout=900
    )
    line = next(l for l in proc.stdout.splitlines() if l.startswith("RESULT "))
    data = json.loads(line[len("RESULT "):])
    base = data[0]
    counts = [d["count"] for d in data]
    spread = (max(counts) - min(counts)) / max(abs(counts[0]), 1e-9)
    # fp32 reassociation across mesh sizes (the paper's Fig 14 effect)
    assert spread < 1e-5, f"count drifted beyond fp tolerance: {counts}"
    for d in data:
        record(
            f"fig13/strong_scaling/{d['devices']}dev",
            d["wall_s"] * 1e6,
            f"flops_per_shard_frac={d['flops_per_shard'] / max(base['flops_per_shard'], 1):.3f};"
            f"bytes_per_shard_frac={d['bytes_per_shard'] / max(base['bytes_per_shard'], 1):.3f}",
        )
    _run_ring()


def _run_ring() -> None:
    """fig13/ring rows: interleaved blocking-vs-pipelined A/B per mesh size.

    Shells out to the perf driver (it owns XLA_FLAGS and the interleaving
    discipline); the config is sized so the all-gathered buffer
    (n_padded x B x cb ~ 256 MB) spills cache while a ring slice does not —
    that locality gap is the honest ring win measurable on a single host,
    where true comm/compute overlap cannot show.
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_MESH_COMM", None)
    for n_dev in (4, 8):
        out = os.path.join(tempfile.mkdtemp(prefix="fig13_ring_"), "ab.json")
        subprocess.run(
            [
                sys.executable, "scripts/perf_subgraph_u20.py",
                "--devices", str(n_dev), "--template", "u7",
                "--n", "65536", "--edges", "262144",
                "--column-batch", "256", "--chunk-size", "2",
                "--iters", "2", "--repeats", "2", "--out", out,
            ],
            check=True, capture_output=True, text=True, env=env, timeout=1800,
        )
        with open(out) as fh:
            ab = json.load(fh)
        assert ab["bit_exact"], f"A/B arms diverged at {n_dev} devices"
        record(
            f"fig13/ring/{n_dev}dev",
            ab["pipelined"]["us_per_coloring"],
            f"ratio={ab['ratio_pipelined_vs_blocking']:.3f};"
            f"per_shard_byte_frac={ab['per_shard_byte_fraction']:.3f};"
            f"overlap_eff={ab['measured_overlap_efficiency']:.2f}",
        )
