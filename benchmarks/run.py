"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only tableIII,fig14,...]

Emits ``name,us_per_call,derived`` CSV rows.
"""

from __future__ import annotations

import argparse
import sys
import traceback

from . import bench_counting, bench_error, bench_kernels, bench_scaling, bench_template_scaling
from .common import emit_header

BENCHES = {
    "tableIII": bench_counting.run,        # S vs F execution time + speedup
    "fig8": bench_counting.run,            # same data isolates the vectorization win
    "fig12": bench_template_scaling.run,   # template-size scaling / memory
    "fig13": bench_scaling.run,            # distributed strong scaling
    "fig14": bench_error.run,              # relative error
    "kernels": bench_kernels.run,          # Table IV analogue (SpMM/eMA)
}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated bench keys")
    args = ap.parse_args()
    keys = list(dict.fromkeys(args.only.split(","))) if args.only else [
        "tableIII", "fig12", "fig13", "fig14", "kernels"
    ]

    emit_header()
    failed = []
    for key in keys:
        try:
            BENCHES[key]()
        except Exception:
            traceback.print_exc()
            failed.append(key)
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
