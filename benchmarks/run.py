"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only tableIII,fig14,...]

Emits ``name,us_per_call,derived`` CSV rows, and writes every recorded row
(plus the derived engine speedups) to ``BENCH_counting.json`` so the perf
trajectory is tracked across PRs.
"""

from __future__ import annotations

import argparse
import json
import re
import sys
import traceback

from . import bench_counting, bench_error, bench_kernels, bench_scaling, bench_template_scaling
from .common import ROWS, emit_header

BENCHES = {
    "tableIII": bench_counting.run,        # S vs F execution time + speedup
    "fig8": bench_counting.run,            # same data isolates the vectorization win
    "fig12": bench_template_scaling.run,   # template-size scaling / memory
    "fig13": bench_scaling.run,            # distributed strong scaling
    "fig14": bench_error.run,              # relative error
    "kernels": bench_kernels.run,          # Table IV analogue (SpMM/eMA)
}


def emit_json(path: str = "BENCH_counting.json") -> None:
    """Persist all recorded rows + headline engine speedups for trend tracking.

    Merges into an existing file (rows keyed by name, new results win) so a
    partial ``--only`` run refreshes its own rows without clobbering the
    speedup record of the last full run.
    """
    existing_rows: dict = {}
    speedups: dict = {}
    try:
        with open(path) as fh:
            prev = json.load(fh)
        existing_rows = {r["name"]: r for r in prev.get("rows", [])}
        speedups = dict(prev.get("engine_speedup_vs_loop", {}))
    except (FileNotFoundError, json.JSONDecodeError, KeyError, TypeError):
        pass
    for name, us, derived in ROWS:
        existing_rows[name] = {"name": name, "us_per_call": us, "derived": derived}
        m = re.match(r"engine/(.+)/batched(\d+)$", name)
        sp = re.search(r"speedup=([0-9.]+)x", derived)
        if m and sp:
            speedups[f"{m.group(1)}/{m.group(2)}iter"] = float(sp.group(1))
    payload = {
        "rows": sorted(existing_rows.values(), key=lambda r: r["name"]),
        "engine_speedup_vs_loop": speedups,
    }
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2)
    print(f"wrote {path} ({len(ROWS)} new rows)", file=sys.stderr)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated bench keys")
    ap.add_argument("--json", default="BENCH_counting.json", help="output JSON path")
    args = ap.parse_args()
    keys = list(dict.fromkeys(args.only.split(","))) if args.only else [
        "tableIII", "fig12", "fig13", "fig14", "kernels"
    ]

    emit_header()
    failed = []
    for key in keys:
        try:
            BENCHES[key]()
        except Exception:
            traceback.print_exc()
            failed.append(key)
    emit_json(args.json)
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
