"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only tableIII,fig14,...]

Emits ``name,us_per_call,derived`` CSV rows, and writes every recorded row
(plus the derived engine speedups) to ``BENCH_counting.json`` so the perf
trajectory is tracked across PRs.  Before overwriting, this run's rows are
diffed against the previous file's and a regression/trend table is printed
(see README §Benchmarks for the workflow).
"""

from __future__ import annotations

import argparse
import json
import re
import sys
import traceback

from . import (
    bench_counting,
    bench_error,
    bench_kernels,
    bench_scaling,
    bench_service,
    bench_template_scaling,
    bench_tuning,
)
from .common import ROWS, emit_header

BENCHES = {
    "tableIII": bench_counting.run,        # S vs F execution time + speedup
    "fig8": bench_counting.run,            # same data isolates the vectorization win
    "fig12": bench_template_scaling.run,   # template-size scaling / memory
    "fig13": bench_scaling.run,            # distributed strong scaling
    "fig14": bench_error.run,              # relative error
    "kernels": bench_kernels.run,          # Table IV analogue (SpMM/eMA)
    "service": bench_service.run,          # CountingService qps/latency/adaptive
    "tuning": bench_tuning.run,            # autotuner winner vs heuristic
}

#: Rows slower than the previous run by more than this fraction are flagged.
REGRESSION_THRESHOLD = 0.10

#: ``tuned_vs_heuristic`` rows whose measured heuristic/tuned ratio falls
#: below this are flagged: the tuner picked a config >5% SLOWER than the
#: analytic heuristic it was supposed to beat (or at least match).
TUNING_RATIO_FLOOR = 0.95


def print_trend(prev_rows: dict, threshold: float = REGRESSION_THRESHOLD) -> int:
    """Diff this run's rows against the previous ``BENCH_counting.json``.

    Prints a per-row trend table (previous vs current us_per_call, delta %)
    to stderr, flags rows slower by more than ``threshold``, and returns the
    number of flagged regressions.  Micro-benchmarks on shared CI hosts are
    noisy — the flag is a prompt to re-run, not a hard failure.
    """
    regressions = flag_tuning_ratios()
    if not prev_rows:
        print("trend: no previous BENCH_counting.json — baseline run", file=sys.stderr)
        return regressions
    width = max((len(name) for name, _, _ in ROWS), default=20)
    fresh = 0
    print(f"\n== trend vs previous run ({len(ROWS)} rows) ==", file=sys.stderr)
    print(f"{'name':<{width}}  {'prev_us':>12}  {'now_us':>12}  {'delta':>8}", file=sys.stderr)
    for name, us, _ in ROWS:
        prev = prev_rows.get(name)
        prev_us = prev.get("us_per_call") if prev else None
        if prev_us is not None:
            # tolerate unparsable previous values (hand-edited files, rows
            # written by newer schema) — treat them as newly-introduced keys
            try:
                prev_us = float(prev_us)
            except (TypeError, ValueError):
                prev_us = None
        if prev_us is None:
            fresh += 1
            print(f"{name:<{width}}  {'-':>12}  {us:>12.1f}  {'new':>8}", file=sys.stderr)
            continue
        if prev_us == 0.0:
            # legit zero baseline (e.g. derived-only rows): nothing to diff
            print(f"{name:<{width}}  {prev_us:>12.1f}  {us:>12.1f}  {'n/a':>8}", file=sys.stderr)
            continue
        delta = (us - prev_us) / prev_us
        flag = ""
        if delta > threshold:
            flag = "  <-- REGRESSION"
            regressions += 1
        print(
            f"{name:<{width}}  {prev_us:>12.1f}  {us:>12.1f}  {delta:>+7.1%}{flag}",
            file=sys.stderr,
        )
    if fresh:
        print(f"trend: {fresh} new row(s) with no previous record", file=sys.stderr)
    if regressions:
        print(
            f"trend: {regressions} row(s) regressed beyond {threshold:.0%} — "
            "re-run to rule out machine noise",
            file=sys.stderr,
        )
    return regressions


def flag_tuning_ratios(floor: float = TUNING_RATIO_FLOOR) -> int:
    """Flag ``tuned_vs_heuristic`` rows whose ratio fell below ``floor``.

    The ratio is measured *within* this run (interleaved launches), so
    unlike the cross-run trend diff it needs no previous file — a tuner
    that loses to the heuristic by >5% is flagged on every run.
    """
    flagged = 0
    for name, _, derived in ROWS:
        if not name.endswith("/tuned_vs_heuristic"):
            continue
        m = re.search(r"ratio=([0-9.]+)", derived)
        if m and float(m.group(1)) < floor:
            flagged += 1
            print(
                f"trend: {name} ratio {float(m.group(1)):.3f} < {floor} — "
                f"the tuned config is slower than the heuristic "
                f"<-- REGRESSION",
                file=sys.stderr,
            )
    return flagged


def emit_json(path: str = "BENCH_counting.json") -> None:
    """Persist all recorded rows + headline engine speedups for trend tracking.

    Merges into an existing file (rows keyed by name, new results win) so a
    partial ``--only`` run refreshes its own rows without clobbering the
    speedup record of the last full run.  The previous file's rows are
    diffed against this run's first (:func:`print_trend`).
    """
    existing_rows: dict = {}
    speedups: dict = {}
    try:
        with open(path) as fh:
            prev = json.load(fh)
        existing_rows = {r["name"]: r for r in prev.get("rows", [])}
        speedups = dict(prev.get("engine_speedup_vs_loop", {}))
    except (FileNotFoundError, json.JSONDecodeError, KeyError, TypeError):
        pass
    print_trend(existing_rows)
    for name, us, derived in ROWS:
        existing_rows[name] = {"name": name, "us_per_call": us, "derived": derived}
        m = re.match(r"engine/(.+)/batched(\d+)$", name)
        sp = re.search(r"speedup=([0-9.]+)x", derived)
        if m and sp:
            speedups[f"{m.group(1)}/{m.group(2)}iter"] = float(sp.group(1))
    payload = {
        "rows": sorted(existing_rows.values(), key=lambda r: r["name"]),
        "engine_speedup_vs_loop": speedups,
    }
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2)
    print(f"wrote {path} ({len(ROWS)} new rows)", file=sys.stderr)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated bench keys")
    ap.add_argument("--json", default="BENCH_counting.json", help="output JSON path")
    ap.add_argument(
        "--quick",
        action="store_true",
        help="~1min smoke subset (rmat2k engine rows + the rmat8k cliff "
        "rows), merged into the JSON so the trend diff still flags them",
    )
    args = ap.parse_args()
    emit_header()
    failed = []
    if args.quick:
        try:
            bench_counting.run(quick=True)
            bench_service.run(quick=True)
            bench_tuning.run(quick=True)
        except Exception:
            traceback.print_exc()
            failed.append("quick")
    else:
        keys = list(dict.fromkeys(args.only.split(","))) if args.only else [
            "tableIII", "fig12", "fig13", "fig14", "kernels", "service",
            "tuning",
        ]
        for key in keys:
            try:
                BENCHES[key]()
            except Exception:
                traceback.print_exc()
                failed.append(key)
    emit_json(args.json)
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
