"""CountingService benchmark: serving throughput, latency, cache efficiency,
and the adaptive-stopping iteration spend.

Rows (all merged into ``BENCH_counting.json`` for the trend diff):

* ``service/<graph>/<template>/cold_query`` — first query on an empty
  service: engine construction + trace + compile + the run itself.
  Min-of-``COLD_SAMPLES`` fresh services (each sample pays its own
  build+compile; the min strips scheduler noise, not the compile).
  ``--warmup`` runs one untimed throwaway cold query first so process-
  level one-time costs (JAX backend init, dispatch caches) don't land in
  the samples; the ``derived`` column records ``samples``/``agg``/
  ``warmup`` so trend diffs know what they are comparing.
* ``service/<graph>/<template>/warm_query`` — p50 latency of serial warm
  queries (cache hit, zero recompilation); ``derived`` carries p95,
  queries/sec, and the cache hit rate.
* ``service/<graph>/<template>/batchedN`` — N concurrent queries submitted
  together and drained through the cross-query batched admission loop;
  per-query wall time (the merged launches amortize each chunk).
* ``service/<graph>/<template>/adaptive`` — the (epsilon, delta) stopper
  vs blind fixed-N: iterations actually spent, measured relative error vs
  a 512-iteration exhaustive reference, and the a-priori
  ``required_iterations`` bound the stopper replaces (the paper's
  practical fixed default of ~100 iterations for <1% error is the other
  yardstick).
* ``service/<graph>/<template>/frontend_loadN`` — N queries submitted
  concurrently by ``FRONTEND_TENANTS`` tenant threads through the async
  ``ServiceFrontend`` (warm engine): p50 per-query latency, with p99,
  aggregate queries/sec, and the cross-tenant fairness ratio (max/min of
  the per-tenant mean latencies — ~1.0 when the round-robin admission is
  fair) in ``derived``.  Also runnable alone via ``--frontend-only`` (the
  check.sh load smoke).
* ``service/frontend_scale/q<N>/tenants<T>`` — the scale sweep: N total
  queries from T tenant threads, round-robined across 6 pre-warmed
  engine keys (2 graphs x 3 templates); p50 per-query latency with
  p99/qps/fairness per tenant count — how admission latency grows as the
  tenant ring widens over a busy multi-key service.
* ``service/<graph>/<template>/frontend_chaosN`` — the same N-query load
  under a seeded ``FaultPlan`` injecting transient launch failures at rate
  1/8 (schedule fixed by ``REPRO_FAULT_SEED``): p50/p99 with the
  injected-fault / retry / failed counts in ``derived``, asserting zero
  unresolved futures (the failure-semantics acceptance bar).
"""

from __future__ import annotations

import argparse
import sys
import threading
import time

import numpy as np

from repro.core import CountingEngine, get_template, rmat_graph
from repro.core.estimator import required_iterations
from repro.serve import CountingService, ServiceFrontend

from .common import emit_header, record

WARM_QUERIES = 12
BATCHED_QUERIES = 8
COLD_SAMPLES = 3
FIXED_ITERATIONS = 16
ADAPTIVE_EPSILON = 0.01
ADAPTIVE_DELTA = 0.05
ADAPTIVE_BUDGET = 512
REFERENCE_ITERATIONS = 512
FRONTEND_QUERIES = 32
FRONTEND_TENANTS = 2


def _bench_one(dname: str, g, tname: str, quick: bool, warmed: bool) -> None:
    # cold: min over fresh services — every sample pays its own engine
    # build + trace + compile, the min only strips host scheduler noise
    samples = 1 if quick else COLD_SAMPLES
    cold_times = []
    svc = None
    for _ in range(samples):
        svc = CountingService()
        svc.register_graph(dname, g)
        t0 = time.perf_counter()
        svc.query(dname, tname, iterations=FIXED_ITERATIONS, seed=0)
        cold_times.append(time.perf_counter() - t0)
    record(
        f"service/{dname}/{tname}/cold_query",
        min(cold_times) * 1e6,
        f"iters={FIXED_ITERATIONS};includes_compile=1;samples={samples};"
        f"agg=min;warmup={int(warmed)}",
    )

    n_warm = WARM_QUERIES // 2 if quick else WARM_QUERIES
    lats = []
    for s in range(1, n_warm + 1):
        t0 = time.perf_counter()
        svc.query(dname, tname, iterations=FIXED_ITERATIONS, seed=s)
        lats.append(time.perf_counter() - t0)
    lats_us = np.asarray(lats) * 1e6
    cache = svc.stats()["cache"]
    hit_rate = cache["hits"] / max(cache["hits"] + cache["misses"], 1)
    qps = n_warm / (np.sum(lats_us) / 1e6)
    record(
        f"service/{dname}/{tname}/warm_query",
        float(np.percentile(lats_us, 50)),
        f"p95_us={np.percentile(lats_us, 95):.0f};qps={qps:.1f};"
        f"cache_hit_rate={hit_rate:.3f};iters={FIXED_ITERATIONS}",
    )

    # concurrent tenants: one admission loop, launches merged per chunk
    t0 = time.perf_counter()
    qs = [
        svc.submit(dname, tname, iterations=FIXED_ITERATIONS, seed=100 + s)
        for s in range(BATCHED_QUERIES)
    ]
    svc.run()
    wall = time.perf_counter() - t0
    assert all(q.done for q in qs)
    launches = svc.stats()["launches_by_key"][qs[0].engine_key]
    record(
        f"service/{dname}/{tname}/batched{BATCHED_QUERIES}",
        wall / BATCHED_QUERIES * 1e6,
        f"wall_us={wall * 1e6:.0f};launches_total={launches}",
    )

    # adaptive (epsilon, delta) stopping vs the blind fixed-N choice
    engine = CountingEngine(g, [get_template(tname)])
    ref = engine.estimate(iterations=REFERENCE_ITERATIONS, seed=1000)[0]
    q = svc.submit(
        dname,
        tname,
        epsilon=ADAPTIVE_EPSILON,
        delta=ADAPTIVE_DELTA,
        iterations=ADAPTIVE_BUDGET,
        seed=123,
    )
    t0 = time.perf_counter()
    svc.run()
    adaptive_s = time.perf_counter() - t0
    est = q.result()[0]
    rel_err = abs(est.mean - ref.mean) / max(abs(ref.mean), 1e-9)
    blind_n = required_iterations(
        get_template(tname).k, ADAPTIVE_EPSILON, ADAPTIVE_DELTA
    )
    record(
        f"service/{dname}/{tname}/adaptive",
        adaptive_s * 1e6,
        f"iters={q.iterations};rel_err={rel_err:.5f};eps={ADAPTIVE_EPSILON};"
        f"delta={ADAPTIVE_DELTA};blind_n={blind_n};converged={int(est.converged)}",
    )
    print(
        f"# service adaptive {dname}/{tname}: {q.iterations} iters "
        f"(blind bound {blind_n}), rel err {rel_err:.3%} vs "
        f"{REFERENCE_ITERATIONS}-iter reference",
        file=sys.stderr,
    )


def frontend_load(
    dname: str = "rmat2k",
    tname: str = "u5-1",
    *,
    graph=None,
    queries: int = FRONTEND_QUERIES,
    record_row: bool = True,
) -> dict:
    """Drive ``queries`` concurrent queries through the async front-end.

    ``FRONTEND_TENANTS`` tenant threads submit an equal share each through
    a started (threaded) :class:`ServiceFrontend` over a pre-warmed engine,
    then block on their futures.  Returns p50/p99 per-query latency (the
    front-end's own clock stamps, submit -> resolve), aggregate throughput,
    and the cross-tenant fairness ratio; records the
    ``frontend_load<N>`` row unless ``record_row=False``.  This doubles as
    the scripts/check.sh load smoke.
    """
    g = graph if graph is not None else rmat_graph(2048, 20_000, seed=1)
    svc = CountingService()
    svc.register_graph(dname, g)
    svc.prewarm(dname, tname)  # compile off the measured path
    fe = ServiceFrontend(svc)
    per_tenant = queries // FRONTEND_TENANTS
    futs = {f"tenant{k}": [] for k in range(FRONTEND_TENANTS)}

    def submitter(tenant: str, base_seed: int) -> None:
        for i in range(per_tenant):
            futs[tenant].append(
                fe.submit(
                    tenant, dname, tname, iterations=FIXED_ITERATIONS,
                    seed=base_seed + i,
                )
            )

    t0 = time.perf_counter()
    with fe:
        threads = [
            threading.Thread(target=submitter, args=(tenant, 1000 * k))
            for k, tenant in enumerate(futs)
        ]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        for fs in futs.values():
            for f in fs:
                f.result(timeout=600)
    wall = time.perf_counter() - t0

    lat_us = {
        tenant: np.asarray([f.resolved_at - f.submitted_at for f in fs]) * 1e6
        for tenant, fs in futs.items()
    }
    all_us = np.concatenate(list(lat_us.values()))
    tenant_means = [float(l.mean()) for l in lat_us.values()]
    fairness = max(tenant_means) / max(min(tenant_means), 1e-9)
    out = {
        "p50_us": float(np.percentile(all_us, 50)),
        "p99_us": float(np.percentile(all_us, 99)),
        "qps": per_tenant * FRONTEND_TENANTS / wall,
        "fairness": fairness,
        "wall_s": wall,
        "queries": per_tenant * FRONTEND_TENANTS,
    }
    if record_row:
        record(
            f"service/{dname}/{tname}/frontend_load{out['queries']}",
            out["p50_us"],
            f"p99_us={out['p99_us']:.0f};qps={out['qps']:.1f};"
            f"fairness={fairness:.2f};tenants={FRONTEND_TENANTS};"
            f"iters={FIXED_ITERATIONS}",
        )
    print(
        f"# frontend load {dname}/{tname}: {out['queries']} queries / "
        f"{FRONTEND_TENANTS} tenants, p50 {out['p50_us']:.0f}us, "
        f"p99 {out['p99_us']:.0f}us, {out['qps']:.1f} q/s, "
        f"fairness {fairness:.2f}",
        file=sys.stderr,
    )
    return out


def frontend_scale(
    *,
    queries: int = 240,
    tenant_counts=(2, 4, 8),
    record_rows: bool = True,
) -> dict:
    """Scale the async front-end: hundreds of queries, many engine keys.

    For each tenant count ``T`` a fresh threaded frontend takes ``queries``
    total queries from ``T`` tenant threads; each tenant round-robins its
    submissions across every (graph, template) pair — 2 graphs x 3
    templates = 6 distinct engine keys live in the service's round-robin
    launch ring at once (all pre-warmed, so the rows measure scheduling,
    not compilation).  One row per tenant count:
    ``service/frontend_scale/q<N>/tenants<T>`` — p50 per-query latency
    with p99, aggregate qps, fairness (max/min of per-tenant mean
    latency), and the engine-key count in ``derived``.  The p50/p99-vs-
    tenant-count series is the scheduling-fairness trend the check
    harness watches.
    """
    workloads = [
        ("rmat2k", rmat_graph(2048, 20_000, seed=1), "u5-1"),
        ("rmat2k", None, "u5-2"),  # None: reuse the graph registered above
        ("rmat2k", None, "u6"),
        ("rmat1k", rmat_graph(1024, 10_000, seed=2), "u5-1"),
        ("rmat1k", None, "u5-2"),
        ("rmat1k", None, "u6"),
    ]
    out = {}
    for tenants in tenant_counts:
        svc = CountingService()
        for dname, g, tname in workloads:
            if g is not None:
                svc.register_graph(dname, g)
            svc.prewarm(dname, tname)  # all keys warm: measure scheduling
        fe = ServiceFrontend(svc)
        per_tenant = queries // tenants
        futs = {f"tenant{k}": [] for k in range(tenants)}

        def submitter(tenant: str, base_seed: int) -> None:
            for i in range(per_tenant):
                dname, _, tname = workloads[(base_seed + i) % len(workloads)]
                futs[tenant].append(
                    fe.submit(
                        tenant, dname, tname, iterations=FIXED_ITERATIONS,
                        seed=base_seed + i,
                    )
                )

        t0 = time.perf_counter()
        with fe:
            threads = [
                threading.Thread(target=submitter, args=(tenant, 1000 * k))
                for k, tenant in enumerate(futs)
            ]
            for th in threads:
                th.start()
            for th in threads:
                th.join()
            for fs in futs.values():
                for f in fs:
                    f.result(timeout=600)
        wall = time.perf_counter() - t0

        lat_us = {
            t: np.asarray([f.resolved_at - f.submitted_at for f in fs]) * 1e6
            for t, fs in futs.items()
        }
        all_us = np.concatenate(list(lat_us.values()))
        tenant_means = [float(l.mean()) for l in lat_us.values()]
        fairness = max(tenant_means) / max(min(tenant_means), 1e-9)
        total = per_tenant * tenants
        row = {
            "p50_us": float(np.percentile(all_us, 50)),
            "p99_us": float(np.percentile(all_us, 99)),
            "qps": total / wall,
            "fairness": fairness,
            "queries": total,
            "engine_keys": len(workloads),
        }
        out[tenants] = row
        if record_rows:
            record(
                f"service/frontend_scale/q{queries}/tenants{tenants}",
                row["p50_us"],
                f"p99_us={row['p99_us']:.0f};qps={row['qps']:.1f};"
                f"fairness={fairness:.2f};keys={len(workloads)};"
                f"queries={total};iters={FIXED_ITERATIONS}",
            )
        print(
            f"# frontend scale: {total} queries / {tenants} tenants over "
            f"{len(workloads)} engine keys, p50 {row['p50_us']:.0f}us, "
            f"p99 {row['p99_us']:.0f}us, {row['qps']:.1f} q/s, "
            f"fairness {fairness:.2f}",
            file=sys.stderr,
        )
    return out


def frontend_chaos(
    dname: str = "rmat2k",
    tname: str = "u5-1",
    *,
    graph=None,
    queries: int = FRONTEND_QUERIES,
    record_row: bool = True,
) -> dict:
    """``frontend_load`` under a seeded FaultPlan: 1-in-8 transient launch
    failures.

    The same ``FRONTEND_TENANTS``-thread load as :func:`frontend_load`, but
    every 8th launch (in expectation; the schedule is fixed by
    ``REPRO_FAULT_SEED``) raises a transient fault the retry/backoff path
    must absorb.  The acceptance bar: **zero unresolved futures** — every
    query either resolves with a result or fails with a structured
    ``ServiceError`` — and the row records the latency cost of surviving
    the chaos (p50/p99) plus the retry/failure counts.
    """
    from repro.serve import ServiceError
    from repro.serve.resilience import RetryPolicy
    from repro.testing.faults import FaultPlan, FaultSpec

    g = graph if graph is not None else rmat_graph(2048, 20_000, seed=1)
    svc = CountingService(
        # short real-time backoff: the bench measures retry cost, not sleep
        retry_policy=RetryPolicy(max_retries=8, backoff_base=0.002,
                                 max_backoff=0.05),
    )
    svc.register_graph(dname, g)
    svc.prewarm(dname, tname)
    fe = ServiceFrontend(svc)
    per_tenant = queries // FRONTEND_TENANTS
    futs = {f"tenant{k}": [] for k in range(FRONTEND_TENANTS)}

    def submitter(tenant: str, base_seed: int) -> None:
        for i in range(per_tenant):
            futs[tenant].append(
                fe.submit(
                    tenant, dname, tname, iterations=FIXED_ITERATIONS,
                    seed=base_seed + i,
                )
            )

    plan = FaultPlan(
        [FaultSpec(site="launch", kind="transient", rate=1 / 8)], seed=None
    )
    failed = 0
    t0 = time.perf_counter()
    with plan, fe:
        threads = [
            threading.Thread(target=submitter, args=(tenant, 1000 * k))
            for k, tenant in enumerate(futs)
        ]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        for fs in futs.values():
            for f in fs:
                try:
                    f.result(timeout=600)
                except ServiceError:
                    failed += 1
    wall = time.perf_counter() - t0

    all_futs = [f for fs in futs.values() for f in fs]
    unresolved = [f for f in all_futs if not f.done()]
    assert not unresolved, (
        f"{len(unresolved)} futures left unresolved under chaos — the "
        f"failure-semantics acceptance bar is zero"
    )
    stats = svc.stats()["faults"]
    lat_us = np.asarray([f.resolved_at - f.submitted_at for f in all_futs]) * 1e6
    out = {
        "p50_us": float(np.percentile(lat_us, 50)),
        "p99_us": float(np.percentile(lat_us, 99)),
        "qps": len(all_futs) / wall,
        "wall_s": wall,
        "queries": len(all_futs),
        "faults_injected": plan.fires_by_site().get("launch", 0),
        "retries": stats["retries"],
        "failed": failed,
    }
    if record_row:
        record(
            f"service/{dname}/{tname}/frontend_chaos{out['queries']}",
            out["p50_us"],
            f"p99_us={out['p99_us']:.0f};qps={out['qps']:.1f};"
            f"faults={out['faults_injected']};retries={out['retries']};"
            f"failed={failed};fault_rate=0.125;tenants={FRONTEND_TENANTS};"
            f"iters={FIXED_ITERATIONS}",
        )
    print(
        f"# frontend chaos {dname}/{tname}: {out['queries']} queries, "
        f"{out['faults_injected']} injected faults, {out['retries']} retries, "
        f"{failed} failed, p50 {out['p50_us']:.0f}us, p99 {out['p99_us']:.0f}us",
        file=sys.stderr,
    )
    return out


def run(quick: bool = False, warmup: bool = False) -> None:
    g = rmat_graph(2048, 20_000, seed=1)
    if warmup:
        # one untimed throwaway cold query: process-level one-time costs
        # (backend init, dispatch caches) land here, not in the samples
        scratch = CountingService()
        scratch.register_graph("warmup", g)
        scratch.query("warmup", "u5-1", iterations=2, seed=0)
    templates = ["u5-1"] if quick else ["u5-1", "u5-2"]
    for tname in templates:
        _bench_one("rmat2k", g, tname, quick, warmup)
    frontend_load(graph=g)
    frontend_chaos(graph=g)
    if quick:
        frontend_scale(queries=60, tenant_counts=(2, 4))
    else:
        frontend_scale()


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="smoke subset")
    ap.add_argument(
        "--warmup",
        action="store_true",
        help="run one untimed cold query first (keeps process-level "
        "one-time costs out of the cold samples)",
    )
    ap.add_argument(
        "--frontend-only",
        action="store_true",
        help="only the async front-end rows: the 2-tenant load smoke plus "
        "the multi-engine-key scale sweep (p50/p99 vs tenant count)",
    )
    ap.add_argument(
        "--queries",
        type=int,
        default=240,
        help="total concurrent queries per scale point (default 240)",
    )
    ap.add_argument(
        "--tenants",
        default="2,4,8",
        help="comma-separated tenant counts for the scale sweep",
    )
    args = ap.parse_args()
    emit_header()
    if args.frontend_only:
        frontend_load()
        frontend_scale(
            queries=args.queries,
            tenant_counts=tuple(int(t) for t in args.tenants.split(",")),
        )
    else:
        run(quick=args.quick, warmup=args.warmup)
    return 0


if __name__ == "__main__":
    sys.exit(main())
