"""CountingService benchmark: serving throughput, latency, cache efficiency,
and the adaptive-stopping iteration spend.

Rows (all merged into ``BENCH_counting.json`` for the trend diff):

* ``service/<graph>/<template>/cold_query`` — first query on an empty
  service: engine construction + trace + compile + the run itself.
* ``service/<graph>/<template>/warm_query`` — p50 latency of serial warm
  queries (cache hit, zero recompilation); ``derived`` carries p95,
  queries/sec, and the cache hit rate.
* ``service/<graph>/<template>/batchedN`` — N concurrent queries submitted
  together and drained through the cross-query batched admission loop;
  per-query wall time (the merged launches amortize each chunk).
* ``service/<graph>/<template>/adaptive`` — the (epsilon, delta) stopper
  vs blind fixed-N: iterations actually spent, measured relative error vs
  a 512-iteration exhaustive reference, and the a-priori
  ``required_iterations`` bound the stopper replaces (the paper's
  practical fixed default of ~100 iterations for <1% error is the other
  yardstick).
"""

from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.core import CountingEngine, get_template, rmat_graph
from repro.core.estimator import required_iterations
from repro.serve import CountingService

from .common import emit_header, record

WARM_QUERIES = 12
BATCHED_QUERIES = 8
FIXED_ITERATIONS = 16
ADAPTIVE_EPSILON = 0.01
ADAPTIVE_DELTA = 0.05
ADAPTIVE_BUDGET = 512
REFERENCE_ITERATIONS = 512


def _bench_one(dname: str, g, tname: str, quick: bool) -> None:
    svc = CountingService()
    svc.register_graph(dname, g)

    t0 = time.perf_counter()
    svc.query(dname, tname, iterations=FIXED_ITERATIONS, seed=0)
    cold_s = time.perf_counter() - t0
    record(
        f"service/{dname}/{tname}/cold_query",
        cold_s * 1e6,
        f"iters={FIXED_ITERATIONS};includes_compile=1",
    )

    n_warm = WARM_QUERIES // 2 if quick else WARM_QUERIES
    lats = []
    for s in range(1, n_warm + 1):
        t0 = time.perf_counter()
        svc.query(dname, tname, iterations=FIXED_ITERATIONS, seed=s)
        lats.append(time.perf_counter() - t0)
    lats_us = np.asarray(lats) * 1e6
    cache = svc.stats()["cache"]
    hit_rate = cache["hits"] / max(cache["hits"] + cache["misses"], 1)
    qps = n_warm / (np.sum(lats_us) / 1e6)
    record(
        f"service/{dname}/{tname}/warm_query",
        float(np.percentile(lats_us, 50)),
        f"p95_us={np.percentile(lats_us, 95):.0f};qps={qps:.1f};"
        f"cache_hit_rate={hit_rate:.3f};iters={FIXED_ITERATIONS}",
    )

    # concurrent tenants: one admission loop, launches merged per chunk
    t0 = time.perf_counter()
    qs = [
        svc.submit(dname, tname, iterations=FIXED_ITERATIONS, seed=100 + s)
        for s in range(BATCHED_QUERIES)
    ]
    svc.run()
    wall = time.perf_counter() - t0
    assert all(q.done for q in qs)
    launches = svc.stats()["launches_by_key"][qs[0].engine_key]
    record(
        f"service/{dname}/{tname}/batched{BATCHED_QUERIES}",
        wall / BATCHED_QUERIES * 1e6,
        f"wall_us={wall * 1e6:.0f};launches_total={launches}",
    )

    # adaptive (epsilon, delta) stopping vs the blind fixed-N choice
    engine = CountingEngine(g, [get_template(tname)])
    ref = engine.estimate(iterations=REFERENCE_ITERATIONS, seed=1000)[0]
    q = svc.submit(
        dname,
        tname,
        epsilon=ADAPTIVE_EPSILON,
        delta=ADAPTIVE_DELTA,
        iterations=ADAPTIVE_BUDGET,
        seed=123,
    )
    t0 = time.perf_counter()
    svc.run()
    adaptive_s = time.perf_counter() - t0
    est = q.result()[0]
    rel_err = abs(est.mean - ref.mean) / max(abs(ref.mean), 1e-9)
    blind_n = required_iterations(
        get_template(tname).k, ADAPTIVE_EPSILON, ADAPTIVE_DELTA
    )
    record(
        f"service/{dname}/{tname}/adaptive",
        adaptive_s * 1e6,
        f"iters={q.iterations};rel_err={rel_err:.5f};eps={ADAPTIVE_EPSILON};"
        f"delta={ADAPTIVE_DELTA};blind_n={blind_n};converged={int(est.converged)}",
    )
    print(
        f"# service adaptive {dname}/{tname}: {q.iterations} iters "
        f"(blind bound {blind_n}), rel err {rel_err:.3%} vs "
        f"{REFERENCE_ITERATIONS}-iter reference",
        file=sys.stderr,
    )


def run(quick: bool = False) -> None:
    g = rmat_graph(2048, 20_000, seed=1)
    templates = ["u5-1"] if quick else ["u5-1", "u5-2"]
    for tname in templates:
        _bench_one("rmat2k", g, tname, quick)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="smoke subset")
    args = ap.parse_args()
    emit_header()
    run(quick=args.quick)
    return 0


if __name__ == "__main__":
    sys.exit(main())
