"""Table III analog: SUBGRAPH2VEC (S) vs the graph-traversal model (F).

The baseline implements FASCIA's Algorithm 2 access pattern *in JAX* for a
fair comparison: the neighbor reduction (an SpMV) is re-executed for every
(output color set, split) pair — exactly the redundancy Equation 1 removes.
SUBGRAPH2VEC runs Algorithm 5: ONE batched SpMM per stage + vertex-local eMA.

Scaled to CPU budgets: RMAT graphs (the paper's synthetic family, including
the skew sweep a=0.45/0.57/0.7 mirroring K=3/5/8) x templates u5-u10.
Reported ``derived`` = speedup (traversal_us / vectorized_us).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build_counting_plan, count_colorful_vectorized, get_template, rmat_graph, spmm_edges
from .common import record, time_fn


def traversal_count_jax(plan, src, dst, n, colors):
    """Algorithm 2 in JAX: per-(out,split) SpMV — the redundant baseline."""
    k = plan.k
    leaf = jax.nn.one_hot(colors, k, dtype=jnp.float32)
    slots = {}
    for i, sub in enumerate(plan.partition.subs):
        if sub.is_leaf:
            slots[i] = leaf
            continue
        table = plan.tables[i]
        m_a, m_p = slots[sub.active], slots[sub.passive]
        cols = []
        for out in range(table.n_out):
            acc = jnp.zeros((n,), jnp.float32)
            for t in range(table.n_splits):
                ia = int(table.idx_a[out, t])
                ip = int(table.idx_p[out, t])
                # the per-split neighbor traversal (SpMV re-done every time)
                b_col = jax.ops.segment_sum(m_p[src, ip], dst, num_segments=n)
                acc = acc + m_a[:, ia] * b_col
            cols.append(acc)
        slots[i] = jnp.stack(cols, axis=1)
        del slots[sub.active], slots[sub.passive]
    return jnp.sum(slots[plan.partition.root_index])


def run() -> None:
    datasets = {
        "rmat2k": rmat_graph(2048, 20_000, seed=1),
        "rmat2k-skew": rmat_graph(2048, 20_000, seed=1, a=0.7, b=0.12, c=0.12),
        "rmat8k": rmat_graph(8192, 80_000, seed=2),
    }
    templates = ["u5-1", "u5-2", "u6", "u7"]
    rng = np.random.default_rng(0)

    for dname, g in datasets.items():
        src, dst = jnp.asarray(g.src), jnp.asarray(g.dst)
        spmm = partial(spmm_edges, src, dst, g.n)
        for tname in templates:
            t = get_template(tname)
            plan = build_counting_plan(t)
            colors = jnp.asarray(rng.integers(0, t.k, size=g.n))

            vec = jax.jit(lambda c, p=plan, s=spmm: count_colorful_vectorized(p, c, s))
            trav = jax.jit(
                lambda c, p=plan, sr=src, ds=dst, n=g.n: traversal_count_jax(p, sr, ds, n, c)
            )
            # correctness cross-check before timing
            v, tr = float(vec(colors)), float(trav(colors))
            assert abs(v - tr) <= 1e-4 * max(abs(v), 1.0), (v, tr)

            us_v = time_fn(vec, colors)
            us_t = time_fn(trav, colors)
            record(f"tableIII/{dname}/{tname}/subgraph2vec", us_v, f"count={v:.3e}")
            record(f"tableIII/{dname}/{tname}/traversal", us_t, f"speedup={us_t / us_v:.1f}x")
