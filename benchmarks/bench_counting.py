"""Table III analog: SUBGRAPH2VEC (S) vs the graph-traversal model (F),
plus the batched CountingEngine vs the per-coloring dispatch loop.

Two comparisons, both on RMAT graphs (the paper's synthetic family):

* **tableIII** — per coloring, the engine's fused SpMM+eMA pipeline (no
  aggregate product ever materialized; backend auto-selected per graph) vs
  FASCIA's Algorithm 2 access pattern implemented in JAX for fairness: the
  neighbor reduction (an SpMV) re-executed for every (output color set,
  split) pair — exactly the redundancy Equation 1 removes.  rmat8k is the
  regime where the old two-pass dataflow fell off the XLA:CPU scatter
  cliff (0.1–0.2x vs traversal); the fused rows track that it stays fixed.
  Results are cross-checked against the legacy two-pass reference
  (``count_colorful_vectorized``) before timing.
* **engine** — a full 64-iteration estimation run: the legacy per-coloring
  jit-dispatch loop (one device call + one host sync per coloring) vs the
  :class:`~repro.core.engine.CountingEngine`, which fuses a chunk of
  colorings into the column dimension of the DP state and runs the whole
  thing in one jit.  Estimates are cross-checked to fp32 tolerance before
  timing; ``derived`` records the speedup.  A ``memory_model`` row per
  config compares the chunk picker's predicted live bytes with XLA's
  ``memory_analysis()`` temp allocation when the backend exposes it (the
  ROADMAP calibration item).

Run standalone for the CI smoke:  ``python -m benchmarks.bench_counting --quick``
(the quick subset includes an rmat8k row so the cliff regression is caught
in CI, not just the full suite).
"""

from __future__ import annotations

import argparse
import sys
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    CountingEngine,
    build_counting_plan,
    count_colorful_vectorized,
    get_template,
    make_count_step,
    rmat_graph,
    spmm_edges,
)
from .common import emit_header, record, time_fn

ENGINE_ITERATIONS = 64


def traversal_count_jax(plan, src, dst, n, colors):
    """Algorithm 2 in JAX: per-(out,split) SpMV — the redundant baseline."""
    k = plan.k
    leaf = jax.nn.one_hot(colors, k, dtype=jnp.float32)
    slots = {}
    for i, sub in enumerate(plan.partition.subs):
        if sub.is_leaf:
            slots[i] = leaf
            continue
        table = plan.tables[i]
        m_a, m_p = slots[sub.active], slots[sub.passive]
        cols = []
        for out in range(table.n_out):
            acc = jnp.zeros((n,), jnp.float32)
            for t in range(table.n_splits):
                ia = int(table.idx_a[out, t])
                ip = int(table.idx_p[out, t])
                # the per-split neighbor traversal (SpMV re-done every time)
                b_col = jax.ops.segment_sum(m_p[src, ip], dst, num_segments=n)
                acc = acc + m_a[:, ia] * b_col
            cols.append(acc)
        slots[i] = jnp.stack(cols, axis=1)
        del slots[sub.active], slots[sub.passive]
    return jnp.sum(slots[plan.partition.root_index])


def _run_table_iii(datasets, templates) -> None:
    rng = np.random.default_rng(0)
    for dname, g in datasets.items():
        src, dst = jnp.asarray(g.src), jnp.asarray(g.dst)
        spmm = partial(spmm_edges, src, dst, g.n)
        for tname in templates:
            t = get_template(tname)
            plan = build_counting_plan(t)
            colors = jnp.asarray(rng.integers(0, t.k, size=g.n))

            # the system under test: the engine's fused SpMM+eMA pipeline
            engine = CountingEngine(g, [t], plans=[plan])
            fused = jax.jit(engine.backend_impl.counts_for_colors)
            trav = jax.jit(
                lambda c, p=plan, sr=src, ds=dst, n=g.n: traversal_count_jax(p, sr, ds, n, c)
            )
            # correctness cross-check (vs the legacy two-pass reference AND
            # the traversal model) before timing
            v = float(fused(colors[None, :])[0, 0])
            ref = float(count_colorful_vectorized(plan, colors, spmm))
            tr = float(trav(colors))
            assert abs(v - ref) <= 1e-4 * max(abs(ref), 1.0), (v, ref)
            assert abs(v - tr) <= 1e-4 * max(abs(v), 1.0), (v, tr)

            us_v = time_fn(fused, colors[None, :])
            us_t = time_fn(trav, colors)
            record(
                f"tableIII/{dname}/{tname}/subgraph2vec",
                us_v,
                f"count={v:.3e};backend={engine.backend}",
            )
            record(f"tableIII/{dname}/{tname}/traversal", us_t, f"speedup={us_t / us_v:.1f}x")


def _run_engine_vs_loop(datasets, templates, iterations: int, timing_iters: int) -> None:
    for dname, g in datasets.items():
        src, dst = jnp.asarray(g.src), jnp.asarray(g.dst)
        spmm = partial(spmm_edges, src, dst, g.n)
        for tname in templates:
            t = get_template(tname)
            plan = build_counting_plan(t)
            keys = jax.random.split(jax.random.PRNGKey(0), iterations)
            engine = CountingEngine(g, [t], plans=[plan])

            # the seed estimator loop: one jit dispatch + one host sync per
            # coloring (this first run doubles as the step's jit warmup)
            step = make_count_step(plan, g.n, spmm)

            def run_loop():
                return np.array([float(step(key)) for key in keys])

            def run_engine():
                return engine.count_keys(keys)

            loop_vals = run_loop()
            engine_vals = engine.count_keys(keys)[:, 0]
            # same keys => same colorings: estimates must agree to fp32 tolerance
            assert np.allclose(engine_vals, loop_vals, rtol=1e-5), (
                tname,
                float(np.max(np.abs(engine_vals - loop_vals))),
            )

            # both sides are warm from the cross-check run above
            us_loop = time_fn(run_loop, warmup=1, iters=timing_iters)
            us_engine = time_fn(run_engine, warmup=1, iters=timing_iters)
            speedup = us_loop / max(us_engine, 1e-9)
            record(
                f"engine/{dname}/{tname}/loop{iterations}",
                us_loop,
                "per_coloring_dispatch",
            )
            record(
                f"engine/{dname}/{tname}/batched{iterations}",
                us_engine,
                f"speedup={speedup:.2f}x;chunk={engine.chunk_size};backend={engine.backend}",
            )
            # chunk-picker calibration: predicted live bytes vs XLA's
            # measured temp allocation (None when the backend lacks
            # memory_analysis — it is optional in XLA)
            ma = engine.compiled_memory_analysis(iterations)
            actual = ma["actual_temp_bytes"]
            ratio = ma["ratio"]
            # applied_fusion_slack records what the picker already folded
            # in, so re-calibration sees the raw analytic ratio:
            # raw predicted/actual = predicted_over_actual * slack
            record(
                f"engine/{dname}/{tname}/memory_model",
                0.0,
                f"predicted_bytes={ma['predicted_bytes']:.0f};"
                f"actual_temp_bytes={'%.0f' % actual if actual else 'n/a'};"
                f"predicted_over_actual={'%.3f' % ratio if ratio else 'n/a'};"
                f"applied_fusion_slack={engine.cost.fusion_slack:.4f}",
            )
            if ratio:
                print(
                    f"# memory model {dname}/{tname}: predicted/actual = {ratio:.3f}",
                    file=sys.stderr,
                )


def run(quick: bool = False) -> None:
    if quick:
        datasets = {"rmat2k": rmat_graph(2048, 20_000, seed=1)}
        _run_engine_vs_loop(datasets, ["u5-1", "u6"], iterations=16, timing_iters=1)
        # the rmat8k cliff row: the fused pipeline must stay ahead of the
        # traversal baseline here (the two-pass dataflow was 5-10x BEHIND)
        _run_table_iii({"rmat8k": rmat_graph(8192, 80_000, seed=2)}, ["u5-2", "u6"])
        return
    datasets = {
        "rmat2k": rmat_graph(2048, 20_000, seed=1),
        "rmat2k-skew": rmat_graph(2048, 20_000, seed=1, a=0.7, b=0.12, c=0.12),
        "rmat8k": rmat_graph(8192, 80_000, seed=2),
    }
    _run_table_iii(datasets, ["u5-1", "u5-2", "u6", "u7"])
    _run_engine_vs_loop(
        {"rmat2k": datasets["rmat2k"]},
        ["u5-1", "u5-2", "u6", "u7"],
        iterations=ENGINE_ITERATIONS,
        timing_iters=3,
    )


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="~30s CI smoke subset")
    args = ap.parse_args()
    emit_header()
    run(quick=args.quick)
    return 0


if __name__ == "__main__":
    sys.exit(main())
