"""Per-kernel microbenchmarks (SpMM / eMA) — the paper's Table IV analogue.

Times the high-level jnp kernels (the production CPU path) and verifies the
Pallas kernels against them in interpret mode.  On-TPU timing is N/A in this
container; the Pallas rows report correctness (max rel err) as ``derived``.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rmat_graph, spmm_edges
from repro.core.colorsets import build_split_table, binom
from repro.core.counting import _ema_apply
from repro.kernels.spmm_blocked.ops import prepare_operand, spmm_blocked
from .common import record, time_fn


def run() -> None:
    g = rmat_graph(4096, 40_000, seed=5)
    rng = np.random.default_rng(0)

    for cols in (32, 128, 512):
        m = jnp.asarray(rng.standard_normal((g.n, cols)).astype(np.float32))
        spmm = jax.jit(partial(spmm_edges, jnp.asarray(g.src), jnp.asarray(g.dst), g.n))
        us = time_fn(spmm, m)
        nnz = g.num_directed
        record(f"kernel/spmm_edges/c{cols}", us, f"gflops={2 * nnz * cols / us / 1e3:.2f}")

    op = prepare_operand(g, block_size=256, edge_chunk=256)
    m = jnp.asarray(rng.standard_normal((g.n, 128)).astype(np.float32))
    ref = spmm_edges(jnp.asarray(g.src), jnp.asarray(g.dst), g.n, m)
    out = spmm_blocked(op, m, interpret=True)
    err = float(jnp.max(jnp.abs(out - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9))
    record("kernel/spmm_pallas_interpret/c128", 0.0, f"max_rel_err={err:.2e}")

    t = build_split_table(8, 5, 3)
    ma = jnp.asarray(rng.standard_normal((g.n, binom(8, 3))).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((g.n, binom(8, 2))).astype(np.float32))
    ia, ip = jnp.asarray(t.idx_a), jnp.asarray(t.idx_p)
    # the production eMA primitive (kernels/ema was removed; the fused
    # Pallas SpMM+eMA path is exercised by bench_counting's blocked rows)
    ema = jax.jit(_ema_apply)
    us = time_fn(ema, ma, b, ia, ip)
    flops = 2.0 * g.n * t.n_out * t.n_splits
    record("kernel/ema_jnp/k8m5", us, f"gflops={flops / us / 1e3:.2f}")
