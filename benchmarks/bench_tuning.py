"""Autotuner benchmark: measured tuned config vs the analytic heuristic.

Rows (merged into ``BENCH_counting.json`` for the trend diff):

* ``tune/<graph>/<template>/tuned_vs_heuristic`` — warm per-coloring
  latency of the tuner's winning config, measured against the analytic
  heuristic's pick on the same graph with **interleaved** timed launches
  (heuristic, tuned, heuristic, tuned, ... — so host-load drift hits both
  sides equally).  ``us_per_call`` is the tuned median; ``derived``
  carries ``ratio=heuristic_us/tuned_us`` (>= 1.0 means the tuned config
  is at least as fast — the acceptance bar; the trend diff flags
  ratio < ``TUNING_RATIO_FLOOR``), the heuristic median, and both
  backend names.
* ``tune/<graph>/<template>/search`` — wall time of the full ``tune()``
  call itself (lattice ranking + top-N compile/measure + cache write):
  the cost a ``CountingService`` pays per background tune.

The tuner writes to a throwaway cache file — benchmark runs never touch
the repo-root ``TUNED_counting.json``.
"""

from __future__ import annotations

import argparse
import os
import sys
import tempfile
import time

import numpy as np

from repro.core import CountingEngine, get_template, rmat_graph
from repro.exec.select import heuristic_backend
from repro.tune import tune

from .common import emit_header, record

TUNE_TOP_N = 4
TUNE_PROBES = 3
COMPARE_PROBES = 7


def _per_coloring_us(engine, keys) -> float:
    t0 = time.perf_counter()
    engine.count_keys_chunk(keys)  # returns a host array: synchronous
    return (time.perf_counter() - t0) * 1e6 / max(1, engine.chunk_size)


def tuned_vs_heuristic(
    dname: str = "rmat2k",
    tname: str = "u5-1",
    *,
    graph=None,
    top_n: int = TUNE_TOP_N,
    probes: int = TUNE_PROBES,
    compare_probes: int = COMPARE_PROBES,
    record_row: bool = True,
) -> dict:
    """Tune one (graph, template) pair, then race winner vs heuristic.

    Returns the medians, the ratio, and the search wall time; records the
    ``tuned_vs_heuristic`` and ``search`` rows unless ``record_row=False``.
    """
    import jax

    g = graph if graph is not None else rmat_graph(2048, 20_000, seed=1)
    template = get_template(tname)

    fd, cache_path = tempfile.mkstemp(prefix="repro_tune_bench_", suffix=".json")
    os.close(fd)
    os.unlink(cache_path)  # the tuner writes it fresh (empty file = corrupt)
    try:
        t0 = time.perf_counter()
        result = tune(
            g, [template], top_n=top_n, probes=probes, cache_path=cache_path
        )
        search_s = time.perf_counter() - t0
    finally:
        if os.path.exists(cache_path):
            os.unlink(cache_path)

    cfg = result.config
    tuned_eng = CountingEngine(
        g,
        [template],
        backend=cfg.backend_name,
        tuning=cfg if cfg.backend_name == "mixed" else None,
        chunk_size=cfg.chunk_size,
        column_batch=cfg.column_batch,
    )
    heur_name, _ = heuristic_backend(g)
    # explicit backend= so neither env nor tuned cache touches the baseline
    heur_eng = CountingEngine(g, [template], backend=heur_name)

    tuned_keys = jax.random.split(jax.random.PRNGKey(0), tuned_eng.chunk_size)
    heur_keys = jax.random.split(jax.random.PRNGKey(0), heur_eng.chunk_size)
    tuned_eng.count_keys_chunk(tuned_keys)  # warmup: compile
    heur_eng.count_keys_chunk(heur_keys)
    tuned_us, heur_us = [], []
    for _ in range(max(1, compare_probes)):  # interleaved: drift hits both
        heur_us.append(_per_coloring_us(heur_eng, heur_keys))
        tuned_us.append(_per_coloring_us(tuned_eng, tuned_keys))
    tuned_med = float(np.median(tuned_us))
    heur_med = float(np.median(heur_us))
    ratio = heur_med / max(tuned_med, 1e-9)

    out = {
        "tuned_us": tuned_med,
        "heuristic_us": heur_med,
        "ratio": ratio,
        "tuned_backend": cfg.backend_name,
        "heuristic_backend": heur_name,
        "search_s": search_s,
        "lattice_size": result.lattice_size,
    }
    if record_row:
        record(
            f"tune/{dname}/{tname}/tuned_vs_heuristic",
            tuned_med,
            f"ratio={ratio:.3f};heuristic_us={heur_med:.1f};"
            f"backend={cfg.backend_name};heuristic_backend={heur_name};"
            f"chunk={tuned_eng.chunk_size};cb={tuned_eng.column_batch};"
            f"probes={compare_probes}",
        )
        record(
            f"tune/{dname}/{tname}/search",
            search_s * 1e6,
            f"lattice={result.lattice_size};top_n={len(result.measured)};"
            f"probes={probes};winner={cfg.backend_name}",
        )
    print(
        f"# tune {dname}/{tname}: tuned {cfg.backend_name} "
        f"{tuned_med:.1f}us/coloring vs heuristic {heur_name} "
        f"{heur_med:.1f}us (ratio {ratio:.3f}), search took {search_s:.1f}s "
        f"over {result.lattice_size}-candidate lattice",
        file=sys.stderr,
    )
    return out


def run(quick: bool = False) -> None:
    g = rmat_graph(2048, 20_000, seed=1)
    tuned_vs_heuristic(
        graph=g,
        top_n=3 if quick else TUNE_TOP_N,
        probes=3 if quick else TUNE_PROBES,
    )


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="smaller search")
    args = ap.parse_args()
    emit_header()
    run(quick=args.quick)
    return 0


if __name__ == "__main__":
    sys.exit(main())
