"""CountingService tests: engine-cache semantics, zero-recompile warm
queries, cross-query batching equality, adaptive-stopper behavior and
determinism, and starvation-freedom of the admission loop."""

import math

import jax
import numpy as np
import pytest

from repro.core import CountingEngine, engine_cache_key, get_template, rmat_graph
from repro.serve import AdaptiveStopper, CountingService, EngineCache, normal_quantile
from repro.serve.stopping import adaptive_estimate


def _fold_keys(seed: int, n: int) -> np.ndarray:
    base = jax.random.PRNGKey(seed)
    return np.stack([np.asarray(jax.random.fold_in(base, i)) for i in range(n)])


def _service(**kw):
    kw.setdefault("chunk_size", 4)
    return CountingService(**kw)


# ---------------------------------------------------------------------------
# EngineCache semantics
# ---------------------------------------------------------------------------


def test_cache_hit_miss_evict_counters():
    cache = EngineCache(capacity=2)
    built = []

    def factory(tag):
        def build():
            built.append(tag)
            return tag

        return build

    assert cache.get("a", factory("a")) == "a"  # miss
    assert cache.get("a", factory("a2")) == "a"  # hit (no rebuild)
    assert cache.get("b", factory("b")) == "b"  # miss
    assert cache.get("c", factory("c")) == "c"  # miss -> evicts LRU "a"
    assert "a" not in cache and "b" in cache and "c" in cache
    assert cache.get("a", factory("a3")) == "a3"  # miss again -> evicts "b"
    assert cache.counters() == {
        "hits": 1,
        "misses": 4,
        "evictions": 2,
        "build_failures": 0,
        "invalidations": 0,
        "size": 2,
        "capacity": 2,
    }
    assert built == ["a", "b", "c", "a3"]


def test_cache_lru_order_follows_hits():
    cache = EngineCache(capacity=2)
    cache.get("a", lambda: 1)
    cache.get("b", lambda: 2)
    cache.get("a", lambda: None)  # touch "a" -> "b" becomes LRU
    cache.get("c", lambda: 3)
    assert "a" in cache and "b" not in cache


def test_service_cache_counters_and_eviction():
    g1 = rmat_graph(300, 1500, seed=2)
    g2 = rmat_graph(260, 1100, seed=3)
    svc = _service(max_engines=1)
    svc.register_graph("g1", g1)
    svc.register_graph("g2", g2)
    svc.query("g1", "u5-1", iterations=2)
    svc.query("g2", "u5-1", iterations=2)  # evicts g1's engine
    svc.query("g1", "u5-1", iterations=2)  # rebuilt: miss again
    c = svc.stats()["cache"]
    assert c["misses"] == 3 and c["evictions"] == 2 and c["hits"] == 0

    wide = _service(max_engines=4)
    wide.register_graph("g1", g1)
    wide.register_graph("g2", g2)
    wide.query("g1", "u5-1", iterations=2)
    wide.query("g2", "u5-1", iterations=2)
    wide.query("g1", "u5-1", iterations=3, seed=7)  # warm: key ignores N/seed
    c = wide.stats()["cache"]
    assert c["misses"] == 2 and c["hits"] == 1 and c["evictions"] == 0


def test_register_graph_content_conflict():
    svc = _service()
    svc.register_graph("g", rmat_graph(100, 300, seed=0))
    svc.register_graph("g", rmat_graph(100, 300, seed=0))  # same content: ok
    with pytest.raises(ValueError, match="different content"):
        svc.register_graph("g", rmat_graph(100, 300, seed=1))


def test_engine_cache_key_identity():
    g = rmat_graph(300, 1500, seed=2)
    g_copy = rmat_graph(300, 1500, seed=2)
    t = [get_template("u5-1")]
    assert engine_cache_key(g, t) == engine_cache_key(g_copy, t)
    assert engine_cache_key(g, t) != engine_cache_key(rmat_graph(300, 1500, seed=3), t)
    assert engine_cache_key(g, t, chunk_size=4) != engine_cache_key(g, t, chunk_size=8)
    assert engine_cache_key(g, t, dtype_policy="bf16") != engine_cache_key(g, t)
    # the engine's own key matches the pre-construction computation
    eng = CountingEngine(g, t, chunk_size=4)
    assert eng.cache_key() == engine_cache_key(g, t, chunk_size=4)


# ---------------------------------------------------------------------------
# Warm repeat queries: zero new jit compilations
# ---------------------------------------------------------------------------


def test_warm_repeat_query_zero_new_compilations():
    svc = _service()
    svc.register_graph("g", rmat_graph(300, 1500, seed=2))
    q1 = svc.submit("g", "u5-2", iterations=6, seed=1)
    svc.run()
    engine = svc.engine(q1.engine_key)
    assert engine is not None and engine.trace_count == 1
    # different seed AND different iteration target: same key, same shape
    # (launches are padded to chunk_size), so nothing re-traces
    q2 = svc.submit("g", "u5-2", iterations=3, seed=42)
    q3 = svc.submit("g", "u5-2", epsilon=0.5, delta=0.2, iterations=8, seed=5)
    svc.run()
    assert q2.done and q3.done
    assert svc.engine(q2.engine_key) is engine
    assert engine.trace_count == 1  # zero new compilations


# ---------------------------------------------------------------------------
# Cross-query batching == per-query engine runs (acceptance: u3-u7, rmat2k)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("tname", ["u3", "u5-1", "u5-2", "u6", "u7"])
def test_cross_query_batched_equals_per_query_engine(tname):
    g = rmat_graph(2048, 20_000, seed=1)
    svc = _service()
    svc.register_graph("rmat2k", g)
    # two tenants of one engine key: their colorings share chunk launches
    qa = svc.submit("rmat2k", tname, iterations=3, seed=11, record_rows=True)
    qb = svc.submit("rmat2k", tname, iterations=2, seed=22, record_rows=True)
    svc.run()
    key_launches = svc.stats()["launches_by_key"][qa.engine_key]
    assert key_launches == 2  # 5 slots through a chunk of 4 => shared launches
    engine = CountingEngine(g, [get_template(tname)], chunk_size=4)
    for q, seed, iters in ((qa, 11, 3), (qb, 22, 2)):
        solo = engine.count_keys(_fold_keys(seed, iters))
        got = q.per_iteration()
        assert got.shape == solo.shape
        rel = np.max(np.abs(got - solo) / np.maximum(np.abs(solo), 1e-9))
        assert rel <= 1e-5, (tname, rel)
        # fp32 edges path: batching may not change values at all
        assert np.array_equal(got, solo), tname


def test_multi_template_query_matches_engine():
    g = rmat_graph(400, 2000, seed=5)
    names = ("path6", "star6", "u6")
    svc = _service()
    svc.register_graph("g", g)
    q = svc.submit("g", names, iterations=4, seed=3, record_rows=True)
    svc.run()
    engine = CountingEngine(g, [get_template(n) for n in names], chunk_size=4)
    solo = engine.count_keys(_fold_keys(3, 4))
    assert np.allclose(q.per_iteration(), solo, rtol=1e-6)


# ---------------------------------------------------------------------------
# Adaptive stopping
# ---------------------------------------------------------------------------


def test_normal_quantile_known_values():
    assert normal_quantile(0.975) == pytest.approx(1.959964, abs=1e-5)
    assert normal_quantile(0.5) == pytest.approx(0.0, abs=1e-12)
    assert normal_quantile(0.025) == pytest.approx(-1.959964, abs=1e-5)
    assert normal_quantile(0.995) == pytest.approx(2.575829, abs=1e-5)
    with pytest.raises(ValueError):
        normal_quantile(0.0)


def test_stopper_welford_matches_numpy():
    rng = np.random.default_rng(0)
    rows = rng.normal(100.0, 5.0, size=(50, 2))
    st = AdaptiveStopper(2, epsilon=0.01, budget=1000)
    st.update(rows[:17])
    st.update(rows[17:])
    for t, est in enumerate(st.estimates()):
        assert est.mean == pytest.approx(rows[:, t].mean(), rel=1e-12)
        assert est.std == pytest.approx(rows[:, t].std(ddof=1), rel=1e-10)


def test_stopper_converges_on_tight_stream_and_respects_budget():
    # near-constant stream: converges right at min_iterations
    st = AdaptiveStopper(1, epsilon=0.01, budget=1000, min_iterations=8)
    st.update(np.full((7, 1), 50.0) + np.linspace(0, 1e-6, 7)[:, None])
    assert not st.done  # CI not armed yet
    st.update(np.full((1, 1), 50.0))
    assert st.converged and st.done and st.iterations == 8
    # wild stream: runs to the budget without converging
    rng = np.random.default_rng(1)
    st = AdaptiveStopper(1, epsilon=0.0001, budget=32, min_iterations=8)
    while not st.done:
        st.update(rng.normal(10.0, 8.0, size=(4, 1)))
    assert st.iterations == 32 and not st.converged
    # epsilon=None: pure fixed-budget mode
    st = AdaptiveStopper(1, epsilon=None, budget=5)
    st.update(np.zeros((5, 1)))
    assert st.done and not st.converged


def test_adaptive_stops_earlier_than_budget_on_real_graph():
    g = rmat_graph(300, 1500, seed=2)
    engine = CountingEngine(g, [get_template("u5-1")], chunk_size=8)
    res = adaptive_estimate(engine, epsilon=0.08, delta=0.1, seed=0, max_iterations=512)[0]
    assert res.iterations < 512  # stopped on the CI, not the budget
    assert res.iterations >= 8
    assert res.per_iteration.shape == (res.iterations,)


def test_adaptive_estimate_deterministic_and_batch_invariant():
    g = rmat_graph(300, 1500, seed=2)
    a = adaptive_estimate(
        CountingEngine(g, [get_template("u5-2")], chunk_size=8),
        epsilon=0.1, delta=0.1, seed=7, max_iterations=256,
    )[0]
    b = adaptive_estimate(
        CountingEngine(g, [get_template("u5-2")], chunk_size=8),
        epsilon=0.1, delta=0.1, seed=7, max_iterations=256,
    )[0]
    assert a.iterations == b.iterations
    assert np.array_equal(a.per_iteration, b.per_iteration)


def test_service_adaptive_determinism_under_fixed_seed():
    def run_once():
        svc = _service()
        svc.register_graph("g", rmat_graph(300, 1500, seed=2))
        q = svc.submit("g", "u5-1", epsilon=0.1, delta=0.1, iterations=256, seed=9)
        svc.run()
        return q

    q1, q2 = run_once(), run_once()
    assert q1.iterations == q2.iterations
    assert [e.mean for e in q1.result()] == [e.mean for e in q2.result()]
    assert [e.halfwidth for e in q1.result()] == [e.halfwidth for e in q2.result()]


def test_estimator_epsilon_delta_entry_point():
    from repro.core import estimate_embeddings

    g = rmat_graph(300, 1500, seed=2)
    t = get_template("u5-1")
    res = estimate_embeddings(g, t, epsilon=0.1, delta=0.1, max_iterations=256, seed=0)
    ref = estimate_embeddings(g, t, iterations=256, seed=0)
    assert res.iterations < 256
    assert res.mean == pytest.approx(ref.mean, rel=0.25)  # same estimator family
    # the CI the stopper certified: mean within ~epsilon of the long run
    assert not math.isnan(res.std)


# ---------------------------------------------------------------------------
# Admission loop fairness
# ---------------------------------------------------------------------------


def test_round_robin_no_starvation_under_skewed_load():
    g_hot = rmat_graph(300, 1500, seed=2)
    g_cold = rmat_graph(260, 1100, seed=3)
    svc = _service(max_engines=4, chunk_size=2)
    svc.register_graph("hot", g_hot)
    svc.register_graph("cold", g_cold)
    # skew: the hot graph has 6 queries x 8 iterations, the cold one 1 x 4
    hot = [svc.submit("hot", "u5-1", iterations=8, seed=s) for s in range(6)]
    cold = svc.submit("cold", "u5-1", iterations=4, seed=0)
    svc.run()
    assert all(q.done for q in hot) and cold.done
    hot_key, cold_key = hot[0].engine_key, cold.engine_key
    log = svc.launch_log
    cold_positions = [i for i, k in enumerate(log) if k == cold_key]
    # while the cold query was live, the hot key never got two consecutive
    # launches — every cycle served both keys (round-robin admission)
    last_cold = cold_positions[-1]
    for i in range(1, last_cold + 1):
        assert not (log[i] == hot_key and log[i - 1] == hot_key), log
    # and the cold query finished long before the hot backlog drained
    assert last_cold < len(log) - 1


def test_launches_merge_queries_not_serialize_them():
    svc = _service(chunk_size=8)
    svc.register_graph("g", rmat_graph(300, 1500, seed=2))
    queries = [svc.submit("g", "u5-1", iterations=4, seed=s) for s in range(4)]
    svc.run()
    # 16 iterations across 4 queries fit 8-wide launches: 2, not 4+
    assert svc.stats()["launches"] == 2
    assert all(q.done for q in queries)


# ---------------------------------------------------------------------------
# describe() / observability
# ---------------------------------------------------------------------------


def test_engine_describe_structure():
    g = rmat_graph(300, 1500, seed=2)
    eng = CountingEngine(g, [get_template("u5-1")], chunk_size=4)
    d = eng.describe()
    assert d["backend"]["name"] == eng.backend
    assert d["backend"]["source"] in (
        "heuristic", "env", "explicit", "tuned", "custom", "mesh"
    )
    assert d["backend"]["reason"]
    assert d["backend"]["tuning"] is None  # no tuned config bound here
    assert d["n"] == g.n and d["k"] == 5
    assert d["cache_key"] == eng.cache_key()
    assert d["memory"]["bytes_per_coloring"] == eng.bytes_per_coloring()
    assert d["dtype_policy"] == {"store": "float32", "accum": "float32"}


def test_service_stats_exposes_engine_descriptions():
    svc = _service()
    svc.register_graph("g", rmat_graph(300, 1500, seed=2))
    svc.query("g", "u5-1", iterations=2)
    stats = svc.stats()
    assert stats["queries_completed"] == 1
    assert len(stats["engines"]) == 1
    assert stats["engines"][0]["backend"]["reason"]
