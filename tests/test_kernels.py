"""Per-kernel allclose tests vs pure-jnp oracles (interpret mode), with
shape/dtype sweeps and a full kernel-backed Algorithm 5 cross-check.
"""

from functools import partial

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import build_counting_plan, count_colorful_vectorized, get_template
from repro.core.colorsets import build_split_table
from repro.core.counting import _ema_apply
from repro.core.graph import erdos_renyi_graph, grid_graph, rmat_graph
from repro.kernels.spmm_blocked.ops import prepare_operand, spmm_blocked
from repro.kernels.spmm_blocked.ref import spmm_ref


def _rel_err(a, b):
    denom = float(jnp.max(jnp.abs(b))) + 1e-9
    return float(jnp.max(jnp.abs(a - b))) / denom


# ---------------------------------------------------------------------------
# SpMM kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["mxu", "loop"])
@pytest.mark.parametrize(
    "n,e,cols,block,chunk",
    [
        (200, 800, 16, 128, 128),
        (300, 1500, 40, 128, 256),
        (513, 2000, 130, 256, 256),  # ragged n and cols
        (64, 100, 1, 128, 128),      # single column (SpMV)
    ],
)
def test_spmm_blocked_shapes(mode, n, e, cols, block, chunk):
    g = rmat_graph(n, e, seed=n + e)
    op = prepare_operand(g, block_size=block, edge_chunk=chunk)
    rng = np.random.default_rng(0)
    m = jnp.asarray(rng.standard_normal((g.n, cols)).astype(np.float32))
    ref = spmm_ref(jnp.asarray(g.src), jnp.asarray(g.dst), g.n, m)
    out = spmm_blocked(op, m, mode=mode, interpret=True)
    assert out.shape == ref.shape
    assert _rel_err(out, ref) < 1e-5


def test_spmm_blocked_dtype_sweep():
    g = erdos_renyi_graph(150, 600, seed=1)
    op = prepare_operand(g, block_size=128, edge_chunk=128)
    rng = np.random.default_rng(0)
    base = rng.standard_normal((g.n, 24))
    for dtype, tol in [(np.float32, 1e-5), (np.float64, 1e-5)]:
        m = jnp.asarray(base.astype(dtype))
        ref = spmm_ref(jnp.asarray(g.src), jnp.asarray(g.dst), g.n, m)
        out = spmm_blocked(op, m, mode="mxu", interpret=True)
        assert _rel_err(out, ref) < tol


def test_spmm_blocked_empty_rows():
    """Isolated vertices must produce zero rows (dummy-pair zeroing path)."""
    import repro.core.graph as G

    # star graph: vertex 0 connected to 1..9; vertices 10..63 isolated
    src = np.array([0] * 9 + list(range(1, 10)), dtype=np.int32)
    dst = np.array(list(range(1, 10)) + [0] * 9, dtype=np.int32)
    order = np.lexsort((src, dst))
    g = G.Graph(n=64, src=src[order], dst=dst[order])
    op = prepare_operand(g, block_size=128, edge_chunk=128)
    m = jnp.ones((64, 8), dtype=jnp.float32)
    out = spmm_blocked(op, m, interpret=True)
    ref = spmm_ref(jnp.asarray(g.src), jnp.asarray(g.dst), g.n, m)
    assert _rel_err(out, ref) < 1e-6
    assert float(jnp.abs(out[10:]).max()) == 0.0


@given(
    n=st.integers(min_value=20, max_value=200),
    e=st.integers(min_value=20, max_value=600),
    cols=st.integers(min_value=1, max_value=48),
    seed=st.integers(min_value=0, max_value=99),
)
@settings(max_examples=10, deadline=None)
def test_spmm_blocked_property(n, e, cols, seed):
    g = erdos_renyi_graph(n, e, seed=seed)
    op = prepare_operand(g, block_size=128, edge_chunk=128)
    m = jnp.asarray(np.random.default_rng(seed).standard_normal((g.n, cols)).astype(np.float32))
    ref = spmm_ref(jnp.asarray(g.src), jnp.asarray(g.dst), g.n, m)
    out = spmm_blocked(op, m, interpret=True)
    assert _rel_err(out, ref) < 1e-5


def test_spmm_linearity_property():
    """SpMM(aX + bY) == a SpMM(X) + b SpMM(Y) — kernel is linear."""
    g = rmat_graph(100, 400, seed=2)
    op = prepare_operand(g, block_size=128, edge_chunk=128)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((g.n, 8)).astype(np.float32))
    y = jnp.asarray(rng.standard_normal((g.n, 8)).astype(np.float32))
    lhs = spmm_blocked(op, 2.0 * x + 3.0 * y, interpret=True)
    rhs = 2.0 * spmm_blocked(op, x, interpret=True) + 3.0 * spmm_blocked(op, y, interpret=True)
    assert _rel_err(lhs, rhs) < 1e-4


# ---------------------------------------------------------------------------
# eMA reference (the jnp fused gather-FMA; the eMA-only Pallas kernel was
# removed with kernels/ema — the fused kernels/spmm_ema path is covered by
# tests/test_fused.py)
# ---------------------------------------------------------------------------


def _ema_numpy_oracle(ma, b, idx_a, idx_p):
    n = ma.shape[0]
    n_out, n_splits = idx_a.shape
    out = np.zeros((n, n_out), np.float64)
    for o in range(n_out):
        for t in range(n_splits):
            out[:, o] += np.asarray(ma)[:, idx_a[o, t]].astype(np.float64) * np.asarray(b)[
                :, idx_p[o, t]
            ].astype(np.float64)
    return out


@pytest.mark.parametrize(
    "k,m,m_a,n",
    [
        (5, 3, 1, 100),
        (7, 5, 3, 777),
        (8, 4, 2, 256),
        (6, 6, 3, 333),  # full-size color set (top template)
        (9, 2, 1, 64),
    ],
)
def test_ema_apply_matches_oracle(k, m, m_a, n):
    t = build_split_table(k, m, m_a)
    rng = np.random.default_rng(k * m)
    from repro.core.colorsets import binom

    ma = jnp.asarray(rng.standard_normal((n, binom(k, m_a))).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((n, binom(k, m - m_a))).astype(np.float32))
    ia, ip = jnp.asarray(t.idx_a), jnp.asarray(t.idx_p)
    ref = _ema_numpy_oracle(ma, b, t.idx_a, t.idx_p)
    out = _ema_apply(ma, b, ia, ip)
    assert out.shape == ref.shape == (n, t.n_out)
    assert _rel_err(out, jnp.asarray(ref, jnp.float32)) < 1e-6


# ---------------------------------------------------------------------------
# Full Algorithm 5 running on the Pallas SpMM kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("tname", ["u3", "u5-2", "u6"])
def test_full_dp_on_pallas_kernels(tname):
    g = rmat_graph(96, 380, seed=4)
    t = get_template(tname)
    plan = build_counting_plan(t)
    colors = np.random.default_rng(5).integers(0, t.k, size=g.n)

    from repro.core import spmm_edges

    jnp_spmm = partial(spmm_edges, jnp.asarray(g.src), jnp.asarray(g.dst), g.n)
    ref_total = float(count_colorful_vectorized(plan, jnp.asarray(colors), jnp_spmm))

    op = prepare_operand(g, block_size=128, edge_chunk=128)
    kern_spmm = lambda m: spmm_blocked(op, m, interpret=True)
    kern_total = float(
        count_colorful_vectorized(plan, jnp.asarray(colors), kern_spmm)
    )
    assert kern_total == pytest.approx(ref_total, rel=1e-5)
