"""Tests for tree templates, partitioning, and automorphism counting."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.templates import (
    PAPER_TEMPLATES,
    Template,
    binary_tree_template,
    get_template,
    partition_template,
    path_template,
    random_tree_template,
    star_template,
    tree_automorphisms,
)


def test_known_automorphisms():
    assert tree_automorphisms(path_template(2)) == 2
    assert tree_automorphisms(path_template(5)) == 2
    assert tree_automorphisms(star_template(5)) == 24  # (k-1)!
    assert tree_automorphisms(star_template(7)) == 720
    # "H" tree: path 0-1-2 with leaves 3,4 on 0 and 5,6 on 2 -> 2*2*2 = 8
    h = Template("h", ((0, 1), (1, 2), (0, 3), (0, 4), (2, 5), (2, 6)))
    assert tree_automorphisms(h) == 8
    # single edge center flip
    assert tree_automorphisms(path_template(4)) == 2


def test_partition_structure():
    for name, t in PAPER_TEMPLATES.items():
        if t.k > 14:
            continue
        part = partition_template(t)
        subs = part.subs
        # binary recursion tree over k leaves => 2k-1 sub-templates
        assert len(subs) == 2 * t.k - 1
        # last is the full template
        assert subs[-1].vertices == tuple(range(t.k))
        for i, s in enumerate(subs):
            if s.is_leaf:
                assert s.size == 1
            else:
                a, p = subs[s.active], subs[s.passive]
                assert s.active < i and s.passive < i  # topological order
                assert set(a.vertices) | set(p.vertices) == set(s.vertices)
                assert not (set(a.vertices) & set(p.vertices))
                assert a.root == s.root  # active keeps the root
                assert s.size == a.size + p.size


@given(k=st.integers(min_value=2, max_value=12), seed=st.integers(min_value=0, max_value=10_000))
@settings(max_examples=60, deadline=None)
def test_random_tree_valid_and_partitionable(k, seed):
    t = random_tree_template(k, seed)
    t.validate()
    assert t.k == k
    part = partition_template(t)
    assert len(part.subs) == 2 * k - 1
    assert tree_automorphisms(t) >= 1


def test_get_template_constructors():
    assert get_template("path6").k == 6
    assert get_template("star4").k == 4
    assert get_template("bintree7").k == 7
    assert get_template("u12").k == 12
    with pytest.raises(KeyError):
        get_template("nope")
    for name, t in PAPER_TEMPLATES.items():
        t.validate()
        assert t.name == name
