"""Training-substrate tests: optimizers, checkpoint/restart, fault tolerance,
compression, elastic planning, samplers, data determinism, serving engine."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st


def test_adamw_converges_quadratic():
    from repro.train.optimizer import adamw_init, adamw_update

    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    st_ = adamw_init(params)
    for _ in range(300):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, st_ = adamw_update(g, st_, params, 0.05, weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adafactor_state_is_factored():
    from repro.train.optimizer import adafactor_init

    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((32,))}
    s = adafactor_init(params)
    assert s.row["w"].shape == (64,) and s.col["w"].shape == (32,)
    assert s.row["b"].shape == (32,)


def test_clip_by_global_norm():
    from repro.train.optimizer import clip_by_global_norm

    g = {"a": jnp.ones((10,)) * 10.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(1000.0))
    new_norm = float(jnp.linalg.norm(clipped["a"]))
    assert new_norm == pytest.approx(1.0, rel=1e-5)


def test_checkpoint_roundtrip_and_atomicity():
    from repro.train.checkpoint import restore_latest, save_checkpoint

    with tempfile.TemporaryDirectory() as d:
        tree = {"a": jnp.arange(5.0), "b": {"c": jnp.ones((2, 3))}}
        save_checkpoint(d, 3, tree)
        save_checkpoint(d, 7, jax.tree.map(lambda x: x * 2, tree))
        # a torn write must be ignored
        os.makedirs(os.path.join(d, "step_00000009.tmp"), exist_ok=True)
        restored, manifest = restore_latest(d, tree)
        assert manifest["step"] == 7
        np.testing.assert_allclose(np.asarray(restored["a"]), np.arange(5.0) * 2)


def test_checkpoint_shape_mismatch_raises():
    from repro.train.checkpoint import restore_checkpoint, save_checkpoint

    with tempfile.TemporaryDirectory() as d:
        path = save_checkpoint(d, 1, {"a": jnp.zeros((4,))})
        with pytest.raises(ValueError):
            restore_checkpoint(path, {"a": jnp.zeros((5,))})


def test_async_checkpointer_gc():
    from repro.train.checkpoint import AsyncCheckpointer

    with tempfile.TemporaryDirectory() as d:
        ck = AsyncCheckpointer(d, keep=2)
        for step in (1, 2, 3, 4):
            ck.save(step, {"x": jnp.full((3,), float(step))})
        ck.wait()
        kept = sorted(p for p in os.listdir(d) if p.startswith("step_"))
        assert kept == ["step_00000003", "step_00000004"]


def test_loop_crash_restart_bitexact():
    from repro.train.loop import LoopConfig, TrainLoop

    def train_step(s, b):
        return {"p": s["p"] * 1.5 + b, "n": s["n"] + 1}, {"loss": jnp.sum(s["p"])}

    def data(start):
        def gen():
            i = start
            while True:
                yield jnp.float32(i % 3)
                i += 1
        return gen()

    init = {"p": jnp.ones(()), "n": jnp.zeros(())}
    with tempfile.TemporaryDirectory() as d:
        cfg = LoopConfig(total_steps=20, ckpt_dir=d, ckpt_every=5, log_every=100)
        straight = TrainLoop(cfg, train_step, data, init)
        expected = straight.run()

    with tempfile.TemporaryDirectory() as d:
        cfg = LoopConfig(total_steps=20, ckpt_dir=d, ckpt_every=5, log_every=100)
        loop = TrainLoop(cfg, train_step, data, init)
        loop.inject_fault_at(13)
        with pytest.raises(RuntimeError):
            loop.run()
        loop2 = TrainLoop(cfg, train_step, data, init)
        assert loop2.try_restore() and loop2.step == 10
        resumed = loop2.run()

    np.testing.assert_allclose(np.asarray(resumed["p"]), np.asarray(expected["p"]), rtol=1e-6)
    assert float(resumed["n"]) == 20


def test_straggler_watchdog_raises():
    import time

    from repro.train.loop import LoopConfig, TrainLoop

    calls = {"i": 0}

    def train_step(s, b):
        calls["i"] += 1
        if calls["i"] == 15:
            time.sleep(0.25)
        else:
            time.sleep(0.005)
        return s, {"loss": jnp.zeros(())}

    def data(start):
        def gen():
            while True:
                yield 0.0
        return gen()

    cfg = LoopConfig(total_steps=30, straggler_factor=5.0, straggler_policy="raise", log_every=100)
    loop = TrainLoop(cfg, train_step, data, {"x": jnp.zeros(())})
    with pytest.raises(RuntimeError, match="straggler"):
        loop.run()
    assert loop.straggler_events and loop.straggler_events[0].step == 14


@given(seed=st.integers(min_value=0, max_value=100))
@settings(max_examples=10, deadline=None)
def test_compression_error_feedback_property(seed):
    """sum of decompressed grads -> true sum as steps accumulate (EF property)."""
    from repro.train.compression import compress_with_feedback

    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(256).astype(np.float32))
    res = jnp.zeros_like(x)
    acc = jnp.zeros_like(x)
    n = 16
    for _ in range(n):
        dec, res = compress_with_feedback(x, res, codec="int8")
        acc = acc + dec
    err = float(jnp.abs(acc / n - x).max()) / (float(jnp.abs(x).max()) + 1e-9)
    assert err < 0.02


def test_topk_sparsify():
    from repro.train.compression import topk_sparsify

    x = jnp.asarray(np.random.default_rng(0).standard_normal(1000).astype(np.float32))
    y = topk_sparsify(x, frac=0.05)
    nz = int((y != 0).sum())
    assert 50 <= nz <= 60  # ties allowed
    # surviving entries are the largest magnitudes
    assert float(jnp.abs(y[y != 0]).min()) >= float(jnp.sort(jnp.abs(x))[-60])


def test_elastic_plan_and_reshard():
    from repro.train.elastic import plan_elastic_mesh, survivors_after_failure

    assert plan_elastic_mesh(16, model_parallel=4) == (4, 4)
    assert plan_elastic_mesh(13, model_parallel=4) == (3, 4)  # drops a straggler
    with pytest.raises(ValueError):
        plan_elastic_mesh(3, model_parallel=4)
    devs = list(range(8))
    assert survivors_after_failure(devs, [2, 5]) == [0, 1, 3, 4, 6, 7]


def test_neighbor_sampler_shapes_and_validity():
    from repro.core.graph import rmat_graph
    from repro.models.gnn.sampler import node_flow_to_batch, sample_node_flow

    g = rmat_graph(500, 3000, seed=0)
    row_ptr, col_idx = g.csr()
    seeds = jnp.arange(32)
    flow = sample_node_flow(
        jax.random.PRNGKey(0), jnp.asarray(row_ptr), jnp.asarray(col_idx), seeds, (5, 3)
    )
    assert [x.shape[0] for x in flow.layer_nodes] == [32, 160, 480]
    # every valid sampled neighbor is a real neighbor of its parent
    parents = np.asarray(flow.layer_nodes[0])
    children = np.asarray(flow.layer_nodes[1]).reshape(32, 5)
    valid = np.asarray(flow.layer_valid[1]).reshape(32, 5)
    rp, ci = np.asarray(row_ptr), np.asarray(col_idx)
    for i, p in enumerate(parents):
        nbrs = set(ci[rp[p] : rp[p + 1]].tolist())
        for j in range(5):
            if valid[i, j]:
                assert int(children[i, j]) in nbrs

    batch = node_flow_to_batch(flow, jnp.ones((500, 8)))
    assert batch.n_nodes == 32 + 160 + 480
    assert batch.n_edges == 2 * (160 + 480)


def test_data_pipeline_determinism_and_resume():
    from repro.configs.granite_8b import SMOKE_CONFIG as cfg
    from repro.data.pipeline import token_batches

    a = token_batches(cfg, 2, 16, seed=5, start_step=0)
    b = token_batches(cfg, 2, 16, seed=5, start_step=0)
    t1, _ = next(a)
    t2, _ = next(b)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    # resume semantics: start_step=1 stream matches the second batch
    c = token_batches(cfg, 2, 16, seed=5, start_step=1)
    t1b, _ = next(a)
    t3, _ = next(c)
    np.testing.assert_array_equal(np.asarray(t1b), np.asarray(t3))


def test_serve_engine_matches_offline_greedy():
    import dataclasses

    from repro.configs.granite_8b import SMOKE_CONFIG
    from repro.models import transformer as T
    from repro.serve.engine import Request, ServeEngine

    cfg = dataclasses.replace(SMOKE_CONFIG, n_layers=2)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompts = [np.array([3, 5, 7], np.int32), np.array([11, 2, 9], np.int32)]
    engine = ServeEngine(cfg, params, max_batch=2, max_len=32)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=5) for i, p in enumerate(prompts)]
    engine.run(reqs)
    for req, prompt in zip(reqs, prompts):
        assert len(req.generated) == 5
        # offline greedy reference
        toks = list(prompt)
        for _ in range(5):
            logits, _, _ = T.forward(params, cfg, jnp.asarray(toks, jnp.int32)[None, :])
            toks.append(int(jnp.argmax(logits[0, -1])))
        assert req.generated == toks[len(prompt):], (req.generated, toks[len(prompt):])
