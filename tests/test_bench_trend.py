"""The benchmark harness's trend diff must tolerate imperfect history.

``benchmarks/run.py`` diffs this run's rows against the committed
``BENCH_counting.json``: newly-introduced row keys (a bench module grew
rows, e.g. the non-tree template-scaling entries) and unparsable previous
values (hand-edited files, schema drift) must both degrade to "new row",
never crash the run.
"""

import sys

import benchmarks.run as bench_run
from benchmarks.common import ROWS


def _with_rows(monkeypatch, rows):
    monkeypatch.setattr(bench_run, "ROWS", rows)


def test_trend_tolerates_new_row_keys(monkeypatch, capsys):
    _with_rows(
        monkeypatch,
        [("old/row", 10.0, ""), ("brand/new/row", 5.0, "")],
    )
    prev = {"old/row": {"name": "old/row", "us_per_call": 10.0, "derived": ""}}
    regressions = bench_run.print_trend(prev)
    err = capsys.readouterr().err
    assert regressions == 0
    assert "brand/new/row" in err
    assert "1 new row(s)" in err


def test_trend_tolerates_unparsable_previous_values(monkeypatch, capsys):
    _with_rows(monkeypatch, [("weird/row", 7.0, ""), ("none/row", 3.0, "")])
    prev = {
        "weird/row": {"name": "weird/row", "us_per_call": "not-a-number"},
        "none/row": {"name": "none/row"},  # us_per_call key absent entirely
    }
    regressions = bench_run.print_trend(prev)
    err = capsys.readouterr().err
    assert regressions == 0
    assert "2 new row(s)" in err


def test_trend_still_flags_regressions(monkeypatch, capsys):
    _with_rows(monkeypatch, [("slow/row", 100.0, "")])
    prev = {"slow/row": {"name": "slow/row", "us_per_call": 10.0}}
    assert bench_run.print_trend(prev) == 1
    assert "REGRESSION" in capsys.readouterr().err


def test_trend_zero_baseline_is_not_a_regression(monkeypatch, capsys):
    _with_rows(monkeypatch, [("derived/row", 4.0, "")])
    prev = {"derived/row": {"name": "derived/row", "us_per_call": 0.0}}
    assert bench_run.print_trend(prev) == 0
    assert "n/a" in capsys.readouterr().err


def test_trend_flags_tuner_losing_to_heuristic(monkeypatch, capsys):
    # a tuned config >5% slower than the heuristic (ratio < 0.95) is a
    # tuner regression — flagged even on a baseline run with no history
    _with_rows(
        monkeypatch,
        [("tune/g/t/tuned_vs_heuristic", 10.0, "ratio=0.800;heuristic_us=8.0")],
    )
    assert bench_run.print_trend({}) == 1
    assert "REGRESSION" in capsys.readouterr().err


def test_trend_accepts_tuner_matching_heuristic(monkeypatch, capsys):
    _with_rows(
        monkeypatch,
        [
            ("tune/g/t/tuned_vs_heuristic", 10.0, "ratio=1.080;heuristic_us=10.8"),
            ("tune/g/t/search", 5e6, "lattice=30"),
        ],
    )
    assert bench_run.print_trend({}) == 0
    assert "REGRESSION" not in capsys.readouterr().err
