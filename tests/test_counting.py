"""The exactness chain (paper §VI-H / Fig 14):

brute-force colorful == traversal (Algorithm 2) == vectorized (Algorithm 5),
per coloring; and the multi-iteration estimator converges to the exact
embedding count.
"""

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    brute_force_colorful,
    brute_force_embeddings,
    build_counting_plan,
    count_colorful_traversal,
    count_colorful_vectorized,
    erdos_renyi_graph,
    estimate_embeddings,
    get_template,
    grid_graph,
    normalize_count,
    path_template,
    random_tree_template,
    rmat_graph,
    spmm_edges,
    spmm_ell,
    star_template,
)

TEMPLATES_SMALL = ["u3", "path4", "star4", "u5-1", "u5-2", "u6"]


def _spmm(graph):
    return partial(spmm_edges, jnp.asarray(graph.src), jnp.asarray(graph.dst), graph.n)


@pytest.mark.parametrize("tname", TEMPLATES_SMALL)
@pytest.mark.parametrize(
    "graph",
    [grid_graph(4, 4), erdos_renyi_graph(24, 60, seed=7), rmat_graph(32, 96, seed=5)],
    ids=["grid4x4", "er24", "rmat32"],
)
def test_exactness_chain_per_coloring(tname, graph):
    t = get_template(tname)
    plan = build_counting_plan(t)
    rng = np.random.default_rng(42)
    colors = rng.integers(0, t.k, size=graph.n)

    bf = brute_force_colorful(graph, t, colors)
    trav = count_colorful_traversal(plan, graph, colors) / plan.automorphisms
    vec = float(
        count_colorful_vectorized(plan, jnp.asarray(colors), _spmm(graph))
    ) / plan.automorphisms

    assert trav == pytest.approx(bf, rel=1e-9), "traversal != brute force"
    assert vec == pytest.approx(bf, rel=1e-5), "vectorized != brute force (Fig 14 bound)"


@pytest.mark.parametrize("tname", ["u3", "u5-2", "path5"])
def test_spmm_variants_agree(tname):
    graph = erdos_renyi_graph(40, 120, seed=3)
    t = get_template(tname)
    plan = build_counting_plan(t)
    colors = jnp.asarray(np.random.default_rng(0).integers(0, t.k, size=graph.n))
    v_edges = float(count_colorful_vectorized(plan, colors, _spmm(graph)))
    nbr, mask = graph.ell()
    v_ell = float(
        count_colorful_vectorized(
            plan, colors, partial(spmm_ell, jnp.asarray(nbr), jnp.asarray(mask))
        )
    )
    assert v_ell == pytest.approx(v_edges, rel=1e-5)


def test_estimator_converges_to_exact():
    graph = erdos_renyi_graph(30, 90, seed=11)
    t = get_template("u3")  # small template -> low variance
    exact = brute_force_embeddings(graph, t)
    res = estimate_embeddings(graph, t, iterations=300, seed=0)
    assert res.mean == pytest.approx(exact, rel=0.05)


def test_estimator_unbiased_across_templates():
    graph = grid_graph(5, 5)
    for tname in ["path4", "star4"]:
        t = get_template(tname)
        exact = brute_force_embeddings(graph, t)
        res = estimate_embeddings(graph, t, iterations=400, seed=2)
        # 3-sigma band of the iteration mean
        sem = res.std / np.sqrt(res.iterations)
        assert abs(res.mean - exact) < 4 * sem + 1e-6, (tname, res.mean, exact, sem)


@given(
    n=st.integers(min_value=8, max_value=28),
    e=st.integers(min_value=10, max_value=80),
    k=st.integers(min_value=3, max_value=5),
    seed=st.integers(min_value=0, max_value=1000),
)
@settings(max_examples=15, deadline=None)
def test_property_vectorized_equals_traversal(n, e, k, seed):
    """Property: for ANY random graph/template/coloring, Algorithm 5 == Algorithm 2."""
    graph = erdos_renyi_graph(n, e, seed=seed)
    t = random_tree_template(k, seed=seed + 1)
    plan = build_counting_plan(t)
    colors = np.random.default_rng(seed).integers(0, k, size=n)
    trav = count_colorful_traversal(plan, graph, colors)
    vec = float(count_colorful_vectorized(plan, jnp.asarray(colors), _spmm(graph)))
    assert vec == pytest.approx(trav, rel=1e-5, abs=1e-6)


@given(seed=st.integers(min_value=0, max_value=500))
@settings(max_examples=10, deadline=None)
def test_property_count_invariant_under_vertex_relabeling(seed):
    """Permuting graph vertex ids (and the coloring with them) preserves counts."""
    from repro.core.graph import Graph, _canonicalize

    rng = np.random.default_rng(seed)
    g = erdos_renyi_graph(20, 50, seed=seed)
    perm = rng.permutation(g.n).astype(np.int32)
    g2 = _canonicalize(g.n, perm[g.src], perm[g.dst])
    t = get_template("u5-2")
    plan = build_counting_plan(t)
    colors = rng.integers(0, t.k, size=g.n)
    colors2 = np.empty_like(colors)
    colors2[perm] = colors
    v1 = float(count_colorful_vectorized(plan, jnp.asarray(colors), _spmm(g)))
    v2 = float(count_colorful_vectorized(plan, jnp.asarray(colors2), _spmm(g2)))
    assert v1 == pytest.approx(v2, rel=1e-5)


def test_partition_root_choice_invariance():
    """Any partition root must give the same colorful count (plan property)."""
    graph = erdos_renyi_graph(25, 70, seed=9)
    t = get_template("u6")
    colors = np.random.default_rng(1).integers(0, t.k, size=graph.n)
    vals = []
    for root in range(t.k):
        plan = build_counting_plan(t, root=root)
        vals.append(
            float(count_colorful_vectorized(plan, jnp.asarray(colors), _spmm(graph)))
        )
    assert np.allclose(vals, vals[0], rtol=1e-5)


def test_counts_nonnegative_and_zero_on_empty():
    from repro.core.graph import Graph

    empty = Graph(n=10, src=np.zeros(0, np.int32), dst=np.zeros(0, np.int32))
    t = path_template(3)
    plan = build_counting_plan(t)
    colors = jnp.asarray(np.arange(10) % 3)
    v = float(count_colorful_vectorized(plan, colors, _spmm(empty)))
    assert v == 0.0


@given(seed=st.integers(min_value=0, max_value=300))
@settings(max_examples=10, deadline=None)
def test_property_disjoint_union_additivity(seed):
    """Counts over a disjoint union of two graphs = sum of the counts
    (connectivity property of tree embeddings)."""
    from repro.core.graph import Graph

    g1 = erdos_renyi_graph(14, 30, seed=seed)
    g2 = erdos_renyi_graph(12, 26, seed=seed + 1)
    union = Graph(
        n=g1.n + g2.n,
        src=np.concatenate([g1.src, g2.src + g1.n]),
        dst=np.concatenate([g1.dst, g2.dst + g1.n]),
    )
    t = get_template("u5-2")
    plan = build_counting_plan(t)
    rng = np.random.default_rng(seed)
    c1 = rng.integers(0, t.k, size=g1.n)
    c2 = rng.integers(0, t.k, size=g2.n)
    cu = np.concatenate([c1, c2])
    v1 = float(count_colorful_vectorized(plan, jnp.asarray(c1), _spmm(g1)))
    v2 = float(count_colorful_vectorized(plan, jnp.asarray(c2), _spmm(g2)))
    vu = float(count_colorful_vectorized(plan, jnp.asarray(cu), _spmm(union)))
    assert vu == pytest.approx(v1 + v2, rel=1e-5, abs=1e-6)


def test_path_counting_known_closed_form():
    """Complete graph K_n: # of k-paths = C(n,k) * k!/2 — exact check."""
    from itertools import combinations
    from math import comb, factorial
    from repro.core.graph import _canonicalize

    n, k = 9, 4
    pairs = np.array(list(combinations(range(n), 2)), dtype=np.int32)
    g = _canonicalize(n, pairs[:, 0], pairs[:, 1])
    exact = comb(n, k) * factorial(k) // 2
    t = path_template(k)
    assert brute_force_embeddings(g, t) == pytest.approx(exact)
