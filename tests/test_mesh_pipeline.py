"""Pipelined ring collectives: bit-exactness and fault-replay acceptance
(4/8 host devices via subprocess — the test process itself must keep the
default single-device view).

The acceptance bar for the ring pipeline (docs/distributed.md "The ring
pipeline"): on 4- AND 8-virtual-device meshes, ``mesh_comm="pipelined"``
produces counts **bit-exact** (``np.array_equal``, not allclose) against
``mesh_comm="blocking"`` for the u5–u12 template class — both modes fold
the same per-source-shard bucket partial segment-sums in the same ring
order, so no intermediate rounding ever differs.  Under a seeded
collective :class:`~repro.testing.faults.FaultPlan`, the pipelined path
re-uses the PR 8 ``collective`` injection site once per ring step, and the
whole failure schedule replays exactly: same seed, same fires, same
surviving counts.
"""

import os
import subprocess
import sys

import pytest

# subprocess smokes over virtual devices: the slow check.sh lane
pytestmark = pytest.mark.slow

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_child(code: str, devices: int, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["REPRO_DEVICES"] = str(devices)
    env.pop("REPRO_MESH_COMM", None)  # the tests set modes explicitly
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        timeout=timeout,
    )
    assert proc.returncode == 0, (
        f"child failed:\nstdout={proc.stdout}\nstderr={proc.stderr}"
    )
    return proc.stdout


@pytest.mark.parametrize("devices", [4, 8])
def test_pipelined_bit_exact_vs_blocking(devices):
    """Pipelined counts are np.array_equal to blocking counts — same seed
    folds, same fold order — for u5-1/u7/u10/u12 on D virtual devices,
    through both the fixed-coloring and the batched PRNG-key paths."""
    out = _run_child(
        r"""
import os
import jax, jax.numpy as jnp, numpy as np
from repro.core import CountingEngine, get_template, rmat_graph

D = int(os.environ["REPRO_DEVICES"])
g = rmat_graph(60 * D, 300 * D, seed=5)
mesh = jax.make_mesh((D,), ("dev",))
keys = jax.random.split(jax.random.PRNGKey(1), 4)
for tname in ("u5-1", "u7", "u10", "u12"):
    t = get_template(tname)
    colors = np.random.default_rng(3).integers(0, t.k, size=g.n)
    block = CountingEngine(g, [t], backend="mesh", mesh=mesh, column_batch=8,
                           chunk_size=2, mesh_comm="blocking")
    ring = CountingEngine(g, [t], backend="mesh", mesh=mesh, column_batch=8,
                          chunk_size=2, mesh_comm="pipelined")
    assert ring.backend_impl.comm == "pipelined", ring.backend_impl.describe_comm()
    assert block.backend_impl.comm == "blocking"
    a = np.asarray(block.raw_counts(colors))
    b = np.asarray(ring.raw_counts(colors))
    assert np.array_equal(a, b), (tname, a, b)
    ka = np.asarray(block.count_keys(keys))
    kb = np.asarray(ring.count_keys(keys))
    assert np.array_equal(ka, kb), (tname, ka, kb)
    print("EXACT", tname)

# the comm plan is visible in describe(): mode, source, per-stage schedule
d = ring.describe()
comm = d["comm"]
assert comm["mode"] == "pipelined" and comm["source"] == "explicit"
assert comm["collective_dispatches"] == D
assert all(s["ring_steps"] == D for s in comm["schedule"])
print("DESCRIBE_OK", len(comm["schedule"]))
"""
        , devices
    )
    assert out.count("EXACT") == 4
    assert "DESCRIBE_OK" in out


@pytest.mark.parametrize("devices", [4, 8])
def test_pipelined_fault_schedule_replays_exactly(devices):
    """Under a seeded collective FaultPlan the pipelined path visits the
    ``collective`` site once per ring step, the fire schedule replays
    bit-for-bit across identically-seeded runs (same fires_by_site, same
    per-visit fire log), and the counts that survive are bit-exact."""
    out = _run_child(
        r"""
import os
import jax, jax.numpy as jnp, numpy as np
from repro.core import CountingEngine, get_template, rmat_graph
from repro.testing.faults import FaultPlan, FaultSpec, TransientFault

D = int(os.environ["REPRO_DEVICES"])
g = rmat_graph(60 * D, 300 * D, seed=5)
mesh = jax.make_mesh((D,), ("dev",))
t = get_template("u7")
keys = jax.random.split(jax.random.PRNGKey(1), 2)

def run(comm):
    # count_keys_chunk is the serving increment — the fault seams fire at
    # its Python launch boundary (count_keys wraps everything in one jit)
    eng = CountingEngine(g, [t], backend="mesh", mesh=mesh, column_batch=8,
                         chunk_size=2, mesh_comm=comm)
    eng.count_keys_chunk(keys)  # warm: compile outside the fault window
    plan = FaultPlan(
        [FaultSpec(site="collective", kind="transient", rate=0.7, max_fires=3)],
        seed=11,
    )
    outcomes, counts = [], None
    with plan:
        for attempt in range(8):  # retry-until-clean, like the scheduler
            try:
                counts = np.asarray(eng.count_keys_chunk(keys))
                outcomes.append("ok")
                break
            except TransientFault:
                outcomes.append("fault")
    return counts, outcomes, plan.fires_by_site(), plan.describe()

c1, o1, f1, d1 = run("pipelined")
c2, o2, f2, d2 = run("pipelined")
assert f1 == f2, (f1, f2)                      # identical fires_by_site
assert o1 == o2, (o1, o2)                      # identical outcome sequence
assert [s["fire_log"] for s in d1] == [s["fire_log"] for s in d2]
assert c1 is not None and np.array_equal(c1, c2)
assert 1 <= f1["collective"] <= 3, f1  # fired, then the run went clean
assert o1.count("fault") == f1["collective"]
print("REPLAY_OK", o1.count("fault"))

# and once the faults are spent, blocking converges to identical counts
cb, ob, fb, db = run("blocking")
assert cb is not None and np.array_equal(c1, cb)

# the ring multiplies the site's visit count: D dispatches per chunk
# launch vs the blocking path's one.  A never-firing spec (huge ``after``)
# still counts every eligible visit, so a clean launch measures the pure
# dispatch multiplicity: D ring steps vs 1.
def visits(comm):
    eng = CountingEngine(g, [t], backend="mesh", mesh=mesh, column_batch=8,
                         chunk_size=2, mesh_comm=comm)
    plan = FaultPlan(
        [FaultSpec(site="collective", kind="transient", after=10**6)], seed=0
    )
    with plan:
        eng.count_keys_chunk(keys)
    return plan.describe()[0]["visits"]

ring_visits, block_visits = visits("pipelined"), visits("blocking")
assert ring_visits == D, (ring_visits, D)
assert block_visits == 1, block_visits
print("VISITS_OK", ring_visits, block_visits)
"""
        , devices
    )
    assert "REPLAY_OK" in out
    assert "VISITS_OK" in out
