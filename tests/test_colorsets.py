"""Unit + property tests for combinadic indexing and split tables."""

import itertools

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.colorsets import (
    binom,
    build_split_table,
    colorful_probability,
    enumerate_subsets,
    rank_subsets,
    unrank_subsets,
)


def test_binom_matches_math():
    import math

    for n in range(0, 15):
        for r in range(0, n + 1):
            assert binom(n, r) == math.comb(n, r)
    assert binom(5, 7) == 0
    assert binom(3, -1) == 0


@pytest.mark.parametrize("k,m", [(5, 2), (7, 3), (8, 4), (10, 1), (6, 6), (9, 0)])
def test_enumerate_rank_roundtrip(k, m):
    subsets = enumerate_subsets(k, m)
    assert subsets.shape == (binom(k, m), m)
    ranks = rank_subsets(subsets)
    # enumerate returns colex order == identity ranks
    np.testing.assert_array_equal(ranks, np.arange(binom(k, m)))
    if m > 0:
        back = unrank_subsets(ranks, k, m)
        np.testing.assert_array_equal(back, subsets)


@given(
    k=st.integers(min_value=2, max_value=10),
    data=st.data(),
)
@settings(max_examples=50, deadline=None)
def test_rank_is_bijection_property(k, data):
    m = data.draw(st.integers(min_value=1, max_value=k))
    subsets = enumerate_subsets(k, m)
    ranks = rank_subsets(subsets)
    assert len(set(ranks.tolist())) == binom(k, m)
    assert ranks.min() == 0 and ranks.max() == binom(k, m) - 1


@pytest.mark.parametrize("k,m,m_a", [(5, 3, 1), (7, 5, 3), (8, 4, 2), (6, 6, 3), (9, 2, 1)])
def test_split_table_completeness(k, m, m_a):
    """Every (C_s, split) must decompose into disjoint subsets that union to C_s."""
    t = build_split_table(k, m, m_a)
    assert t.n_out == binom(k, m)
    assert t.n_splits == binom(m, m_a)
    sets_m = enumerate_subsets(k, m)
    sets_a = enumerate_subsets(k, m_a)
    sets_p = enumerate_subsets(k, m - m_a)
    for out in range(min(t.n_out, 40)):
        full = set(sets_m[out].tolist())
        seen_splits = set()
        for s in range(t.n_splits):
            a = set(sets_a[t.idx_a[out, s]].tolist())
            p = set(sets_p[t.idx_p[out, s]].tolist())
            assert a | p == full
            assert not (a & p)
            seen_splits.add(frozenset(a))
        # all C(m, m_a) distinct active subsets appear exactly once
        assert len(seen_splits) == t.n_splits


def test_colorful_probability():
    import math

    for k in range(1, 12):
        assert colorful_probability(k) == pytest.approx(math.factorial(k) / k**k, rel=1e-12)
