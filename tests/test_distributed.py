"""Distributed runtime tests (8 host devices via subprocess — the test
process itself must keep the default single-device view)."""

import json
import os
import subprocess
import sys

import pytest

# subprocess smokes over 8 virtual devices: the slow check.sh lane
pytestmark = pytest.mark.slow

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_child(code: str, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env, timeout=timeout
    )
    assert proc.returncode == 0, f"child failed:\nstdout={proc.stdout}\nstderr={proc.stderr}"
    return proc.stdout


def test_distributed_count_matches_single_device():
    out = _run_child(
        r"""
import jax, jax.numpy as jnp, numpy as np
from repro.compat import set_mesh, shard_map
from functools import partial
from repro.core import (build_counting_plan, count_colorful_vectorized, get_template,
                        rmat_graph, spmm_edges)
from repro.core.distributed import shard_graph, make_distributed_count_fn

mesh = jax.make_mesh((2, 4), ("data", "model"))
g = rmat_graph(600, 3000, seed=2)
t = get_template("u6")
plan = build_counting_plan(t)
sg = shard_graph(g, 8)
fn = make_distributed_count_fn(plan, mesh, sg.n_padded, sg.edges_per_shard, column_batch=8)
colors = np.random.default_rng(1).integers(0, t.k, size=sg.n_padded).astype(np.int32)
with set_mesh(mesh):
    dist = float(fn(jnp.asarray(colors), jnp.asarray(sg.src), jnp.asarray(sg.dst_local),
                    jnp.asarray(sg.edge_mask)))
ref = float(count_colorful_vectorized(plan, jnp.asarray(colors[:g.n]),
    partial(spmm_edges, jnp.asarray(g.src), jnp.asarray(g.dst), g.n)))
assert abs(dist - ref) / max(abs(ref), 1e-9) < 1e-5, (dist, ref)
print("MATCH", dist, ref)
"""
    )
    assert "MATCH" in out


def test_distributed_count_balance_degrees():
    out = _run_child(
        r"""
import jax, jax.numpy as jnp, numpy as np
from repro.compat import set_mesh, shard_map
from functools import partial
from repro.core import (build_counting_plan, count_colorful_vectorized, get_template,
                        rmat_graph, spmm_edges)
from repro.core.distributed import shard_graph, make_distributed_count_fn

mesh = jax.make_mesh((8,), ("data",))
g = rmat_graph(400, 4000, seed=3, a=0.7, b=0.12, c=0.12)  # skewed
t = get_template("u5-2")
plan = build_counting_plan(t)
sg_plain = shard_graph(g, 8)
sg_bal = shard_graph(g, 8, balance_degrees=True)
# round-robin balancing reduces the max per-shard edge padding on skewed graphs
print("PLAIN", sg_plain.edges_per_shard, "BAL", sg_bal.edges_per_shard)
assert sg_bal.edges_per_shard < sg_plain.edges_per_shard, (
    sg_bal.edges_per_shard, sg_plain.edges_per_shard)
colors_g = np.random.default_rng(0).integers(0, t.k, size=g.n).astype(np.int32)
ref = float(count_colorful_vectorized(plan, jnp.asarray(colors_g),
    partial(spmm_edges, jnp.asarray(g.src), jnp.asarray(g.dst), g.n)))
# balanced partition must count the same (after scattering colors with the
# recorded vertex relabeling; new ids live in [0, n_padded))
colors_bal = np.zeros(sg_bal.n_padded, np.int32)
colors_bal[sg_bal.perm] = colors_g  # color follows the vertex relabeling
fn = make_distributed_count_fn(plan, mesh, sg_bal.n_padded, sg_bal.edges_per_shard, column_batch=8)
with set_mesh(mesh):
    dist = float(fn(jnp.asarray(colors_bal), jnp.asarray(sg_bal.src),
                    jnp.asarray(sg_bal.dst_local), jnp.asarray(sg_bal.edge_mask)))
assert abs(dist - ref) / max(abs(ref), 1e-9) < 1e-5, (dist, ref)
print("MATCH")
"""
    )
    assert "MATCH" in out


def test_streamed_ema_equals_baseline():
    """Beyond-paper fusion (streamed eMA) must be bit-compatible with the
    paper-faithful batched Algorithm 5 (EXPERIMENTS.md §Perf, paper core)."""
    out = _run_child(
        r"""
import jax, jax.numpy as jnp, numpy as np
from repro.compat import set_mesh, shard_map
from repro.core import build_counting_plan, get_template, rmat_graph
from repro.core.distributed import make_distributed_count_fn, shard_graph

mesh = jax.make_mesh((2, 4), ("data", "model"))
g = rmat_graph(500, 2500, seed=1)
t = get_template("u7")
plan = build_counting_plan(t)
sg = shard_graph(g, 8)
colors = jnp.asarray(np.random.default_rng(0).integers(0, t.k, size=sg.n_padded))
args = (colors, jnp.asarray(sg.src), jnp.asarray(sg.dst_local), jnp.asarray(sg.edge_mask))
f_base = make_distributed_count_fn(plan, mesh, sg.n_padded, sg.edges_per_shard, column_batch=8)
f_str = make_distributed_count_fn(plan, mesh, sg.n_padded, sg.edges_per_shard,
                                  column_batch=8, ema_mode="streamed")
with set_mesh(mesh):
    base = float(f_base(*args))
    streamed = float(f_str(*args))
assert abs(base - streamed) / max(abs(base), 1e-9) < 1e-6, (base, streamed)
print("STREAMED_MATCH", base)
"""
    )
    assert "STREAMED_MATCH" in out


def test_moe_ep_shard_map_matches_dense_path():
    """EP shard_map MoE == the single-device scatter path when capacity is
    ample (per-shard routing is identical for identical tokens)."""
    out = _run_child(
        r"""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.compat import set_mesh, shard_map
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.configs import dbrx_132b
from repro.models import layers as L

cfg = dataclasses.replace(dbrx_132b.SMOKE_CONFIG, capacity_factor=float(dbrx_132b.SMOKE_CONFIG.n_experts))
mesh = jax.make_mesh((2, 4), ("data", "model"))
key = jax.random.PRNGKey(0)
params = L.init_moe(key, cfg)
x = jax.random.normal(jax.random.fold_in(key, 1), (4, 16, cfg.d_model), jnp.float32)
ref, aux_ref = L.moe_apply(params, cfg, x)  # single-device scatter path

act_spec = P("data", "model", None)

def param_sharding(a):
    spec = P("model", None, None) if a.ndim == 3 else P(*([None] * a.ndim))
    return NamedSharding(mesh, spec)

with set_mesh(mesh):
    params_d = jax.device_put(params, jax.tree.map(param_sharding, params))
    x_d = jax.device_put(x, NamedSharding(mesh, act_spec))
    @jax.jit
    def f(p, xx):
        return L.moe_apply(p, cfg, xx, act_spec=act_spec)
    out, aux = f(params_d, x_d)
err = float(jnp.max(jnp.abs(out - ref)))
print("EP_ERR", err)
assert err < 1e-4, err
"""
    )
    assert "EP_ERR" in out


def test_compressed_psum_preserves_mean():
    out = _run_child(
        r"""
import jax, jax.numpy as jnp, numpy as np
from repro.compat import set_mesh, shard_map
from functools import partial
from jax.sharding import PartitionSpec as P
from repro.train.compression import compressed_psum

mesh = jax.make_mesh((8,), ("data",))
def f(x, res):
    return compressed_psum(x, ("data",), res)
g = shard_map(f, mesh=mesh, in_specs=(P("data"), P("data")), out_specs=(P("data"), P("data")))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((8, 64)).astype(np.float32))
res = jnp.zeros_like(x)
with set_mesh(mesh):
    mean, new_res = g(x, res)
true_mean = np.asarray(x).mean(0)
got = np.asarray(mean)[0]
err = np.abs(got - true_mean).max() / (np.abs(true_mean).max() + 1e-9)
assert err < 0.05, err  # int8 quantization error bound
print("OK", err)
"""
    )
    assert "OK" in out


def test_lm_pjit_train_step_on_mesh():
    """End-to-end sharded LM train step on a (2, 4) host mesh."""
    out = _run_child(
        r"""
import dataclasses
import jax, jax.numpy as jnp
from repro.compat import set_mesh
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.configs import granite_8b
from repro.models import transformer as T
from repro.train.optimizer import adamw_init, adamw_update

cfg = dataclasses.replace(granite_8b.SMOKE_CONFIG, n_heads=8, n_kv_heads=4, scan_layers=True)
mesh = jax.make_mesh((2, 4), ("data", "model"))
params = T.init_params(jax.random.PRNGKey(0), cfg)
pspecs = T.param_pspecs(cfg, model_size=4)
with set_mesh(mesh):
    params = jax.device_put(params, jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                                                 is_leaf=lambda x: isinstance(x, P)))
    opt = adamw_init(params)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size)
    tokens = jax.device_put(tokens, NamedSharding(mesh, P("data", None)))

    @jax.jit
    def step(params, opt, tokens):
        loss, grads = jax.value_and_grad(T.loss_fn)(params, cfg, tokens, tokens, P("data", "model", None))
        params, opt = adamw_update(grads, opt, params, 1e-3)
        return params, opt, loss

    l0 = None
    for i in range(3):
        params, opt, loss = step(params, opt, tokens)
        l0 = l0 or float(loss)
    assert float(loss) < l0, (float(loss), l0)
print("TRAINED", float(loss))
"""
    )
    assert "TRAINED" in out
