"""Flash attention Pallas kernel vs pure-jnp oracle (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def _mk(b, sq, sk, h, h_kv, d, dtype=np.float32, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, sq, h, d)).astype(dtype))
    k = jnp.asarray(rng.standard_normal((b, sk, h_kv, d)).astype(dtype))
    v = jnp.asarray(rng.standard_normal((b, sk, h_kv, d)).astype(dtype))
    return q, k, v


def _ref(q, k, v, causal):
    b, sq, h, d = q.shape
    group = h // k.shape[2]
    kk = jnp.repeat(k, group, axis=2)
    vv = jnp.repeat(v, group, axis=2)
    to_bh = lambda x: x.transpose(0, 2, 1, 3).reshape(b * h, x.shape[1], d)
    out = attention_ref(to_bh(q), to_bh(kk), to_bh(vv), causal=causal)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize(
    "b,sq,sk,h,h_kv,d,bq,bk",
    [
        (2, 128, 128, 4, 4, 64, 64, 64),      # MHA square
        (1, 256, 256, 4, 2, 64, 128, 64),     # GQA
        (2, 128, 256, 8, 1, 32, 64, 128),     # MQA, rectangular (kv longer)
        (1, 192, 192, 2, 2, 64, 64, 64),      # non-power-of-two seq (pads)
    ],
)
def test_flash_attention_shapes(causal, b, sq, sk, h, h_kv, d, bq, bk):
    if causal and sq != sk:
        pytest.skip("causal requires aligned positions in this harness")
    q, k, v = _mk(b, sq, sk, h, h_kv, d)
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk, interpret=True)
    ref = _ref(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    q, k, v = _mk(1, 128, 128, 2, 2, 64)
    qb, kb, vb = (x.astype(jnp.bfloat16) for x in (q, k, v))
    out = flash_attention(qb, kb, vb, causal=True, block_q=64, block_k=64, interpret=True)
    ref = _ref(q, k, v, True)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref)))
    assert err < 2e-2, err  # bf16 tolerance


def test_flash_attention_matches_model_sdpa():
    """Cross-check against the model's chunked-SDPA implementation."""
    from repro.models.layers import _sdpa_chunked

    q, k, v = _mk(2, 128, 128, 4, 2, 64, seed=3)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64, interpret=True)
    ref = _sdpa_chunked(q, k, v, jnp.arange(128), None, causal=True, q_chunk=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_flash_backend_matches_sdpa_in_model():
    """cfg.attn_impl="flash" must reproduce the sdpa forward end-to-end."""
    import dataclasses

    from repro.configs.granite_8b import SMOKE_CONFIG
    from repro.models import transformer as T

    cfg = dataclasses.replace(SMOKE_CONFIG, n_layers=2, attn_q_chunk=128)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 128), 0, cfg.vocab_size)
    ref, _, _ = T.forward(params, cfg, tokens)
    flash_cfg = dataclasses.replace(cfg, attn_impl="flash")
    out, _, _ = T.forward(params, flash_cfg, tokens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_flash_attention_batch_permutation_invariance():
    """Permuting the batch permutes outputs identically (no cross-batch leak)."""
    q, k, v = _mk(4, 128, 128, 2, 2, 64, seed=9)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64, interpret=True)
    perm = jnp.asarray([2, 0, 3, 1])
    out_p = flash_attention(q[perm], k[perm], v[perm], causal=True, block_q=64, block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(out[perm]), np.asarray(out_p), rtol=1e-6, atol=1e-6)


def test_flash_attention_softmax_rows_convex():
    """Output rows are convex combinations of V rows: bounded by V extrema."""
    q, k, v = _mk(1, 128, 128, 1, 1, 32, seed=4)
    out = flash_attention(q, k, v, causal=False, block_q=64, block_k=64, interpret=True)
    vmin = float(v.min())
    vmax = float(v.max())
    assert float(out.min()) >= vmin - 1e-5 and float(out.max()) <= vmax + 1e-5
