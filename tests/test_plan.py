"""TemplatePlan IR invariants (property tests; hypothesis fallback OK).

The plan layer's contract with the executors, pinned over u3-u10 and
random trees:

* the liveness peak never exceeds the naive in-place plan bound (sharing
  can only help) and never undershoots the widest single stage;
* every exec-group member's active state is live at the leader's position
  (the group executes there, so inputs must already exist and must not
  have been freed);
* plan equality implies identical ``engine_cache_key`` (the plan IS the
  template half of the key);
* the schedule is executable: a symbolic walk never reads a freed or
  not-yet-computed state, and every plan's root is live at its read.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import engine_cache_key, get_template, rmat_graph
from repro.core.counting import build_counting_plan
from repro.core.templates import random_tree_template
from repro.plan.ir import build_template_plan, template_set_canons

U_TEMPLATES = ["u3", "u5-1", "u5-2", "u6", "u7", "u10"]

#: same-k groups for multi-template plans
SAME_K_SETS = [
    ["u5-1", "u5-2"],
    ["path6", "star6", "bintree6", "u6"],
    ["path7", "star7", "u7"],
]


def _coexistence_floor(plan) -> int:
    """Columns that MUST coexist at some stage: output + distinct children
    (``max_stage_columns`` double-counts a child read twice, e.g. u3's two
    leaf children are ONE shared canonical state — the Pallas staging
    figure wants that, a liveness lower bound does not)."""
    floor = 1
    for s in plan.stages:
        if s.is_leaf:
            continue
        cols = s.columns + s.active_columns
        if s.passive_canon != s.active_canon:
            cols += s.passive_columns
        floor = max(floor, cols)
    return floor


def _simulate(plan):
    """Walk the schedule exactly like an executor: returns the sequence of
    (position, live-set-before-free) snapshots and asserts basic sanity."""
    live = set()
    executed = set()
    snapshots = []
    pos = 0
    for p_idx, cplan in enumerate(plan.counting_plans):
        pc = plan.canons[p_idx]
        for i, sub in enumerate(cplan.partition.subs):
            if pc[i] in executed:
                continue
            executed.add(pc[i])
            if not sub.is_leaf:
                # inputs must be computed and still live
                assert pc[sub.active] in live, (pos, "active freed or missing")
                assert pc[sub.passive] in live, (pos, "passive freed or missing")
            live.add(pc[i])
            snapshots.append((pos, frozenset(live)))
            for dead in plan.free_at.get(pos, ()):
                live.discard(dead)
            pos += 1
        root_canon = pc[cplan.partition.root_index]
        assert root_canon in live, "plan root freed before its read"
        snapshots.append((pos, frozenset(live)))
        for dead in plan.free_at.get(pos, ()):
            live.discard(dead)
        pos += 1
    assert pos == plan.num_positions
    return snapshots


# ---------------------------------------------------------------------------
# Liveness peak bounds
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("tname", U_TEMPLATES)
def test_liveness_peak_le_plan_bound_u3_to_u10(tname):
    """Single template: the IR's liveness peak is sandwiched between the
    widest single stage and the per-plan in-place bound."""
    cplan = build_counting_plan(get_template(tname))
    plan = build_template_plan([get_template(tname)], plans=[cplan])
    assert plan.peak_columns <= cplan.peak_columns()
    assert plan.peak_columns >= _coexistence_floor(plan)


@pytest.mark.parametrize("names", SAME_K_SETS)
def test_multi_template_peak_le_sum_of_plan_bounds(names):
    """Shared schedules only ever help: the multi-template peak never
    exceeds the sum of the independent per-plan bounds."""
    templates = [get_template(n) for n in names]
    plan = build_template_plan(templates)
    naive = sum(p.peak_columns() for p in plan.counting_plans)
    assert plan.peak_columns <= naive
    assert plan.peak_columns >= _coexistence_floor(plan)


@settings(max_examples=15, deadline=None)
@given(k=st.integers(min_value=3, max_value=10), seed=st.integers(0, 2**16))
def test_liveness_peak_bounds_random_trees(k, seed):
    """Arbitrary trees: canonical sharing holds a state live from its
    first computation to its LAST duplicate read, where the in-place
    executor recomputes (and quickly re-frees) each duplicate — so the
    liveness peak may exceed the naive bound by at most the width of the
    within-plan duplicated canons (it trades that residency for strictly
    fewer stage computations).  The strict ``peak <= plan bound`` of the
    u3-u10 test only holds when no duplicate spans the widest region."""
    from collections import Counter

    from repro.core.colorsets import binom

    t = random_tree_template(k, seed=seed, name=f"rt{k}-{seed}")
    cplan = build_counting_plan(t)
    plan = build_template_plan([t], plans=[cplan])
    counts = Counter(plan.canons[0])
    dup_allowance = sum(
        binom(k, len(sub.vertices))
        for i, sub in enumerate(cplan.partition.subs)
        if counts[plan.canons[0][i]] > 1 and plan.stage_at(0, i) is not None
    )
    assert plan.peak_columns <= cplan.peak_columns() + dup_allowance
    assert plan.peak_columns >= _coexistence_floor(plan)
    _simulate(plan)


# ---------------------------------------------------------------------------
# Exec-group validity
# ---------------------------------------------------------------------------


def _assert_groups_valid(plan):
    """Every member's active is computed AND still live at the leader's
    position, and every member reads the leader's passive canon."""
    live_at = dict(_simulate(plan))
    for (lp, li), members in plan.exec_groups.items():
        leader_stage = plan.stage_at(lp, li)
        assert leader_stage is not None and not leader_stage.is_leaf
        assert members[0] == (lp, li), "leader must come first"
        live = live_at[leader_stage.position]
        for q, j in members:
            sub = plan.counting_plans[q].partition.subs[j]
            assert plan.canons[q][sub.passive] == leader_stage.passive_canon
            assert plan.canons[q][sub.active] in live, (
                f"member ({q},{j}) active not live at leader position "
                f"{leader_stage.position}"
            )


@pytest.mark.parametrize("names", SAME_K_SETS + [[n] for n in U_TEMPLATES])
def test_exec_group_actives_live_at_leader(names):
    _assert_groups_valid(build_template_plan([get_template(n) for n in names]))


@settings(max_examples=10, deadline=None)
@given(
    k=st.integers(min_value=4, max_value=9),
    s1=st.integers(0, 2**10),
    s2=st.integers(0, 2**10),
    s3=st.integers(0, 2**10),
)
def test_exec_groups_valid_random_multi_template(k, s1, s2, s3):
    templates = [
        random_tree_template(k, seed=s, name=f"rt{k}-{s}-{i}")
        for i, s in enumerate((s1, s2, s3))
    ]
    _assert_groups_valid(build_template_plan(templates))


# ---------------------------------------------------------------------------
# Plan equality => identical engine_cache_key
# ---------------------------------------------------------------------------


def test_plan_equality_implies_identical_cache_key():
    """Two independently built plans over the same template set are equal,
    and equal plans yield byte-identical engine cache keys."""
    g = rmat_graph(300, 1500, seed=2)
    for names in SAME_K_SETS:
        templates_a = [get_template(n) for n in names]
        templates_b = [get_template(n) for n in names]
        pa, pb = build_template_plan(templates_a), build_template_plan(templates_b)
        assert pa == pb and hash(pa) == hash(pb)
        assert pa.schedule_key() == pb.schedule_key()
        ka = engine_cache_key(g, templates_a, backend="edges")
        kb = engine_cache_key(g, templates_b, backend="edges")
        assert ka == kb


@settings(max_examples=15, deadline=None)
@given(
    k=st.integers(min_value=3, max_value=9),
    seed_a=st.integers(0, 64),
    seed_b=st.integers(0, 64),
)
def test_plan_equality_implies_cache_key_random(k, seed_a, seed_b):
    """The implication direction, over random tree pairs (some coincide,
    some differ): plans equal => cache keys equal; plans unequal => the
    template halves of the keys differ."""
    g = rmat_graph(120, 500, seed=1)
    ta = random_tree_template(k, seed=seed_a, name="a")
    tb = random_tree_template(k, seed=seed_b, name="b")
    pa, pb = build_template_plan([ta]), build_template_plan([tb])
    ka = engine_cache_key(g, [ta], backend="edges")
    kb = engine_cache_key(g, [tb], backend="edges")
    if pa == pb:
        assert ka == kb  # names differ, schedules agree -> same compiled engine
    else:
        assert ka != kb


def test_canons_are_label_free():
    """template_set_canons (the key's template half) ignores names and
    equals the plan IR's canons."""
    t = get_template("u6")
    plan = build_template_plan([t])
    assert template_set_canons([t]) == plan.canons


# ---------------------------------------------------------------------------
# Schedule executability + engine integration
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("names", SAME_K_SETS)
def test_schedule_executes_without_dangling_reads(names):
    _simulate(build_template_plan([get_template(n) for n in names]))


# ---------------------------------------------------------------------------
# Cost model: fusion-slack calibration
# ---------------------------------------------------------------------------


def test_fusion_slack_defaults_to_one_without_bench_rows(tmp_path, caplog):
    """Missing file, unparsable file, and row-free file all fall back to
    the safe 1.0 (the uncalibrated analytic model)."""
    import json
    import logging

    from repro.plan.cost import load_fusion_slack

    with caplog.at_level(logging.DEBUG, logger="repro.plan"):
        assert load_fusion_slack(str(tmp_path / "missing.json")) == 1.0
        empty = tmp_path / "empty.json"
        empty.write_text(json.dumps({"rows": []}))
        assert load_fusion_slack(str(empty)) == 1.0
        junk = tmp_path / "junk.json"
        junk.write_text("not json at all")
        assert load_fusion_slack(str(junk)) == 1.0


def test_fusion_slack_calibration_applied_and_logged(tmp_path, caplog):
    """memory_model rows calibrate the factor (geometric mean, raw-ratio
    fixed point via applied_fusion_slack) and the application is logged on
    the repro.plan logger."""
    import json
    import logging
    import math

    from repro.plan.cost import load_fusion_slack

    bench = tmp_path / "bench.json"
    bench.write_text(
        json.dumps(
            {
                "rows": [
                    {
                        "name": "engine/g/u5/memory_model",
                        "derived": "predicted_over_actual=0.900",
                    },
                    {
                        "name": "engine/g/u6/memory_model",
                        # calibrated row: raw ratio = 1.000 * 0.8 = 0.8
                        "derived": "predicted_over_actual=1.000;"
                        "applied_fusion_slack=0.8",
                    },
                    {"name": "engine/g/u6/batched64", "derived": "speedup=3x"},
                ]
            }
        )
    )
    with caplog.at_level(logging.INFO, logger="repro.plan"):
        got = load_fusion_slack(str(bench))
    assert got == pytest.approx(math.sqrt(0.9 * 0.8))
    assert any("fusion-slack calibration applied" in r.message for r in caplog.records)


def test_picker_applies_slack_to_bytes():
    """slack < 1 (model under-predicts) inflates the effective bytes and
    can only shrink the picked chunk; slack = 1 is the identity."""
    from repro.core import CountingEngine
    from repro.plan.cost import CostModel

    g = rmat_graph(2048, 20_000, seed=1)
    eng = CountingEngine(g, [get_template("u6")])
    raw = (
        eng.backend_impl.transient_elements() + eng.backend_impl.resident_elements()
    ) * eng.cost.itemsize
    identity = CostModel(eng.plan_ir, g, fusion_slack=1.0)
    halved = CostModel(eng.plan_ir, g, fusion_slack=0.5)
    t, r = eng.backend_impl.transient_elements(), eng.backend_impl.resident_elements()
    assert identity.bytes_per_coloring(t, r) == raw
    assert halved.bytes_per_coloring(t, r) == 2 * raw
    budget = 32 * 1024 * 1024
    assert halved.pick_chunk_size(halved.bytes_per_coloring(t, r), budget) <= (
        identity.pick_chunk_size(identity.bytes_per_coloring(t, r), budget)
    )
    # out-of-band factors are rejected, not silently clamped
    with pytest.raises(ValueError, match="fusion_slack"):
        CostModel(eng.plan_ir, g, fusion_slack=4.0)


def test_engine_binds_the_plan_it_was_given():
    """The façade derives its public figures from the bound plan."""
    from repro.core import CountingEngine

    g = rmat_graph(300, 1500, seed=2)
    templates = [get_template(n) for n in ("path6", "u6")]
    plan = build_template_plan(templates)
    eng = CountingEngine(g, templates)
    assert eng.plan_ir == plan
    assert eng.peak_columns() == plan.peak_columns
    assert eng._canons == plan.canons
    assert eng._exec_groups == plan.exec_groups
    # counts are unchanged by the planning indirection (vs per-template runs)
    colors = np.random.default_rng(0).integers(0, 6, size=g.n)
    multi = eng.raw_counts(colors)
    for ti, t in enumerate(templates):
        single = CountingEngine(g, [t]).raw_counts(colors)[0]
        assert float(multi[ti]) == pytest.approx(float(single), rel=1e-6)
