"""Deterministic concurrency suite for the async serving front-end.

Everything scheduler-related runs on the **fake-clock + single-stepped
seam** (`ManualClock` + `ServiceFrontend.step()`): no sleeps, no wall-clock
races — every admission decision, launch, and completion is reproducible.
The only genuinely multi-threaded tests are the ones whose *subject* is threading
(bit-exact concurrent submission, the EngineCache hammer), and those assert
on order-independent facts.

The whole module is the check.sh "concurrency lane": it runs under a
per-test timeout (`pytest-timeout`, or the conftest SIGALRM fallback) so a
scheduler deadlock fails fast instead of hanging tier-1.
"""

import functools
import random
import threading
from concurrent.futures import CancelledError

import numpy as np
import pytest

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import get_template, rmat_graph
from repro.plan.cost import admission_estimate
from repro.serve import (
    CountingService,
    EngineCache,
    ManualClock,
    QoSRejected,
    ServiceFrontend,
    TokenBucket,
)

pytestmark = [pytest.mark.concurrency, pytest.mark.timeout(300)]

CHUNK = 4
GRAPHS = {"a": (200, 900, 2), "b": (180, 700, 3)}


@functools.lru_cache(maxsize=None)
def _graph(name):
    n, e, s = GRAPHS[name]
    return rmat_graph(n, e, seed=s)


def _service(**kw):
    kw.setdefault("chunk_size", CHUNK)
    svc = CountingService(**kw)
    for name in GRAPHS:
        svc.register_graph(name, _graph(name))
    return svc


def _frontend(**fe_kw):
    """Manual-mode frontend on a fresh service; returns (svc, fe, clock)."""
    svc_kw = fe_kw.pop("svc_kw", {})
    clock = fe_kw.pop("clock", None) or ManualClock()
    svc = _service(**svc_kw)
    fe = ServiceFrontend(svc, clock=clock, **fe_kw)
    return svc, fe, clock


# one shared serial oracle: plain synchronous CountingService queries,
# memoized — the ground truth every concurrent/interleaved run must match
_ORACLE_SVC = None
_ORACLE_CACHE = {}


def _oracle(gname, tname, seed, iterations):
    global _ORACLE_SVC
    key = (gname, tname, seed, iterations)
    if key not in _ORACLE_CACHE:
        if _ORACLE_SVC is None:
            _ORACLE_SVC = _service()
        ests = _ORACLE_SVC.query(gname, tname, iterations=iterations, seed=seed)
        _ORACLE_CACHE[key] = tuple(e.mean for e in ests)
    return _ORACLE_CACHE[key]


# ---------------------------------------------------------------------------
# QoS primitives (pure fake-clock units)
# ---------------------------------------------------------------------------


def test_token_bucket_refill_is_clock_driven():
    clock = ManualClock()
    bucket = TokenBucket(rate=2.0, burst=3.0, clock=clock)
    assert [bucket.try_acquire() for _ in range(3)] == [True, True, True]
    assert not bucket.try_acquire()  # drained, clock frozen
    clock.advance(0.5)  # +1 token
    assert bucket.try_acquire() and not bucket.try_acquire()
    clock.advance(10.0)  # refill caps at burst
    assert bucket.available() == pytest.approx(3.0)


def test_manual_clock_never_moves_on_its_own():
    clock = ManualClock(start=5.0)
    assert clock.now() == clock.now() == 5.0
    assert clock.advance(1.5) == 6.5
    with pytest.raises(ValueError):
        clock.advance(-1)


# ---------------------------------------------------------------------------
# Futures API basics (single-stepped)
# ---------------------------------------------------------------------------


def test_submit_returns_future_immediately_and_resolves_on_drain():
    _, fe, _ = _frontend()
    fut = fe.submit("t0", "a", "u3", iterations=4, seed=1)
    assert not fut.done() and fut.state == "queued"
    assert fe.stats()["service"]["launches"] == 0  # nothing ran yet
    snap = fut.progress()
    assert snap[0].status == "queued" and snap[0].iterations == 0
    fe.drain()
    assert fut.done() and not fut.cancelled()
    means = tuple(e.mean for e in fut.result(timeout=0))
    assert means == _oracle("a", "u3", 1, 4)


def test_result_timeout_raises_when_not_driven():
    _, fe, _ = _frontend()
    fut = fe.submit("t0", "a", "u3", iterations=2)
    with pytest.raises(TimeoutError):
        fut.result(timeout=0.01)


def test_cancel_queued_future_before_any_round():
    _, fe, _ = _frontend()
    keep = fe.submit("t0", "a", "u3", iterations=4, seed=1)
    drop = fe.submit("t0", "a", "u3", iterations=4, seed=2)
    assert drop.cancel()
    assert drop.cancelled() and not drop.cancel()  # second cancel is a no-op
    with pytest.raises(CancelledError):
        drop.result(timeout=0)
    fe.drain()
    assert tuple(e.mean for e in keep.result(0)) == _oracle("a", "u3", 1, 4)
    assert fe.stats()["tenants"]["t0"]["cancelled"] == 1


def test_cancel_running_query_conserves_other_results():
    svc, fe, _ = _frontend()
    victim = fe.submit("t0", "a", "u5-1", epsilon=1e-6, iterations=64, seed=7)
    bystander = fe.submit("t1", "a", "u5-1", iterations=8, seed=3)
    fe.step()
    fe.step()
    assert victim.state == "admitted" and victim.iterations > 0
    assert victim.cancel()
    with pytest.raises(CancelledError):
        victim.result(timeout=0)
    rounds = fe.drain()
    assert rounds < 64  # the cancelled budget is NOT drained
    # the co-batched bystander's values are untouched by the cancellation
    assert tuple(e.mean for e in bystander.result(0)) == _oracle("a", "u5-1", 3, 8)
    assert svc.stats()["queries_cancelled"] == 1


# ---------------------------------------------------------------------------
# Streaming progress
# ---------------------------------------------------------------------------


def test_streaming_progress_monotone_with_both_ci_bounds():
    _, fe, _ = _frontend()
    # epsilon far beyond reach: runs its full 24-iteration budget (6 rounds)
    fut = fe.submit("t0", "a", "u5-1", epsilon=1e-9, iterations=24, seed=5)
    seen_iters = [fut.progress()[0].iterations]
    seen_done = [fut.done()]
    for _ in range(10):
        fe.step()
        p = fut.progress()[0]
        seen_iters.append(p.iterations)
        seen_done.append(fut.done())
        if p.iterations >= 2:
            # a real interval around the running mean, under BOTH bounds
            assert p.lower <= p.mean <= p.upper
            assert np.isfinite(p.halfwidth_normal) and np.isfinite(
                p.halfwidth_bernstein
            )
            # empirical-Bernstein is strictly the more conservative CI
            assert p.halfwidth_bernstein >= p.halfwidth_normal
    # iterations only ever grow; done is absorbing
    assert seen_iters == sorted(seen_iters)
    assert seen_iters[-1] == 24
    first_done = seen_done.index(True)
    assert all(seen_done[first_done:])
    assert fut.progress()[0].status == "done"


def test_progress_mean_converges_to_final_result():
    _, fe, _ = _frontend()
    fut = fe.submit("t0", "a", "u3", iterations=8, seed=2)
    fe.drain()
    final = fut.result(0)[0]
    p = fut.progress()[0]
    assert p.mean == final.mean and p.iterations == 8


# ---------------------------------------------------------------------------
# Fairness / priority / rate limits (the QoS core, fully deterministic)
# ---------------------------------------------------------------------------


def test_cold_tenant_not_starved_by_flooding_tenant():
    _, fe, _ = _frontend()
    hot = [
        fe.submit("hot", "a", "u5-1", iterations=8, seed=100 + i) for i in range(12)
    ]
    cold = fe.submit("cold", "b", "u3", iterations=4, seed=1)
    rounds = 0
    while not cold.done():
        fe.step()
        rounds += 1
        assert rounds <= 6, "cold tenant starved by the flooding tenant"
    # the flood was still in flight when the cold query finished — the cold
    # tenant did NOT have to wait for the hot backlog to drain
    assert not all(f.done() for f in hot)
    assert cold.resolved_round is not None and cold.resolved_round <= 6
    fe.drain()
    assert tuple(e.mean for e in cold.result(0)) == _oracle("b", "u3", 1, 4)


def test_priority_tier_admits_first_under_scarce_budget():
    svc = _service()
    one_query_bytes = svc.admission_bytes("a", "u5-1")
    fe = ServiceFrontend(
        svc, clock=ManualClock(), admission_budget_bytes=one_query_bytes
    )
    fe.register_tenant("low", priority=0)
    fe.register_tenant("high", priority=5)
    low = fe.submit("low", "a", "u5-1", iterations=4, seed=1)  # submitted FIRST
    high = fe.submit("high", "a", "u5-1", iterations=4, seed=2)
    fe.drain()
    # only one query's bytes fit at a time: the higher tier went first even
    # though it was submitted second
    assert high.admitted_round < low.admitted_round
    assert high.resolved_round <= low.resolved_round
    for fut, seed in ((high, 2), (low, 1)):
        assert tuple(e.mean for e in fut.result(0)) == _oracle("a", "u5-1", seed, 4)


def test_round_robin_within_tier_splits_admissions_evenly():
    _, fe, _ = _frontend()
    futs = {
        t: [fe.submit(t, "a", "u3", iterations=4, seed=i) for i in range(4)]
        for t in ("t0", "t1", "t2")
    }
    info = fe.step()
    admitted_tenants = [name for name, _ in info["admitted"]]
    # one admission per tenant per round — nobody doubles up within a round
    assert sorted(admitted_tenants) == ["t0", "t1", "t2"]
    fe.drain()
    for t in futs:
        for i, f in enumerate(futs[t]):
            assert tuple(e.mean for e in f.result(0)) == _oracle("a", "u3", i, 4)


def test_rate_limit_admissions_follow_the_fake_clock():
    _, fe, clock = _frontend()
    fe.register_tenant("limited", rate_qps=1.0, burst=1.0)
    futs = [fe.submit("limited", "a", "u3", iterations=4, seed=i) for i in range(4)]
    admitted = lambda: fe.stats()["tenants"]["limited"]["admitted"]  # noqa: E731
    fe.step()
    assert admitted() == 1  # the burst token
    for _ in range(5):  # frozen clock => zero refill, however many rounds
        fe.step()
    assert admitted() == 1
    clock.advance(1.0)
    fe.step()
    assert admitted() == 2  # exactly one token accrued
    clock.advance(10.0)  # refill caps at burst=1, not 10 tokens
    fe.step()
    assert admitted() == 3
    clock.advance(1.0)
    fe.drain()
    assert admitted() == 4
    for i, f in enumerate(futs):
        assert tuple(e.mean for e in f.result(0)) == _oracle("a", "u3", i, 4)


# ---------------------------------------------------------------------------
# Backpressure / load shedding
# ---------------------------------------------------------------------------


def test_queue_cap_rejects_with_backpressure():
    _, fe, _ = _frontend()
    fe.register_tenant("t0", max_pending=2)
    fe.submit("t0", "a", "u3", iterations=2)
    fe.submit("t0", "a", "u3", iterations=2)
    with pytest.raises(QoSRejected) as exc:
        fe.submit("t0", "a", "u3", iterations=2)
    assert exc.value.reason == "queue_full"
    stats = fe.stats()
    assert stats["rejections"]["queue_full"] == 1
    assert stats["tenants"]["t0"]["rejected"] == 1
    # other tenants are unaffected by t0's cap
    fe.submit("t1", "a", "u3", iterations=2)
    fe.drain()


def test_cost_model_sheds_queries_that_can_never_fit():
    svc = _service()
    fe = ServiceFrontend(svc, clock=ManualClock(), admission_budget_bytes=1)
    with pytest.raises(QoSRejected) as exc:
        fe.submit("t0", "a", "u5-1", iterations=2)
    assert exc.value.reason == "over_budget"
    assert fe.stats()["rejections"]["over_budget"] == 1


def test_admission_budget_caps_inflight_bytes_not_throughput():
    svc = _service()
    one = svc.admission_bytes("a", "u5-1")
    fe = ServiceFrontend(svc, clock=ManualClock(), admission_budget_bytes=one)
    # 8 iterations at chunk=4 => two launches, so a query stays in flight
    # across a round boundary and the inflight peak is observable
    futs = [fe.submit("t0", "a", "u5-1", iterations=8, seed=i) for i in range(3)]
    peak = 0
    rounds = 0
    while not all(f.done() for f in futs):
        fe.step()
        peak = max(peak, fe.stats()["inflight_bytes"])
        rounds += 1
        assert rounds < 100
    assert 0 < peak <= one  # never more than one query's bytes resident
    for i, f in enumerate(futs):
        assert tuple(e.mean for e in f.result(0)) == _oracle("a", "u5-1", i, 8)


def test_admission_estimate_plan_vs_warm_engine():
    g = _graph("a")
    est = admission_estimate(g, [get_template("u5-1")], chunk_size=CHUNK)
    assert est.resident_bytes > 0 and est.chunk_bytes == est.resident_bytes * CHUNK
    svc = _service()
    cold = svc.admission_bytes("a", "u5-1")
    assert cold == est.chunk_bytes  # cold path = the plan-layer estimate
    svc.query("a", "u5-1", iterations=2)  # warms the engine
    warm = svc.admission_bytes("a", "u5-1")
    # the warm engine's figure includes the backend transient too, so it
    # can only be at least the plan-level resident-only admission price
    assert warm >= cold


# ---------------------------------------------------------------------------
# Warming + zero-retrace acceptance
# ---------------------------------------------------------------------------


def test_prewarm_compiles_off_the_query_path_and_dedupes():
    svc, fe, _ = _frontend()
    key = fe.prewarm("a", "u5-1")
    assert fe.prewarm("a", "u5-1") == key  # queued once
    assert fe.stats()["warm"] == {"queued": 1, "completed": 0}
    info = fe.step()
    assert info["warmed"] == key
    assert fe.stats()["warm"] == {"queued": 0, "completed": 1}
    assert svc.engine(key) is not None and svc.engine(key).trace_count >= 1
    fe.prewarm("a", "u5-1")  # already warm: no new queue entry
    assert fe.stats()["warm"] == {"queued": 0, "completed": 1}


def test_warm_concurrent_queries_trace_zero_new_programs():
    svc, fe, _ = _frontend()
    key = fe.prewarm("a", "u5-1")
    fe.step()
    engine = svc.engine(key)
    traces = engine.trace_count
    futs = [
        fe.submit(f"t{i % 2}", "a", "u5-1", iterations=6, seed=i) for i in range(6)
    ]
    fe.drain()
    assert engine.trace_count == traces, "a warm concurrent query re-traced"
    assert svc.engine(key) is engine
    for i, f in enumerate(futs):
        assert tuple(e.mean for e in f.result(0)) == _oracle("a", "u5-1", i, 6)


# ---------------------------------------------------------------------------
# Bit-exactness: concurrent submission vs the serial oracle (acceptance)
# ---------------------------------------------------------------------------


@pytest.mark.timeout(600)
def test_concurrent_submission_bit_exact_vs_serial_16_threads():
    svc = _service()
    fe = ServiceFrontend(svc)
    jobs = [
        ("a" if i % 2 else "b", "u3" if i % 3 else "u5-1", i % 5, 5)
        for i in range(32)
    ]
    results = {}
    lock = threading.Lock()

    def worker(wid):
        for j in range(wid, len(jobs), 16):
            gname, tname, seed, iters = jobs[j]
            fut = fe.submit(f"tenant{wid % 4}", gname, tname, iterations=iters, seed=seed)
            means = tuple(e.mean for e in fut.result(timeout=300))
            with lock:
                results[j] = means

    with fe:
        threads = [threading.Thread(target=worker, args=(w,)) for w in range(16)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

    assert len(results) == len(jobs)
    for j, (gname, tname, seed, iters) in enumerate(jobs):
        assert results[j] == _oracle(gname, tname, seed, iters), (
            f"job {j} diverged from the serial oracle under 16-thread submission"
        )
    # duplicated (graph, template, seed) jobs agreed with each other too
    # (implied by the oracle equality above, asserted for the error message)
    by_shape = {}
    for j, shape in enumerate(jobs):
        by_shape.setdefault(shape, set()).add(results[j])
    assert all(len(v) == 1 for v in by_shape.values())


# ---------------------------------------------------------------------------
# EngineCache under concurrent hammering (the PR's lock fix)
# ---------------------------------------------------------------------------


def test_engine_cache_hammered_from_threads_keeps_counters_consistent():
    cache = EngineCache(capacity=3)
    keys = [f"k{i}" for i in range(6)]
    builds = []
    build_lock = threading.Lock()
    ops_per_thread = 400
    n_threads = 8

    def factory(key):
        def build():
            with build_lock:
                builds.append(key)
            return object()

        return build

    def hammer(tid):
        rng = random.Random(tid)
        for _ in range(ops_per_thread):
            key = rng.choice(keys)
            assert cache.get(key, factory(key)) is not None
            if rng.random() < 0.1:
                cache.peek(key)
                cache.keys()

    threads = [threading.Thread(target=hammer, args=(t,)) for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    c = cache.counters()
    assert c["hits"] + c["misses"] == n_threads * ops_per_thread
    assert c["misses"] == len(builds)  # every miss built exactly once
    assert c["size"] <= c["capacity"]
    assert c["evictions"] == len(builds) - c["size"]


def test_engine_cache_raising_factory_never_poisons_the_miss_path():
    """A factory that raises must leave NO entry behind: a poisoned
    placeholder would be served to every later hit of that key forever.
    Hammered from threads with factories that fail ~half the time, every
    failure propagates, every eventual success is the real object, and the
    counters reconcile exactly."""
    cache = EngineCache(capacity=4)
    keys = [f"k{i}" for i in range(5)]
    outcomes = []  # ("built" | "raised", key) in build order, lock-held
    state_lock = threading.Lock()

    def factory(key, should_fail):
        def build():
            with state_lock:
                if should_fail():
                    outcomes.append(("raised", key))
                    raise RuntimeError(f"flaky build of {key}")
                outcomes.append(("built", key))
            return ("engine", key)

        return build

    n_threads, ops = 8, 300

    def hammer(tid):
        rng = random.Random(1000 + tid)
        for _ in range(ops):
            key = rng.choice(keys)
            fails_now = rng.random() < 0.5
            try:
                got = cache.get(key, factory(key, lambda: fails_now))
            except RuntimeError:
                assert cache.peek(key) is None or cache.peek(key) == (
                    "engine",
                    key,
                ), "a failed build left a poisoned entry behind"
            else:
                assert got == ("engine", key)

    threads = [threading.Thread(target=hammer, args=(t,)) for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    c = cache.counters()
    raised = sum(1 for kind, _ in outcomes if kind == "raised")
    built = sum(1 for kind, _ in outcomes if kind == "built")
    assert raised > 0 and built > 0  # both paths actually exercised
    assert c["build_failures"] == raised
    assert c["misses"] == raised + built  # every miss either built or raised
    assert c["hits"] + c["misses"] == n_threads * ops
    # after the dust settles, a clean rebuild works for every key
    for key in keys:
        assert cache.get(key, factory(key, lambda: False)) == ("engine", key)


# ---------------------------------------------------------------------------
# Property/stress: random interleavings of submit/cancel/step (satellite)
# ---------------------------------------------------------------------------

_STRESS_TEMPLATES = ("u3", "path4")


@settings(max_examples=4, deadline=None)
@given(st.integers(min_value=0, max_value=10**6))
def test_random_interleavings_never_deadlock_or_drop_queries(seed):
    rng = random.Random(seed)
    svc = _service()
    clock = ManualClock()
    fe = ServiceFrontend(svc, clock=clock)
    fe.register_tenant("t0", priority=rng.randint(0, 2))
    fe.register_tenant("t1", priority=rng.randint(0, 2))
    fe.register_tenant("t2", rate_qps=2.0, burst=2.0)  # one rate-limited tenant
    live, cancelled, expected = [], set(), {}

    for _ in range(rng.randint(10, 28)):
        op = rng.random()
        if op < 0.55:
            gname = rng.choice(list(GRAPHS))
            tname = rng.choice(_STRESS_TEMPLATES)
            iters = rng.randint(2, 6)
            qseed = rng.randint(0, 4)
            fut = fe.submit(
                f"t{rng.randint(0, 2)}", gname, tname, iterations=iters, seed=qseed
            )
            live.append(fut)
            expected[id(fut)] = (gname, tname, qseed, iters)
        elif op < 0.7 and live:
            fut = rng.choice(live)
            if fut.cancel():
                cancelled.add(id(fut))
        elif op < 0.9:
            fe.step()
        else:
            clock.advance(rng.uniform(0.1, 1.5))

    # no deadlock: bounded drive loop finishes every future (rate-limited
    # work needs the clock to move, so advance alongside the stepping)
    for _ in range(500):
        if not fe._unresolved():
            break
        fe.step()
        clock.advance(0.5)
    assert fe._unresolved() == 0, "stress drive loop failed to converge"

    # no query dropped: every future resolved exactly one way, and every
    # non-cancelled result conserves the serial oracle's answer
    for fut in live:
        assert fut.done()
        if id(fut) in cancelled:
            assert fut.cancelled()
            with pytest.raises(CancelledError):
                fut.result(timeout=0)
        else:
            gname, tname, qseed, iters = expected[id(fut)]
            assert tuple(e.mean for e in fut.result(0)) == _oracle(
                gname, tname, qseed, iters
            )
    stats = fe.stats()["tenants"]
    total = {k: sum(s[k] for s in stats.values()) for k in ("submitted", "admitted")}
    assert total["submitted"] == len(live)
    assert total["admitted"] >= len(live) - len(cancelled)
