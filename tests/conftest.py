"""Test bootstrap: make ``src`` importable and soften optional deps.

``hypothesis`` is an *optional* dev dependency (requirements-dev.txt): when
it is missing, a fixed-seed fallback implementing the subset the suite uses
is installed so all modules still collect and run.
"""

import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

try:
    import hypothesis  # noqa: F401  (the real package always wins)
except ImportError:
    from repro.testing import hypothesis_fallback

    hypothesis_fallback.install()
