"""Test bootstrap: make ``src`` importable and soften optional deps.

``hypothesis`` is an *optional* dev dependency (requirements-dev.txt): when
it is missing, a fixed-seed fallback implementing the subset the suite uses
is installed so all modules still collect and run.
"""

import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

try:
    import hypothesis

    _USING_HYPOTHESIS_FALLBACK = getattr(hypothesis, "__is_repro_fallback__", False)
except ImportError:
    from repro.testing import hypothesis_fallback

    hypothesis_fallback.install()
    _USING_HYPOTHESIS_FALLBACK = True


def pytest_report_header(config):
    return (
        "hypothesis: fixed-seed repro fallback (property tests run 10-20 "
        "deterministic examples)"
        if _USING_HYPOTHESIS_FALLBACK
        else "hypothesis: real package"
    )


def pytest_configure(config):
    # fast/slow split: `-m "not slow"` is the quick tier-1 lane in
    # scripts/check.sh; the multi-process mesh smokes run behind `-m slow`
    config.addinivalue_line(
        "markers",
        "slow: multi-process / virtual-device subprocess tests (run via "
        "`pytest -m slow`; excluded from the fast check.sh lane)",
    )
