"""Test bootstrap: make ``src`` importable and soften optional deps.

``hypothesis`` is an *optional* dev dependency (requirements-dev.txt): when
it is missing, a fixed-seed fallback implementing the subset the suite uses
is installed so all modules still collect and run.

``pytest-timeout`` is likewise optional: the concurrency lane
(tests/test_frontend.py) runs under per-test timeouts so a scheduler
deadlock fails fast instead of hanging tier-1.  When the real plugin is
absent, a minimal SIGALRM-based fallback honors ``@pytest.mark.timeout(N)``
and ``--timeout=N`` on POSIX main threads — enough to turn a deadlock into
a loud failure with a traceback.
"""

import os
import signal
import sys
import threading

import pytest

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

try:
    import hypothesis

    _USING_HYPOTHESIS_FALLBACK = getattr(hypothesis, "__is_repro_fallback__", False)
except ImportError:
    from repro.testing import hypothesis_fallback

    hypothesis_fallback.install()
    _USING_HYPOTHESIS_FALLBACK = True

try:
    import pytest_timeout  # noqa: F401  (the real plugin takes over fully)

    _HAVE_PYTEST_TIMEOUT = True
except ImportError:
    _HAVE_PYTEST_TIMEOUT = False


def pytest_report_header(config):
    lines = [
        "hypothesis: fixed-seed repro fallback (property tests run 10-20 "
        "deterministic examples)"
        if _USING_HYPOTHESIS_FALLBACK
        else "hypothesis: real package"
    ]
    if not _HAVE_PYTEST_TIMEOUT:
        lines.append(
            "pytest-timeout: SIGALRM fallback (honors @pytest.mark.timeout "
            "and --timeout)"
        )
    return lines


def pytest_addoption(parser):
    if not _HAVE_PYTEST_TIMEOUT:
        parser.addoption(
            "--timeout",
            action="store",
            default=None,
            type=float,
            help="per-test timeout in seconds (SIGALRM fallback for the "
            "absent pytest-timeout plugin)",
        )


def pytest_configure(config):
    # fast/slow split: `-m "not slow"` is the quick tier-1 lane in
    # scripts/check.sh; the multi-process mesh smokes run behind `-m slow`
    config.addinivalue_line(
        "markers",
        "slow: multi-process / virtual-device subprocess tests (run via "
        "`pytest -m slow`; excluded from the fast check.sh lane)",
    )
    config.addinivalue_line(
        "markers",
        "concurrency: deterministic scheduler / threading tests "
        "(tests/test_frontend.py); check.sh runs them as their own lane "
        "under a per-test timeout so a deadlock fails fast",
    )
    config.addinivalue_line(
        "markers",
        "chaos: seeded fault-injection tests (tests/test_faults.py); "
        "check.sh runs them as their own lane with a fixed "
        "REPRO_FAULT_SEED under a per-test timeout",
    )
    if not _HAVE_PYTEST_TIMEOUT:
        config.addinivalue_line(
            "markers",
            "timeout(seconds): per-test timeout (SIGALRM fallback when "
            "pytest-timeout is not installed)",
        )


def _fallback_timeout_for(item):
    marker = item.get_closest_marker("timeout")
    if marker is not None and (marker.args or "timeout" in marker.kwargs):
        return float(marker.kwargs.get("timeout", marker.args[0] if marker.args else 0))
    opt = item.config.getoption("--timeout", default=None)
    return float(opt) if opt else None


@pytest.hookimpl(wrapper=True)
def pytest_runtest_call(item):
    # only when the real plugin is missing, on a POSIX main thread (SIGALRM
    # interrupts even a lock wait there, which is exactly the deadlock case
    # this guards)
    timeout = None
    if (
        not _HAVE_PYTEST_TIMEOUT
        and hasattr(signal, "SIGALRM")
        and threading.current_thread() is threading.main_thread()
    ):
        timeout = _fallback_timeout_for(item)
    if not timeout or timeout <= 0:
        return (yield)

    def _on_alarm(signum, frame):
        raise TimeoutError(
            f"{item.nodeid} exceeded the {timeout:g}s per-test timeout "
            f"(fallback pytest-timeout)"
        )

    previous = signal.signal(signal.SIGALRM, _on_alarm)
    signal.setitimer(signal.ITIMER_REAL, timeout)
    try:
        return (yield)
    finally:
        signal.setitimer(signal.ITIMER_REAL, 0)
        signal.signal(signal.SIGALRM, previous)
