"""Non-tree (bag-compiled) template counting, pinned against an oracle.

The bag pipeline's correctness contract, checked end to end:

* an INDEPENDENT brute-force oracle (ordered-tuple enumeration over vertex
  permutations — no code shared with ``repro.core.counting``) must agree
  per-coloring and bit-tight with the engine's raw colorful totals for
  triangle / square / diamond / cliques / 5-graphlets on small random
  graphs, across the ``edges`` and ``sell`` backends;
* plan equality implies engine-cache-key equality across BOTH plan
  families (label-permuted graphlets share one schedule);
* tree decompositions satisfy the textbook properties (vertex/edge cover,
  running intersection, width floors);
* the graphlet-profile service query runs warm with zero new traces;
* ``required_iterations`` is generic over k-vertex templates (k!/k^k).
"""

import itertools

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.counting import brute_force_colorful, build_counting_plan
from repro.core.engine import CountingEngine, engine_cache_key
from repro.core.estimator import required_iterations
from repro.core.graph import erdos_renyi_graph
from repro.core.templates import (
    Template,
    build_bag_program,
    build_tree_decomposition,
    connected_graphlets,
    get_template,
    graph_automorphisms,
)
from repro.plan.ir import build_template_plan

# ---------------------------------------------------------------------------
# The oracle: ordered-tuple enumeration, independent of repro.core.counting
# ---------------------------------------------------------------------------


def oracle_colorful_injective(graph, template, colors) -> int:
    """# injective colorful homomorphisms = |Aut| * colorful embeddings.

    Enumerates every ordered k-tuple of distinct vertices and checks all
    template edges plus colorfulness directly — O(n^k), fine for n <= 9.
    """
    k = template.k
    adj = set()
    for u, v in zip(graph.src, graph.dst):
        adj.add((int(u), int(v)))
    count = 0
    for tup in itertools.permutations(range(graph.n), k):
        if len({int(colors[v]) for v in tup}) != k:
            continue
        if all((tup[a], tup[b]) in adj for a, b in template.edges):
            count += 1
    return count


GRAPHS = [
    erdos_renyi_graph(7, 16, seed=1),
    erdos_renyi_graph(8, 22, seed=2),
    erdos_renyi_graph(9, 30, seed=5),
]

NON_TREE_NAMES = ["triangle", "square", "diamond", "clique4"]
FIVE_GRAPHLETS = [t for t in connected_graphlets(5) if not t.is_tree][:4]


# ---------------------------------------------------------------------------
# Golden per-coloring equality: engine == oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", NON_TREE_NAMES)
@pytest.mark.parametrize("backend", ["edges", "sell"])
def test_engine_matches_oracle_per_coloring(name, backend):
    t = get_template(name)
    rng = np.random.default_rng(11)
    hits = 0
    for g in GRAPHS:
        eng = CountingEngine(g, t, backend=backend)
        colors = rng.integers(0, t.k, size=(6, g.n))
        raw = np.asarray(eng.backend_impl.counts_for_colors(jnp.asarray(colors)))
        for b in range(colors.shape[0]):
            exact = oracle_colorful_injective(g, t, colors[b])
            assert raw[b, 0] == pytest.approx(exact, rel=1e-5, abs=1e-5)
            hits += exact > 0
    assert hits > 0, "test graphs too sparse — no colorful hit exercised"


@pytest.mark.parametrize("template", FIVE_GRAPHLETS, ids=lambda t: t.name)
def test_five_graphlets_match_oracle(template):
    rng = np.random.default_rng(13)
    g = GRAPHS[2]
    eng = CountingEngine(g, template, backend="edges")
    colors = rng.integers(0, 5, size=(8, g.n))
    raw = np.asarray(eng.backend_impl.counts_for_colors(jnp.asarray(colors)))
    for b in range(colors.shape[0]):
        exact = oracle_colorful_injective(g, template, colors[b])
        assert raw[b, 0] == pytest.approx(exact, rel=1e-5, abs=1e-5)


def test_oracle_agrees_with_core_brute_force():
    """The in-repo brute force (used by other suites) matches the
    independent oracle through the |Aut| normalization."""
    rng = np.random.default_rng(3)
    for name in NON_TREE_NAMES:
        t = get_template(name)
        g = GRAPHS[0]
        colors = rng.integers(0, t.k, size=g.n)
        assert oracle_colorful_injective(g, t, colors) == pytest.approx(
            brute_force_colorful(g, t, colors) * graph_automorphisms(t)
        )


def test_mixed_tree_and_bag_one_engine():
    """One engine serving a tree and a non-tree of the same k (the
    graphlet-profile shape): both columns match the oracle."""
    g = GRAPHS[1]
    path3, tri = get_template("u3"), get_template("triangle")
    eng = CountingEngine(g, [path3, tri], backend="edges")
    rng = np.random.default_rng(7)
    colors = rng.integers(0, 3, size=(6, g.n))
    raw = np.asarray(eng.backend_impl.counts_for_colors(jnp.asarray(colors)))
    for b in range(colors.shape[0]):
        assert raw[b, 0] == pytest.approx(
            oracle_colorful_injective(g, path3, colors[b]), rel=1e-5
        )
        assert raw[b, 1] == pytest.approx(
            oracle_colorful_injective(g, tri, colors[b]), rel=1e-5
        )


# ---------------------------------------------------------------------------
# Template / decomposition structure
# ---------------------------------------------------------------------------


def test_connected_graphlet_counts():
    assert [len(connected_graphlets(k)) for k in (2, 3, 4, 5)] == [1, 2, 6, 21]


def test_connected_graphlets_valid_and_distinct():
    for k in (3, 4, 5):
        ts = connected_graphlets(k)
        for t in ts:
            t.validate()
            assert t.k == k
        assert len({t.edge_set() for t in ts}) == len(ts)


@pytest.mark.parametrize(
    "name,width",
    [("triangle", 2), ("square", 2), ("diamond", 2), ("clique4", 3), ("clique5", 4)],
)
def test_decomposition_width(name, width):
    assert build_tree_decomposition(get_template(name)).width == width


def test_decomposition_textbook_properties():
    for t in connected_graphlets(5):
        dec = build_tree_decomposition(t)
        # vertex + edge cover
        assert set().union(*dec.bags) == set(range(t.k))
        for u, v in t.edges:
            assert any(u in b and v in b for b in dec.bags)
        # running intersection: bags containing v form a connected subtree
        for v in range(t.k):
            holding = [i for i, b in enumerate(dec.bags) if v in b]
            seen = {holding[0]}
            frontier = [holding[0]]
            holding_set = set(holding)
            while frontier:
                i = frontier.pop()
                for j in holding:
                    if j in seen:
                        continue
                    if dec.parent[j] == i or dec.parent[i] == j:
                        seen.add(j)
                        frontier.append(j)
            assert seen == holding_set, (t.name, v)


def test_tree_bag_program_shares_ahu_canons():
    """A tree compiled through the BAG route yields single-axis states
    whose canons are the same AHU strings the tree pipeline uses — the
    cross-family sharing hook."""
    t = get_template("u5-1")
    prog = build_bag_program(t)
    assert prog.width == 1
    assert all(len(op.axes) <= 1 for op in prog.ops)
    for op in prog.ops:
        if op.axes:
            assert op.canon.startswith("("), op.canon  # AHU, not "bag:"


# ---------------------------------------------------------------------------
# Plan identity across families
# ---------------------------------------------------------------------------


def _relabel(template: Template, perm, name: str) -> Template:
    return Template(
        name=name, edges=tuple((perm[u], perm[v]) for u, v in template.edges)
    )


def test_plan_equality_implies_cache_key_bag_family():
    g = GRAPHS[0]
    tri = get_template("triangle")
    tri_p = _relabel(tri, {0: 2, 1: 0, 2: 1}, "triangle")
    p1 = build_template_plan((tri,))
    p2 = build_template_plan((tri_p,))
    assert p1 == p2
    assert engine_cache_key(g, [tri]) == engine_cache_key(g, [tri_p])


def test_plan_equality_spans_families():
    """Permuted diamonds agree; tree vs non-tree of equal k never do."""
    g = GRAPHS[0]
    dia = get_template("diamond")
    dia_p = _relabel(dia, {0: 3, 1: 1, 2: 2, 3: 0}, "diamond")
    assert build_template_plan((dia,)) == build_template_plan((dia_p,))
    assert engine_cache_key(g, [dia]) == engine_cache_key(g, [dia_p])

    tree4 = get_template("square")  # non-tree, k=4
    star4 = Template(name="star4", edges=((0, 1), (0, 2), (0, 3)))
    assert build_template_plan((tree4,)) != build_template_plan((star4,))
    assert engine_cache_key(g, [tree4]) != engine_cache_key(g, [star4])


def test_mesh_backend_rejects_bag_plans():
    from repro.exec.mesh import MeshBackend  # noqa: F401 — import must work

    g = GRAPHS[0]
    with pytest.raises((NotImplementedError, ValueError)):
        CountingEngine(g, get_template("triangle"), backend="mesh", mesh=None)


def test_vectorized_counter_rejects_bag_plans():
    from repro.core.counting import count_colorful_vectorized

    t = get_template("triangle")
    plan = build_counting_plan(t)
    with pytest.raises(ValueError):
        count_colorful_vectorized(plan, np.zeros(5, np.int32), lambda m: m)


# ---------------------------------------------------------------------------
# Serving: graphlet profiles + the generic iteration bound
# ---------------------------------------------------------------------------


def test_graphlet_profile_warm_requery_zero_traces():
    from repro.serve.counting import CountingService

    svc = CountingService(backend="edges", chunk_size=4)
    svc.register_graph("g", GRAPHS[1])
    prof = svc.graphlet_profile("g", 4, iterations=4)
    assert set(prof) == {t.name for k in (3, 4) for t in connected_graphlets(k)}
    traces = {k: svc.engine(k).trace_count for k in svc._cache.keys()}
    prof2 = svc.graphlet_profile("g", 4, iterations=4)
    assert {k: svc.engine(k).trace_count for k in svc._cache.keys()} == traces
    for name in prof:
        assert prof2[name].mean == pytest.approx(prof[name].mean)


def test_required_iterations_generic_over_templates():
    import math

    # template and raw-k spellings agree
    assert required_iterations(get_template("triangle"), 0.1, 0.05) == (
        required_iterations(3, 0.1, 0.05)
    )
    # exact k!/k^k inverse probability, tighter than the classical e^k form
    k, eps, delta = 5, 0.1, 0.05
    inv_p = k**k / math.factorial(k)
    expect = math.ceil(inv_p * math.log(1 / delta) / eps**2)
    assert required_iterations(k, eps, delta) == expect
    assert required_iterations(k, eps, delta) < math.ceil(
        math.exp(k) * math.log(1 / delta) / eps**2
    )


def test_adaptive_budget_capped_by_blind_bound():
    """A loose (epsilon, delta) target makes the a-priori bound SMALLER
    than the default budget — the submit cap must follow it."""
    from repro.serve.counting import CountingService

    svc = CountingService(backend="edges", chunk_size=4)
    svc.register_graph("g", GRAPHS[0])
    q = svc.submit("g", "triangle", epsilon=1.0, delta=0.5)
    blind = required_iterations(3, 1.0, 0.5)
    assert blind < svc.default_budget
    assert q.budget == blind


def test_estimate_embeddings_runs_nontree():
    from repro.core.estimator import estimate_embeddings

    res = estimate_embeddings(GRAPHS[2], get_template("triangle"), iterations=6)
    assert np.isfinite(res.mean)
    assert res.mean >= 0
    assert res.iterations == 6
