"""CountingEngine mesh-backend tests (4 host devices via subprocess — the
test process itself must keep the default single-device view).

The acceptance bar for the mesh backend: counts comparable to the local
engine within fp32 tolerance for u3–u7 templates on a 4-virtual-device mesh,
identical PRNG-key -> coloring mapping, multi-template sharing, the dtype
policy, and the degree-balancing relabel all working under shard_map.
"""

import os
import subprocess
import sys

import pytest

# subprocess smokes over 4 virtual devices: the slow check.sh lane
pytestmark = pytest.mark.slow

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_child(code: str, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env, timeout=timeout
    )
    assert proc.returncode == 0, f"child failed:\nstdout={proc.stdout}\nstderr={proc.stderr}"
    return proc.stdout


def test_mesh_backend_matches_local_u3_to_u7():
    """Mesh counts == local engine counts (fp32 tolerance) for every paper
    template from u3 to u7, both for a fixed coloring (raw_counts) and for
    the batched PRNG-key path (count_keys shares the coloring draw)."""
    out = _run_child(
        r"""
import jax, jax.numpy as jnp, numpy as np
from repro.core import CountingEngine, get_template, rmat_graph

g = rmat_graph(240, 1200, seed=5)
mesh = jax.make_mesh((4,), ("dev",))
for tname in ("u3", "u5-1", "u5-2", "u6", "u7"):
    t = get_template(tname)
    colors = np.random.default_rng(3).integers(0, t.k, size=g.n)
    local = CountingEngine(g, [t], backend="edges")
    dist = CountingEngine(g, [t], backend="mesh", mesh=mesh, column_batch=8)
    a = float(local.raw_counts(colors)[0])
    b = float(dist.raw_counts(colors)[0])
    assert abs(a - b) <= 1e-5 * max(abs(a), 1.0), (tname, a, b)
    print("RAW_MATCH", tname, a)

# batched key path for one mid-size template: one jit, lax.map over chunks
t = get_template("u6")
keys = jax.random.split(jax.random.PRNGKey(1), 7)  # ragged: 7 = 2*3 + 1
ref = CountingEngine(g, [t], backend="edges", chunk_size=3).count_keys(keys)
got = CountingEngine(g, [t], backend="mesh", mesh=mesh, column_batch=8,
                     chunk_size=3).count_keys(keys)
assert np.allclose(got, ref, rtol=1e-5), (got, ref)
print("KEYS_MATCH")
"""
    )
    assert out.count("RAW_MATCH") == 5
    assert "KEYS_MATCH" in out


def test_mesh_backend_modes_and_policy():
    """loop-mode eMA, degree balancing, compressed gathers, and the bf16
    dtype policy all agree with the local fp32 reference."""
    out = _run_child(
        r"""
import jax, jax.numpy as jnp, numpy as np
from repro.core import CountingEngine, get_template, rmat_graph

g = rmat_graph(300, 2400, seed=3, a=0.7, b=0.12, c=0.12)  # skewed
t = get_template("u6")
mesh = jax.make_mesh((2, 2), ("data", "model"))
colors = np.random.default_rng(0).integers(0, t.k, size=g.n)
ref = float(CountingEngine(g, [t], backend="edges").raw_counts(colors)[0])

for tag, kw, tol in (
    ("loop", dict(ema_mode="loop"), 1e-5),
    ("balanced", dict(balance_degrees=True), 1e-5),
    ("bf16_gather", dict(gather_dtype=jnp.bfloat16), 2e-2),
    ("bf16_policy", dict(dtype_policy="bf16"), 2e-2),
):
    eng = CountingEngine(g, [t], backend="mesh", mesh=mesh, column_batch=8, **kw)
    got = float(eng.raw_counts(colors)[0])
    assert abs(got - ref) <= tol * max(abs(ref), 1.0), (tag, got, ref)
    print("MODE_OK", tag)
"""
    )
    assert out.count("MODE_OK") == 4


def test_mesh_backend_multi_template_sharing():
    """Multi-template mesh run == independent local runs, and the shared
    canonical schedule computes fewer stages than the plans would alone."""
    out = _run_child(
        r"""
import jax, jax.numpy as jnp, numpy as np
from repro.core import CountingEngine, get_template, rmat_graph

g = rmat_graph(240, 1200, seed=2)
mesh = jax.make_mesh((4,), ("dev",))
treelets = [get_template(n) for n in ("path6", "star6", "u6")]
keys = jax.random.split(jax.random.PRNGKey(7), 4)
eng = CountingEngine(g, treelets, backend="mesh", mesh=mesh, column_batch=8,
                     chunk_size=2)
multi = eng.count_keys(keys)
unique = {k for canons in eng._canons for k in canons}
assert len(unique) < sum(len(c) for c in eng._canons)  # sharing happened
for ti, t in enumerate(treelets):
    single = CountingEngine(g, [t], backend="edges", chunk_size=2).count_keys(keys)[:, 0]
    assert np.allclose(multi[:, ti], single, rtol=1e-5), t.name
    print("TEMPLATE_OK", t.name)
"""
    )
    assert out.count("TEMPLATE_OK") == 3


def test_mesh_chunk_picker_uses_shard_model():
    """The mesh memory model is per shard: budget-driven chunk picking works
    and chunked vs unchunked estimates agree."""
    out = _run_child(
        r"""
import jax, jax.numpy as jnp, numpy as np
from repro.core import CountingEngine, get_template, rmat_graph

g = rmat_graph(240, 1200, seed=2)
t = get_template("u5-2")
mesh = jax.make_mesh((4,), ("dev",))
tiny = CountingEngine(g, [t], backend="mesh", mesh=mesh, column_batch=8,
                      memory_budget_bytes=1)
wide = CountingEngine(g, [t], backend="mesh", mesh=mesh, column_batch=8,
                      memory_budget_bytes=1 << 30)
assert tiny.chunk_size == 1 and wide.chunk_size > 1
assert tiny.bytes_per_coloring() == wide.bytes_per_coloring() > 0
keys = jax.random.split(jax.random.PRNGKey(0), 3)
assert np.allclose(tiny.count_keys(keys), wide.count_keys(keys), rtol=1e-6)
print("CHUNK_OK", wide.chunk_size, wide.bytes_per_coloring())
"""
    )
    assert "CHUNK_OK" in out
