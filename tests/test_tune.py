"""Autotuner tests: search determinism, cache round-trip/robustness, the
backend resolution ladder (explicit > env > tuned > heuristic), mixed-
backend execution equality, and the quarantine -> tuned-entry interop."""

import json

import numpy as np
import pytest

from repro.core import CountingEngine, engine_cache_key, get_template, rmat_graph
from repro.core.graph import erdos_renyi_graph, grid_graph
from repro.exec.select import resolve_backend_config, tune_mode
from repro.plan.cost import CostModel
from repro.plan.ir import build_template_plan
from repro.tune import (
    TUNING_SCHEMA_VERSION,
    TuningCache,
    TuningConfig,
    consult,
    tune,
)
from repro.tune.cache import entry_key, load_calibration


def _graph():
    return rmat_graph(120, 600, seed=3)


def _leaders(graph, tname):
    plan = build_template_plan([get_template(tname)])
    cost = CostModel(plan, graph, np.float32)
    return plan, cost.tree_group_leaders()


def _mixed_config(leaders, backends=("edges", "sell")):
    return TuningConfig(
        default_backend=backends[0],
        group_backends=tuple(
            (addr, backends[k % len(backends)]) for k, addr in enumerate(leaders)
        ),
    )


# ---------------------------------------------------------------------------
# TuningConfig: JSON round trip, normalization, key fragments
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "cfg",
    [
        TuningConfig(default_backend="edges"),
        TuningConfig(default_backend="sell", column_batch=8, chunk_size=24),
        TuningConfig(
            default_backend="edges",
            group_backends=(((0, 5), "sell"), ((0, 4), "edges")),
            column_batch=4,
        ),
    ],
)
def test_config_json_roundtrip_bit_exact(cfg):
    # through an actual serialize/parse cycle, not just dict identity
    back = TuningConfig.from_json(json.loads(json.dumps(cfg.to_json())))
    assert back == cfg
    assert back.key_fragment() == cfg.key_fragment()
    assert back.describe() == cfg.describe()


def test_config_bindings_normalized_sorted():
    a = TuningConfig(
        "edges", group_backends=(((0, 5), "sell"), ((0, 4), "edges"))
    )
    b = TuningConfig(
        "edges", group_backends=(((0, 4), "edges"), ((0, 5), "sell"))
    )
    assert a == b and a.key_fragment() == b.key_fragment()
    assert a.mixed and a.backend_name == "mixed"
    assert not TuningConfig("edges", group_backends=(((0, 4), "edges"),)).mixed


def test_config_version_mismatch_raises():
    data = TuningConfig("edges").to_json()
    data["version"] = TUNING_SCHEMA_VERSION + 1
    with pytest.raises(ValueError):
        TuningConfig.from_json(data)
    with pytest.raises(ValueError):
        TuningConfig.from_json({"default_backend": "edges"})  # no version
    with pytest.raises(ValueError):
        TuningConfig.from_json("edges")  # not an object


# ---------------------------------------------------------------------------
# TuningCache: persistence round trip + corrupt-file robustness
# ---------------------------------------------------------------------------


def test_cache_roundtrip_bit_exact(tmp_path):
    path = str(tmp_path / "tuned.json")
    cfg = TuningConfig(
        "edges", group_backends=(((0, 4), "sell"),), column_batch=6, chunk_size=20
    )
    cache = TuningCache(path)
    cache.put("sig-a", [[0, 1, 2]], cfg, device="cpu", meta={"measured_us": 1.5})
    cache.merge_calibration({"edges": 1.25, "sell": 0.8})
    assert cache.save() == path

    loaded = TuningCache.load(path)
    assert loaded.get("sig-a", [[0, 1, 2]], "cpu") == cfg
    assert loaded.get("sig-a", [[0, 1, 2]], "cpu").key_fragment() == cfg.key_fragment()
    assert loaded.meta("sig-a", [[0, 1, 2]], "cpu")["measured_us"] == 1.5
    assert loaded.calibration == {"edges": 1.25, "sell": 0.8}
    # the memoized read path sees the same entry
    assert consult("sig-a", [[0, 1, 2]], device="cpu", path=path) == cfg
    assert load_calibration(path) == {"edges": 1.25, "sell": 0.8}
    # a different graph / canons / device is a miss, not a crash
    assert loaded.get("sig-b", [[0, 1, 2]], "cpu") is None
    assert loaded.get("sig-a", [[9, 9]], "cpu") is None
    assert loaded.get("sig-a", [[0, 1, 2]], "tpu") is None


@pytest.mark.parametrize(
    "content",
    [
        "this is not json{{{",
        json.dumps([1, 2, 3]),  # not an object
        json.dumps({"version": TUNING_SCHEMA_VERSION + 7, "entries": {}}),
        json.dumps({}),  # missing version
    ],
)
def test_cache_corrupt_or_stale_files_ignored(tmp_path, content, caplog):
    path = str(tmp_path / "bad.json")
    with open(path, "w") as fh:
        fh.write(content)
    with caplog.at_level("WARNING", logger="repro.tune"):
        cache = TuningCache.load(path)
    assert cache.entries == {} and cache.calibration == {}
    # never raises on the resolution hot path either
    assert consult("sig", [[0]], device="cpu", path=path) is None
    assert load_calibration(path) == {}


def test_cache_malformed_entry_ignored(tmp_path):
    path = str(tmp_path / "tuned.json")
    key = entry_key("sig-a", [[0, 1]], "cpu")
    with open(path, "w") as fh:
        json.dump(
            {
                "version": TUNING_SCHEMA_VERSION,
                "entries": {key: {"config": {"version": 99, "default_backend": 3}}},
                "calibration": {"edges": "NaNsense", "sell": -2, "dense": 1.5},
            },
            fh,
        )
    cache = TuningCache.load(path)
    assert cache.get("sig-a", [[0, 1]], "cpu") is None  # warned, not raised
    assert cache.calibration == {"dense": 1.5}  # bad ratios dropped


# ---------------------------------------------------------------------------
# The search: deterministic given the measurements
# ---------------------------------------------------------------------------


def _fake_measure(engine, probes):
    # a pure function of the probed configuration: favors sell strongly so
    # the winner differs from the lattice's predicted order
    base = {"edges": 50.0, "ell": 40.0, "sell": 10.0, "dense": 70.0}.get(
        engine.backend, 30.0
    )
    return base + 0.01 * engine.chunk_size + 0.1 * (engine.column_batch or 0)


def test_tuner_determinism_same_measurements_same_config(tmp_path):
    g = _graph()
    templates = [get_template("u5-1")]
    results = [
        tune(g, templates, top_n=4, probes=1, save=False, measure_fn=_fake_measure)
        for _ in range(2)
    ]
    assert results[0].config == results[1].config
    assert results[0].measured == results[1].measured
    assert results[0].calibration == results[1].calibration
    assert results[0].cache_path is None  # save=False never writes
    # the winner is the injected-measurement argmin, not the predicted one
    best = min(results[0].measured, key=lambda m: m.measured_us)
    assert results[0].config == best.config


def test_tune_persists_and_engine_picks_it_up(tmp_path, monkeypatch):
    path = str(tmp_path / "tuned.json")
    g = _graph()
    templates = [get_template("u5-1")]
    result = tune(
        g, templates, top_n=2, probes=1, cache_path=path, measure_fn=_fake_measure
    )
    assert result.cache_path == path
    plan = build_template_plan(templates)
    assert consult(g.signature(), plan.canons, path=path) == result.config

    # a fresh engine under REPRO_TUNE=cached (the default) resolves to it
    monkeypatch.setenv("REPRO_TUNE_CACHE", path)
    monkeypatch.delenv("REPRO_ENGINE_BACKEND", raising=False)
    eng = CountingEngine(g, templates)
    d = eng.describe()["backend"]
    assert d["source"] == "tuned"
    assert d["name"] == result.config.backend_name
    if result.config.chunk_size is not None:
        assert eng.chunk_size == result.config.chunk_size
    if result.config.column_batch is not None:
        assert eng.column_batch == result.config.column_batch
    # pre-construction key == built key (the service's contract)
    assert engine_cache_key(g, templates) == eng.cache_key()
    assert eng.cache_key()[-1] == result.config.key_fragment()


# ---------------------------------------------------------------------------
# Resolution ladder: explicit > env > tuned > heuristic
# ---------------------------------------------------------------------------


def _seed_cache(path, g, templates, backend="sell"):
    plan = build_template_plan(templates)
    cache = TuningCache(path)
    cache.put(
        g.signature(), plan.canons, TuningConfig(default_backend=backend)
    )
    cache.save()
    return plan


def test_env_override_beats_tuned_and_heuristic(tmp_path, monkeypatch):
    path = str(tmp_path / "tuned.json")
    g = _graph()
    templates = [get_template("u5-1")]
    _seed_cache(path, g, templates, backend="sell")
    monkeypatch.setenv("REPRO_TUNE_CACHE", path)

    monkeypatch.setenv("REPRO_ENGINE_BACKEND", "dense")
    eng = CountingEngine(g, templates)
    d = eng.describe()["backend"]
    assert (d["name"], d["source"]) == ("dense", "env")
    assert eng.cache_key()[-1] is None  # env result is not a tuned engine

    # explicit backend= beats even the env override
    eng2 = CountingEngine(g, templates, backend="edges")
    d2 = eng2.describe()["backend"]
    assert (d2["name"], d2["source"]) == ("edges", "explicit")


def test_tune_mode_off_falls_back_to_heuristic(tmp_path, monkeypatch):
    path = str(tmp_path / "tuned.json")
    g = _graph()
    templates = [get_template("u5-1")]
    _seed_cache(path, g, templates, backend="sell")
    monkeypatch.setenv("REPRO_TUNE_CACHE", path)
    monkeypatch.delenv("REPRO_ENGINE_BACKEND", raising=False)

    monkeypatch.setenv("REPRO_TUNE", "off")
    d = CountingEngine(g, templates).describe()["backend"]
    assert d["source"] == "heuristic"

    monkeypatch.setenv("REPRO_TUNE", "cached")
    d = CountingEngine(g, templates).describe()["backend"]
    assert (d["name"], d["source"]) == ("sell", "tuned")


def test_tune_mode_bad_value_warns_and_defaults(monkeypatch, caplog):
    monkeypatch.setenv("REPRO_TUNE", "frobnicate")
    with caplog.at_level("WARNING", logger="repro.engine"):
        assert tune_mode() == "cached"  # never raises


def test_resolve_backend_config_sources(tmp_path, monkeypatch):
    g = _graph()
    monkeypatch.delenv("REPRO_ENGINE_BACKEND", raising=False)
    name, source, reason, cfg = resolve_backend_config(g, backend="edges")
    assert (name, source, cfg) == ("edges", "explicit", None)
    name, source, reason, cfg = resolve_backend_config(g, backend="auto")
    assert source == "heuristic" and reason
    monkeypatch.setenv("REPRO_ENGINE_BACKEND", "sell")
    name, source, _, _ = resolve_backend_config(g, backend="auto")
    assert (name, source) == ("sell", "env")


# ---------------------------------------------------------------------------
# Mixed-backend execution == single-backend oracle (bit-exact)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("tname", ["u3", "u5-1", "u5-2", "u6", "u7"])
def test_mixed_backend_bit_exact_vs_uniform(tname):
    graphs = [
        rmat_graph(120, 600, seed=3),
        erdos_renyi_graph(100, 500, seed=1),
        grid_graph(8, 12),
    ]
    for g in graphs:
        plan, leaders = _leaders(g, tname)
        cfg = _mixed_config(leaders)
        oracle = CountingEngine(g, [get_template(tname)], backend="edges")
        mixed = CountingEngine(
            g, [get_template(tname)], backend="mixed", tuning=cfg
        )
        rng = np.random.default_rng(7)
        for _ in range(2):
            colors = rng.integers(0, get_template(tname).k, size=g.n)
            a = np.asarray(oracle.raw_counts(colors))
            b = np.asarray(mixed.raw_counts(colors))
            assert np.array_equal(a, b), (tname, g.signature(), a, b)


def test_mixed_engine_requires_tuning_config():
    g = _graph()
    with pytest.raises(ValueError):
        CountingEngine(g, [get_template("u5-1")], backend="mixed")


# ---------------------------------------------------------------------------
# REPRO_TUNE=full: the service self-queues, the frontend drains
# ---------------------------------------------------------------------------


def test_full_mode_service_queues_and_frontend_drains_tune(tmp_path, monkeypatch):
    from repro.serve import CountingService
    from repro.serve.frontend import make_frontend

    path = str(tmp_path / "tuned.json")
    monkeypatch.setenv("REPRO_TUNE_CACHE", path)
    monkeypatch.setenv("REPRO_TUNE", "full")
    monkeypatch.delenv("REPRO_ENGINE_BACKEND", raising=False)
    # canned measurements: probe engines are built but never launched
    monkeypatch.setattr("repro.tune.search.measure_engine_us", _fake_measure)

    g = _graph()
    svc = CountingService(chunk_size=4)
    svc.register_graph("g", g)
    fe = make_frontend(svc, manual=True)
    fut = fe.submit("t0", "g", "u5-1", iterations=4, seed=1)
    fe.drain()
    assert fut.done() and not fut.failed()
    # the untuned workload self-queued a background tune at submit; it
    # drains through the frontend's warm/tune round slot
    tuned_round = None
    for _ in range(4):
        info = fe.step()
        if info["tuned"] is not None:
            tuned_round = info["tuned"]
            break
    assert tuned_round == ("g", ("u5-1",))
    assert fe.tunes_run == 1 and svc.tunes_completed == 1
    assert svc.stats()["tuning"]["tunes_completed"] == 1
    plan = build_template_plan([get_template("u5-1")])
    assert consult(g.signature(), plan.canons, path=path) is not None
    # the tuned workload is not re-queued, and new queries resolve tuned
    q = svc.submit("g", "u5-1", iterations=2, seed=2)
    svc.run()
    assert q.done
    assert svc.engine(q.engine_key).describe()["backend"]["source"] == "tuned"
    assert svc.stats()["tuning"]["pending"] == 0


# ---------------------------------------------------------------------------
# Quarantine interop: a quarantined key loses its tuned entry
# ---------------------------------------------------------------------------


def test_quarantine_drops_tuned_cache_entry(tmp_path, monkeypatch):
    from repro.serve import CountingService

    path = str(tmp_path / "tuned.json")
    g = _graph()
    templates = [get_template("u5-1")]
    plan = _seed_cache(path, g, templates, backend="edges")
    monkeypatch.setenv("REPRO_TUNE_CACHE", path)
    assert consult(g.signature(), plan.canons, path=path) is not None

    svc = CountingService()
    svc.register_graph("g", g)
    key = svc.engine_key_for("g", svc._resolve_templates("u5-1"))
    assert key[-1] is not None  # the tuned fragment is in the key
    svc._drop_tuned_entry(key)
    assert consult(g.signature(), plan.canons, path=path) is None
