"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes and finiteness (deliverable f).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, get_arch

LM_ARCHS = [a for a, (f, _) in ARCHS.items() if f == "lm"]
GNN_ARCHS = [a for a, (f, _) in ARCHS.items() if f == "gnn"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke(arch):
    from repro.models import transformer as T
    from repro.train.optimizer import adamw_init, adamw_update

    _, module = get_arch(arch)
    cfg = module.SMOKE_CONFIG
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)

    logits, aux, _ = T.forward(params, cfg, tokens)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))

    loss, grads = jax.value_and_grad(T.loss_fn)(params, cfg, tokens, tokens)
    assert np.isfinite(float(loss))
    opt = adamw_init(params)
    new_params, opt = adamw_update(grads, opt, params, 1e-3)
    # params actually moved
    delta = jax.tree.reduce(
        lambda a, b: a + b, jax.tree.map(lambda a, b: float(jnp.abs(a - b).sum()), params, new_params)
    )
    assert delta > 0


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_decode_matches_forward(arch):
    from repro.models import transformer as T

    _, module = get_arch(arch)
    cfg = module.SMOKE_CONFIG
    if cfg.moe:  # no capacity drops so teacher-forced == decode
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    key = jax.random.PRNGKey(1)
    params = T.init_params(key, cfg)
    tokens = jax.random.randint(key, (2, 11), 0, cfg.vocab_size)
    caches = T.init_kv_cache(cfg, 2, 32)
    lg, caches = T.prefill(params, cfg, tokens, caches)
    nxt = jnp.argmax(lg[:, -1], -1)[:, None]
    lg2, _ = T.decode_step(params, cfg, nxt, caches, jnp.int32(11))
    ref, _, _ = T.forward(params, cfg, jnp.concatenate([tokens, nxt], 1))
    np.testing.assert_allclose(np.asarray(lg2), np.asarray(ref[:, -1]), rtol=2e-2, atol=2e-4)


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_smoke(arch):
    from repro.data.pipeline import graph_batch_from_shape
    from repro.models import gnn as G
    from repro.train.optimizer import adamw_init, adamw_update

    _, module = get_arch(arch)
    cfg = module.SMOKE_CONFIG
    batch, labels = graph_batch_from_shape(40, 90, 12, seed=0, batch_graphs=2)
    if cfg.model in ("nequip", "mace"):
        labels = jnp.ones((batch.n_graphs,), jnp.float32)
    params = G.init_model(jax.random.PRNGKey(0), cfg, 12)
    out = G.forward(params, cfg, batch)
    if cfg.model in ("gcn", "gat"):
        assert out.shape == (batch.n_nodes, cfg.n_classes)
    else:
        assert out.shape == (batch.n_graphs,)
    assert bool(jnp.all(jnp.isfinite(out)))
    loss, grads = jax.value_and_grad(G.loss_fn)(params, cfg, batch, labels)
    assert np.isfinite(float(loss))
    opt = adamw_init(params)
    adamw_update(grads, opt, params, 1e-3)


def test_recsys_smoke():
    from repro.configs.two_tower_retrieval import SMOKE_CONFIG as cfg
    from repro.models import recsys as R

    key = jax.random.PRNGKey(0)
    params = R.init_params(key, cfg)
    b = 8
    uix = jax.random.randint(key, (b, cfg.n_user_fields, cfg.multi_hot_per_field), 0, 90)
    iix = jax.random.randint(key, (b, cfg.n_item_fields, cfg.multi_hot_per_field), 0, 90)
    u, i = R.forward(params, cfg, uix, iix)
    assert u.shape == (b, cfg.tower_mlp[-1]) and i.shape == (b, cfg.tower_mlp[-1])
    loss = R.loss_fn(params, cfg, uix, iix)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: R.loss_fn(p, cfg, uix, iix))(params)
    assert np.isfinite(float(jnp.abs(grads["user_tables"][0]).sum()))


def test_subgraph_smoke():
    from repro.configs.subgraph2vec import SMOKE_CONFIG as cfg
    from repro.core import brute_force_embeddings, estimate_embeddings, get_template, rmat_graph

    g = rmat_graph(cfg.n_vertices, cfg.n_edges, seed=0)
    t = get_template(cfg.template)
    res = estimate_embeddings(g, t, iterations=8, seed=0)
    assert np.isfinite(res.mean) and res.mean >= 0


def test_equivariance_full_configs_reduced_graph():
    """nequip/mace FULL layer counts (reduced width) stay equivariant."""
    from repro.core.graph import erdos_renyi_graph
    from repro.models import gnn as G
    from repro.models.gnn.message import GraphBatch

    rng = np.random.default_rng(3)
    g = erdos_renyi_graph(24, 60, seed=1)
    pos = rng.standard_normal((g.n, 3)).astype(np.float32)
    q, _ = np.linalg.qr(rng.standard_normal((3, 3)))
    if np.linalg.det(q) < 0:
        q[:, 0] *= -1

    def mk(p):
        return GraphBatch(
            node_feat=jnp.asarray(rng.standard_normal((g.n, 4)).astype(np.float32)),
            positions=jnp.asarray(p),
            src=jnp.asarray(g.src),
            dst=jnp.asarray(g.dst),
            edge_mask=jnp.ones(g.num_directed, jnp.float32),
            node_mask=jnp.ones(g.n, jnp.float32),
            graph_id=jnp.zeros(g.n, jnp.int32),
            n_graphs=1,
        )

    from repro.configs import mace, nequip
    from repro.configs.base import GNNConfig
    import dataclasses as dc

    for module in (nequip, mace):
        cfg = dc.replace(module.CONFIG, d_hidden=8)  # full depth, reduced width
        params = G.init_model(jax.random.PRNGKey(0), cfg, 4)
        feats_fixed = rng.standard_normal((g.n, 4)).astype(np.float32)

        def fwd(p):
            b = mk(p)
            b = dc.replace(b, node_feat=jnp.asarray(feats_fixed))
            return float(G.forward(params, cfg, b)[0])

        e1 = fwd(pos)
        e2 = fwd(pos @ q.T.astype(np.float32))
        assert abs(e1 - e2) < 1e-3 * max(abs(e1), 1.0), (cfg.name, e1, e2)
