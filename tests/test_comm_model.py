"""Fast-lane units for the mesh comm model and its plumbing: the
plan-time blocking-vs-pipelined decision (``CostModel.comm_schedule``),
the ``REPRO_MESH_COMM`` env override, the src-bucketed shard layout the
ring consumes, and the v2 ``TuningConfig`` fields (``memory_budget_bytes``
+ ``mesh_comm``) through key_fragment / JSON round-trip and the candidate
lattice.  No devices, no subprocesses — the multi-device acceptance lives
in ``tests/test_mesh_pipeline.py`` (slow lane)."""

import numpy as np
import pytest

from repro.core import CountingEngine, get_template, rmat_graph
from repro.core.distributed import shard_graph
from repro.exec import select
from repro.plan.cost import (
    DEFAULT_MEMORY_BUDGET_BYTES,
    RING_STEP_OVERHEAD_US,
    mesh_link_bytes_per_us,
)
from repro.tune.config import TUNING_SCHEMA_VERSION, TuningConfig


@pytest.fixture(scope="module")
def cost():
    g = rmat_graph(2048, 20_000, seed=1)
    return CountingEngine(g, [get_template("u7")], backend="edges").cost


# -- the plan-time comm model ------------------------------------------------


def test_comm_schedule_covers_every_tree_leader(cost):
    scheds = cost.mesh_comm_schedules(4, column_batch=16)
    assert set(scheds) == set(cost.tree_group_leaders())
    for leader, s in scheds.items():
        assert s.stage == leader
        assert s.mode in ("blocking", "pipelined")
        assert s.ring_steps == (4 if s.mode == "pipelined" else 1)
        assert 0.0 <= s.overlap_efficiency <= 1.0
        assert s.comm_us == pytest.approx(
            s.wire_bytes / mesh_link_bytes_per_us()
        )
        d = s.describe()
        assert d["mode"] == s.mode and d["wire_bytes"] == s.wire_bytes


def test_single_shard_is_always_blocking(cost):
    for s in cost.mesh_comm_schedules(1, column_batch=16).values():
        assert s.mode == "blocking" and s.ring_steps == 1
        assert "single shard" in s.reason


def test_decision_rule_pipeline_iff_hidden_beats_ring_overhead(cost):
    # near-free wire: the hidden time cannot beat the per-hop dispatch
    # tax, so the ring is pure overhead -> blocking
    for s in cost.mesh_comm_schedules(
        4, column_batch=16, link_bytes_per_us=1e12
    ).values():
        assert s.mode == "blocking", s.reason
        assert "ring overhead" in s.reason
    for leader in cost.tree_group_leaders():
        base = cost.comm_schedule(leader, 4, column_batch=16)
        padded = base.wire_bytes // (3 * base.slice_rows * cost.itemsize)
        ring_tax = max(1, padded // 16) * 4 * RING_STEP_OVERHEAD_US
        # link sized so the wire time is 2x the ring's dispatch tax (and
        # the gather-bound compute still swallows it) -> pipelined
        mid = cost.comm_schedule(
            leader, 4, column_batch=16,
            link_bytes_per_us=base.wire_bytes / (2 * ring_tax),
        )
        assert mid.mode == "pipelined", mid.reason
        # starved link: per-step wire dwarfs compute, so only a sliver of
        # the transfer hides -- but a sliver of an enormous comm_us still
        # beats the fixed tax (hidden == (D-1) * compute_step there)
        starved = cost.comm_schedule(
            leader, 4, column_batch=16, link_bytes_per_us=1e-9
        )
        assert starved.overlap_efficiency < 0.05
        assert starved.mode == "pipelined"


def test_forced_mode_is_recorded_verbatim(cost):
    for forced in ("blocking", "pipelined"):
        for s in cost.mesh_comm_schedules(
            4, column_batch=16, forced=forced
        ).values():
            assert s.mode == forced
            assert "override" in s.reason


def test_wire_bytes_scale_with_shards_and_padded_width(cost):
    leader = cost.tree_group_leaders()[0]
    s4 = cost.comm_schedule(leader, 4, column_batch=16)
    s8 = cost.comm_schedule(leader, 8, column_batch=16)
    # (D-1) * ceil(n/D) * padded_cols * itemsize: more shards, smaller rows
    assert s8.wire_bytes == pytest.approx(
        s4.wire_bytes * (7 / 8) / (3 / 4), rel=0.01
    )


# -- the env override --------------------------------------------------------


def test_mesh_comm_env_override(monkeypatch):
    monkeypatch.delenv(select.MESH_COMM_ENV_VAR, raising=False)
    assert select.mesh_comm_mode() is None
    monkeypatch.setenv(select.MESH_COMM_ENV_VAR, "pipelined")
    assert select.mesh_comm_mode() == "pipelined"
    monkeypatch.setenv(select.MESH_COMM_ENV_VAR, "BLOCKING ")
    assert select.mesh_comm_mode() == "blocking"
    monkeypatch.setenv(select.MESH_COMM_ENV_VAR, "ring")  # typo: warn, unset
    assert select.mesh_comm_mode() is None


# -- the src-bucketed shard layout the ring walks ----------------------------


@pytest.mark.parametrize("n_shards", [2, 4])
def test_bucket_by_src_layout_invariants(n_shards):
    g = rmat_graph(257, 1800, seed=3)  # odd n: exercises row padding
    sh = shard_graph(g, n_shards, bucket_by_src=True)
    assert sh.bucket_stride is not None
    assert sh.edges_per_shard == n_shards * sh.bucket_stride
    rows = sh.rows_per_shard
    src = sh.src.reshape(n_shards, n_shards, sh.bucket_stride)
    dst = sh.dst_local.reshape(n_shards, n_shards, sh.bucket_stride)
    mask = sh.edge_mask.reshape(n_shards, n_shards, sh.bucket_stride)
    total = 0
    for shard in range(n_shards):
        for owner in range(n_shards):
            m = mask[shard, owner] > 0
            total += int(m.sum())
            # every valid slot's src sits in the owner shard's row range —
            # the invariant the ring's `cur[src - owner*rows]` gather needs
            assert np.all(src[shard, owner][m] // rows == owner)
            assert np.all((0 <= dst[shard, owner][m]) & (dst[shard, owner][m] < rows))
    assert total == g.num_directed  # no edge lost or duplicated by bucketing


# -- TuningConfig v2: budget + comm fields -----------------------------------


def test_tuning_config_v2_round_trip():
    cfg = TuningConfig(
        default_backend="mesh",
        column_batch=32,
        chunk_size=4,
        memory_budget_bytes=1 << 24,
        mesh_comm="pipelined",
    )
    assert cfg.version == TUNING_SCHEMA_VERSION
    # new fields append at the END of the cache-key fragment
    assert cfg.key_fragment()[-2:] == (1 << 24, "pipelined")
    back = TuningConfig.from_json(cfg.to_json())
    assert back == cfg
    d = cfg.describe()
    assert d["memory_budget_bytes"] == 1 << 24 and d["mesh_comm"] == "pipelined"
    # omitted fields survive as None (and key distinct from the set ones)
    plain = TuningConfig(default_backend="edges")
    assert TuningConfig.from_json(plain.to_json()) == plain
    assert plain.key_fragment() != cfg.key_fragment()


def test_tuning_config_rejects_bad_mesh_comm():
    cfg = TuningConfig(default_backend="mesh")
    data = cfg.to_json()
    data["mesh_comm"] = "ring"
    with pytest.raises(ValueError):
        TuningConfig.from_json(data)


def test_candidate_lattice_sweeps_budget_and_comm(cost):
    cands = cost.candidate_lattice(
        memory_budget_bytes=DEFAULT_MEMORY_BUDGET_BYTES, mesh_shards=4
    )
    budgets = {c.config.memory_budget_bytes for c in cands}
    assert budgets == {
        DEFAULT_MEMORY_BUDGET_BYTES, DEFAULT_MEMORY_BUDGET_BYTES // 2
    }
    mesh = [c.config for c in cands if c.config.default_backend == "mesh"]
    assert {c.mesh_comm for c in mesh} == {"blocking", "pipelined"}
    # every candidate priced, ranked cheapest-first, no duplicate keys
    assert all(c.predicted_us > 0 for c in cands)
    assert [c.predicted_us for c in cands] == sorted(
        c.predicted_us for c in cands
    )
    frags = [c.config.key_fragment() for c in cands]
    assert len(frags) == len(set(frags))
