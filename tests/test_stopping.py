"""AdaptiveStopper CI-bound tests: normal vs empirical-Bernstein.

The per-coloring colorful counts of skewed graphs are heavy-tailed (a hub
that happens to be rainbow-colored spikes the count); the Bernstein bound's
whole reason to exist is honest coverage on such streams.  These tests run
both bounds over a fixed heavy-tailed synthetic stream (lognormal — finite
variance, tail heavy enough that the sample variance lags) and pin the
ordering and determinism properties the serving layer relies on.
"""

import numpy as np
import pytest

from repro.serve.stopping import AdaptiveStopper


def _heavy_tailed_stream(
    n: int, templates: int = 1, seed: int = 0, sigma: float = 1.2
) -> np.ndarray:
    """(n, T) lognormal rows: occasional >10x spikes over the median."""
    rng = np.random.default_rng(seed)
    return rng.lognormal(mean=1.0, sigma=sigma, size=(n, templates))


def _run_until_done(stopper: AdaptiveStopper, rows: np.ndarray, block: int = 8) -> int:
    i = 0
    while not stopper.done and i < rows.shape[0]:
        stopper.update(rows[i : i + block])
        i += block
    return stopper.iterations


def test_bound_validation():
    with pytest.raises(ValueError, match="unknown CI bound"):
        AdaptiveStopper(1, epsilon=0.1, bound="hoeffding")
    # the two supported bounds construct fine
    AdaptiveStopper(1, epsilon=0.1, bound="normal")
    AdaptiveStopper(1, epsilon=0.1, bound="bernstein")


def test_bernstein_halfwidth_dominates_normal_on_heavy_tail():
    """On the same stream state, the empirical-Bernstein halfwidth must be
    at least the normal halfwidth (it adds the range-guard term and its
    variance term carries the larger ln(3/delta) constant at any delta
    below ~0.5), i.e. Bernstein is never less conservative."""
    rows = _heavy_tailed_stream(256, templates=3, seed=1)
    normal = AdaptiveStopper(3, epsilon=0.05, delta=0.05, budget=10**6)
    bern = AdaptiveStopper(3, epsilon=0.05, delta=0.05, budget=10**6, bound="bernstein")
    normal.update(rows)
    bern.update(rows)
    for e_n, e_b in zip(normal.estimates(), bern.estimates()):
        assert e_b.halfwidth >= e_n.halfwidth
        # moments are bound-independent
        assert e_b.mean == e_n.mean and e_b.std == e_n.std


def test_bernstein_stops_later_than_normal_same_stream():
    """Sequentially, at the same (epsilon, delta), the Bernstein stopper
    can only spend MORE iterations than the normal one on any stream —
    and both must actually converge on this one within the budget."""
    rows = _heavy_tailed_stream(4096, seed=2, sigma=1.0)
    n_iters = _run_until_done(
        AdaptiveStopper(1, epsilon=0.15, delta=0.1, budget=4096), rows
    )
    b_stop = AdaptiveStopper(1, epsilon=0.15, delta=0.1, budget=4096, bound="bernstein")
    b_iters = _run_until_done(b_stop, rows)
    assert b_iters >= n_iters
    assert b_stop.converged, "bernstein must still converge within the budget"
    assert b_iters < 4096  # ... and strictly before the budget cap here


def test_bernstein_deterministic_and_batch_invariant_decisions():
    """Same sample sequence => same moments and same converged verdict at
    every common inspection point, however the rows were batched."""
    rows = _heavy_tailed_stream(512, seed=3)
    fine = AdaptiveStopper(1, epsilon=0.1, delta=0.1, budget=10**6, bound="bernstein")
    coarse = AdaptiveStopper(1, epsilon=0.1, delta=0.1, budget=10**6, bound="bernstein")
    for i in range(0, 512, 4):
        fine.update(rows[i : i + 4])
        if i % 16 == 12:
            coarse.update(rows[i - 12 : i + 4])
            e_f, e_c = fine.estimates()[0], coarse.estimates()[0]
            assert e_f.mean == e_c.mean
            assert e_f.halfwidth == e_c.halfwidth
            assert fine.converged == coarse.converged


def test_bernstein_range_guard_blocks_early_stop_on_quiet_prefix():
    """A stream whose first samples are near-constant fools the normal CI
    (tiny sample variance => instant convergence) but the Bernstein range
    term keeps the interval open once a spike reveals the tail."""
    quiet = np.full((16, 1), 100.0) + np.linspace(0, 0.1, 16)[:, None]
    spike = np.array([[1000.0]])
    normal = AdaptiveStopper(1, epsilon=0.01, delta=0.05, budget=10**6)
    bern = AdaptiveStopper(1, epsilon=0.01, delta=0.05, budget=10**6, bound="bernstein")
    normal.update(quiet)
    bern.update(quiet)
    assert normal.converged  # the CLT interval collapses on the quiet prefix
    bern.update(spike)
    assert not bern.converged  # range guard: 3 * range * ln(3/d) / n >> eps*mean


def test_fixed_budget_path_ignores_bound():
    """epsilon=None degenerates both bounds to the fixed-budget run."""
    rows = _heavy_tailed_stream(64, seed=4)
    for bound in ("normal", "bernstein"):
        st = AdaptiveStopper(1, epsilon=None, budget=32, bound=bound)
        _run_until_done(st, rows)
        assert st.iterations == 32 and st.done and not st.converged


def test_service_accepts_bernstein_bound():
    """End-to-end: a CountingService query with bound="bernstein" runs,
    stops before the budget on an easy target, and never stops earlier
    than the normal-bound twin of the same query."""
    from repro.core import rmat_graph
    from repro.serve import CountingService

    svc = CountingService(chunk_size=8)
    svc.register_graph("g", rmat_graph(260, 1200, seed=5))
    qn = svc.submit("g", "u5-1", epsilon=0.2, delta=0.1, iterations=512, seed=0)
    qb = svc.submit(
        "g", "u5-1", epsilon=0.2, delta=0.1, iterations=512, seed=0, bound="bernstein"
    )
    svc.run()
    assert qn.done and qb.done
    assert qb.iterations >= qn.iterations
    assert qb.iterations < 512 and qb.result()[0].converged


def test_update_rejects_non_finite_block_atomically():
    """A NaN/Inf row must never reach the Welford state: NaN variance makes
    every CI comparison silently False, so the stopper would run its whole
    budget and report garbage.  The update is rejected atomically — state
    identical to before the call — and the error names the bad cell."""
    st = AdaptiveStopper(2, epsilon=0.1, budget=1024)
    clean = _heavy_tailed_stream(16, templates=2, seed=7)
    st.update(clean)
    before = [(ci.mean, ci.std, ci.halfwidth) for ci in st.estimates()]
    count_before = st.count

    bad = _heavy_tailed_stream(8, templates=2, seed=8)
    bad[3, 1] = np.nan
    with pytest.raises(ValueError, match=r"non-finite.*\(3, 1\)"):
        st.update(bad)
    bad[3, 1] = np.inf
    with pytest.raises(ValueError, match="non-finite"):
        st.update(bad)

    assert st.count == count_before  # nothing folded in
    assert [(ci.mean, ci.std, ci.halfwidth) for ci in st.estimates()] == before
    # and the stopper still works: the clean continuation is accepted
    st.update(_heavy_tailed_stream(8, templates=2, seed=9))
    assert st.count == count_before + 8
    assert all(np.isfinite(ci.mean) for ci in st.estimates())


def test_non_finite_guard_on_heavy_tailed_stream_with_spikes():
    """Heavy-tailed but FINITE spikes must pass the guard (they are exactly
    what the Bernstein bound exists for); only true NaN/Inf is rejected."""
    rows = _heavy_tailed_stream(128, seed=10, sigma=2.0)  # extreme spikes
    st = AdaptiveStopper(1, epsilon=0.05, budget=10**6, bound="bernstein")
    st.update(rows)  # finite, however spiky: accepted
    assert st.count == 128
    poisoned = rows.copy()
    poisoned[0, 0] = -np.inf
    with pytest.raises(ValueError, match="non-finite"):
        st.update(poisoned)
    assert st.count == 128
