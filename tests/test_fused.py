"""Fused SpMM+eMA pipeline tests.

The acceptance bar for the fused execution model: every backend produces
the same counts as the legacy two-pass reference
(``count_colorful_vectorized``, which materializes the aggregate product)
without ever materializing that product itself — across templates u3-u7,
dtype policies, ragged shapes, coloring-chunk sizes, and the mesh backend
on a 4-virtual-device mesh.  The fused Pallas kernel is checked in
interpret mode against both the pure-JAX fused fallback and the two-pass
reference.
"""

import os
import subprocess
import sys
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CountingEngine,
    build_counting_plan,
    bucketed_split_entries,
    count_colorful_vectorized,
    fused_aggregate_ema,
    get_template,
    rmat_graph,
    spmm_edges,
)
from repro.core.colorsets import binom, build_split_table

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _two_pass_reference(g, t, colors) -> float:
    plan = build_counting_plan(t)
    spmm = partial(spmm_edges, jnp.asarray(g.src), jnp.asarray(g.dst), g.n)
    return float(count_colorful_vectorized(plan, jnp.asarray(colors), spmm))


# ---------------------------------------------------------------------------
# Fused engine vs the legacy two-pass reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("tname", ["u3", "u5-1", "u5-2", "u6", "u7"])
@pytest.mark.parametrize("backend", ["edges", "sell"])
def test_fused_matches_two_pass_u3_to_u7(tname, backend):
    g = rmat_graph(300, 1500, seed=2)
    t = get_template(tname)
    colors = np.random.default_rng(0).integers(0, t.k, size=g.n)
    ref = _two_pass_reference(g, t, colors)
    got = float(CountingEngine(g, [t], backend=backend).raw_counts(colors)[0])
    assert got == pytest.approx(ref, rel=1e-5)


@pytest.mark.parametrize("policy,tol", [("fp32", 1e-5), ("bf16", 2e-2)])
def test_fused_dtype_policies(policy, tol):
    g = rmat_graph(300, 1500, seed=3)
    t = get_template("u6")
    colors = np.random.default_rng(1).integers(0, t.k, size=g.n)
    ref = _two_pass_reference(g, t, colors)
    for backend in ("edges", "sell"):
        got = float(
            CountingEngine(g, [t], backend=backend, dtype_policy=policy).raw_counts(colors)[0]
        )
        assert got == pytest.approx(ref, rel=tol), backend


@pytest.mark.parametrize("n,block", [(513, 128), (200, 256), (97, 64)])
def test_fused_pallas_backend_ragged_shapes(n, block):
    """Odd vertex counts / block remainders through the fused Pallas kernel
    (interpret mode) — padding bands and dummy pairs must stay silent."""
    g = rmat_graph(n, 4 * n, seed=n)
    t = get_template("u5-2")
    colors = np.random.default_rng(0).integers(0, t.k, size=g.n)
    ref = _two_pass_reference(g, t, colors)
    got = float(
        CountingEngine(g, [t], backend="blocked", interpret=True, block_size=block)
        .raw_counts(colors)[0]
    )
    assert got == pytest.approx(ref, rel=1e-5)


def test_fused_sell_ragged_group():
    """n not a multiple of the SELL group size exercises the short tail
    group and the inverse-permutation stitch."""
    g = rmat_graph(333, 1600, seed=9)
    t = get_template("u6")
    colors = np.random.default_rng(4).integers(0, t.k, size=g.n)
    ref = _two_pass_reference(g, t, colors)
    got = float(CountingEngine(g, [t], backend="sell").raw_counts(colors)[0])
    assert got == pytest.approx(ref, rel=1e-5)


@pytest.mark.parametrize("backend", ["edges", "sell"])
def test_fused_chunked_equals_unchunked_bit_exact(backend):
    """B>1 coloring chunks: the fused batch order is static per coloring, so
    chunked and sequential runs must agree bit-for-bit."""
    g = rmat_graph(400, 2400, seed=5)
    t = get_template("u6")
    keys = jax.random.split(jax.random.PRNGKey(0), 11)  # ragged: 11 = 2*4 + 3
    chunked = CountingEngine(g, [t], backend=backend, chunk_size=4).count_keys(keys)
    single = CountingEngine(g, [t], backend=backend, chunk_size=1).count_keys(keys)
    assert np.array_equal(chunked, single)


def test_fused_pallas_chunked_matches_reference():
    g = rmat_graph(200, 800, seed=3)
    t = get_template("u5-1")
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    ref = CountingEngine(g, [t], backend="edges", chunk_size=3).count_keys(keys)
    got = CountingEngine(
        g, [t], backend="blocked", interpret=True, chunk_size=3, block_size=128
    ).count_keys(keys)
    assert np.allclose(got, ref, rtol=1e-5)


# ---------------------------------------------------------------------------
# The fused executor / kernel in isolation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("k,m,m_a,column_batch", [(5, 3, 1, 2), (7, 5, 3, 8), (6, 6, 3, 4)])
def test_fused_fallback_matches_two_pass_stage(k, m, m_a, column_batch):
    """One stage of the pure-JAX fused fallback == two-pass SpMM then eMA."""
    g = rmat_graph(150, 700, seed=k * m)
    table = build_split_table(k, m, m_a)
    rng = np.random.default_rng(0)
    c_a, c_p = binom(k, m_a), binom(k, m - m_a)
    m_p = jnp.asarray(rng.standard_normal((g.n, 2, c_p)).astype(np.float32))
    m_aa = jnp.asarray(rng.standard_normal((g.n, 2, c_a)).astype(np.float32))
    spmm = lambda m: jax.ops.segment_sum(
        m[jnp.asarray(g.src)], jnp.asarray(g.dst), num_segments=g.n, indices_are_sorted=True
    )
    batches = tuple(
        (lo, w, jnp.asarray(ia), jnp.asarray(ip), None if va is None else jnp.asarray(va))
        for lo, w, ia, ip, va in bucketed_split_entries(table, column_batch)
    )
    got = fused_aggregate_ema(m_p, m_aa, batches, table.n_out, spmm, jnp.float32)
    # two-pass: full aggregate, then the plain eMA
    b = spmm(m_p)
    ref = jnp.zeros_like(got)
    for t_ in range(table.n_splits):
        ref = ref + jnp.take(m_aa, jnp.asarray(table.idx_a[:, t_]), axis=2) * jnp.take(
            b, jnp.asarray(table.idx_p[:, t_]), axis=2
        )
    assert np.allclose(np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("k,m,m_a", [(5, 3, 1), (7, 4, 2), (6, 6, 3)])
def test_spmm_ema_kernel_matches_fallback_and_two_pass(k, m, m_a):
    """Interpret-mode Pallas fused kernel == pure-JAX fused fallback ==
    legacy two-pass reference, for single and batched colorings."""
    from repro.kernels.spmm_ema.ops import prepare_fused_operand, spmm_ema, spmm_ema_batched
    from repro.kernels.spmm_ema.ref import spmm_ema_ref

    g = rmat_graph(130, 520, seed=m)
    op = prepare_fused_operand(g, block_size=64, edge_chunk=64)
    table = build_split_table(k, m, m_a)
    rng = np.random.default_rng(1)
    c_a, c_p = binom(k, m_a), binom(k, m - m_a)
    src, dst = jnp.asarray(g.src), jnp.asarray(g.dst)

    m_p = jnp.asarray(rng.standard_normal((g.n, c_p)).astype(np.float32))
    m_aa = jnp.asarray(rng.standard_normal((g.n, c_a)).astype(np.float32))
    two_pass = spmm_ema_ref(src, dst, g.n, m_p, m_aa, jnp.asarray(table.idx_a), jnp.asarray(table.idx_p))
    kern = spmm_ema(op, m_p, m_aa, table.idx_a, table.idx_p, interpret=True)
    assert np.allclose(np.asarray(kern), np.asarray(two_pass), rtol=1e-5, atol=1e-4)

    spmm = lambda x: jax.ops.segment_sum(x[src], dst, num_segments=g.n, indices_are_sorted=True)
    batches = tuple(
        (lo, w, jnp.asarray(ia), jnp.asarray(ip), None if va is None else jnp.asarray(va))
        for lo, w, ia, ip, va in bucketed_split_entries(table, 4)
    )
    m_pb = jnp.asarray(rng.standard_normal((g.n, 3, c_p)).astype(np.float32))
    m_ab = jnp.asarray(rng.standard_normal((g.n, 3, c_a)).astype(np.float32))
    fallback = fused_aggregate_ema(m_pb, m_ab, batches, table.n_out, spmm, jnp.float32)
    kern_b = spmm_ema_batched(op, m_pb, m_ab, table.idx_a, table.idx_p, interpret=True)
    assert np.allclose(np.asarray(kern_b), np.asarray(fallback), rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# Backend selection / env override / memory model
# ---------------------------------------------------------------------------


def test_env_override_forces_backend(monkeypatch):
    from repro.core.engine import BACKEND_ENV_VAR

    g = rmat_graph(300, 1500, seed=2)  # would auto-pick edges
    monkeypatch.setenv(BACKEND_ENV_VAR, "sell")
    eng = CountingEngine(g, [get_template("u5-1")])
    assert eng.backend == "sell"
    monkeypatch.setenv(BACKEND_ENV_VAR, "bogus")
    with pytest.raises(ValueError, match="REPRO_ENGINE_BACKEND"):
        CountingEngine(g, [get_template("u5-1")])


def test_select_backend_rmat8k_class_picks_sell():
    from repro.core import select_backend

    assert select_backend(rmat_graph(8192, 80_000, seed=2), platform="cpu") == "sell"
    # small skewed graphs stay on the edge list
    assert select_backend(rmat_graph(2048, 20_000, seed=1), platform="cpu") == "edges"


def test_fused_transient_is_column_batch_sized():
    """The memory model must reflect fusion: the per-stage transient scales
    with column_batch, not with the full passive width."""
    g = rmat_graph(2048, 20_000, seed=1)
    t = get_template("u7")
    eng = CountingEngine(g, [t])
    maxcp = eng._max_passive_columns()
    assert eng.column_batch < maxcp
    transient = eng.backend_impl.transient_elements()
    assert transient == (g.num_directed + g.n) * eng.column_batch
    # the old two-pass model charged the full passive width on the edge gather
    assert transient < g.num_directed * maxcp


def test_compiled_memory_analysis_reports_prediction():
    g = rmat_graph(300, 1500, seed=2)
    eng = CountingEngine(g, [get_template("u5-1")], chunk_size=2)
    report = eng.compiled_memory_analysis(iterations=2)
    assert report["predicted_bytes"] == pytest.approx(2 * eng.bytes_per_coloring())
    actual = report["actual_temp_bytes"]
    if actual is not None:
        assert actual > 0 and report["ratio"] > 0


# ---------------------------------------------------------------------------
# Mesh backend (4 virtual devices, subprocess)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_fused_mesh_backend_matches_two_pass():
    """The mesh backend's streamed all-gather fusion agrees with the local
    fused engine AND the legacy two-pass reference on a 4-device mesh."""
    code = r"""
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from repro.core import (CountingEngine, build_counting_plan,
                        count_colorful_vectorized, get_template, rmat_graph,
                        spmm_edges)

g = rmat_graph(240, 1200, seed=5)
mesh = jax.make_mesh((4,), ("dev",))
for tname in ("u5-2", "u6"):
    t = get_template(tname)
    colors = np.random.default_rng(3).integers(0, t.k, size=g.n)
    plan = build_counting_plan(t)
    ref = float(count_colorful_vectorized(
        plan, jnp.asarray(colors),
        partial(spmm_edges, jnp.asarray(g.src), jnp.asarray(g.dst), g.n)))
    local = float(CountingEngine(g, [t], backend="edges").raw_counts(colors)[0])
    dist = float(CountingEngine(g, [t], backend="mesh", mesh=mesh,
                                column_batch=8).raw_counts(colors)[0])
    assert abs(local - ref) <= 1e-5 * max(abs(ref), 1.0), (tname, local, ref)
    assert abs(dist - ref) <= 1e-5 * max(abs(ref), 1.0), (tname, dist, ref)
    print("MESH_FUSED_OK", tname)
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env, timeout=900
    )
    assert proc.returncode == 0, f"child failed:\nstdout={proc.stdout}\nstderr={proc.stderr}"
    assert proc.stdout.count("MESH_FUSED_OK") == 2
