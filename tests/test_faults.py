"""Chaos lane: seeded fault injection through the serving stack.

Everything here runs under a :class:`repro.testing.faults.FaultPlan` — the
deterministic fault seam — and asserts the failure semantics documented in
docs/serving.md: transient launch failures retry with backoff and keep
survivors bit-exact, memory failures walk the degradation ladder, repeated
deterministic failures quarantine the engine key, deadlines degrade armed
queries instead of dropping them, NaN chunk results fail only the poisoned
query, and a scheduler-fatal exception trips the frontend watchdog instead
of wedging futures.

Determinism bar (ISSUE 8 acceptance): the whole module is seeded — every
FaultPlan either passes an explicit seed or inherits ``REPRO_FAULT_SEED``
(fixed by the check.sh chaos lane) — so three consecutive same-seed runs
produce identical outcomes, including each plan's per-spec fire log.
"""

import functools
import threading

import numpy as np
import pytest

from repro.core import rmat_graph
from repro.serve import (
    CountingService,
    ManualClock,
    QoSRejected,
    RetryPolicy,
    ServiceError,
    ServiceFrontend,
)
from repro.serve.resilience import (
    QUARANTINE_STRIKES,
    FailState,
    QuarantinedError,
    classify_failure,
)
from repro.testing import faults
from repro.testing.faults import (
    DeterministicFault,
    FaultPlan,
    FaultSpec,
    MemoryFault,
    TransientFault,
)

pytestmark = [pytest.mark.chaos, pytest.mark.timeout(300)]

CHUNK = 8
GRAPHS = {"a": (160, 700, 2), "b": (140, 520, 3)}

#: Zero-backoff policy: chaos tests drive ManualClocks, and a real-time
#: park would require advancing the clock between every retry round.
FAST_RETRY = RetryPolicy(max_retries=3, backoff_base=0.0)


@functools.lru_cache(maxsize=None)
def _graph(name):
    n, e, s = GRAPHS[name]
    return rmat_graph(n, e, seed=s)


def _service(**kw):
    kw.setdefault("chunk_size", CHUNK)
    kw.setdefault("retry_policy", FAST_RETRY)
    kw.setdefault("clock", ManualClock())
    svc = CountingService(**kw)
    for name in GRAPHS:
        svc.register_graph(name, _graph(name))
    return svc


# the no-fault ground truth every faulted run's survivors must equal
_ORACLE_CACHE = {}


def _oracle(gname, tname, seed, iterations):
    key = (gname, tname, seed, iterations)
    if key not in _ORACLE_CACHE:
        assert faults.active_plan() is None, "oracle must run unfaulted"
        svc = _service()
        ests = svc.query(gname, tname, iterations=iterations, seed=seed)
        _ORACLE_CACHE[key] = tuple(e.mean for e in ests)
    return _ORACLE_CACHE[key]


# ---------------------------------------------------------------------------
# The FaultPlan seam itself
# ---------------------------------------------------------------------------


def test_fault_plan_fires_are_a_pure_function_of_seed_and_visit_order():
    def drive(seed):
        plan = FaultPlan(
            [FaultSpec(site="launch", kind="transient", rate=0.3)], seed=seed
        )
        with plan:
            outcomes = []
            for _ in range(50):
                try:
                    faults.maybe_fail("launch")
                    outcomes.append(0)
                except TransientFault:
                    outcomes.append(1)
        return outcomes, plan.describe()[0]["fire_log"]

    a_out, a_log = drive(7)
    b_out, b_log = drive(7)
    c_out, _ = drive(8)
    assert a_out == b_out and a_log == b_log  # same seed => same schedule
    assert sum(a_out) > 0 and a_out != c_out  # different seed => different
    # positional: the fire log records visit indices, replayable exactly
    assert [i for i, fired in enumerate(a_out) if fired] == a_log


def test_hooks_are_noops_without_an_installed_plan():
    faults.maybe_fail("launch")  # must not raise
    vals = np.ones((4, 2))
    assert faults.corrupt_result("launch", vals) is vals
    assert faults.clock_read(12.5) == 12.5


def test_plan_scope_is_context_managed_and_does_not_nest():
    plan = FaultPlan([FaultSpec(site="launch", kind="deterministic")], seed=0)
    with plan:
        assert faults.active_plan() is plan
        with pytest.raises(RuntimeError, match="do not nest"):
            FaultPlan([], seed=1).install()
        with pytest.raises(DeterministicFault):
            faults.maybe_fail("launch")
    assert faults.active_plan() is None
    faults.maybe_fail("launch")  # scope ended: seam is cold again


def test_spec_after_max_fires_and_ctx_filter():
    plan = FaultPlan(
        [
            FaultSpec(
                site="launch",
                kind="memory",
                after=2,
                max_fires=1,
                ctx_filter="backend=dense",
            )
        ],
        seed=0,
    )
    with plan:
        for _ in range(5):
            faults.maybe_fail("launch", ctx="backend=ell")  # filtered out
        faults.maybe_fail("launch", ctx="backend=dense")  # visit 0 < after
        faults.maybe_fail("launch", ctx="backend=dense")  # visit 1 < after
        with pytest.raises(MemoryFault):
            faults.maybe_fail("launch", ctx="backend=dense")  # fires
        faults.maybe_fail("launch", ctx="backend=dense")  # max_fires spent
    assert plan.fires_by_site() == {"launch": 1}


def test_corrupt_result_poisons_one_seeded_row_in_a_copy():
    plan = FaultPlan([FaultSpec(site="launch", kind="nan")], seed=3)
    original = np.arange(12, dtype=np.float64).reshape(6, 2)
    with plan:
        out1 = faults.corrupt_result("launch", original)
    with FaultPlan([FaultSpec(site="launch", kind="nan")], seed=3):
        out2 = faults.corrupt_result("launch", original)
    assert np.isfinite(original).all()  # never mutated
    bad1 = np.flatnonzero(~np.isfinite(out1).all(axis=1))
    assert bad1.size == 1  # exactly one poisoned row
    assert np.array_equal(out1, out2, equal_nan=True)  # seeded row choice


def test_clock_skew_is_cumulative_and_raising_kinds_raise():
    plan = FaultPlan(
        [FaultSpec(site="clock", kind="skew", magnitude=2.0, max_fires=2)],
        seed=0,
    )
    with plan:
        assert faults.clock_read(10.0) == 12.0
        assert faults.clock_read(10.0) == 14.0
        assert faults.clock_read(10.0) == 14.0  # max_fires: skew holds
    with FaultPlan([FaultSpec(site="clock", kind="deterministic")], seed=0):
        with pytest.raises(DeterministicFault):
            faults.clock_read(0.0)


def test_classify_failure_families():
    assert classify_failure(TransientFault("launch")) == "transient"
    assert classify_failure(MemoryFault("launch")) == "memory"
    assert classify_failure(DeterministicFault("launch")) == "deterministic"
    assert classify_failure(MemoryError("boom")) == "memory"
    assert classify_failure(RuntimeError("RESOURCE_EXHAUSTED: oom")) == "memory"
    assert classify_failure(RuntimeError("UNAVAILABLE: try again")) == "transient"
    assert classify_failure(ValueError("some compiler bug")) == "deterministic"


def test_fail_state_backoff_and_quarantine_windows():
    pol = RetryPolicy(max_retries=5, backoff_base=0.1, backoff_factor=2.0,
                      max_backoff=1.0)
    fs = FailState()
    assert fs.note_transient(0.0, pol) == pytest.approx(0.1)
    assert fs.note_transient(0.0, pol) == pytest.approx(0.2)
    assert fs.note_transient(0.0, pol) == pytest.approx(0.4)
    for _ in range(5):
        fs.note_transient(0.0, pol)
    assert fs.parked_until == pytest.approx(1.0)  # capped
    fs.note_success()
    assert fs.consecutive_transient == 0 and fs.blocked_until(0.0) is None

    # quarantine: QUARANTINE_STRIKES deterministic failures arm it, and the
    # window doubles per re-quarantine
    for i in range(QUARANTINE_STRIKES - 1):
        assert fs.note_deterministic(0.0, 1.0) is None
    assert fs.note_deterministic(0.0, 1.0) == pytest.approx(1.0)
    for i in range(QUARANTINE_STRIKES):
        second = fs.note_deterministic(10.0, 1.0)
    assert second == pytest.approx(12.0)  # 10 + 1.0 * 2**1
    assert fs.blocked_until(11.0) == pytest.approx(12.0)
    fs.note_success()
    assert fs.quarantines == 0 and fs.blocked_until(11.0) is None


# ---------------------------------------------------------------------------
# Service: transient retry keeps results bit-exact
# ---------------------------------------------------------------------------


def test_transient_launch_failures_retry_to_a_bit_exact_result():
    base = _oracle("a", "u3", 7, 24)
    svc = _service()
    plan = FaultPlan(
        [FaultSpec(site="launch", kind="transient", max_fires=2)], seed=11
    )
    with plan:
        q = svc.submit("a", "u3", iterations=24, seed=7)
        svc.run()
    assert q.done and not q.degraded
    assert tuple(e.mean for e in q.result()) == base  # bit-exact, not close
    assert q.retries == 2
    f = svc.stats()["faults"]
    assert f["transient"] == 2 and f["retries"] == 2
    assert plan.fires_by_site()["launch"] == 2


def test_retries_exhausted_is_a_structured_failure():
    svc = _service()
    with FaultPlan([FaultSpec(site="launch", kind="transient")], seed=0):
        q = svc.submit("a", "u3", iterations=8, seed=1,
                       retry_policy=RetryPolicy(max_retries=2, backoff_base=0.0))
        svc.run()
    assert q.failed
    err = q.error
    assert isinstance(err, ServiceError) and err.kind == "retries_exhausted"
    assert err.engine_key == q.engine_key and err.qid == q.qid
    assert isinstance(err.cause, TransientFault)
    with pytest.raises(ServiceError, match="retries_exhausted"):
        q.result()
    assert svc.stats()["queries_failed"] == 1


def test_launch_mates_survive_one_querys_retry_exhaustion():
    base = _oracle("a", "u3", 3, 16)
    svc = _service()
    # first 3 visits fail: the 0-retry query dies on the first, the default
    # policy query rides out the rest and must still be bit-exact
    with FaultPlan(
        [FaultSpec(site="launch", kind="transient", max_fires=3)], seed=0
    ):
        doomed = svc.submit("a", "u3", iterations=16, seed=9,
                            retry_policy=RetryPolicy(max_retries=0))
        survivor = svc.submit("a", "u3", iterations=16, seed=3)
        svc.run()
    assert doomed.failed and doomed.error.kind == "retries_exhausted"
    assert survivor.done
    assert tuple(e.mean for e in survivor.result()) == base


# ---------------------------------------------------------------------------
# Service: memory failures walk the degradation ladder
# ---------------------------------------------------------------------------


def test_memory_failure_walks_one_ladder_rung_bit_exact():
    base = _oracle("a", "u3", 7, 24)
    svc = _service()
    with FaultPlan([FaultSpec(site="launch", kind="memory", max_fires=1)], seed=5):
        q = svc.submit("a", "u3", iterations=24, seed=7)
        svc.run()
    assert q.done
    # estimates are bit-exact across chunk sizes (engine invariant), so the
    # halved-chunk rung changes latency, never the answer
    assert tuple(e.mean for e in q.result()) == base
    stats = svc.stats()["faults"]
    assert stats["memory"] == 1
    (ladder,) = stats["ladder"].values()
    assert ladder[0]["action"] == "halve_chunk"
    assert ladder[0]["chunk_size"] == CHUNK // 2
    assert ladder[0]["repriced_chunk_bytes"] > 0
    assert svc._cache.counters()["invalidations"] == 1  # rung forced rebuild


def test_ladder_exhaustion_fails_with_memory_exhausted():
    svc = _service(chunk_size=2)
    # every BUILD fails RESOURCE_EXHAUSTED-style: the service re-prices and
    # retries down every rung, then gives up with the structured error
    with FaultPlan([FaultSpec(site="engine_build", kind="memory")], seed=0):
        q = svc.submit("a", "u3", iterations=8, seed=1)
        svc.run()
    assert q.failed and q.error.kind == "memory_exhausted"
    stats = svc.stats()["faults"]
    (ladder,) = stats["ladder"].values()
    assert len(ladder) >= 2  # walked multiple rungs before giving up
    assert ladder[-1]["chunk_size"] == 1
    assert stats["memory"] == len(ladder) + 1  # each rung + the final straw


# ---------------------------------------------------------------------------
# Service: deterministic failures quarantine the engine key
# ---------------------------------------------------------------------------


def test_repeat_deterministic_failures_quarantine_then_recover():
    svc = _service()
    clk = svc.clock
    plan = FaultPlan(
        [FaultSpec(site="launch", kind="deterministic",
                   max_fires=QUARANTINE_STRIKES)],
        seed=0,
    )
    with plan:
        q1 = svc.submit("a", "u3", iterations=8, seed=1)
        svc.run()
        assert q1.failed and q1.error.kind == "deterministic"
        q2 = svc.submit("a", "u3", iterations=8, seed=2)
        svc.run()
        assert q2.failed
        # strike QUARANTINE_STRIKES: the key is now quarantined and submit
        # fast-fails without taking a queue slot
        assert svc.stats()["faults"]["quarantined_keys"] == [q1.engine_key]
        with pytest.raises(QuarantinedError) as exc:
            svc.submit("a", "u3", iterations=8, seed=3)
        assert exc.value.kind == "quarantined"
        assert exc.value.retry_at > clk.now()
        # an unrelated graph's key is untouched by the quarantine
        ok = svc.submit("b", "u3", iterations=8, seed=1)
        svc.run()
        assert ok.done
    # window passes + the fault is gone: the key recovers bit-exactly
    clk.advance(svc.quarantine_base_s + 1.0)
    q4 = svc.submit("a", "u3", iterations=8, seed=1)
    svc.run()
    assert q4.done
    assert tuple(e.mean for e in q4.result()) == _oracle("a", "u3", 1, 8)
    assert svc.stats()["faults"]["quarantined_keys"] == []


# ---------------------------------------------------------------------------
# Service: deadlines degrade armed queries, fail unarmed ones
# ---------------------------------------------------------------------------


def test_deadline_resolves_armed_query_degraded_with_both_cis():
    svc = _service()
    clk = svc.clock
    # unreachable epsilon: without the deadline this would run all 64
    q = svc.submit("a", "u3", epsilon=1e-9, iterations=64, seed=5,
                   deadline=100.0)
    for _ in range(2):  # 2 launches * CHUNK colorings: the stopper is armed
        svc.step()
    assert not q.finished
    clk.advance(101.0)
    svc.step()
    assert q.done and q.degraded
    (est,) = q.result()
    assert est.degraded and not est.converged
    assert est.halfwidth_normal > 0 and est.halfwidth_bernstein > 0
    assert est.halfwidth_bernstein >= est.halfwidth_normal
    assert svc.stats()["queries_degraded"] == 1


def test_deadline_with_no_samples_fails_structured():
    svc = _service()
    q = svc.submit("a", "u3", iterations=8, seed=1, deadline=5.0)
    svc.clock.advance(6.0)  # expires before any launch
    svc.step()
    assert q.failed and q.error.kind == "deadline"
    assert svc.stats()["queries_degraded"] == 0


# ---------------------------------------------------------------------------
# Service: NaN chunk results fail only the poisoned query
# ---------------------------------------------------------------------------


def test_nan_chunk_result_is_isolated_to_the_poisoned_query():
    svc = _service()
    with FaultPlan([FaultSpec(site="launch", kind="nan", max_fires=1)], seed=2):
        qs = [svc.submit("a", "u3", iterations=16, seed=s) for s in (4, 5)]
        svc.run()
    failed = [q for q in qs if q.failed]
    survived = [q for q in qs if q.done]
    assert len(failed) == 1 and len(survived) == 1  # co-batched, one poisoned
    assert failed[0].error.kind == "non_finite"
    assert svc.fault_counters["non_finite"] == 1
    s = survived[0]
    assert tuple(e.mean for e in s.result()) == _oracle("a", "u3", s.seed, 16)
    # the failed query's Welford state was never corrupted: its running
    # moments are still finite (the bad block was rejected atomically)
    assert all(np.isfinite(ci.mean) for ci in failed[0].progress())


# ---------------------------------------------------------------------------
# Frontend: deadlines, quarantine pass-through, and the watchdog
# ---------------------------------------------------------------------------


def _frontend(**svc_kw):
    svc = _service(clock=None, **svc_kw)  # frontend re-points the clock
    clk = ManualClock()
    fe = ServiceFrontend(svc, clock=clk)
    return svc, fe, clk


def test_frontend_deadline_expires_in_queue_before_admission():
    _, fe, clk = _frontend()
    fe.register_tenant("slow", rate_qps=0.001, burst=1.0)
    f1 = fe.submit("slow", "a", "u3", iterations=8, seed=1)
    fe.step()  # consumes the only burst token on f1
    f2 = fe.submit("slow", "a", "u3", iterations=8, seed=2, deadline=2.0)
    clk.advance(5.0)
    fe.step()
    assert f2.failed() and f2.exception().kind == "deadline"
    with pytest.raises(ServiceError, match="deadline"):
        f2.result(timeout=0)
    fe.drain()
    assert tuple(e.mean for e in f1.result(0)) == _oracle("a", "u3", 1, 8)
    assert fe.stats()["tenants"]["slow"]["failed"] == 1


def test_quarantined_submit_fails_one_future_not_the_scheduler():
    expected = _oracle("b", "u3", 1, 8)
    _, fe, _ = _frontend()
    with FaultPlan(
        [FaultSpec(site="launch", kind="deterministic",
                   max_fires=QUARANTINE_STRIKES)],
        seed=1,
    ):
        # strike the key QUARANTINE_STRIKES times with separate launch
        # attempts (co-batched queries would share one strike)
        for s in range(QUARANTINE_STRIKES):
            doomed = fe.submit("t", "a", "u3", iterations=8, seed=s)
            fe.drain()
            assert doomed.failed() and doomed.exception().kind == "deterministic"
        late = fe.submit("t", "a", "u3", iterations=8, seed=9)
        fe.step()
        # the quarantine rejection resolves ONE future; the frontend stays
        # healthy and keeps scheduling
        assert late.failed() and late.exception().kind == "quarantined"
        h = fe.health()
        assert h["state"] == "running" and h["healthy"]
        assert h["quarantined_keys"] != []
        ok = fe.submit("t", "b", "u3", iterations=8, seed=1)
        fe.drain()
        assert tuple(e.mean for e in ok.result(0)) == expected


def test_watchdog_trips_on_scheduler_fatal_fault_manual():
    _, fe, _ = _frontend()
    f1 = fe.submit("t0", "a", "u3", iterations=8, seed=1)
    f2 = fe.submit("t1", "b", "u3", iterations=8, seed=2)
    with FaultPlan([FaultSpec(site="clock", kind="deterministic",
                              max_fires=1)], seed=0):
        with pytest.raises(ServiceError) as exc:
            fe.step()
    err = exc.value
    assert err.kind == "scheduler" and err.round_index == 1
    assert isinstance(err.cause, DeterministicFault)
    # EVERY future failed with the structured error — none left hanging
    for f in (f1, f2):
        assert f.failed() and f.exception().kind == "scheduler"
        with pytest.raises(ServiceError, match="scheduler"):
            f.result(timeout=0)
    h = fe.health()
    assert h["state"] == "draining" and not h["healthy"]
    assert h["last_error"]["kind"] == "scheduler"
    assert h["unresolved"] == 0
    # draining: new submits are shed, further rounds refused
    with pytest.raises(QoSRejected, match="draining"):
        fe.submit("t0", "a", "u3", iterations=4)
    with pytest.raises(ServiceError, match="scheduler"):
        fe.step()


@pytest.mark.timeout(60)
def test_watchdog_fails_futures_when_scheduler_thread_dies():
    """The check.sh chaos smoke: kill the live scheduler thread via a
    clock fault and assert every in-flight future fails within one
    watchdog interval instead of hanging."""
    svc = _service(clock=None)
    fe = ServiceFrontend(svc, watchdog_interval=1.0, poll_interval=0.002)
    with FaultPlan(
        [FaultSpec(site="clock", kind="deterministic", max_fires=1)], seed=0
    ):
        with fe:
            fut = fe.submit("t0", "a", "u3", iterations=8, seed=1)
            with pytest.raises(ServiceError, match="scheduler"):
                fut.result(timeout=fe.watchdog_interval)
            assert fut.failed()
            deadline = 50
            while fe._thread is not None and fe._thread.is_alive() and deadline:
                threading.Event().wait(0.01)
                deadline -= 1
            h = fe.health()
            assert h["state"] == "draining" and not h["thread_alive"]


# ---------------------------------------------------------------------------
# Mesh backend failure surface
# ---------------------------------------------------------------------------


def _mesh():
    import jax

    return jax.make_mesh((1,), ("dev",))


def test_mesh_rejects_bag_plans_as_a_structured_query_failure():
    svc = _service(backend="mesh", engine_kwargs={"mesh": _mesh()})
    q = svc.submit("a", "triangle", iterations=8, seed=1)  # non-tree: bag plan
    svc.run()
    # an impossible QUERY, not a poisoned key: the invalid family, with the
    # plan's decomposition widths in the message for the operator
    assert q.failed and q.error.kind == "invalid"
    assert isinstance(q.error.cause, NotImplementedError)
    assert "decomposition widths" in str(q.error)
    assert svc.fault_counters["invalid"] == 1
    assert svc.fault_counters["deterministic"] == 0
    # the scheduler is not wedged: a tree query on the same service works
    ok = svc.submit("a", "u3", iterations=8, seed=1)
    svc.run()
    assert ok.done


def test_bag_plan_rejection_never_trips_quarantine():
    """Resubmitting the same impossible query does NOT walk its engine key
    into quarantine: the invalid family never strikes the FailState."""
    svc = _service(backend="mesh", engine_kwargs={"mesh": _mesh()})
    errors = []
    for _ in range(QUARANTINE_STRIKES + 1):
        q = svc.submit("a", "triangle", iterations=8, seed=1)
        svc.run()
        assert q.failed
        errors.append(q.error)
    # every attempt fails with the structured invalid error — never the
    # quarantined fast-fail, and the key's FailState records no strikes
    assert all(e.kind == "invalid" for e in errors)
    key = errors[0].engine_key
    fs = svc._fail.get(key)
    assert fs is None or (fs.strikes == 0 and fs.quarantines == 0)
    assert svc.fault_counters["invalid"] == QUARANTINE_STRIKES + 1


def test_mesh_collective_fault_fails_query_not_scheduler():
    svc = _service(backend="mesh", engine_kwargs={"mesh": _mesh()})
    base = svc.query("a", "u3", iterations=8, seed=1)
    with FaultPlan(
        [FaultSpec(site="collective", kind="deterministic",
                   max_fires=QUARANTINE_STRIKES - 1)],
        seed=0,
    ):
        q = svc.submit("a", "u3", iterations=8, seed=2)
        svc.run()
        assert q.failed and q.error.kind == "deterministic"
        assert q.error.engine_key == q.engine_key
        # one strike < QUARANTINE_STRIKES: the key still schedules, and the
        # next query is served bit-exactly
        again = svc.submit("a", "u3", iterations=8, seed=1)
        svc.run()
        assert again.done
        assert [e.mean for e in again.result()] == [e.mean for e in base]


def test_local_backends_do_not_expose_the_collective_site():
    svc = _service()  # backend="auto" resolves to a local backend here
    with FaultPlan([FaultSpec(site="collective", kind="deterministic")], seed=0):
        q = svc.submit("a", "u3", iterations=8, seed=1)
        svc.run()
    assert q.done  # the collective spec never matched a local launch


# ---------------------------------------------------------------------------
# Acceptance: PR 7's 16-thread oracle equality holds WITH a FaultPlan active
# ---------------------------------------------------------------------------


@pytest.mark.timeout(600)
def test_concurrent_submission_bit_exact_under_transient_chaos():
    jobs = [("a" if i % 2 else "b", "u3", i % 4, 5) for i in range(32)]
    expected = {j: _oracle(*jobs[j]) for j in range(len(jobs))}

    svc = _service(clock=None)
    fe = ServiceFrontend(svc, poll_interval=0.002)
    results, errors = {}, {}
    lock = threading.Lock()

    def worker(wid):
        for j in range(wid, len(jobs), 16):
            gname, tname, seed, iters = jobs[j]
            fut = fe.submit(f"tenant{wid % 4}", gname, tname,
                            iterations=iters, seed=seed)
            try:
                means = tuple(e.mean for e in fut.result(timeout=300))
                with lock:
                    results[j] = means
            except ServiceError as exc:
                with lock:
                    errors[j] = exc

    plan = FaultPlan(
        [FaultSpec(site="launch", kind="transient", rate=1 / 8)], seed=None
    )  # seed=None: REPRO_FAULT_SEED, the check.sh-pinned schedule
    with plan, fe:
        threads = [threading.Thread(target=worker, args=(w,)) for w in range(16)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

    # zero unresolved futures: every job either produced a result or a
    # structured error — and every survivor is bit-exact vs the oracle
    assert len(results) + len(errors) == len(jobs)
    for j, means in results.items():
        assert means == expected[j], f"job {j} diverged under transient chaos"
    for j, exc in errors.items():
        assert exc.kind == "retries_exhausted"
    assert len(results) > len(jobs) // 2  # chaos at 1/8 is survivable
