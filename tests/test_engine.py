"""CountingEngine tests: backend auto-selection, batched-vs-sequential
bit-exactness, multi-template sharing, and the memory-budget chunk picker."""

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CountingEngine,
    build_counting_plan,
    count_colorful_vectorized,
    get_template,
    grid_graph,
    pick_chunk_size,
    rmat_graph,
    select_backend,
    spmm_edges,
)
from repro.core.engine import DtypePolicy, MAX_CHUNK_SIZE, sub_template_canonical
from repro.core.graph import Graph


def _star_graph(n: int) -> Graph:
    """Hub 0 connected to all others — the ELL worst case (max_deg = n-1)."""
    src = np.concatenate([np.zeros(n - 1, np.int32), np.arange(1, n, dtype=np.int32)])
    dst = np.concatenate([np.arange(1, n, dtype=np.int32), np.zeros(n - 1, np.int32)])
    order = np.lexsort((src, dst))
    return Graph(n=n, src=src[order], dst=dst[order])


# ---------------------------------------------------------------------------
# Backend auto-selection
# ---------------------------------------------------------------------------


def test_backend_star_graph_picks_edges_not_ell():
    # high max-degree: ELL padding would cost n * (n-1) slots for 2(n-1) edges
    assert select_backend(_star_graph(600), platform="cpu") == "edges"


def test_backend_flat_degrees_pick_ell():
    # grid: max_deg == 4 == avg degree, padding waste is bounded
    assert select_backend(grid_graph(30, 30), platform="cpu") == "ell"


def test_backend_tiny_graph_picks_dense():
    assert select_backend(grid_graph(8, 8), platform="cpu") == "dense"


def test_backend_large_tpu_graph_picks_blocked():
    assert select_backend(rmat_graph(8192, 40_000, seed=0), platform="tpu") == "blocked"


def test_engine_resolves_auto_backend():
    eng = CountingEngine(_star_graph(600), get_template("u3"))
    assert eng.backend == "edges"


# ---------------------------------------------------------------------------
# Correctness vs the reference DP, across backends
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["edges", "ell", "dense"])
def test_engine_raw_counts_match_reference(backend):
    g = rmat_graph(300, 1500, seed=2)
    t = get_template("u6")
    plan = build_counting_plan(t)
    colors = np.random.default_rng(0).integers(0, t.k, size=g.n)
    ref = float(
        count_colorful_vectorized(
            plan, jnp.asarray(colors), partial(spmm_edges, jnp.asarray(g.src), jnp.asarray(g.dst), g.n)
        )
    )
    eng = CountingEngine(g, [t], backend=backend)
    got = float(eng.raw_counts(colors)[0])
    assert got == pytest.approx(ref, rel=1e-5)


def test_engine_blocked_pallas_backend_matches_edges():
    g = rmat_graph(200, 800, seed=3)
    t = get_template("u5-2")
    keys = jax.random.split(jax.random.PRNGKey(1), 2)
    ref = CountingEngine(g, [t], backend="edges", chunk_size=2).count_keys(keys)
    got = CountingEngine(g, [t], backend="blocked", interpret=True, chunk_size=2).count_keys(keys)
    assert np.allclose(got, ref, rtol=1e-5)


def test_engine_custom_spmm_fn():
    g = rmat_graph(300, 1200, seed=4)
    t = get_template("u5-1")
    keys = jax.random.split(jax.random.PRNGKey(2), 3)
    ref = CountingEngine(g, [t], backend="edges", chunk_size=3).count_keys(keys)
    custom = partial(spmm_edges, jnp.asarray(g.src), jnp.asarray(g.dst), g.n)
    got = CountingEngine(g, [t], spmm_fn=custom, chunk_size=3).count_keys(keys)
    assert got.shape == ref.shape
    assert np.allclose(got, ref, rtol=1e-6)


# ---------------------------------------------------------------------------
# Batched vs sequential: same keys => bit-exact same estimates
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["edges", "ell"])
def test_batched_equals_sequential_bit_exact(backend):
    g = rmat_graph(400, 2400, seed=5)
    t = get_template("u6")
    keys = jax.random.split(jax.random.PRNGKey(0), 13)  # ragged: 13 = 2*5 + 3
    batched = CountingEngine(g, [t], backend=backend, chunk_size=5).count_keys(keys)
    sequential = CountingEngine(g, [t], backend=backend, chunk_size=1).count_keys(keys)
    assert np.array_equal(batched, sequential)


def test_estimate_deterministic_across_chunk_sizes():
    g = rmat_graph(300, 1500, seed=6)
    t = get_template("u5-2")
    r8 = CountingEngine(g, [t], chunk_size=8).estimate(iterations=16, seed=3)[0]
    r3 = CountingEngine(g, [t], chunk_size=3).estimate(iterations=16, seed=3)[0]
    assert np.array_equal(r8.per_iteration, r3.per_iteration)
    assert r8.mean == r3.mean


# ---------------------------------------------------------------------------
# Multi-template sharing
# ---------------------------------------------------------------------------


def test_multi_template_matches_independent_runs():
    g = rmat_graph(300, 1500, seed=2)
    treelets = [get_template(n) for n in ("path6", "star6", "bintree6", "u6")]
    keys = jax.random.split(jax.random.PRNGKey(7), 8)
    multi = CountingEngine(g, treelets, chunk_size=4).count_keys(keys)
    assert multi.shape == (8, len(treelets))
    for ti, t in enumerate(treelets):
        single = CountingEngine(g, [t], chunk_size=4).count_keys(keys)[:, 0]
        assert np.allclose(multi[:, ti], single, rtol=1e-6), t.name


def test_multi_template_shares_subtemplate_state():
    """Isomorphic sub-templates across templates map to one canonical key,
    so the shared DP computes strictly fewer stages than the independent
    runs would (leaf + coinciding passive sub-templates)."""
    g = rmat_graph(200, 800, seed=1)
    treelets = [get_template(n) for n in ("path6", "star6", "u6")]
    eng = CountingEngine(g, treelets)
    unique_keys = {k for canons in eng._canons for k in canons}
    total_subs = sum(len(c) for c in eng._canons)
    assert len(unique_keys) < total_subs  # sharing actually happened
    # all leaves collapse onto a single canonical key
    leaf_key = sub_template_canonical(treelets[0], (0,), 0)
    assert leaf_key == "()"
    assert sum(1 for c in eng._canons for k in c if k == leaf_key) >= 3


def test_multi_template_requires_same_k():
    g = grid_graph(6, 6)
    with pytest.raises(ValueError, match="share one k"):
        CountingEngine(g, [get_template("u3"), get_template("u6")])


def test_shared_passive_grouping_fewer_aggregations():
    """Stages sharing a passive canon run over ONE column-batch sweep: the
    multi-template engine performs strictly fewer passive aggregations than
    the per-stage (unshared) execution would."""
    g = rmat_graph(200, 800, seed=1)
    treelets = [get_template(n) for n in ("path6", "star6", "bintree6", "u6")]
    eng = CountingEngine(g, treelets, backend="edges")
    # the schedule actually contains a shared group
    assert any(len(members) > 1 for members in eng._exec_groups.values())
    colors = np.random.default_rng(0).integers(0, 6, size=g.n)
    assert eng.counters["passive_aggregations"] == 0
    out = eng.raw_counts(colors)
    shared_calls = eng.counters["passive_aggregations"]
    # what the ungrouped execution would launch: one aggregation per
    # (stage, bucketed batch)
    unshared_calls = sum(
        len(eng._stage_tables[(q, j)].batches)
        for members in eng._exec_groups.values()
        for (q, j) in members
    )
    assert 0 < shared_calls < unshared_calls
    # ... and grouping does not change any count
    for ti, t in enumerate(treelets):
        single = CountingEngine(g, [t], backend="edges").raw_counts(colors)[0]
        assert float(out[ti]) == pytest.approx(float(single), rel=1e-6), t.name


def test_single_template_groups_are_singletons_and_exact():
    """Within one template the actives chain stage-to-stage, so grouping
    must not fire — and per-stage behavior is unchanged."""
    g = rmat_graph(150, 600, seed=3)
    t = get_template("star6")
    eng = CountingEngine(g, [t], backend="edges")
    assert all(len(m) == 1 for m in eng._exec_groups.values())
    colors = np.random.default_rng(1).integers(0, 6, size=g.n)
    got = float(eng.raw_counts(colors)[0])
    from repro.core import build_counting_plan, count_colorful_vectorized, spmm_edges

    plan = build_counting_plan(t)
    ref = float(
        count_colorful_vectorized(
            plan,
            jnp.asarray(colors),
            partial(spmm_edges, jnp.asarray(g.src), jnp.asarray(g.dst), g.n),
        )
    )
    assert got == pytest.approx(ref, rel=1e-5)


# ---------------------------------------------------------------------------
# Chunk-size picker / memory budget
# ---------------------------------------------------------------------------


def test_chunk_picker_respects_tiny_budget():
    g = rmat_graph(300, 1500, seed=2)
    t = get_template("u6")
    eng = CountingEngine(g, [t], memory_budget_bytes=1)
    assert eng.chunk_size == 1
    # ... and the engine still produces correct results at chunk 1
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    wide = CountingEngine(g, [t], memory_budget_bytes=1 << 30)
    assert wide.chunk_size > 1
    assert np.array_equal(eng.count_keys(keys), wide.count_keys(keys))


def test_chunk_picker_scales_with_budget_and_is_capped():
    assert pick_chunk_size(1000, 10_000) == 10
    assert pick_chunk_size(1000, 1) == 1
    assert pick_chunk_size(1, 1 << 40) == MAX_CHUNK_SIZE
    # bigger per-coloring footprint => smaller chunk at a fixed budget
    g = rmat_graph(2048, 20_000, seed=1)
    small_t = CountingEngine(g, [get_template("u5-1")])
    big_t = CountingEngine(g, [get_template("u7")])
    assert big_t.bytes_per_coloring() > small_t.bytes_per_coloring()
    assert big_t.chunk_size <= small_t.chunk_size


def test_peak_columns_liveness_bounds():
    """The liveness-aware engine peak is sandwiched between the widest
    single stage (children + output must coexist) and the per-plan in-place
    bound (which counts each leaf separately; the engine shares one
    canonical leaf state, so it can only do better)."""
    t = get_template("u7")
    plan = build_counting_plan(t)
    eng = CountingEngine(rmat_graph(300, 1200, seed=0), [t], plans=[plan])
    assert eng.peak_columns() <= plan.peak_columns()
    assert eng.peak_columns() >= eng._max_stage_columns()


# ---------------------------------------------------------------------------
# Dtype policy
# ---------------------------------------------------------------------------


def test_dtype_policy_resolution():
    p32 = DtypePolicy.resolve("fp32")
    assert p32.store_dtype == jnp.float32 and p32.accum_dtype == jnp.float32
    p16 = DtypePolicy.resolve("bf16")
    assert p16.store_dtype == jnp.bfloat16 and p16.accum_dtype == jnp.float32
    with pytest.raises(ValueError):
        DtypePolicy.resolve("fp8")


def test_bf16_policy_close_to_fp32():
    g = rmat_graph(300, 1500, seed=2)
    t = get_template("u6")
    colors = np.random.default_rng(0).integers(0, t.k, size=g.n)
    f32 = float(CountingEngine(g, [t]).raw_counts(colors)[0])
    b16 = float(CountingEngine(g, [t], dtype_policy="bf16").raw_counts(colors)[0])
    # bf16 storage with fp32 accumulation: ~0.4% worst-case rounding (paper §VI)
    assert b16 == pytest.approx(f32, rel=2e-2)
