"""--arch registry: maps architecture ids to config modules and shape grids."""

from __future__ import annotations

import importlib
from typing import Dict, List, Tuple

from repro.configs.base import (
    GNN_SHAPES,
    LM_SHAPES,
    RECSYS_SHAPES,
    ShapeCell,
)

__all__ = ["ARCHS", "get_arch", "shapes_for", "all_cells", "SUBGRAPH_SHAPES"]

# arch id -> (family, config module)
ARCHS: Dict[str, Tuple[str, str]] = {
    "deepseek-v2-lite-16b": ("lm", "repro.configs.deepseek_v2_lite_16b"),
    "dbrx-132b": ("lm", "repro.configs.dbrx_132b"),
    "nemotron-4-15b": ("lm", "repro.configs.nemotron_4_15b"),
    "granite-8b": ("lm", "repro.configs.granite_8b"),
    "granite-20b": ("lm", "repro.configs.granite_20b"),
    "gat-cora": ("gnn", "repro.configs.gat_cora"),
    "nequip": ("gnn", "repro.configs.nequip"),
    "gcn-cora": ("gnn", "repro.configs.gcn_cora"),
    "mace": ("gnn", "repro.configs.mace"),
    "two-tower-retrieval": ("recsys", "repro.configs.two_tower_retrieval"),
    # the paper's own workload (extra cells beyond the assigned 40)
    "subgraph2vec": ("subgraph", "repro.configs.subgraph2vec"),
}

# paper workloads: dataset x template (Table II / III / Fig 12 analogues)
SUBGRAPH_SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("rmat1m_u12", "count", {"n_vertices": 1_000_000, "n_edges": 200_000_000, "k": 12}),
    ShapeCell("rmat1m_u17", "count", {"n_vertices": 1_000_000, "n_edges": 200_000_000, "k": 17}),
    ShapeCell("rmat1m_u20", "count", {"n_vertices": 1_000_000, "n_edges": 200_000_000, "k": 20}),
    ShapeCell("gs22_u14", "count", {"n_vertices": 2_000_000, "n_edges": 128_000_000, "k": 14}),
)

_SHAPES = {"lm": LM_SHAPES, "gnn": GNN_SHAPES, "recsys": RECSYS_SHAPES, "subgraph": SUBGRAPH_SHAPES}


def get_arch(arch: str):
    """Returns (family, config module)."""
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    family, module = ARCHS[arch]
    return family, importlib.import_module(module)


def shapes_for(arch: str) -> Tuple[ShapeCell, ...]:
    family, _ = ARCHS[arch]
    return _SHAPES[family]


def all_cells(include_subgraph: bool = False) -> List[Tuple[str, ShapeCell]]:
    """The (arch x shape) dry-run grid: 40 assigned cells (+ paper cells)."""
    cells = []
    for arch, (family, _) in ARCHS.items():
        if family == "subgraph" and not include_subgraph:
            continue
        for shape in _SHAPES[family]:
            cells.append((arch, shape))
    return cells
