"""Granite 20B code (llama-arch, MQA kv=1) [arXiv:2405.04324; hf]."""

from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="granite-20b",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_head=128,
    d_ff=24576,
    vocab_size=49152,
    ffn_activation="gelu",  # GPT-BigCode lineage; matches 20B param count
    rope_theta=10000.0,
)

SMOKE_CONFIG = LMConfig(
    name="granite-20b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    d_head=16,
    d_ff=128,
    vocab_size=128,
    ffn_activation="swiglu",
    remat=False,
    attn_q_chunk=16,
    dtype="float32",
    scan_layers=False,
)
