"""Two-tower retrieval (YouTube, RecSys'19): embed_dim 256, towers
1024-512-256, dot interaction, in-batch sampled softmax."""

from repro.configs.base import RecsysConfig

CONFIG = RecsysConfig(
    name="two-tower-retrieval",
    embed_dim=256,
    tower_mlp=(1024, 512, 256),
)

SMOKE_CONFIG = RecsysConfig(
    name="two-tower-smoke",
    embed_dim=16,
    tower_mlp=(64, 32, 16),
    n_user_fields=3,
    n_item_fields=3,
    user_vocab_sizes=(1000, 500, 100),
    item_vocab_sizes=(2000, 500, 100),
    multi_hot_per_field=2,
)
