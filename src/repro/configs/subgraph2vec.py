"""The paper's own workload configs (RMAT-1M and Graph500-scale datasets)."""

from repro.configs.base import SubgraphConfig

CONFIG = SubgraphConfig(
    name="subgraph2vec",
    n_vertices=1_000_000,
    n_edges=200_000_000,
    template="u17",
)

SMOKE_CONFIG = SubgraphConfig(
    name="subgraph2vec-smoke",
    n_vertices=512,
    n_edges=2_000,
    template="u5-2",
)
