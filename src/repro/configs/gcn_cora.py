"""GCN on Cora (Kipf & Welling) [arXiv:1609.02907]."""

from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="gcn-cora", model="gcn", n_layers=2, d_hidden=16,
    aggregator="mean", sym_norm=True, n_classes=7,
)
SMOKE_CONFIG = CONFIG  # already CPU-sized
