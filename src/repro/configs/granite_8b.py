"""Granite 8B code (llama-arch, GQA kv=8) [arXiv:2405.04324; hf]."""

from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="granite-8b",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab_size=49152,
    ffn_activation="swiglu",
    rope_theta=10000000.0,
)

SMOKE_CONFIG = LMConfig(
    name="granite-8b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab_size=128,
    ffn_activation="swiglu",
    remat=False,
    attn_q_chunk=16,
    dtype="float32",
    scan_layers=False,
)
