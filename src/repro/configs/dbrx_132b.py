"""DBRX 132B (16-expert top-4 MoE, GQA kv=8) [hf:databricks/dbrx-base]."""

from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="dbrx-132b",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_head=128,
    d_ff=10752,
    vocab_size=100352,
    ffn_activation="swiglu",
    moe=True,
    n_experts=16,
    n_shared_experts=0,
    moe_top_k=4,
    moe_d_ff=10752,
    first_k_dense=0,
    rope_theta=500000.0,
)

SMOKE_CONFIG = LMConfig(
    name="dbrx-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab_size=128,
    ffn_activation="swiglu",
    moe=True,
    n_experts=4,
    n_shared_experts=0,
    moe_top_k=2,
    moe_d_ff=128,
    remat=False,
    attn_q_chunk=16,
    dtype="float32",
    scan_layers=False,
)
