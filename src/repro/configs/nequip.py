"""NequIP (Batzner et al.) [arXiv:2101.03164] — l_max=2 in Cartesian form."""

from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="nequip", model="nequip", n_layers=5, d_hidden=32,
    l_max=2, n_rbf=8, cutoff=5.0, n_classes=1,
)
SMOKE_CONFIG = GNNConfig(
    name="nequip-smoke", model="nequip", n_layers=2, d_hidden=8,
    l_max=2, n_rbf=4, cutoff=5.0, n_classes=1,
)
