"""Nemotron-4 15B (dense, GQA kv=8, squared-ReLU) [arXiv:2402.16819]."""

from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="nemotron-4-15b",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_head=128,
    d_ff=24576,
    vocab_size=256000,
    ffn_activation="squared_relu",
    rope_theta=10000.0,
)

SMOKE_CONFIG = LMConfig(
    name="nemotron-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=256,
    vocab_size=128,
    ffn_activation="squared_relu",
    remat=False,
    attn_q_chunk=16,
    dtype="float32",
    scan_layers=False,
)
