"""MACE (Batatia et al.) [arXiv:2206.07697] — correlation order 3, l_max=2."""

from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="mace", model="mace", n_layers=2, d_hidden=128,
    l_max=2, n_rbf=8, cutoff=5.0, correlation_order=3, n_classes=1,
)
SMOKE_CONFIG = GNNConfig(
    name="mace-smoke", model="mace", n_layers=2, d_hidden=8,
    l_max=2, n_rbf=4, cutoff=5.0, correlation_order=3, n_classes=1,
)
