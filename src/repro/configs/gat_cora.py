"""GAT on Cora (Velickovic et al.) [arXiv:1710.10903]."""

from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="gat-cora", model="gat", n_layers=2, d_hidden=8, n_heads=8,
    aggregator="attn", n_classes=7,
)
SMOKE_CONFIG = CONFIG
