"""DeepSeek-V2-Lite 16B (MLA + fine-grained MoE) [arXiv:2405.04434; hf].

Assignment line lists "MoE 64e top-6 ... 2 shared+160 routed"; V2-Lite is
64 routed + 2 shared top-6 (160 routed is full V2) — see DESIGN.md §4.
"""

from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="deepseek-v2-lite-16b",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,            # first dense layer
    vocab_size=102400,
    attention="mla",
    kv_lora_rank=512,
    qk_rope_head_dim=64,
    qk_nope_head_dim=128,
    v_head_dim=128,
    ffn_activation="swiglu",
    moe=True,
    n_experts=64,
    n_shared_experts=2,
    moe_top_k=6,
    moe_d_ff=1408,
    first_k_dense=1,
    rope_theta=10000.0,
)

SMOKE_CONFIG = LMConfig(
    name="deepseek-v2-lite-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=128,
    attention="mla",
    kv_lora_rank=32,
    qk_rope_head_dim=8,
    qk_nope_head_dim=16,
    v_head_dim=16,
    ffn_activation="swiglu",
    moe=True,
    n_experts=4,
    n_shared_experts=1,
    moe_top_k=2,
    moe_d_ff=32,
    first_k_dense=1,
    remat=False,
    attn_q_chunk=16,
    dtype="float32",
    scan_layers=False,
)
