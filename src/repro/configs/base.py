"""Config dataclasses for every architecture family + the shape-cell registry.

Every assigned architecture is a module in ``repro.configs`` exporting
``CONFIG`` (the exact published configuration) and ``SMOKE_CONFIG`` (a reduced
same-family config for CPU smoke tests).  ``repro.configs.registry`` maps
``--arch`` ids to these modules and enumerates the (arch x shape) dry-run
cells.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

__all__ = [
    "LMConfig",
    "GNNConfig",
    "RecsysConfig",
    "SubgraphConfig",
    "ShapeCell",
    "LM_SHAPES",
    "GNN_SHAPES",
    "RECSYS_SHAPES",
]


# ---------------------------------------------------------------------------
# LM transformers
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 128
    ffn_activation: str = "swiglu"  # swiglu | squared_relu | geglu
    attention: str = "gqa"  # gqa | mla
    # MLA (DeepSeek-V2) parameters
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128
    # MoE
    moe: bool = False
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    first_k_dense: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001
    # misc
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: bool = True
    attn_q_chunk: int = 1024  # query-chunked attention (memory)
    attn_impl: str = "sdpa"  # sdpa | flash (Pallas kernel; train/prefill GQA path)
    scan_layers: bool = True  # stack layers + lax.scan (compile-time/production)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + layers)."""
        d, h, kv, dh = self.d_model, self.n_heads, self.n_kv_heads, self.d_head
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.attention == "mla":
            attn = d * self.kv_lora_rank + d * h * self.qk_rope_head_dim // h
            attn += self.kv_lora_rank * h * (self.qk_nope_head_dim + self.v_head_dim)
            attn += d * h * (self.qk_nope_head_dim + self.qk_rope_head_dim)
            attn += h * self.v_head_dim * d
        else:
            attn = d * h * dh + 2 * d * kv * dh + h * dh * d
        ff_mult = 3 if self.ffn_activation in ("swiglu", "geglu") else 2
        dense_ffn = ff_mult * d * self.d_ff
        total = emb
        for layer in range(self.n_layers):
            total += attn
            if self.moe and layer >= self.first_k_dense:
                total += (self.n_experts + self.n_shared_experts) * ff_mult * d * self.moe_d_ff
                total += d * self.n_experts  # router
            else:
                total += dense_ffn
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed top-k + shared)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        ff_mult = 3 if self.ffn_activation in ("swiglu", "geglu") else 2
        full = self.param_count()
        moe_layers = self.n_layers - self.first_k_dense
        inactive = (self.n_experts - self.moe_top_k) * ff_mult * d * self.moe_d_ff * moe_layers
        return full - inactive


# ---------------------------------------------------------------------------
# GNNs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class GNNConfig:
    name: str
    model: str  # gcn | gat | nequip | mace
    n_layers: int
    d_hidden: int
    n_heads: int = 1
    aggregator: str = "sum"  # sum | mean | attn
    sym_norm: bool = False
    # equivariant params
    l_max: int = 0
    n_rbf: int = 0
    cutoff: float = 0.0
    correlation_order: int = 1
    n_classes: int = 16
    edge_chunk: int = 0  # >0: lax.scan edge aggregation in chunks (memory)
    dtype: str = "float32"


# ---------------------------------------------------------------------------
# RecSys
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RecsysConfig:
    name: str
    embed_dim: int
    tower_mlp: Tuple[int, ...]
    interaction: str = "dot"
    n_user_fields: int = 8
    n_item_fields: int = 8
    # per-field vocab sizes (huge sparse tables — the hot path)
    user_vocab_sizes: Tuple[int, ...] = (50_000_000, 10_000_000, 1_000_000, 1_000_000, 100_000, 100_000, 10_000, 1_000)
    item_vocab_sizes: Tuple[int, ...] = (100_000_000, 10_000_000, 1_000_000, 100_000, 100_000, 10_000, 10_000, 1_000)
    multi_hot_per_field: int = 4  # EmbeddingBag bag size
    temperature: float = 0.05
    dtype: str = "float32"


# ---------------------------------------------------------------------------
# The paper's own workload
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SubgraphConfig:
    name: str
    n_vertices: int
    n_edges: int
    template: str
    iterations: int = 1
    block_size: int = 256
    colorset_batch: int = 0  # 0 = no batching (paper's batch-size knob)
    dtype: str = "float32"


# ---------------------------------------------------------------------------
# Shape cells
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeCell:
    """One (input-shape) column of the dry-run grid."""

    name: str
    kind: str  # train | prefill | decode | serve | retrieval | full_graph | minibatch | molecule
    params: Dict[str, int] = field(default_factory=dict)


LM_SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", "train", {"seq_len": 4096, "global_batch": 256}),
    ShapeCell("prefill_32k", "prefill", {"seq_len": 32768, "global_batch": 32}),
    ShapeCell("decode_32k", "decode", {"seq_len": 32768, "global_batch": 128}),
    ShapeCell("long_500k", "decode", {"seq_len": 524288, "global_batch": 1}),
)

GNN_SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("full_graph_sm", "full_graph", {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433}),
    ShapeCell(
        "minibatch_lg",
        "minibatch",
        {"n_nodes": 232965, "n_edges": 114615892, "batch_nodes": 1024, "fanout0": 15, "fanout1": 10},
    ),
    ShapeCell("ogb_products", "full_graph", {"n_nodes": 2449029, "n_edges": 61859140, "d_feat": 100}),
    ShapeCell("molecule", "molecule", {"n_nodes": 30, "n_edges": 64, "batch": 128}),
)

RECSYS_SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_batch", "train", {"batch": 65536}),
    ShapeCell("serve_p99", "serve", {"batch": 512}),
    ShapeCell("serve_bulk", "serve", {"batch": 262144}),
    ShapeCell("retrieval_cand", "retrieval", {"batch": 1, "n_candidates": 1_000_000}),
)
