"""Deterministic fault injection for the serving stack.

The failure-semantics layer (retry/backoff, the degradation ladder,
quarantine, the frontend watchdog — see ``docs/serving.md`` "Failure
semantics") is only testable if every failure mode is *reproducible*: the
same seed must produce the same faults at the same injection sites in the
same order, across runs and across processes.  This module is that seam.

Production code declares **injection sites** — named points where the real
system can fail — by calling the module-level hooks:

* :func:`maybe_fail` at ``engine_build`` (``CountingEngine.__init__``),
  ``launch`` (``CountingEngine.count_keys_chunk``), and ``collective``
  (the mesh backend's dispatch, checked at the Python launch boundary
  because the collective itself runs under jit);
* :func:`corrupt_result` on the ``launch`` result path (NaN/Inf injection
  into otherwise-successful chunk results);
* :func:`clock_read` at the frontend scheduler's per-round clock read.

With no :class:`FaultPlan` installed every hook is a single module-global
read returning immediately — the seams cost nothing in production.  Tests
install a plan as a context manager::

    plan = FaultPlan([FaultSpec(site="launch", kind="transient", rate=0.125)],
                     seed=7)
    with plan:
        ...drive the service...
    assert plan.fires_by_site()["launch"] > 0

Each spec owns its own ``numpy`` Generator seeded from ``(plan seed, spec
index)`` and its own visit counter, so the fire pattern depends only on the
seed and the *order of visits to that site* — never on wall time, thread
identity, or other specs.  The sites all live on the single scheduler
thread by design (the frontend's determinism seam), so visit order is the
scheduler's round order and the whole failure schedule replays exactly.

No monkeypatching, no test-stack dependencies: stdlib + numpy only, per
the ``repro.testing`` charter.
"""

from __future__ import annotations

import os
import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

__all__ = [
    "FAULT_SITES",
    "FAULT_KINDS",
    "FAULT_SEED_ENV_VAR",
    "default_fault_seed",
    "FaultSpec",
    "FaultPlan",
    "FaultInjected",
    "TransientFault",
    "MemoryFault",
    "DeterministicFault",
    "active_plan",
    "maybe_fail",
    "corrupt_result",
    "clock_read",
]

#: The named injection points production code declares.
FAULT_SITES = ("engine_build", "launch", "collective", "clock")

#: What a spec does when it fires.  ``transient`` / ``memory`` /
#: ``deterministic`` raise the matching :class:`FaultInjected` subclass
#: (the retry / ladder / quarantine paths classify on these); ``nan``
#: corrupts one result row per fire (:func:`corrupt_result`); ``skew``
#: adds ``magnitude`` seconds to every subsequent :func:`clock_read`.
FAULT_KINDS = ("transient", "memory", "deterministic", "nan", "skew")

#: Environment variable fixing the default plan seed (the check.sh chaos
#: lane exports it so the whole suite replays one failure schedule).
FAULT_SEED_ENV_VAR = "REPRO_FAULT_SEED"


def default_fault_seed() -> int:
    """The seed a :class:`FaultPlan` built without ``seed=`` uses."""
    raw = os.environ.get(FAULT_SEED_ENV_VAR, "").strip()
    try:
        return int(raw) if raw else 0
    except ValueError:
        return 0


class FaultInjected(RuntimeError):
    """Base class of every injected failure (site + spec recorded)."""

    def __init__(self, site: str, detail: str = ""):
        super().__init__(f"injected fault at {site!r}" + (f": {detail}" if detail else ""))
        self.site = site


class TransientFault(FaultInjected):
    """A failure that a retry is expected to clear (launch hiccup,
    UNAVAILABLE-style collective error)."""


class MemoryFault(FaultInjected):
    """A RESOURCE_EXHAUSTED-style failure — the degradation ladder's cue."""


class DeterministicFault(FaultInjected):
    """A failure retries will never clear (poisoned operands, a compiler
    bug on this shape) — the quarantine path's cue."""


_RAISES = {
    "transient": TransientFault,
    "memory": MemoryFault,
    "deterministic": DeterministicFault,
}


@dataclass(frozen=True)
class FaultSpec:
    """One deterministic failure rule.

    Args:
      site: one of :data:`FAULT_SITES`.
      kind: one of :data:`FAULT_KINDS`.
      rate: per-visit fire probability (1.0 = every eligible visit; drawn
        from the spec's own seeded Generator, so a fractional rate is still
        a fixed schedule for a fixed seed).
      after: skip the first ``after`` visits to the site (lets a test warm
        an engine cleanly, then break its steady state).
      max_fires: stop firing after this many fires (``None`` = unlimited).
      ctx_filter: only visits whose ``ctx`` string contains this substring
        are eligible (e.g. a backend name or an engine-key fragment).
      magnitude: ``skew`` kind only — seconds added per fire, cumulative.
    """

    site: str
    kind: str
    rate: float = 1.0
    after: int = 0
    max_fires: Optional[int] = None
    ctx_filter: Optional[str] = None
    magnitude: float = 0.0

    def __post_init__(self):
        if self.site not in FAULT_SITES:
            raise ValueError(f"unknown fault site {self.site!r} (one of {FAULT_SITES})")
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} (one of {FAULT_KINDS})")
        if not 0.0 < self.rate <= 1.0:
            raise ValueError(f"rate must be in (0, 1], got {self.rate}")
        if self.kind == "skew" and self.site != "clock":
            raise ValueError("kind='skew' only applies to site='clock'")
        if self.kind == "nan" and self.site not in ("launch", "collective"):
            raise ValueError("kind='nan' only applies to result-bearing sites")


@dataclass
class _SpecState:
    """Mutable per-spec bookkeeping (guarded by the plan lock)."""

    rng: np.random.Generator
    visits: int = 0
    fires: int = 0
    fire_log: List[int] = field(default_factory=list)  # visit index per fire


class FaultPlan:
    """A seeded, context-manager-scoped set of :class:`FaultSpec` rules.

    Installing the plan (``with plan:`` or :meth:`install`) routes every
    hook call through its specs; exiting always uninstalls, even on error.
    Exactly one plan may be active per process at a time — nesting raises,
    because two overlapping schedules would not be replayable.

    Determinism: each spec's Generator is seeded ``(seed, spec index)`` and
    consumed one draw per *eligible visit*, so the fire pattern is a pure
    function of (seed, specs, visit order).  All counter state is guarded
    by one lock; the hooks themselves are called from the single scheduler
    thread in every supported harness.
    """

    def __init__(self, specs: Sequence[FaultSpec], *, seed: Optional[int] = None):
        self.specs = tuple(specs)
        self.seed = default_fault_seed() if seed is None else int(seed)
        self._lock = threading.Lock()
        self._states = [
            _SpecState(rng=np.random.default_rng((self.seed, i)))
            for i in range(len(self.specs))
        ]
        self.clock_skew = 0.0

    # -- lifecycle -----------------------------------------------------------

    def install(self) -> "FaultPlan":
        global _ACTIVE
        with _INSTALL_LOCK:
            if _ACTIVE is not None:
                raise RuntimeError(
                    "a FaultPlan is already active — fault plans do not nest"
                )
            _ACTIVE = self
        return self

    def uninstall(self) -> None:
        global _ACTIVE
        with _INSTALL_LOCK:
            if _ACTIVE is self:
                _ACTIVE = None

    def __enter__(self) -> "FaultPlan":
        return self.install()

    def __exit__(self, *exc) -> None:
        self.uninstall()

    # -- observability -------------------------------------------------------

    def fires_by_site(self) -> Dict[str, int]:
        with self._lock:
            out: Dict[str, int] = {}
            for spec, st in zip(self.specs, self._states):
                out[spec.site] = out.get(spec.site, 0) + st.fires
            return out

    def describe(self) -> List[Dict]:
        """Per-spec visit/fire record (the chaos suite's replay assertion)."""
        with self._lock:
            return [
                {
                    "site": spec.site,
                    "kind": spec.kind,
                    "rate": spec.rate,
                    "visits": st.visits,
                    "fires": st.fires,
                    "fire_log": list(st.fire_log),
                }
                for spec, st in zip(self.specs, self._states)
            ]

    # -- the decision kernel -------------------------------------------------

    def _fired_spec(self, site: str, ctx: str, kinds) -> Optional[FaultSpec]:
        """First spec at ``site`` (restricted to ``kinds``) that fires on
        this visit.  Every eligible spec's visit counter advances whether
        or not it fires — the schedule is positional, not outcome-coupled."""
        with self._lock:
            hit: Optional[FaultSpec] = None
            for spec, st in zip(self.specs, self._states):
                if spec.site != site or spec.kind not in kinds:
                    continue
                if spec.ctx_filter is not None and spec.ctx_filter not in ctx:
                    continue
                visit = st.visits
                st.visits += 1
                if visit < spec.after:
                    continue
                if spec.max_fires is not None and st.fires >= spec.max_fires:
                    continue
                draw = float(st.rng.random())
                if draw < spec.rate and hit is None:
                    st.fires += 1
                    st.fire_log.append(visit)
                    hit = spec
            return hit

    def _pick_row(self, site: str, n_rows: int) -> int:
        """Seeded row choice for a ``nan`` corruption (separate stream so
        raising specs at the same site keep their draw sequence)."""
        with self._lock:
            # numeric-only seed sequence (strings must be hex for numpy):
            # a large constant tags the stream, the site by its index
            seq = (self.seed, 0x0BAD0_40A, FAULT_SITES.index(site), n_rows)
            return int(np.random.default_rng(seq).integers(n_rows))


_INSTALL_LOCK = threading.Lock()
_ACTIVE: Optional[FaultPlan] = None


def active_plan() -> Optional[FaultPlan]:
    return _ACTIVE


# ---------------------------------------------------------------------------
# The hooks production code calls
# ---------------------------------------------------------------------------


def maybe_fail(site: str, ctx: str = "") -> None:
    """Raise the planned failure for this visit to ``site``, if any.

    No-op (one global read) without an active plan.  Raises the
    :class:`FaultInjected` subclass matching the fired spec's kind.
    """
    plan = _ACTIVE
    if plan is None:
        return
    spec = plan._fired_spec(site, ctx, ("transient", "memory", "deterministic"))
    if spec is not None:
        raise _RAISES[spec.kind](site, f"kind={spec.kind} ctx={ctx!r}")


def corrupt_result(site: str, values: np.ndarray, ctx: str = "") -> np.ndarray:
    """Apply any planned ``nan`` corruption to a result block.

    Fires set ONE seeded row of the ``(m, T)`` block to NaN — the shape of
    a single poisoned coloring — and return a corrupted copy; the original
    is never mutated.  No-op without an active plan.
    """
    plan = _ACTIVE
    if plan is None or values.shape[0] == 0:
        return values
    spec = plan._fired_spec(site, ctx, ("nan",))
    if spec is None:
        return values
    out = np.array(values, copy=True)
    out[plan._pick_row(site, out.shape[0])] = np.nan
    return out


def clock_read(base: float) -> float:
    """The frontend scheduler's per-round clock read, fault-checked.

    ``skew`` specs add their ``magnitude`` cumulatively; raising kinds
    raise (the watchdog kill-switch used by the check.sh smoke).  Returns
    ``base`` untouched without an active plan.
    """
    plan = _ACTIVE
    if plan is None:
        return base
    spec = plan._fired_spec(
        "clock", "", ("transient", "memory", "deterministic", "skew")
    )
    if spec is None:
        return base + plan.clock_skew
    if spec.kind == "skew":
        with plan._lock:
            plan.clock_skew += spec.magnitude
        return base + plan.clock_skew
    raise _RAISES[spec.kind]("clock", f"kind={spec.kind}")
