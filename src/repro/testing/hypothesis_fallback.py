"""Minimal, dependency-free stand-in for the ``hypothesis`` package.

The property tests in ``tests/`` use a small, stable subset of hypothesis:
``@given(**strategies)``, ``@settings(max_examples=..., deadline=...)``,
``st.integers(min_value=..., max_value=...)``, and ``st.data()`` with
``data.draw(...)``.  When the real package is installed it is always
preferred (see ``tests/conftest.py``); this module only exists so the suite
still collects and runs in minimal environments.

Semantics of the fallback: each ``@given`` test runs ``max_examples``
examples drawn from a **fixed-seed** PRNG derived from the test name, so
failures are reproducible run-to-run (no shrinking, no example database).
"""

from __future__ import annotations

import random
import sys
import types
from typing import Any, Callable, Dict, Optional, Sequence

__all__ = ["given", "settings", "strategies", "install", "DEFAULT_MAX_EXAMPLES"]

DEFAULT_MAX_EXAMPLES = 20


class SearchStrategy:
    """Base strategy: knows how to produce one example from a PRNG."""

    def example_from(self, rng: random.Random) -> Any:
        raise NotImplementedError


class _Integers(SearchStrategy):
    def __init__(self, min_value: Optional[int] = None, max_value: Optional[int] = None):
        self.min_value = -(2**31) if min_value is None else min_value
        self.max_value = 2**31 - 1 if max_value is None else max_value

    def example_from(self, rng: random.Random) -> int:
        return rng.randint(self.min_value, self.max_value)


class _Floats(SearchStrategy):
    def __init__(self, min_value: float = 0.0, max_value: float = 1.0, **_ignored):
        self.min_value = min_value
        self.max_value = max_value

    def example_from(self, rng: random.Random) -> float:
        return rng.uniform(self.min_value, self.max_value)


class _Booleans(SearchStrategy):
    def example_from(self, rng: random.Random) -> bool:
        return rng.random() < 0.5


class _SampledFrom(SearchStrategy):
    def __init__(self, elements: Sequence[Any]):
        self.elements = list(elements)

    def example_from(self, rng: random.Random) -> Any:
        return rng.choice(self.elements)


class _Lists(SearchStrategy):
    def __init__(self, elements: SearchStrategy, min_size: int = 0, max_size: int = 10, **_ignored):
        self.elements = elements
        self.min_size = min_size
        self.max_size = max_size

    def example_from(self, rng: random.Random) -> list:
        size = rng.randint(self.min_size, self.max_size)
        return [self.elements.example_from(rng) for _ in range(size)]


class DataObject:
    """Interactive draw handle (the fallback for ``st.data()``)."""

    def __init__(self, rng: random.Random):
        self._rng = rng

    def draw(self, strategy: SearchStrategy, label: str = "") -> Any:
        return strategy.example_from(self._rng)


class _DataStrategy(SearchStrategy):
    def example_from(self, rng: random.Random) -> DataObject:
        return DataObject(rng)


def settings(*args, max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    """Decorator recording ``max_examples``; order-independent wrt @given."""

    def decorate(fn: Callable) -> Callable:
        fn._shim_settings = {"max_examples": max_examples}
        return fn

    if args and callable(args[0]):  # bare @settings usage
        return decorate(args[0])
    return decorate


def given(*arg_strategies: SearchStrategy, **kw_strategies: SearchStrategy):
    """Run the test over fixed-seed examples of the declared strategies."""

    def decorate(fn: Callable) -> Callable:
        def wrapper():
            conf = getattr(wrapper, "_shim_settings", None) or getattr(
                fn, "_shim_settings", {}
            )
            max_examples = conf.get("max_examples", DEFAULT_MAX_EXAMPLES)
            for example in range(max_examples):
                rng = random.Random(f"{fn.__module__}.{fn.__qualname__}:{example}")
                args = [s.example_from(rng) for s in arg_strategies]
                kwargs = {name: s.example_from(rng) for name, s in kw_strategies.items()}
                try:
                    fn(*args, **kwargs)
                except Exception as err:
                    raise AssertionError(
                        f"falsifying example #{example} for {fn.__qualname__}: "
                        f"args={args} kwargs={kwargs}"
                    ) from err

        # NOTE: no functools.wraps — pytest must see the zero-arg signature,
        # not the strategy parameters of the wrapped test.
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.hypothesis_fallback = True
        return wrapper

    return decorate


def _build_strategies_module() -> types.ModuleType:
    st = types.ModuleType("hypothesis.strategies")
    st.SearchStrategy = SearchStrategy
    st.integers = lambda min_value=None, max_value=None: _Integers(min_value, max_value)
    st.floats = lambda *a, **kw: _Floats(*a, **kw)
    st.booleans = lambda: _Booleans()
    st.sampled_from = lambda elements: _SampledFrom(elements)
    st.lists = lambda elements, **kw: _Lists(elements, **kw)
    st.data = lambda: _DataStrategy()
    return st


#: module-level alias so ``from hypothesis import strategies as st`` works
strategies = _build_strategies_module()


def install() -> None:
    """Register this fallback as ``hypothesis`` in ``sys.modules``.

    A no-op when the real package is importable — the real thing always wins.
    """
    if "hypothesis" in sys.modules:
        return
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.strategies = strategies
    mod.HealthCheck = types.SimpleNamespace(too_slow=None, data_too_large=None)
    mod.__is_repro_fallback__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies
