"""Test-support utilities (no runtime dependencies on the test stack)."""
