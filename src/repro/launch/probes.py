"""Scan-aware roofline cost probes.

``cost_analysis()`` (and the HLO text) count a ``while``/``scan`` body ONCE,
not multiplied by the trip count, so the scanned production artifacts
under-report flops/bytes/collectives.  The probes recover true totals by
compiling *unrolled* reduced variants and extrapolating:

* **LM** — unroll layers (``scan_layers=False``) and the attention q-chunk
  loop (``attn_q_chunk=seq``) at two layer counts L1 < L2; every cost is
  affine in L, so ``cost(L) = a + b*L`` is fit exactly from the two points
  and evaluated at the real depth.
* **GNN (equivariant, edge-chunked)** — two unchunked probes at reduced edge
  counts e1 < e2 with the full node count; costs are affine in e.
* **subgraph2vec** — one probe with ``column_batch=None`` (single full-width
  all-gather) + vectorized eMA: the DP stage loop is a Python loop (already
  unrolled), so a single probe sees all the work.
* **recsys / non-chunked GNN** — loop-free; the production artifact's own
  numbers are exact (no probe).

Returned costs are per-device, matching cost_analysis semantics.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import numpy as np

from repro.configs.base import ShapeCell
from repro.configs.registry import get_arch
from repro.launch.roofline import collective_wire_bytes

__all__ = ["probe_costs"]


def _compile_costs(cell) -> Tuple[float, float, float]:
    jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings, donate_argnums=cell.donate_argnums)
    compiled = jitted.lower(*cell.args).compile()
    ca = compiled.cost_analysis() or {}
    coll, _ = collective_wire_bytes(compiled.as_text())
    return float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0)), float(coll)


def _affine_extrapolate(c1, c2, x1: float, x2: float, x_full: float):
    out = []
    for v1, v2 in zip(c1, c2):
        b = (v2 - v1) / (x2 - x1)
        a = v1 - b * x1
        out.append(max(a + b * x_full, 0.0))
    return tuple(out)


def probe_costs(arch: str, shape: ShapeCell, mesh) -> Optional[Dict[str, float]]:
    """Corrected per-device (flops, bytes, collective_bytes) — or None when
    the production artifact is already loop-free (exact)."""
    from repro.launch.cells import build_cell

    family, module = get_arch(arch)
    cfg = module.CONFIG

    if family == "lm":
        seq = shape.params["seq_len"] if shape.kind != "decode" else 1
        fk = cfg.first_k_dense if cfg.moe else 0
        l1, l2 = fk + 1, fk + 2

        def override(layers):
            return dataclasses.replace(
                cfg,
                n_layers=layers,
                scan_layers=False,
                attn_q_chunk=max(seq, shape.params["seq_len"]),
            )

        cell1 = build_cell(arch, shape, mesh, cfg_override=override(l1))
        cell2 = build_cell(arch, shape, mesh, cfg_override=override(l2))
        c1 = _compile_costs(cell1)
        c2 = _compile_costs(cell2)
        flops, byts, coll = _affine_extrapolate(c1, c2, l1, l2, cfg.n_layers)
        # probes run the full batch as ONE microbatch — identical total work
        # to the production n_micro-accumulated step, so no scaling needed
        return {"flops": flops, "bytes": byts, "collective_bytes": coll,
                "method": f"lm-unroll L={l1},{l2}"}

    if family == "gnn" and cfg.model in ("nequip", "mace"):
        # chunked only on big-edge full-graph cells; otherwise exact already
        if shape.kind != "full_graph":
            return None
        if build_cell(arch, shape, mesh).meta["n_edges"] <= (1 << 22):
            return None
        e1, e2 = 1 << 20, 1 << 21

        def shape_override(e):
            p = dict(shape.params)
            p["n_edges"] = e
            return ShapeCell(shape.name, shape.kind, p)

        cell1 = build_cell(arch, shape_override(e1), mesh)
        cell2 = build_cell(arch, shape_override(e2), mesh)
        c1 = _compile_costs(cell1)
        c2 = _compile_costs(cell2)
        # builder pads edge counts; extrapolate in the padded directed count
        e1p, e2p = cell1.meta["n_edges"], cell2.meta["n_edges"]
        e_target = build_cell(arch, shape, mesh).meta["n_edges"]
        flops, byts, coll = _affine_extrapolate(c1, c2, e1p, e2p, e_target)
        return {"flops": flops, "bytes": byts, "collective_bytes": coll, "method": f"gnn-edges e={e1p},{e2p}"}

    if family == "subgraph":
        cell = build_cell(arch, shape, mesh, subgraph_probe=True)
        flops, byts, coll = _compile_costs(cell)
        return {"flops": flops, "bytes": byts, "collective_bytes": coll, "method": "subgraph-unbatched"}

    return None  # recsys, gcn/gat: loop-free, production numbers exact
