import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be run as its own process (the two lines above must execute before any
other jax-touching import — jax locks the device count on first init).

Usage:
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--include-subgraph]
  python -m repro.launch.dryrun --list

Per cell it runs ``jax.jit(fn, in_shardings=...).lower(*specs).compile()``,
prints ``memory_analysis()`` (fits-in-HBM proof) and ``cost_analysis()``
(FLOPs/bytes for §Roofline), and appends a JSON record to
``results/dryrun/<arch>__<shape>__<mesh>.json``.
"""

import argparse
import json
import sys
import time
import traceback


def run_cell(arch: str, shape_name: str, mesh_name: str, out_dir: str, probe: bool = False) -> dict:
    import jax

    from repro.configs.registry import shapes_for
    from repro import compat
    from repro.launch.cells import build_cell
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import analyze_compiled

    shape = next(s for s in shapes_for(arch) if s.name == shape_name)
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    n_devices = mesh.devices.size

    t0 = time.monotonic()
    with compat.set_mesh(mesh):
        cell = build_cell(arch, shape, mesh)
        jitted = jax.jit(
            cell.fn,
            in_shardings=cell.in_shardings,
            donate_argnums=cell.donate_argnums,
        )
        lowered = jitted.lower(*cell.args)
        t_lower = time.monotonic() - t0
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0 - t_lower

    mem = compiled.memory_analysis()
    print(f"== {arch} x {shape_name} x {mesh_name} ({n_devices} devices) ==")
    print(f"memory_analysis: {mem}")
    ca = compiled.cost_analysis() or {}
    print(
        "cost_analysis: flops/device=%.3e bytes/device=%.3e"
        % (ca.get("flops", 0.0), ca.get("bytes accessed", 0.0))
    )

    report = analyze_compiled(
        compiled,
        arch=arch,
        shape=shape_name,
        mesh_name=mesh_name,
        n_devices=n_devices,
        model_flops=cell.model_flops,
        meta={**cell.meta, "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2)},
    )
    rec = report.to_json()

    if probe:
        from repro.launch.probes import probe_costs
        from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS

        with compat.set_mesh(mesh):
            corr = probe_costs(arch, shape, mesh)
        if corr is not None:
            rec["probe"] = corr
            rec["hlo_flops"] = corr["flops"]
            rec["hlo_bytes"] = corr["bytes"]
            rec["collective_bytes"] = corr["collective_bytes"]
            rec["compute_s"] = corr["flops"] / PEAK_FLOPS
            rec["memory_s"] = corr["bytes"] / HBM_BW
            rec["collective_s"] = corr["collective_bytes"] / ICI_BW
            terms = {
                "compute": rec["compute_s"],
                "memory": rec["memory_s"],
                "collective": rec["collective_s"],
            }
            rec["bottleneck"] = max(terms, key=terms.get)
            denom = corr["flops"] * n_devices
            rec["useful_flops_ratio"] = cell.model_flops / denom if denom else 0.0
            print(
                f"probe-corrected: compute={rec['compute_s']:.3e}s memory={rec['memory_s']:.3e}s "
                f"collective={rec['collective_s']:.3e}s bottleneck={rec['bottleneck']} "
                f"useful={rec['useful_flops_ratio']:.3f} ({corr['method']})"
            )
    hbm = 16e9
    per_dev = report.per_device_memory_bytes or 0.0
    rec["fits_hbm"] = bool(per_dev < hbm)
    print(
        f"roofline: compute={report.compute_s:.3e}s memory={report.memory_s:.3e}s "
        f"collective={report.collective_s:.3e}s bottleneck={report.bottleneck} "
        f"useful_flops_ratio={report.useful_flops_ratio:.3f}"
    )
    print(f"per-device bytes (arg+out+temp): {per_dev:.3e} fits_16GB={rec['fits_hbm']}")

    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"wrote {path}")
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--include-subgraph", action="store_true")
    ap.add_argument("--probe", action="store_true", help="scan-corrected roofline costs")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    from repro.configs.registry import all_cells

    if args.list:
        for arch, shape in all_cells(include_subgraph=True):
            print(f"{arch} {shape.name}")
        return 0

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = (
        all_cells(include_subgraph=args.include_subgraph)
        if args.all
        else [(args.arch, s) for s in __import__("repro.configs.registry", fromlist=["shapes_for"]).shapes_for(args.arch) if args.shape in (None, s.name)]
    )

    failures = []
    for arch, shape in cells:
        for mesh_name in meshes:
            try:
                run_cell(arch, shape.name, mesh_name, args.out, probe=args.probe)
            except Exception as e:
                traceback.print_exc()
                failures.append((arch, shape.name, mesh_name, repr(e)))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        return 1
    print("\nALL CELLS COMPILED")
    return 0


if __name__ == "__main__":
    sys.exit(main())
