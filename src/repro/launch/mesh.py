"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before the first
jax call.
"""

from __future__ import annotations

from typing import Tuple

import jax

__all__ = ["make_production_mesh", "dp_axes", "all_axes"]


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) data x model single pod (256 chips) or (2, 16, 16)
    pod x data x model for the 2-pod, 512-chip configuration."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes(mesh) -> Tuple[str, ...]:
    """Batch-like axes: ("pod", "data") on the multi-pod mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def all_axes(mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)
