"""Dry-run cell builder: (arch x shape x mesh) -> lowerable jit spec.

``build_cell`` returns everything needed to ``jax.jit(fn, in_shardings=...)
.lower(*args).compile()`` a cell with ShapeDtypeStruct stand-ins (no device
allocation): the step function, abstract args, shardings, and analytic
MODEL_FLOPS for the roofline's useful-compute ratio.

Sharding strategy per family is documented in DESIGN.md §5; highlights:
* LM params: TP over "model" + FSDP over the data axes on a replicated major
  dim (required to fit 132B fp32 + Adam in 16 GB/chip HBM).
* LM long_500k: batch=1 -> KV cache sharded along the *sequence* axis.
* GNN: nodes over data axes, edges over every axis (the scatter psum is the
  aggregation collective); equivariant models use edge-chunked scan.
* recsys: tables row(vocab)-sharded over "model"; batch over data axes.
* subgraph2vec: the paper's distributed DP (vertex 1-D partition, batched
  all-gather SpMM) via shard_map.
"""

from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import GNNConfig, LMConfig, RecsysConfig, ShapeCell
from repro.configs.registry import get_arch
from repro.launch.mesh import dp_axes
from repro.models import recsys as RS
from repro.models import transformer as T
from repro.models.gnn.message import GraphBatch
from repro.train.optimizer import (
    adafactor_init,
    adafactor_update,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
)

__all__ = ["CellSpec", "build_cell"]


@dataclass
class CellSpec:
    arch: str
    shape: str
    fn: Callable
    args: Tuple  # pytrees of ShapeDtypeStruct
    in_shardings: Tuple
    donate_argnums: Tuple[int, ...]
    model_flops: float  # analytic useful FLOPs per step (MODEL_FLOPS)
    meta: Dict[str, Any]


def _shard(mesh, tree_specs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def _pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------


def _fsdp_param_pspecs(cfg: LMConfig, dp: Tuple[str, ...], mesh: Mesh):
    """TP pspecs from the model + FSDP over the data axes on a free,
    divisible major dim (skipping the stacked layer axis).  Required to fit
    132B fp32 params + Adam state in 16 GB/chip HBM."""
    model_size = mesh.shape["model"]
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    specs = T.param_pspecs(cfg, model_size=model_size)
    shapes = T.param_shapes(cfg)

    def upgrade(spec, shape, start):
        if not isinstance(spec, P):
            return spec
        parts = list(spec)
        dims = shape.shape
        for i in range(start, len(parts)):
            if parts[i] is None and dims[i] % dp_size == 0:
                parts[i] = dp
                return P(*parts)
        return spec

    out = {
        "embed": upgrade(specs["embed"], shapes["embed"], 0),
        "final_norm": P(None),
        "groups": [],
    }
    for g_spec, g_shape in zip(specs["groups"], shapes["groups"]):
        gg = {}
        for k, v in g_spec.items():
            if k in ("attn_norm", "ffn_norm"):
                gg[k] = v
            else:
                gg[k] = jax.tree.map(
                    lambda sp, sh: upgrade(sp, sh, 1),
                    v,
                    g_shape[k],
                    is_leaf=lambda x: isinstance(x, P),
                )
        out["groups"].append(gg)
    if "unembed" in specs:
        out["unembed"] = upgrade(specs["unembed"], shapes["unembed"], 0)
    return out


def _lm_train_flops(cfg: LMConfig, tokens: int) -> float:
    return 6.0 * cfg.active_param_count() * tokens


def _lm_fwd_flops(cfg: LMConfig, tokens: int, kv_len: int, batch: int) -> float:
    dense = 2.0 * cfg.active_param_count() * tokens
    # attention scores+values: 2 * 2 * h * dh * q * kv per sequence
    attn = 4.0 * cfg.n_layers * cfg.n_heads * cfg.d_head * (tokens // max(batch, 1)) * kv_len * batch
    return dense + attn


def _build_lm_cell(arch, cfg: LMConfig, shape: ShapeCell, mesh, probe_n_micro_one: bool = False) -> CellSpec:
    dp = dp_axes(mesh)
    n_dp = int(np.prod([mesh.shape[a] for a in dp]))
    pspecs = _fsdp_param_pspecs(cfg, dp, mesh)
    p_shapes = T.param_shapes(cfg)
    kind = shape.kind
    seq = shape.params["seq_len"]
    batch = shape.params["global_batch"]

    if kind == "train":
        # optimizer: Adafactor for the 100B-class archs (factored second
        # moments: O(n+m) state vs Adam's 2x O(nm) — the T5/PaLM recipe;
        # Adam moments alone would be 8.2 GB/device for dbrx-132b)
        use_adafactor = cfg.param_count() > 6e10

        def _row_spec(spec, shape):
            return P(*spec[: max(len(shape.shape) - 1, 0)]) if len(shape.shape) >= 2 else spec

        def _col_spec(spec, shape):
            nd = len(shape.shape)
            if nd < 2:
                return P()
            full = tuple(spec) + (None,) * (nd - len(spec))
            return P(*(full[: nd - 2] + (full[nd - 1],)))

        if use_adafactor:
            opt_shapes = jax.eval_shape(adafactor_init, p_shapes)
            row_specs = jax.tree.map(_row_spec, pspecs, p_shapes, is_leaf=lambda x: isinstance(x, P))
            col_specs = jax.tree.map(_col_spec, pspecs, p_shapes, is_leaf=lambda x: isinstance(x, P))
            opt_specs = type(opt_shapes)(row=row_specs, col=col_specs, count=P())
            opt_update = adafactor_update
        else:
            opt_shapes = jax.eval_shape(adamw_init, p_shapes)
            opt_specs = type(opt_shapes)(mu=pspecs, nu=pspecs, count=P())
            opt_update = adamw_update

        act_spec = P(dp, "model", None)  # batch x sequence-parallel residual
        # gradient-accumulation microbatching: activation memory scales with
        # batch/n_micro while params/optimizer stay resident (the standard
        # big-model memory lever)
        pc = cfg.param_count()
        n_micro = 1 if probe_n_micro_one else (16 if pc > 6e10 else (2 if pc > 1.4e10 else 1))
        micro = max(batch // max(n_micro, 1), n_dp)
        n_micro = batch // micro

        def train_step(params, opt_state, tokens, labels):
            t_m = tokens.reshape(n_micro, micro, seq)
            l_m = labels.reshape(n_micro, micro, seq)

            def micro_step(acc, inp):
                tm, lm = inp
                tm = jax.lax.with_sharding_constraint(tm, P(dp, None))
                loss, grads = jax.value_and_grad(T.loss_fn)(
                    params, cfg, tm, lm, act_spec, 512  # chunked vocab loss
                )
                acc_g, acc_l = acc
                acc_g = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc_g, grads)
                return (acc_g, acc_l + loss), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            if n_micro == 1:
                loss, grads = jax.value_and_grad(T.loss_fn)(
                    params, cfg, tokens, labels, act_spec, 512
                )
            else:
                (grads, loss), _ = jax.lax.scan(micro_step, (zeros, 0.0), (t_m, l_m))
                grads = jax.tree.map(lambda g: g / n_micro, grads)
                loss = loss / n_micro
            grads, gnorm = clip_by_global_norm(grads, 1.0)
            params, opt_state = opt_update(grads, opt_state, params, 3e-4)
            return params, opt_state, {"loss": loss, "gnorm": gnorm}

        args = (
            p_shapes,
            opt_shapes,
            jax.ShapeDtypeStruct((batch, seq), jnp.int32),
            jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        )
        in_sh = (
            _shard(mesh, pspecs),
            _shard(mesh, opt_specs),
            NamedSharding(mesh, P(dp, None)),
            NamedSharding(mesh, P(dp, None)),
        )
        return CellSpec(
            arch, shape.name, train_step, args, in_sh, (0, 1),
            _lm_train_flops(cfg, batch * seq),
            {"family": "lm", "kind": kind, "tokens": batch * seq, "n_micro": n_micro},
        )

    dtype = jnp.dtype(cfg.dtype)
    if kind == "prefill":
        cache_shapes = T.kv_cache_shapes(cfg, batch, seq)
        cache_specs = T.kv_cache_pspecs(cfg, dp, model_size=mesh.shape["model"])

        act_spec = P(dp, "model", None)

        def prefill_step(params, caches, tokens):
            logits, new_caches = T.prefill(params, cfg, tokens, caches, act_spec=act_spec)
            return logits[:, -1], new_caches

        args = (p_shapes, cache_shapes, jax.ShapeDtypeStruct((batch, seq), jnp.int32))
        in_sh = (_shard(mesh, pspecs), _shard(mesh, cache_specs), NamedSharding(mesh, P(dp, None)))
        return CellSpec(
            arch, shape.name, prefill_step, args, in_sh, (1,),
            _lm_fwd_flops(cfg, batch * seq, seq, batch),
            {"family": "lm", "kind": kind, "tokens": batch * seq},
        )

    # decode: one new token against a seq-length cache
    shard_seq = batch < n_dp  # long_500k: batch=1 -> shard the sequence axis
    cache_shapes = T.kv_cache_shapes(cfg, batch, seq)
    cache_specs = T.kv_cache_pspecs(cfg, dp, shard_seq=shard_seq, model_size=mesh.shape["model"])
    tok_spec = P(dp, None) if not shard_seq else P(None, None)

    def decode(params, caches, token, index):
        return T.decode_step(params, cfg, token, caches, index)

    args = (
        p_shapes,
        cache_shapes,
        jax.ShapeDtypeStruct((batch, 1), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
    )
    in_sh = (
        _shard(mesh, pspecs),
        _shard(mesh, cache_specs),
        NamedSharding(mesh, tok_spec),
        NamedSharding(mesh, P()),
    )
    return CellSpec(
        arch, shape.name, decode, args, in_sh, (1,),
        _lm_fwd_flops(cfg, batch, seq, batch),
        {"family": "lm", "kind": "decode", "tokens": batch, "kv_len": seq},
    )


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------


def _gnn_batch_specs(n: int, e: int, d_feat: int, mesh, equivariant: bool, n_graphs: int):
    dp = dp_axes(mesh)
    every = tuple(mesh.axis_names)
    shapes = GraphBatch(
        node_feat=jax.ShapeDtypeStruct((n, d_feat), jnp.float32),
        positions=jax.ShapeDtypeStruct((n, 3), jnp.float32) if equivariant else None,
        src=jax.ShapeDtypeStruct((e,), jnp.int32),
        dst=jax.ShapeDtypeStruct((e,), jnp.int32),
        edge_mask=jax.ShapeDtypeStruct((e,), jnp.float32),
        node_mask=jax.ShapeDtypeStruct((n,), jnp.float32),
        graph_id=jax.ShapeDtypeStruct((n,), jnp.int32),
        n_graphs=n_graphs,
    )
    specs = GraphBatch(
        node_feat=P(dp, None),
        positions=P(dp, None) if equivariant else None,
        src=P(every),
        dst=P(every),
        edge_mask=P(every),
        node_mask=P(dp),
        graph_id=P(dp),
        n_graphs=n_graphs,
    )
    return shapes, specs


def _gnn_flops(cfg: GNNConfig, n: int, e: int, d_feat: int) -> float:
    c = cfg.d_hidden
    if cfg.model == "gcn":
        return 2.0 * cfg.n_layers * (e * c + n * d_feat * c)
    if cfg.model == "gat":
        return 2.0 * cfg.n_layers * (e * cfg.n_heads * c * 3 + n * d_feat * cfg.n_heads * c)
    # equivariant: tp paths ~ 60c muls per edge per degree set + radial MLP
    per_edge = 60.0 * c + 2.0 * cfg.n_rbf * c + 6.0 * c * c
    per_node = 2.0 * (13 * c) * (3 * c) * 3  # linear mixes on s/v/t
    order = {1: 1, 2: 2, 3: 3}[max(cfg.correlation_order, 1)]
    return cfg.n_layers * (e * per_edge + n * per_node * order)


def _build_gnn_cell(arch, cfg: GNNConfig, shape: ShapeCell, mesh) -> CellSpec:
    equivariant = cfg.model in ("nequip", "mace")
    lanes = 512  # pad node/edge counts to a multiple that divides every mesh

    if shape.kind == "molecule":
        bsz = shape.params["batch"]
        n = _pad_to(shape.params["n_nodes"] * bsz, lanes)
        e = _pad_to(shape.params["n_edges"] * bsz * 2, lanes)
        d_feat, n_graphs = 16, bsz
    elif shape.kind == "minibatch":
        b = shape.params["batch_nodes"]
        f0, f1 = shape.params["fanout0"], shape.params["fanout1"]
        n = _pad_to(b * (1 + f0 + f0 * f1), lanes)
        e = _pad_to(2 * b * (f0 + f0 * f1), lanes)
        d_feat, n_graphs = 128, 1
    else:  # full_graph
        n = _pad_to(shape.params["n_nodes"], lanes)
        e = _pad_to(shape.params["n_edges"], lanes)
        d_feat, n_graphs = shape.params["d_feat"], 1

    run_cfg = cfg
    if equivariant and e > (1 << 22):
        run_cfg = dataclasses.replace(cfg, edge_chunk=1 << 18)

    from repro.models import gnn as G

    dp = dp_axes(mesh)
    p_shapes = jax.eval_shape(lambda: G.init_model(jax.random.PRNGKey(0), run_cfg, d_feat))
    p_specs = jax.tree.map(lambda _: P(), p_shapes)
    opt_shapes = jax.eval_shape(adamw_init, p_shapes)
    opt_specs = type(opt_shapes)(mu=p_specs, nu=p_specs, count=P())
    batch_shapes, batch_specs = _gnn_batch_specs(n, e, d_feat, mesh, equivariant, n_graphs)

    if cfg.model in ("gcn", "gat"):
        label_shape = jax.ShapeDtypeStruct((n,), jnp.int32)
        label_spec = P(dp)
    else:
        label_shape = jax.ShapeDtypeStruct((n_graphs,), jnp.float32)
        label_spec = P(dp) if n_graphs % max(int(np.prod([mesh.shape[a] for a in dp])), 1) == 0 and n_graphs > 1 else P(None)

    # Sharding layout: node-axis sharding for small/aligned graphs; CHANNEL
    # sharding for huge equivariant full-graph cells (edge gathers then index
    # the replicated node axis — no per-layer node-table all-gathers).
    huge = equivariant and n > (1 << 20)
    node_spec = dp
    chan_spec = "model" if huge else None

    def train_step(params, opt_state, batch, labels):
        loss, grads = jax.value_and_grad(G.loss_fn)(
            params, run_cfg, batch, labels, node_spec, chan_spec
        )
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt_state = adamw_update(grads, opt_state, params, 1e-3)
        return params, opt_state, {"loss": loss, "gnorm": gnorm}

    args = (p_shapes, opt_shapes, batch_shapes, label_shape)
    in_sh = (
        _shard(mesh, p_specs),
        _shard(mesh, opt_specs),
        _shard(mesh, batch_specs),
        NamedSharding(mesh, label_spec),
    )
    return CellSpec(
        arch, shape.name, train_step, args, in_sh, (0, 1),
        3.0 * _gnn_flops(cfg, n, e, d_feat),
        {"family": "gnn", "kind": shape.kind, "n_nodes": n, "n_edges": e},
    )


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------


def _recsys_param_structs(cfg: RecsysConfig, mesh):
    p_shapes = jax.eval_shape(lambda: RS.init_params(jax.random.PRNGKey(0), cfg))
    p_specs = RS.param_pspecs(cfg, dp=dp_axes(mesh))
    return p_shapes, p_specs


def _recsys_flops(cfg: RecsysConfig, batch: int) -> float:
    d = cfg.embed_dim
    lookups = batch * (cfg.n_user_fields + cfg.n_item_fields) * cfg.multi_hot_per_field * d
    dims_u = [d * cfg.n_user_fields] + list(cfg.tower_mlp)
    mlp = sum(2.0 * a * b for a, b in zip(dims_u[:-1], dims_u[1:])) * 2 * batch
    return lookups + mlp


def _build_recsys_cell(arch, cfg: RecsysConfig, shape: ShapeCell, mesh) -> CellSpec:
    dp = dp_axes(mesh)
    p_shapes, p_specs = _recsys_param_structs(cfg, mesh)
    bag = cfg.multi_hot_per_field
    kind = shape.kind
    batch = shape.params["batch"]

    def idx_args(b):
        return (
            jax.ShapeDtypeStruct((b, cfg.n_user_fields, bag), jnp.int32),
            jax.ShapeDtypeStruct((b, cfg.n_item_fields, bag), jnp.int32),
        )

    if kind == "train":
        opt_shapes = jax.eval_shape(adamw_init, p_shapes)
        opt_specs = type(opt_shapes)(mu=p_specs, nu=p_specs, count=P())

        def train_step(params, opt_state, user_idx, item_idx, log_q):
            loss, grads = jax.value_and_grad(RS.loss_fn)(params, cfg, user_idx, item_idx, log_q)
            grads, gnorm = clip_by_global_norm(grads, 1.0)
            params, opt_state = adamw_update(grads, opt_state, params, 1e-3)
            return params, opt_state, {"loss": loss, "gnorm": gnorm}

        args = (p_shapes, opt_shapes, *idx_args(batch), jax.ShapeDtypeStruct((batch,), jnp.float32))
        in_sh = (
            _shard(mesh, p_specs),
            _shard(mesh, opt_specs),
            NamedSharding(mesh, P(dp, None, None)),
            NamedSharding(mesh, P(dp, None, None)),
            NamedSharding(mesh, P(dp)),
        )
        flops = 3.0 * (_recsys_flops(cfg, batch) + 2.0 * batch * batch * cfg.tower_mlp[-1])
        return CellSpec(arch, shape.name, train_step, args, in_sh, (0, 1), flops,
                        {"family": "recsys", "kind": kind, "batch": batch})

    if kind == "serve":
        # bulk scoring: chunk the batch (lax.map) so the per-field gathered
        # (b, bag, d) embeddings never exceed ~1 GB concurrently
        chunk = 16384

        def serve(params, user_idx, item_idx):
            b = user_idx.shape[0]
            if b <= chunk or b % chunk:
                return RS.serve_scores(params, cfg, user_idx, item_idx)
            u_c = user_idx.reshape(b // chunk, chunk, *user_idx.shape[1:])
            i_c = item_idx.reshape(b // chunk, chunk, *item_idx.shape[1:])
            out = jax.lax.map(lambda ui: RS.serve_scores(params, cfg, ui[0], ui[1]), (u_c, i_c))
            return out.reshape(b)

        args = (p_shapes, *idx_args(batch))
        in_sh = (
            _shard(mesh, p_specs),
            NamedSharding(mesh, P(dp, None, None)),
            NamedSharding(mesh, P(dp, None, None)),
        )
        return CellSpec(arch, shape.name, serve, args, in_sh, (), _recsys_flops(cfg, batch),
                        {"family": "recsys", "kind": kind, "batch": batch})

    # retrieval: one query against n_candidates precomputed item vectors
    n_cand = shape.params["n_candidates"]
    d_out = cfg.tower_mlp[-1]

    def retrieve(params, user_idx, candidates):
        scores = RS.retrieval_scores(params, cfg, user_idx, candidates)
        return RS.retrieval_topk(scores, 100)

    args = (
        p_shapes,
        jax.ShapeDtypeStruct((1, cfg.n_user_fields, bag), jnp.int32),
        jax.ShapeDtypeStruct((n_cand, d_out), jnp.float32),
    )
    in_sh = (
        _shard(mesh, p_specs),
        NamedSharding(mesh, P(None, None, None)),
        NamedSharding(mesh, P("model", None)),
    )
    flops = 2.0 * n_cand * d_out + _recsys_flops(cfg, 1)
    return CellSpec(arch, shape.name, retrieve, args, in_sh, (), flops,
                    {"family": "recsys", "kind": kind, "n_candidates": n_cand})


# ---------------------------------------------------------------------------
# SubGraph2Vec (paper) cells
# ---------------------------------------------------------------------------


def _subgraph_flops(plan, n: int, e_directed: int) -> float:
    """SpMM: 2*E*C_p per stage; eMA: 3*n*C_out*splits per stage."""
    from repro.core.colorsets import binom

    total = 0.0
    for sub, table in zip(plan.partition.subs, plan.tables):
        if table is None:
            continue
        c_p = binom(plan.k, table.m_p)
        total += 2.0 * e_directed * c_p
        total += 3.0 * n * table.n_out * table.n_splits
    return total


def _build_subgraph_cell(arch, cfg, shape: ShapeCell, mesh, probe: bool = False) -> CellSpec:
    from repro.core import build_counting_plan, random_tree_template
    from repro.core.distributed import (
        distributed_input_specs,
        make_distributed_count_fn,
    )
    from repro.core.templates import PAPER_TEMPLATES

    k = shape.params["k"]
    tname = {12: "u12", 14: "u14", 17: "u17", 20: "u20"}.get(k)
    template = PAPER_TEMPLATES[tname] if tname else random_tree_template(k, seed=k)
    plan = build_counting_plan(template)

    n_shards = int(np.prod(list(mesh.shape.values())))
    n = shape.params["n_vertices"]
    n_padded = _pad_to(n, n_shards)
    e_directed = 2 * shape.params["n_edges"]
    edges_per_shard = _pad_to(int(e_directed / n_shards * 1.2), 8)

    # k >= 18: ship the streamed-eMA schedule (EXPERIMENTS.md §Perf paper
    # core iteration 1) — the batched-B baseline exceeds single-pod HBM at
    # u20 (19.7 GB/device; see results/perf/subgraph_u20.json)
    streamed = (k >= 18) and not probe
    # split tables are built once inside make_distributed_count_fn and
    # closure-captured — they are jit constants, not cell arguments
    fn = make_distributed_count_fn(
        plan, mesh, n_padded, edges_per_shard,
        column_batch=None if probe else 128,
        ema_mode="vectorized" if probe else ("streamed" if streamed else "loop"),
    )
    specs = distributed_input_specs(n_padded, n_shards, edges_per_shard)
    every = tuple(mesh.axis_names)
    in_sh = (
        NamedSharding(mesh, P(every)),
        NamedSharding(mesh, P(every)),
        NamedSharding(mesh, P(every)),
        NamedSharding(mesh, P(every)),
    )
    return CellSpec(
        arch, shape.name, fn, specs, in_sh, (),
        _subgraph_flops(plan, n_padded, e_directed),
        {"family": "subgraph", "kind": "count", "k": k, "n": n, "edges": e_directed},
    )


# ---------------------------------------------------------------------------


def build_cell(
    arch: str,
    shape: ShapeCell,
    mesh: Mesh,
    cfg_override=None,
    subgraph_probe: bool = False,
) -> CellSpec:
    family, module = get_arch(arch)
    cfg = cfg_override if cfg_override is not None else module.CONFIG
    if family == "lm":
        return _build_lm_cell(arch, cfg, shape, mesh, probe_n_micro_one=(cfg_override is not None))
    if family == "gnn":
        return _build_gnn_cell(arch, cfg, shape, mesh)
    if family == "recsys":
        return _build_recsys_cell(arch, cfg, shape, mesh)
    if family == "subgraph":
        return _build_subgraph_cell(arch, cfg, shape, mesh, probe=subgraph_probe)
    raise ValueError(f"unknown family {family}")
