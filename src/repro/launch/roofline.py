"""Roofline-term extraction from a compiled dry-run artifact.

Three terms per (arch x shape x mesh), in seconds (v5e constants):

    compute    = HLO_FLOPs / (chips * 197e12)           [bf16 peak]
    memory     = HLO_bytes / (chips * 819e9)            [HBM bw]
    collective = collective_bytes / (chips * 50e9)      [per-link ICI]

``cost_analysis()`` reports *per-device* flops/bytes post-partitioning, so
chips==1 in the denominators here (we keep the constants explicit for
clarity).  Collective bytes are parsed from the optimized HLO: every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute
operand, costed with ring-algorithm wire volume per device:

    all-reduce:      2 * (G-1)/G * bytes
    all-gather:          (G-1)/G * bytes   (of the gathered output)
    reduce-scatter:      (G-1)/G * bytes   (of the input)
    all-to-all:          (G-1)/G * bytes
    collective-permute:  bytes
"""

from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["PEAK_FLOPS", "HBM_BW", "ICI_BW", "RooflineReport", "analyze_compiled"]

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9       # bytes/s / chip
ICI_BW = 50e9        # bytes/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"(?P<shape>[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_GROUP_RE = re.compile(r"replica_groups=\[(?P<ng>\d+),(?P<gs>\d+)\]")
_GROUP_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _tensor_bytes(shape_str: str) -> int:
    """Total bytes of a possibly-tuple HLO shape string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    hlo_flops: float            # per device
    hlo_bytes: float            # per device
    collective_bytes: float     # wire bytes per device (ring-costed)
    collective_counts: Dict[str, int]
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float          # analytic useful flops (global)
    useful_flops_ratio: float   # model_flops / (hlo_flops * n_devices)
    per_device_memory_bytes: Optional[float] = None
    argument_bytes: Optional[float] = None
    meta: Dict = field(default_factory=dict)

    def to_json(self) -> Dict:
        return asdict(self)


def collective_wire_bytes(hlo_text: str) -> Tuple[float, Dict[str, int]]:
    """Sum ring-costed per-device wire bytes over every collective op."""
    total = 0.0
    counts: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        nbytes = _tensor_bytes(m.group("shape"))
        g = 1
        mg = _GROUP_RE.search(line)
        if mg:
            g = int(mg.group("gs"))
        else:
            ml = _GROUP_LIST_RE.search(line)
            if ml:
                g = len(ml.group(1).split(","))
        if g <= 1 and op != "collective-permute":
            continue
        frac = (g - 1) / g if g > 1 else 1.0
        if op == "all-reduce":
            total += 2.0 * frac * nbytes
        elif op in ("all-gather", "reduce-scatter", "all-to-all"):
            total += frac * nbytes
        else:  # collective-permute
            total += nbytes
        counts[op] = counts.get(op, 0) + 1
    return total, counts


def analyze_compiled(
    compiled,
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    n_devices: int,
    model_flops: float,
    meta: Optional[Dict] = None,
) -> RooflineReport:
    ca = compiled.cost_analysis() or {}
    flops = float(ca.get("flops", 0.0))
    bytes_accessed = float(ca.get("bytes accessed", 0.0))
    txt = compiled.as_text()
    coll_bytes, coll_counts = collective_wire_bytes(txt)

    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_accessed / HBM_BW
    collective_s = coll_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)

    mem_stats = None
    arg_bytes = None
    try:
        ms = compiled.memory_analysis()
        if ms is not None:
            # resident = arguments + temps + non-aliased outputs (donated
            # outputs alias their argument buffers — no double count)
            mem_stats = float(
                ms.argument_size_in_bytes
                + ms.temp_size_in_bytes
                + max(ms.output_size_in_bytes - ms.alias_size_in_bytes, 0)
            )
            arg_bytes = float(ms.argument_size_in_bytes)
    except Exception:
        pass

    denom = flops * n_devices
    return RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        n_devices=n_devices,
        hlo_flops=flops,
        hlo_bytes=bytes_accessed,
        collective_bytes=coll_bytes,
        collective_counts=coll_counts,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops=model_flops,
        useful_flops_ratio=(model_flops / denom) if denom else 0.0,
        per_device_memory_bytes=mem_stats,
        argument_bytes=arg_bytes,
        meta=meta or {},
    )
