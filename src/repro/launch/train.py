"""Training launcher CLI.

Runs a REAL (small-scale, CPU-capable) training job for any registered arch
using the full production substrate: config registry, data pipeline, AdamW,
checkpoint/restart, straggler watchdog.  The production mesh path is covered
by ``dryrun.py``; this entry point exercises the same step functions on the
local device(s).

  PYTHONPATH=src python -m repro.launch.train --arch granite-8b --smoke \
      --steps 50 --batch 8 --seq-len 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np


def make_lm_job(cfg, batch: int, seq_len: int, lr: float):
    from repro.data.pipeline import token_batches
    from repro.models import transformer as T
    from repro.train.optimizer import adamw_init, adamw_update, clip_by_global_norm

    params = T.init_params(jax.random.PRNGKey(0), cfg)
    state = {"params": params, "opt": adamw_init(params)}

    @jax.jit
    def train_step(state, batch_data):
        tokens, labels = batch_data
        loss, grads = jax.value_and_grad(T.loss_fn)(state["params"], cfg, tokens, labels)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt = adamw_update(grads, state["opt"], state["params"], lr)
        return {"params": params, "opt": opt}, {"loss": loss, "gnorm": gnorm}

    def data_factory(start_step):
        return token_batches(cfg, batch, seq_len, seed=0, start_step=start_step)

    return state, train_step, data_factory


def make_gnn_job(cfg, batch: int, lr: float):
    from repro.data.pipeline import graph_batch_from_shape
    from repro.models import gnn as G
    from repro.train.optimizer import adamw_init, adamw_update, clip_by_global_norm

    d_feat = 16
    gb, labels = graph_batch_from_shape(64, 128, d_feat, seed=0, batch_graphs=max(batch // 16, 1))
    if cfg.model in ("nequip", "mace"):
        labels = jnp.zeros((gb.n_graphs,), jnp.float32)
    params = G.init_model(jax.random.PRNGKey(0), cfg, d_feat)
    state = {"params": params, "opt": adamw_init(params)}

    @jax.jit
    def train_step(state, batch_data):
        gb, labels = batch_data
        loss, grads = jax.value_and_grad(G.loss_fn)(state["params"], cfg, gb, labels)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt = adamw_update(grads, state["opt"], state["params"], lr)
        return {"params": params, "opt": opt}, {"loss": loss, "gnorm": gnorm}

    def data_factory(start_step):
        def gen():
            while True:
                yield (gb, labels)
        return gen()

    return state, train_step, data_factory


def make_recsys_job(cfg, batch: int, lr: float):
    from repro.data.pipeline import click_batches
    from repro.models import recsys as R
    from repro.train.optimizer import adamw_init, adamw_update, clip_by_global_norm

    params = R.init_params(jax.random.PRNGKey(0), cfg)
    state = {"params": params, "opt": adamw_init(params)}

    @jax.jit
    def train_step(state, batch_data):
        uix, iix, log_q = batch_data
        loss, grads = jax.value_and_grad(R.loss_fn)(state["params"], cfg, uix, iix, log_q)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt = adamw_update(grads, state["opt"], state["params"], lr)
        return {"params": params, "opt": opt}, {"loss": loss, "gnorm": gnorm}

    def data_factory(start_step):
        return click_batches(cfg, batch, seed=0, start_step=start_step)

    return state, train_step, data_factory


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="use the reduced SMOKE_CONFIG")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args(argv)

    from repro.configs.registry import get_arch
    from repro.train.loop import LoopConfig, TrainLoop

    family, module = get_arch(args.arch)
    cfg = module.SMOKE_CONFIG if args.smoke else module.CONFIG

    if family == "lm":
        state, step, data = make_lm_job(cfg, args.batch, args.seq_len, args.lr)
    elif family == "gnn":
        state, step, data = make_gnn_job(cfg, args.batch, args.lr)
    elif family == "recsys":
        state, step, data = make_recsys_job(cfg, args.batch, args.lr)
    else:
        raise SystemExit(f"train launcher does not support family {family}")

    loop = TrainLoop(
        LoopConfig(
            total_steps=args.steps,
            ckpt_dir=args.ckpt_dir,
            ckpt_every=args.ckpt_every,
            log_every=max(args.steps // 10, 1),
        ),
        step,
        data,
        state,
    )
    resumed = loop.try_restore()
    print(f"arch={args.arch} family={family} resumed={resumed} start_step={loop.step}")
    t0 = time.monotonic()
    loop.run()
    dt = time.monotonic() - t0
    hist = loop.metrics_history
    print(f"done {args.steps} steps in {dt:.1f}s; loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")
    if loop.straggler_events:
        print(f"straggler events: {len(loop.straggler_events)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
