"""CountingService: a multi-tenant query layer over cached CountingEngines.

The engine (:mod:`repro.core.engine`) answers ONE (graph, template-set)
workload well; a serving deployment faces many tenants asking overlapping
questions — many templates x many graphs x accuracy targets (the
motif/graphlet query workload of the subgraph-counting literature).  Naively
that is one hand-built engine per call: a fresh trace+compile every time and
a blind fixed iteration count.  ``CountingService`` closes both gaps with
three pieces:

* **Compiled-engine cache** (:mod:`repro.serve.cache`): engines are shared
  behind :func:`repro.core.engine.engine_cache_key` with LRU eviction.  A
  repeat query — same graph signature, template canons, backend, dtype
  policy, chunk spec — reuses the warm engine AND its compiled run program:
  zero new traces (``engine.trace_count`` holds still), asserted in tests.
  Iteration counts never enter the key: every launch is padded to the
  engine's ``chunk_size`` (shape-bucketed), so arbitrary iteration targets
  hit one compiled shape.
* **Cross-query batching**: pending queries that resolve to the same engine
  key are merged into ONE chunked ``counts_for_keys_chunk`` launch — their
  colorings ride the same fused column dimension of the DP state (the
  engine's B axis), and results are scattered back per query.  Per-query
  colorings are drawn with ``fold_in(PRNGKey(query.seed), iteration)``, so
  the values each query receives are independent of who shared its launch.
* **Adaptive (epsilon, delta) stopping** (:mod:`repro.serve.stopping`):
  each query folds its per-coloring estimates into a running mean/variance
  and stops at its relative CI target instead of a blind fixed N.

Scheduling is a round-robin **admission loop over engine keys**: one launch
per eligible key per cycle, so a hot graph with a deep queue cannot starve
other tenants — every key with pending work gets device time each cycle.
The loop is single-threaded and deterministic: a fixed submission order and
fixed seeds reproduce every launch, estimate, and stopping decision exactly.

This module stays synchronous by design; the production concurrency story
lives one layer up in :mod:`repro.serve.frontend` (``ServiceFrontend``):
futures, per-tenant priority tiers and token-bucket rate limits, cost-model
backpressure, streaming progress, and background engine pre-warming — all
of it driving *this* loop from exactly one scheduler thread, so the
determinism guarantee above carries over unchanged.
"""

from __future__ import annotations

import logging
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.engine import (
    DEFAULT_MEMORY_BUDGET_BYTES,
    CountingEngine,
    engine_cache_key,
)
from repro.core.estimator import required_iterations
from repro.core.graph import Graph
from repro.core.templates import Template, connected_graphlets, get_template
from repro.plan.cost import degradation_ladder

from .cache import EngineCache
from .qos import Clock, SystemClock
from .resilience import (
    DEFAULT_QUARANTINE_BASE_S,
    DEFAULT_RETRY_POLICY,
    FailState,
    QuarantinedError,
    RetryPolicy,
    ServiceError,
    classify_failure,
)
from .stopping import DEFAULT_MIN_ITERATIONS, AdaptiveStopper, TemplateCI

__all__ = ["CountingService", "Query", "QueryEstimate"]

logger = logging.getLogger("repro.serve")

#: Iterations for a query that names neither an (epsilon, delta) target nor
#: an explicit iteration count (the engine-layer fixed-N default).
DEFAULT_FIXED_ITERATIONS = 32

#: Iteration budget cap for adaptive queries that don't pass their own.
DEFAULT_ADAPTIVE_BUDGET = 1024


@dataclass
class QueryEstimate:
    """Final per-template answer of a completed query.

    ``degraded=True`` marks a deadline-resolved best-effort estimate: the
    query's deadline passed with the stopper still running, so the answer
    is the running mean with BOTH CI halfwidths attached (normal and
    empirical-Bernstein — always populated once two samples exist, degraded
    or not) instead of a converged result.
    """

    template: str
    mean: float
    std: float
    halfwidth: float  # CI halfwidth at stop time (0.0 for fixed-N queries)
    converged: bool  # CI target met (False when the budget ran out / fixed-N)
    halfwidth_normal: float = 0.0  # CLT z-interval at resolve time
    halfwidth_bernstein: float = 0.0  # empirical-Bernstein at resolve time
    degraded: bool = False  # resolved at deadline with the running estimate


@dataclass
class Query:
    """One submitted counting question and its lifecycle state.

    ``status`` walks ``pending -> running -> done`` (or ``-> cancelled``
    via :meth:`CountingService.cancel`, or ``-> failed`` with a structured
    :class:`~repro.serve.resilience.ServiceError` on ``error``);
    ``iterations`` is the number of colorings actually spent (== the fixed
    target for fixed-N queries, <= budget for adaptive ones).  ``tenant``
    is opaque caller metadata (the front-end stamps its tenant name here
    for observability).  ``retries`` counts launch attempts this query
    paid for through transient failures; ``degraded`` marks a
    deadline-resolved best-effort result (status still ``done``).
    """

    qid: int
    graph_ref: str
    templates: Tuple[Template, ...]
    epsilon: Optional[float]
    delta: float
    budget: int
    seed: int
    engine_key: Tuple
    stopper: AdaptiveStopper
    status: str = "pending"
    tenant: Optional[str] = None
    estimates: Optional[List[QueryEstimate]] = None
    record_rows: bool = False
    rows: Optional[List[np.ndarray]] = None  # (m, T) blocks when recording
    deadline_at: Optional[float] = None  # absolute, on the service clock
    retry_policy: Optional[RetryPolicy] = None  # None = service default
    retries: int = 0
    error: Optional[ServiceError] = None
    degraded: bool = False
    _base_key: np.ndarray = field(default=None, repr=False)
    _drawn: int = 0  # next coloring iteration index to draw

    def per_iteration(self) -> np.ndarray:
        """``(iterations, T)`` per-coloring estimates (``record_rows`` only)."""
        if not self.record_rows:
            raise RuntimeError("submit(..., record_rows=True) to keep rows")
        if not self.rows:
            return np.zeros((0, len(self.templates)), np.float64)
        return np.concatenate(self.rows, axis=0)

    @property
    def done(self) -> bool:
        return self.status == "done"

    @property
    def cancelled(self) -> bool:
        return self.status == "cancelled"

    @property
    def failed(self) -> bool:
        return self.status == "failed"

    @property
    def finished(self) -> bool:
        """Terminal any way — done with a result, cancelled, or failed."""
        return self.status in ("done", "cancelled", "failed")

    @property
    def iterations(self) -> int:
        return self.stopper.iterations

    def progress(self) -> List[TemplateCI]:
        """Streaming partial results: the stopper's live per-template view.

        Valid at any point in the lifecycle — running mean, sample std,
        and BOTH CI halfwidths (normal and empirical-Bernstein) plus the
        ``lower``/``upper`` interval edges under the query's configured
        bound (see :class:`repro.serve.stopping.TemplateCI`).  Callers can
        act on a converging estimate before the stopping rule fires.
        """
        return self.stopper.estimates()

    def result(self) -> List[QueryEstimate]:
        if self.failed:
            raise self.error
        if not self.done:
            raise RuntimeError(f"query {self.qid} is {self.status}, not done")
        return self.estimates


class CountingService:
    """Shared serving front-end; see the module docstring for the design.

    Args:
      max_engines: LRU capacity of the compiled-engine cache.
      backend / dtype_policy / chunk_size / memory_budget_bytes: forwarded
        to every engine the service builds (and folded into cache keys).
      default_budget: iteration cap for adaptive queries without their own.
      min_iterations: CI arming threshold (see ``AdaptiveStopper``).
      clock: time source for deadlines, retry backoff, and quarantine
        windows (``SystemClock`` by default; a frontend with a manual
        clock re-points this at its own so the two never disagree).
      retry_policy: default transient-failure policy for queries that
        don't pass their own ``retry_policy=`` at submit.
      quarantine_base_s: first quarantine window for an engine key that
        keeps failing deterministically (doubles per re-quarantine).
      engine_kwargs: extra ``CountingEngine`` construction kwargs every
        build forwards (e.g. ``mesh=`` for a mesh-backed service); NOT
        part of the cache key — callers own their identity.
    """

    def __init__(
        self,
        *,
        max_engines: int = 8,
        backend: str = "auto",
        dtype_policy: Union[str, None] = "fp32",
        chunk_size: Optional[int] = None,
        memory_budget_bytes: int = DEFAULT_MEMORY_BUDGET_BYTES,
        default_budget: int = DEFAULT_ADAPTIVE_BUDGET,
        min_iterations: int = DEFAULT_MIN_ITERATIONS,
        clock: Optional[Clock] = None,
        retry_policy: Optional[RetryPolicy] = None,
        quarantine_base_s: float = DEFAULT_QUARANTINE_BASE_S,
        engine_kwargs: Optional[Dict] = None,
    ):
        self.backend = backend
        self.dtype_policy = dtype_policy
        self.chunk_size = chunk_size
        self.memory_budget_bytes = int(memory_budget_bytes)
        self.default_budget = int(default_budget)
        self.min_iterations = int(min_iterations)
        self.clock = clock if clock is not None else SystemClock()
        self.default_retry_policy = (
            retry_policy if retry_policy is not None else DEFAULT_RETRY_POLICY
        )
        self.quarantine_base_s = float(quarantine_base_s)
        self.engine_kwargs = dict(engine_kwargs or {})
        self._graphs: Dict[str, Graph] = {}
        self._signatures: Dict[str, str] = {}
        self._cache = EngineCache(capacity=max_engines)
        self._next_qid = 0
        self._active: Dict[Tuple, List[Query]] = {}  # engine key -> live queries
        self._rr: Deque[Tuple] = deque()  # round-robin ring of keys with work
        self.launch_log: List[Tuple] = []  # engine key per launch, in order
        self.queries_completed = 0
        self.queries_cancelled = 0
        self.queries_failed = 0
        self.queries_degraded = 0
        # failure semantics (docs/serving.md "Failure semantics"): per-key
        # retry/quarantine state, ladder config overrides, fault counters
        self._fail: Dict[Tuple, FailState] = {}
        self._overrides: Dict[Tuple, Dict] = {}  # ladder-rung engine kwargs
        self._ladders: Dict[Tuple, List] = {}  # key -> its degradation rungs
        self.fault_counters: Dict[str, int] = {
            "transient": 0,
            "memory": 0,
            "deterministic": 0,
            "invalid": 0,
            "non_finite": 0,
        }
        # autotuning (repro.tune): ``REPRO_TUNE=full`` records un-tuned
        # workloads at submit; a front-end scheduler drains them one per
        # round through tune() (prewarm-style background work)
        self._tune_pending: Deque[Tuple[str, Tuple[Template, ...]]] = deque()
        self._tune_requested: set = set()  # engine keys ever queued/tuned
        self.tunes_completed = 0

    # ------------------------------------------------------------------
    # Registration & submission
    # ------------------------------------------------------------------

    def register_graph(self, name: str, graph: Graph) -> str:
        """Register ``graph`` under ``name``; returns its content signature.

        Re-registering a name with an identical signature is a no-op;
        re-registering with different content is an error (queries in
        flight reference the old content).
        """
        sig = graph.signature()
        if name in self._signatures and self._signatures[name] != sig:
            raise ValueError(
                f"graph {name!r} already registered with different content"
            )
        self._graphs[name] = graph
        self._signatures[name] = sig
        return sig

    def graph(self, name: str) -> Graph:
        if name not in self._graphs:
            raise KeyError(
                f"unknown graph {name!r} — register_graph() it first "
                f"(known: {sorted(self._graphs)})"
            )
        return self._graphs[name]

    def _resolve_templates(
        self, templates: Union[str, Template, Sequence[Union[str, Template]]]
    ) -> Tuple[Template, ...]:
        if isinstance(templates, (str, Template)):
            templates = [templates]
        out = tuple(get_template(t) if isinstance(t, str) else t for t in templates)
        if not out:
            raise ValueError("query needs at least one template")
        return out

    def engine_key_for(self, graph_ref: str, templates) -> Tuple:
        """The engine cache key a query of this shape resolves to."""
        return engine_cache_key(
            self.graph(graph_ref),
            self._resolve_templates(templates),
            backend=self.backend,
            dtype_policy=self.dtype_policy,
            chunk_size=self.chunk_size,
            memory_budget_bytes=self.memory_budget_bytes,
        )

    def submit(
        self,
        graph_ref: str,
        templates: Union[str, Template, Sequence[Union[str, Template]]],
        *,
        epsilon: Optional[float] = None,
        delta: float = 0.05,
        iterations: Optional[int] = None,
        seed: int = 0,
        record_rows: bool = False,
        bound: str = "normal",
        tenant: Optional[str] = None,
        deadline: Optional[float] = None,
        retry_policy: Optional[RetryPolicy] = None,
    ) -> Query:
        """Queue a query; returns its handle (drive it with :meth:`run`).

        ``epsilon``/``delta``: relative CI target — the query stops as soon
        as every template's halfwidth is within ``epsilon * |mean|`` at
        confidence ``1 - delta`` (``iterations`` then caps the budget,
        default ``default_budget``).  Without ``epsilon`` the query runs a
        fixed ``iterations`` colorings (default ``32``).  ``record_rows``
        keeps the per-coloring estimates on the handle
        (:meth:`Query.per_iteration`) instead of just the running moments.
        ``bound`` picks the CI the stopper tests: ``"normal"`` (default)
        or the more conservative ``"bernstein"`` for heavy-tailed
        per-coloring counts (see :mod:`repro.serve.stopping`).

        ``deadline``: seconds from now (service clock).  When it passes, a
        query with >= 2 iterations resolves ``done`` with its running
        estimate, both CI halfwidths, and ``degraded=True``; with fewer it
        fails with a ``deadline`` :class:`ServiceError`.  ``retry_policy``
        overrides the service default for transient launch failures.

        Raises :class:`~repro.serve.resilience.QuarantinedError`
        immediately while the query's engine key is quarantined — no queue
        slot is taken for work the scheduler would refuse to run.
        """
        graph = self.graph(graph_ref)
        tset = self._resolve_templates(templates)
        if epsilon is not None:
            if iterations:
                budget = int(iterations)
            else:
                # never budget past the a-priori Alon bound — it is generic
                # over k-vertex templates (k!/k^k colorful-hit probability),
                # so non-tree graphlet queries get the same default cap
                blind = required_iterations(
                    max(t.k for t in tset), epsilon, delta
                )
                budget = min(self.default_budget, blind)
        else:
            budget = int(iterations) if iterations else DEFAULT_FIXED_ITERATIONS
        key = self.engine_key_for(graph_ref, tset)
        self._maybe_queue_tune(key, graph_ref, tset)
        now = self.clock.now()
        fs = self._fail.get(key)
        if fs is not None and now < fs.quarantined_until:
            raise QuarantinedError(
                f"engine key quarantined for another "
                f"{fs.quarantined_until - now:.3f}s (quarantine "
                f"#{fs.quarantines} after repeated deterministic failures)",
                engine_key=key,
                retry_at=fs.quarantined_until,
            )
        stopper = AdaptiveStopper(
            len(tset),
            epsilon=epsilon,
            delta=delta,
            budget=budget,
            min_iterations=self.min_iterations,
            bound=bound,
        )
        query = Query(
            qid=self._next_qid,
            graph_ref=graph_ref,
            templates=tset,
            epsilon=epsilon,
            delta=delta,
            budget=budget,
            seed=seed,
            engine_key=key,
            stopper=stopper,
            tenant=tenant,
            record_rows=record_rows,
            rows=[] if record_rows else None,
            deadline_at=None if deadline is None else now + float(deadline),
            retry_policy=retry_policy,
            _base_key=np.asarray(jax.random.PRNGKey(seed)),
        )
        self._next_qid += 1
        if key not in self._active:
            self._active[key] = []
            self._rr.append(key)
        self._active[key].append(query)
        return query

    # ------------------------------------------------------------------
    # The admission loop
    # ------------------------------------------------------------------

    def _engine_for(self, key: Tuple, query: Query) -> CountingEngine:
        overrides = self._overrides.get(key, {})

        def build():
            kwargs = dict(
                backend=overrides.get("backend", self.backend),
                dtype_policy=self.dtype_policy,
                chunk_size=overrides.get("chunk_size", self.chunk_size),
                memory_budget_bytes=self.memory_budget_bytes,
                **self.engine_kwargs,
            )
            if "column_batch" in overrides:
                kwargs["column_batch"] = overrides["column_batch"]
            return CountingEngine(
                self.graph(query.graph_ref), list(query.templates), **kwargs
            )

        return self._cache.get(key, build)

    def step(self) -> Optional[Tuple]:
        """Serve ONE launch attempt to the next engine key in round-robin
        order.

        Merges that key's live queries into one chunk: slots are dealt one
        coloring at a time, cycling the queries, so concurrent tenants of a
        hot engine split each launch fairly; unfilled slots are padded
        (same compiled shape either way).  Returns the engine key served,
        or ``None`` when no query is runnable *now* (queue empty, or every
        key with work is parked behind retry backoff / quarantine —
        :meth:`run` sleeps or advances the clock to the next timer in that
        case).

        Failure semantics (docs/serving.md): expired deadlines are swept
        first (degrading armed queries instead of failing them); a build
        or launch exception is classified ``transient`` (per-query retry
        accounting + exponential key backoff), ``memory`` (walk one
        degradation-ladder rung and rebuild), or ``deterministic`` (fail
        the attempt's queries; quarantine the key on repeat).  A failed
        attempt still returns the key — failure bookkeeping is progress.
        """
        now = self.clock.now()
        self._sweep_deadlines(now)

        skipped: List[Tuple] = []
        key: Optional[Tuple] = None
        queries: List[Query] = []
        while self._rr:
            cand = self._rr.popleft()
            live = [q for q in self._active.get(cand, []) if not q.finished]
            if not live:
                self._active.pop(cand, None)  # drained key leaves the ring
                continue
            fs = self._fail.get(cand)
            if fs is not None and fs.blocked_until(now) is not None:
                skipped.append(cand)  # parked: backoff or quarantine
                continue
            key, queries = cand, live
            break
        self._rr.extend(skipped)
        if key is None:
            return None

        try:
            engine = self._engine_for(key, queries[0])
        except Exception as exc:
            self._handle_failure(key, queries, exc, now, phase="build")
            self._requeue(key)
            return key
        chunk = engine.chunk_size

        # deal slots round-robin across this key's queries (iteration order
        # per query is preserved: each deal hands out its next index)
        alloc: List[Tuple[Query, int]] = []
        dealt: Dict[int, int] = {}
        ring = deque(queries)
        while ring and len(alloc) < chunk:
            q = ring.popleft()
            d = dealt.get(q.qid, 0)
            if q.stopper.remaining_budget() > d:
                alloc.append((q, q._drawn + d))
                dealt[q.qid] = d + 1
                ring.append(q)

        # one vmapped dispatch for the whole launch's keys (a per-slot
        # fold_in loop costs a host dispatch per coloring — hot-path tax);
        # vmapped fold_in is bit-identical to the per-call draw
        bases = jnp.asarray(np.stack([q._base_key for q, _ in alloc]))
        idxs = jnp.asarray(np.asarray([idx for _, idx in alloc], np.uint32))
        keys_np = np.asarray(jax.vmap(jax.random.fold_in)(bases, idxs), np.uint32)
        try:
            rows = engine.count_keys_chunk(keys_np)  # (len(alloc), T) float64
        except Exception as exc:
            # nothing was scattered and no ``_drawn`` advanced, so a retry
            # re-draws the exact same fold_in colorings — surviving queries
            # stay bit-exact vs an unfailed run (the cancel mechanism)
            self._handle_failure(key, queries, exc, now, phase="launch")
            self._requeue(key)
            return key
        self.launch_log.append(key)
        fs = self._fail.get(key)
        if fs is not None:
            fs.note_success()

        # scatter results back per query, in iteration order, and advance
        per_query: Dict[int, List[np.ndarray]] = {}
        by_qid = {q.qid: q for q, _ in alloc}
        for (q, _), row in zip(alloc, rows):
            per_query.setdefault(q.qid, []).append(row)
        for qid, qrows in per_query.items():
            q = by_qid[qid]
            block = np.stack(qrows)
            q._drawn += block.shape[0]
            if not np.isfinite(block).all():
                # catch NaN/Inf BEFORE the stopper folds it into Welford
                # state — only the query whose colorings produced the bad
                # rows fails; launch-mates keep their (finite) blocks
                self.fault_counters["non_finite"] += 1
                self._fail_query(
                    q,
                    ServiceError(
                        "non_finite",
                        "chunk produced NaN/Inf estimates for this query's "
                        "colorings",
                        engine_key=key,
                        qid=q.qid,
                    ),
                )
                continue
            q.status = "running"
            if q.record_rows:
                q.rows.append(block)
            q.stopper.update(block)
            if q.stopper.done:
                self._finalize(q)

        self._requeue(key)
        return key

    def _requeue(self, key: Tuple) -> None:
        still_live = [q for q in self._active.get(key, []) if not q.finished]
        if still_live:
            self._active[key] = still_live
            self._rr.append(key)
        else:
            self._active.pop(key, None)

    def _finalize(self, query: Query, *, degraded: bool = False) -> None:
        cis: List[TemplateCI] = query.stopper.estimates()
        query.estimates = [
            QueryEstimate(
                template=t.name,
                mean=ci.mean,
                std=ci.std,
                halfwidth=0.0 if query.epsilon is None else ci.halfwidth,
                converged=ci.converged,
                halfwidth_normal=ci.halfwidth_normal,
                halfwidth_bernstein=ci.halfwidth_bernstein,
                degraded=degraded,
            )
            for t, ci in zip(query.templates, cis)
        ]
        query.degraded = degraded
        query.status = "done"
        self.queries_completed += 1
        if degraded:
            self.queries_degraded += 1

    def _fail_query(self, query: Query, error: ServiceError) -> None:
        query.error = error
        query.status = "failed"
        self.queries_failed += 1

    def _sweep_deadlines(self, now: float) -> None:
        """Resolve every live query whose deadline has passed.

        Accuracy/latency degradation, not an error: a query with an armed
        stopper (>= 2 iterations, so both CI halfwidths exist) finalizes
        ``done`` with its running estimate and ``degraded=True``; one that
        never accumulated two samples fails with a ``deadline`` error.
        """
        for key in list(self._active):
            for q in self._active.get(key, []):
                if q.finished or q.deadline_at is None or now < q.deadline_at:
                    continue
                if q.stopper.count >= 2:
                    self._finalize(q, degraded=True)
                else:
                    self._fail_query(
                        q,
                        ServiceError(
                            "deadline",
                            f"deadline passed after {q.stopper.count} "
                            f"iterations — too few for a running estimate",
                            engine_key=key,
                            qid=q.qid,
                        ),
                    )

    def _ladder_for(self, key: Tuple, query: Query) -> List:
        """This key's degradation rungs (memoized; base config from the
        cache key itself, so it is stable however the engine is rebuilt)."""
        if key not in self._ladders:
            backend = key[3]
            chunk_spec, column_batch = key[6], key[7]
            if chunk_spec[0] == "chunk":
                base_chunk = int(chunk_spec[1])
            else:
                from repro.core.engine import DtypePolicy
                from repro.plan.cost import admission_estimate

                base_chunk = admission_estimate(
                    self.graph(query.graph_ref),
                    query.templates,
                    store_dtype=DtypePolicy.resolve(self.dtype_policy).store_dtype,
                    memory_budget_bytes=self.memory_budget_bytes,
                ).chunk_size
            self._ladders[key] = degradation_ladder(
                base_chunk, column_batch, backend
            )
        return self._ladders[key]

    def _handle_failure(
        self,
        key: Tuple,
        queries: List[Query],
        exc: Exception,
        now: float,
        *,
        phase: str,
    ) -> None:
        """Classify one failed build/launch attempt and apply its policy."""
        kind = classify_failure(exc)
        self.fault_counters[kind] += 1
        fs = self._fail.setdefault(key, FailState())

        if kind == "transient":
            policy = queries[0].retry_policy or self.default_retry_policy
            fs.note_transient(now, policy)
            for q in queries:
                pol = q.retry_policy or self.default_retry_policy
                q.retries += 1
                fs.retries_total += 1
                if q.retries > pol.max_retries:
                    self._fail_query(
                        q,
                        ServiceError(
                            "retries_exhausted",
                            f"{pol.max_retries} retries spent at {phase}",
                            engine_key=key,
                            qid=q.qid,
                            cause=exc,
                        ),
                    )
            return

        if kind == "memory":
            fs.note_memory()
            rungs = self._ladder_for(key, queries[0])
            if fs.ladder_rung >= len(rungs):
                for q in queries:
                    self._fail_query(
                        q,
                        ServiceError(
                            "memory_exhausted",
                            f"degradation ladder exhausted after "
                            f"{len(rungs)} rungs at {phase}",
                            engine_key=key,
                            qid=q.qid,
                            cause=exc,
                        ),
                    )
                return
            rung = rungs[fs.ladder_rung]
            fs.ladder_rung += 1
            overrides = {"chunk_size": rung.chunk_size}
            if rung.column_batch is not None:
                overrides["column_batch"] = rung.column_batch
            if rung.backend is not None:
                overrides["backend"] = rung.backend
            self._overrides[key] = overrides
            self._cache.invalidate(key)  # next step rebuilds at the rung
            fs.ladder_log.append(
                {
                    "rung": fs.ladder_rung,
                    "action": rung.action,
                    "phase": phase,
                    **overrides,
                    "repriced_chunk_bytes": self._reprice_rung(
                        key, queries[0], rung
                    ),
                }
            )
            return

        if kind == "invalid":
            # the QUERY is malformed (e.g. a bag plan on the mesh backend:
            # BagPlanUnsupported), not the engine key poisoned — fail the
            # queries with the structured error and leave the FailState
            # untouched, so resubmitting the same impossible query never
            # walks the key into quarantine
            for q in queries:
                self._fail_query(
                    q,
                    ServiceError(
                        "invalid",
                        f"{type(exc).__name__} at {phase}: {exc}",
                        engine_key=key,
                        qid=q.qid,
                        cause=exc,
                    ),
                )
            return

        # deterministic: retries will never clear it — fail the attempt's
        # queries now, and after repeat strikes quarantine the key so the
        # poisoned (graph, template) pair stops consuming its ring slot
        until = fs.note_deterministic(now, self.quarantine_base_s)
        for q in queries:
            self._fail_query(
                q,
                ServiceError(
                    "deterministic",
                    f"{type(exc).__name__} at {phase}: {exc}",
                    engine_key=key,
                    qid=q.qid,
                    cause=exc,
                ),
            )
        if until is not None:
            self._cache.invalidate(key)  # a fresh build gets a clean slate
            # the ladder must not fight a poisoned tuned config: quarantine
            # drops the key's tuned cache entry so the post-quarantine
            # rebuild re-resolves from the heuristic
            self._drop_tuned_entry(key)

    def _reprice_rung(self, key: Tuple, query: Query, rung) -> int:
        """``admission_estimate`` re-prices the rung's launch residency
        (recorded in the ladder log and used by ``admission_bytes`` until
        the rebuilt engine answers exactly)."""
        from repro.core.engine import DtypePolicy
        from repro.plan.cost import admission_estimate

        return admission_estimate(
            self.graph(query.graph_ref),
            query.templates,
            store_dtype=DtypePolicy.resolve(self.dtype_policy).store_dtype,
            chunk_size=rung.chunk_size,
            memory_budget_bytes=self.memory_budget_bytes,
        ).chunk_bytes

    def _next_event_at(self) -> Optional[float]:
        """Earliest instant parked/deadlined work becomes actionable
        (None when nothing is waiting on a timer)."""
        now = self.clock.now()
        times: List[float] = []
        for key, qs in self._active.items():
            live = [q for q in qs if not q.finished]
            if not live:
                continue
            fs = self._fail.get(key)
            until = fs.blocked_until(now) if fs is not None else None
            if until is None:
                return now  # a key is schedulable right now
            times.append(until)
            times.extend(
                q.deadline_at for q in live if q.deadline_at is not None
            )
        return min(times) if times else None

    def _wait_until(self, target: float) -> None:
        """Advance a manual clock, or sleep a bounded slice of wall time."""
        now = self.clock.now()
        if target <= now:
            return
        advance = getattr(self.clock, "advance", None)
        if advance is not None:
            advance(target - now)
        else:
            time.sleep(min(target - now, 0.05))

    def run(self, max_launches: Optional[int] = None) -> None:
        """Drive the admission loop until every submitted query resolves.

        When every key with pending work is parked (retry backoff /
        quarantine), waits for the next timer — advancing a manual clock
        deterministically, or sleeping in bounded slices on a system clock
        — instead of spinning or returning early.
        """
        launches = 0
        while True:
            served = self.step()
            if served is not None:
                launches += 1
                if max_launches is not None and launches >= max_launches:
                    return
                continue
            if not self.has_pending():
                return
            target = self._next_event_at()
            if target is None:  # pragma: no cover - defensive
                raise RuntimeError(
                    "pending work but no schedulable key and no armed timer"
                )
            self._wait_until(target)

    def has_pending(self) -> bool:
        """True while any admitted query still needs launches."""
        return any(
            not q.finished for qs in self._active.values() for q in qs
        )

    def cancel(self, query: Query) -> bool:
        """Cancel a live query; True if it was still cancellable.

        The query flips to ``cancelled`` and is dropped from its engine
        key's merge list — colorings already spent are simply discarded
        (its launch slots are re-dealt to surviving queries from the next
        launch on).  Cancelling a finished query is a no-op returning
        False.  Other queries are untouched: their colorings are seed-
        folded per query, so counts never depend on who shared a launch.
        """
        if query.finished:
            return False
        query.status = "cancelled"
        live = self._active.get(query.engine_key)
        if live is not None:
            remaining = [q for q in live if q.qid != query.qid]
            if remaining:
                self._active[query.engine_key] = remaining
            # an emptied key stays in the ring; step() retires it lazily
        self.queries_cancelled += 1
        return True

    def admission_bytes(self, graph_ref: str, templates) -> int:
        """Predicted live bytes one launch of this query would hold.

        The front-end's load-shedding currency.  A warm cached engine
        answers exactly (``predicted_peak_bytes()``); otherwise the plan
        layer prices the query without building anything
        (:func:`repro.plan.cost.admission_estimate` — same resident
        formula and fusion-slack calibration the engine's chunk picker
        uses, microseconds of host work).
        """
        from repro.core.engine import DtypePolicy
        from repro.plan.cost import admission_estimate

        graph = self.graph(graph_ref)
        tset = self._resolve_templates(templates)
        engine = self._cache.peek(self.engine_key_for(graph_ref, tset))
        if engine is not None:
            return engine.predicted_peak_bytes()
        est = admission_estimate(
            graph,
            tset,
            store_dtype=DtypePolicy.resolve(self.dtype_policy).store_dtype,
            chunk_size=self.chunk_size,
            memory_budget_bytes=self.memory_budget_bytes,
        )
        return est.chunk_bytes

    def _maybe_queue_tune(self, key: Tuple, graph_ref: str, tset) -> None:
        """``REPRO_TUNE=full``: record an un-tuned workload for background
        tuning (drained by a front-end scheduler via :meth:`pop_pending_tune`
        -> :meth:`tune`, one per round — prewarm-style off-query-path work).

        ``key[-1]`` is the tuning fragment: non-``None`` means a tuned
        config already resolved, so there is nothing to schedule.  Only
        auto-resolved services tune — an explicit service ``backend=`` is
        an operator decision the tuner must not fight.
        """
        from repro.exec.select import tune_mode

        if (
            self.backend != "auto"
            or key[-1] is not None
            or key in self._tune_requested
            or tune_mode() != "full"
        ):
            return
        self._tune_requested.add(key)
        self._tune_pending.append((graph_ref, tset))
        logger.debug(
            "queued background tune for %s (%d templates)",
            graph_ref,
            len(tset),
        )

    def pop_pending_tune(self) -> Optional[Tuple[str, Tuple[Template, ...]]]:
        """Next ``(graph_ref, templates)`` awaiting a background tune, or
        ``None`` (``REPRO_TUNE=full`` submissions queue them)."""
        return self._tune_pending.popleft() if self._tune_pending else None

    def tune(self, graph_ref: str, templates, **tune_kwargs):
        """Tune ``(graph_ref, templates)`` now; returns the
        :class:`~repro.tune.search.TuneResult`.

        Runs the measurement-driven search (:func:`repro.tune.search.tune`)
        with this service's dtype policy and memory budget, persists the
        winner in the tuning cache, then invalidates every cached engine
        (and memoized degradation ladder) for that ``(graph, canons)`` pair
        so the next build re-resolves — with ``REPRO_TUNE`` at its default
        ``cached``, that build binds the freshly tuned config.

        Probe launches run inline on the calling thread (the front-end
        schedules this off the query path, like prewarms).
        """
        from repro.plan.ir import template_set_canons
        from repro.tune.search import tune as run_tune

        graph = self.graph(graph_ref)
        tset = self._resolve_templates(templates)
        tune_kwargs.setdefault("dtype_policy", self.dtype_policy)
        tune_kwargs.setdefault("memory_budget_bytes", self.memory_budget_bytes)
        result = run_tune(graph, list(tset), **tune_kwargs)
        canons = template_set_canons(tset)
        dropped = 0
        for k in list(self._cache.keys()):
            if k[1] == result.graph_signature and k[2] == canons:
                self._cache.invalidate(k)
                self._ladders.pop(k, None)
                dropped += 1
        self._tune_requested.add(self.engine_key_for(graph_ref, tset))
        self.tunes_completed += 1
        logger.info(
            "tuned %s: winner=%s (%d stale cached engines dropped)",
            graph_ref,
            result.config.describe(),
            dropped,
        )
        return result

    def _drop_tuned_entry(self, key: Tuple) -> None:
        """Quarantine interop: a deterministically-failing engine key must
        not be re-picked from the tuning cache, so its tuned entry (the
        ``key[-1]`` fragment marks one) is removed from the cache file."""
        if len(key) < 9 or key[-1] is None:
            return
        try:
            from repro.tune.cache import invalidate_entry

            if invalidate_entry(key[1], key[2]):
                logger.info(
                    "quarantine invalidated tuned entry for engine key %s",
                    key[3],
                )
        except Exception as exc:  # pragma: no cover - defensive
            logger.debug("tuned-entry invalidation failed: %s", exc)

    def prewarm(self, graph_ref: str, templates) -> Tuple:
        """Build AND compile the engine a query shape will need; returns
        its engine key.

        Constructs the engine into the cache (device operands shipped) and
        runs one padded dummy launch through the fixed-shape
        ``count_keys_chunk`` program so the jit trace+compile — the ~50x
        cold/warm gap in the service bench rows — happens *now*, off the
        query path.  Subsequent queries behind the same key trace zero new
        programs.  Idempotent: a warm key costs one cheap compiled launch.
        """
        graph = self.graph(graph_ref)
        tset = self._resolve_templates(templates)
        key = self.engine_key_for(graph_ref, tset)

        def build():
            return CountingEngine(
                graph,
                list(tset),
                backend=self.backend,
                dtype_policy=self.dtype_policy,
                chunk_size=self.chunk_size,
                memory_budget_bytes=self.memory_budget_bytes,
                **self.engine_kwargs,
            )

        engine = self._cache.get(key, build)
        dummy = np.asarray(jax.random.PRNGKey(0), np.uint32)[None]
        engine.count_keys_chunk(dummy)
        return key

    def query(
        self,
        graph_ref: str,
        templates,
        **submit_kwargs,
    ) -> List[QueryEstimate]:
        """Synchronous convenience: submit + drain + result."""
        q = self.submit(graph_ref, templates, **submit_kwargs)
        self.run()
        return q.result()

    def graphlet_profile(
        self,
        graph_ref: str,
        max_size: int = 5,
        *,
        min_size: int = 3,
        run: bool = True,
        **submit_kwargs,
    ) -> Union[Dict[str, QueryEstimate], List[Query]]:
        """Estimate counts of EVERY connected graphlet up to ``max_size``.

        First-class motif/graphlet-profile queries: one submission covers
        all connected templates of each size ``min_size <= k <= max_size``
        (:func:`repro.core.templates.connected_graphlets` — 2, 6, and 21
        shapes for k = 3, 4, 5).  Templates of one size share one query —
        and therefore one engine, one set of colorings, and the plan
        layer's canonical sub-plan sharing (trees ride the fused tree
        pipeline, non-trees the bag pipeline, duplicated stage canons
        de-duplicated within the shared schedule).  Different sizes need
        different colorings, so they become separate queries served
        round-robin by the same admission loop.

        With ``run=True`` (default) drains the loop and returns
        ``{template name: QueryEstimate}``; with ``run=False`` returns the
        queued :class:`Query` handles (drive them with :meth:`run`, e.g.
        to interleave with other tenants).  ``submit_kwargs`` are forwarded
        to every :meth:`submit` (epsilon/delta/iterations/seed/...).
        """
        if min_size > max_size:
            raise ValueError(f"min_size {min_size} > max_size {max_size}")
        queries = [
            self.submit(graph_ref, connected_graphlets(k), **submit_kwargs)
            for k in range(min_size, max_size + 1)
        ]
        if not run:
            return queries
        self.run()
        return {est.template: est for q in queries for est in q.result()}

    # ------------------------------------------------------------------
    # Observability
    # ------------------------------------------------------------------

    def engine(self, key: Tuple) -> Optional[CountingEngine]:
        """The warm engine behind a query's ``engine_key`` (None if evicted)."""
        return self._cache.peek(key)

    def stats(self) -> Dict:
        """Service counters: cache hit/miss/evict, launches, completions,
        and the failure-semantics block (``faults``: classified failure
        counts, total retries, currently-quarantined keys, per-key failure
        state, and each key's degradation-ladder walk)."""
        from repro.exec.select import tune_mode

        by_key: Dict[Tuple, int] = {}
        for key in self.launch_log:
            by_key[key] = by_key.get(key, 0) + 1
        now = self.clock.now()
        return {
            "tuning": {
                "mode": tune_mode(),
                "tunes_completed": self.tunes_completed,
                "pending": len(self._tune_pending),
                "tuned_cached_engines": sum(
                    1
                    for k in self._cache.keys()
                    if len(k) >= 9 and k[-1] is not None
                ),
            },
            "cache": self._cache.counters(),
            "launches": len(self.launch_log),
            "launches_by_key": by_key,
            "queries_submitted": self._next_qid,
            "queries_completed": self.queries_completed,
            "queries_cancelled": self.queries_cancelled,
            "queries_failed": self.queries_failed,
            "queries_degraded": self.queries_degraded,
            "faults": {
                **self.fault_counters,
                "retries": sum(fs.retries_total for fs in self._fail.values()),
                "quarantined_keys": [
                    k for k, fs in self._fail.items()
                    if fs.quarantined_until > now
                ],
                "keys": {k: fs.describe(now) for k, fs in self._fail.items()},
                "ladder": {
                    k: list(fs.ladder_log)
                    for k, fs in self._fail.items()
                    if fs.ladder_log
                },
            },
            "engines": [
                self._cache.peek(k).describe()
                for k in self._cache.keys()
                if self._cache.peek(k) is not None
            ],
        }
