"""Failure semantics for the serving layer: structured errors, retry
policies, failure classification, and per-engine-key failure state.

The pieces (consumed by :mod:`repro.serve.counting` and
:mod:`repro.serve.frontend`; behavior documented in ``docs/serving.md``
"Failure semantics"):

* :class:`ServiceError` — the ONE structured error shape every failed
  query resolves with: a machine-readable ``kind``, the engine key, the
  query id, the scheduler round, and the underlying cause.  Futures raise
  it from ``result()``; ``Query.error`` holds it on the handle.
* :class:`QuarantinedError` — a :class:`ServiceError` subclass raised at
  *submit* time while an engine key is quarantined (fast-fail: no queue
  slot is taken for work that cannot run).
* :class:`RetryPolicy` — per-query knobs for the transient-failure path:
  how many retries, and the exponential backoff the key parks under
  between attempts.
* :func:`classify_failure` — maps an arbitrary exception from the build /
  launch path onto the four failure families the scheduler distinguishes:
  ``transient`` (retry with backoff), ``memory`` (walk the degradation
  ladder), ``invalid`` (the *query* is malformed — fail it, never strike
  the engine key), ``deterministic`` (fail fast, quarantine on repeat).
* :class:`FailState` — the scheduler's per-engine-key bookkeeping:
  consecutive-transient count (drives the backoff exponent), backoff
  parking, deterministic strike count, and the quarantine window with its
  exponential reset.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.testing.faults import DeterministicFault, MemoryFault, TransientFault

__all__ = [
    "ServiceError",
    "QuarantinedError",
    "RetryPolicy",
    "DEFAULT_RETRY_POLICY",
    "classify_failure",
    "FailState",
    "QUARANTINE_STRIKES",
    "DEFAULT_QUARANTINE_BASE_S",
]

#: Consecutive deterministic failures on one engine key before it is
#: quarantined (the first one already fails its queries; the threshold is
#: about protecting the *ring slot*, not the queries).
QUARANTINE_STRIKES = 2

#: First quarantine window (seconds); doubles on every re-quarantine and
#: resets to this base after a clean launch.
DEFAULT_QUARANTINE_BASE_S = 1.0


class ServiceError(RuntimeError):
    """Structured terminal error of a failed query (or a tripped scheduler).

    ``kind`` is machine-readable::

        retries_exhausted | memory_exhausted | deterministic | invalid
        | non_finite | deadline | quarantined | scheduler

    ``engine_key`` / ``qid`` / ``round_index`` locate the failure;
    ``cause`` (also chained as ``__cause__``) is the underlying exception.
    """

    def __init__(
        self,
        kind: str,
        detail: str = "",
        *,
        engine_key: Optional[Tuple] = None,
        qid: Optional[int] = None,
        round_index: Optional[int] = None,
        cause: Optional[BaseException] = None,
    ):
        super().__init__(f"{kind}: {detail}" if detail else kind)
        self.kind = kind
        self.detail = detail
        self.engine_key = engine_key
        self.qid = qid
        self.round_index = round_index
        self.cause = cause
        if cause is not None:
            self.__cause__ = cause

    def describe(self) -> Dict:
        return {
            "kind": self.kind,
            "detail": self.detail,
            "engine_key": self.engine_key,
            "qid": self.qid,
            "round_index": self.round_index,
            "cause": None if self.cause is None else repr(self.cause),
        }


class QuarantinedError(ServiceError):
    """Submit-time fast-fail: the engine key is inside its quarantine
    window (see :class:`FailState`); retry after ``retry_at``."""

    def __init__(self, detail: str, *, engine_key: Tuple, retry_at: float):
        super().__init__("quarantined", detail, engine_key=engine_key)
        self.retry_at = retry_at


@dataclass(frozen=True)
class RetryPolicy:
    """Per-query transient-failure policy.

    A failed launch counts one retry against EVERY query merged into it
    (they all re-run); a query past ``max_retries`` fails with
    ``retries_exhausted`` while its launch-mates keep retrying.  Between
    attempts the engine key parks for ``backoff_base *
    backoff_factor**(consecutive_failures - 1)`` seconds, capped at
    ``max_backoff`` — exponential backoff on the key, so a flapping device
    is not hammered at ring speed.
    """

    max_retries: int = 3
    backoff_base: float = 0.05
    backoff_factor: float = 2.0
    max_backoff: float = 2.0

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {self.max_retries}")
        if self.backoff_base < 0:
            raise ValueError(f"backoff_base must be >= 0, got {self.backoff_base}")
        if self.backoff_factor < 1.0:
            raise ValueError(
                f"backoff_factor must be >= 1, got {self.backoff_factor}"
            )

    def backoff(self, consecutive_failures: int) -> float:
        """Park duration after the ``consecutive_failures``-th failure."""
        if consecutive_failures <= 0:
            return 0.0
        return min(
            self.backoff_base * self.backoff_factor ** (consecutive_failures - 1),
            self.max_backoff,
        )


DEFAULT_RETRY_POLICY = RetryPolicy()

#: Message fragments mapping foreign exceptions (XLA / jaxlib runtime
#: errors carry their status as text) onto the failure families.
_MEMORY_MARKERS = ("resource_exhausted", "out of memory", "oom", "allocation fail")
_TRANSIENT_MARKERS = ("unavailable", "deadline_exceeded", "connection reset",
                      "transient", "temporarily")


def classify_failure(exc: BaseException) -> str:
    """``transient`` | ``memory`` | ``invalid`` | ``deterministic`` for a
    build/launch exception.

    The injected fault types classify by isinstance; exceptions carrying a
    truthy ``invalid_request`` attribute (e.g.
    :class:`repro.exec.mesh.BagPlanUnsupported`) classify as ``invalid`` —
    the *query* can never run, but the engine key is healthy, so the
    scheduler fails it without a deterministic strike and quarantine never
    trips.  Foreign exceptions classify by status-text markers (XLA
    surfaces RESOURCE_EXHAUSTED / UNAVAILABLE in the message).  Anything
    unrecognized is ``deterministic`` — the safe default: fail fast and
    quarantine on repeat rather than retry a failure that will never
    clear.
    """
    if isinstance(exc, TransientFault):
        return "transient"
    if isinstance(exc, MemoryFault):
        return "memory"
    if isinstance(exc, DeterministicFault):
        return "deterministic"
    if getattr(exc, "invalid_request", False):
        return "invalid"
    msg = str(exc).lower()
    if isinstance(exc, MemoryError) or any(m in msg for m in _MEMORY_MARKERS):
        return "memory"
    if any(m in msg for m in _TRANSIENT_MARKERS):
        return "transient"
    return "deterministic"


@dataclass
class FailState:
    """Per-engine-key failure bookkeeping (scheduler-thread-owned).

    ``consecutive_transient`` drives the backoff exponent and clears on any
    clean launch.  ``strikes`` counts consecutive deterministic failures;
    at :data:`QUARANTINE_STRIKES` the key enters a quarantine window that
    doubles on every re-quarantine (``quarantines`` is the exponent) and
    resets after a clean launch.  Cumulative counters (``retries_total``,
    ``failures_total``) survive resets — they feed ``stats()``/``health()``.
    """

    consecutive_transient: int = 0
    parked_until: float = 0.0
    strikes: int = 0
    quarantines: int = 0
    quarantined_until: float = 0.0
    ladder_rung: int = 0
    ladder_log: List[Dict] = field(default_factory=list)
    retries_total: int = 0
    failures_total: int = 0

    def blocked_until(self, now: float) -> Optional[float]:
        """The time this key becomes schedulable again, or None if it
        already is."""
        until = max(self.parked_until, self.quarantined_until)
        return until if until > now else None

    def note_transient(self, now: float, policy: RetryPolicy) -> float:
        """Record a transient failure; returns when the key unparks."""
        self.consecutive_transient += 1
        self.failures_total += 1
        self.parked_until = now + policy.backoff(self.consecutive_transient)
        return self.parked_until

    def note_deterministic(
        self, now: float, base_s: float = DEFAULT_QUARANTINE_BASE_S
    ) -> Optional[float]:
        """Record a deterministic failure; returns the quarantine deadline
        when this strike triggers one (else None)."""
        self.strikes += 1
        self.failures_total += 1
        if self.strikes < QUARANTINE_STRIKES:
            return None
        self.strikes = 0
        self.quarantines += 1
        self.quarantined_until = now + base_s * 2.0 ** (self.quarantines - 1)
        return self.quarantined_until

    def note_memory(self) -> None:
        self.failures_total += 1

    def note_success(self) -> None:
        """A clean launch clears every *consecutive* counter (the ladder
        rung is deliberately sticky — a config that fit stays)."""
        self.consecutive_transient = 0
        self.strikes = 0
        self.quarantines = 0
        self.parked_until = 0.0
        self.quarantined_until = 0.0

    def describe(self, now: float) -> Dict:
        return {
            "consecutive_transient": self.consecutive_transient,
            "parked_for_s": max(0.0, self.parked_until - now),
            "strikes": self.strikes,
            "quarantines": self.quarantines,
            "quarantined_for_s": max(0.0, self.quarantined_until - now),
            "ladder_rung": self.ladder_rung,
            "retries_total": self.retries_total,
            "failures_total": self.failures_total,
        }
