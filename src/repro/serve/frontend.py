"""Async serving front-end: futures, per-tenant QoS, backpressure, warming.

:class:`~repro.serve.counting.CountingService` answers queries correctly
and fairly — but synchronously: ``run()`` drains the queue on the caller's
thread.  :class:`ServiceFrontend` is the production loop above it:

* **Futures.** ``submit()`` enqueues and returns a :class:`QueryFuture`
  immediately; callers ``result(timeout=...)`` when they need the answer
  and ``progress()`` any time for streaming partials (running mean, sample
  std, and BOTH CI halfwidths — normal and empirical-Bernstein — plus the
  lower/upper interval edges from the query's ``AdaptiveStopper``).
* **Per-tenant QoS** (:mod:`repro.serve.qos`): priority tiers (higher
  tiers are offered admission first each round; within a tier tenants
  round-robin, so a flooding tenant cannot starve a peer), and token-
  bucket rate limits that *delay* admission rather than reject it.  These
  layer on top of the service's round-robin engine-key ring — the frontend
  decides *which query enters the service*, the service decides *which
  engine key launches next*.
* **Backpressure / load shedding** priced by the plan-layer cost model
  (:meth:`CountingService.admission_bytes`): a query whose predicted
  launch residency can never fit ``admission_budget_bytes`` is rejected at
  submit (``over_budget``), a tenant past its ``max_pending`` queue cap is
  rejected at submit (``queue_full``), and an admissible query simply
  waits until enough in-flight bytes retire.
* **Background pre-warming** keyed by the engine key (graph signature +
  the plan IR's template canons): ``prewarm()`` queues an engine
  build+compile that runs on the scheduler thread, off every caller's
  submit path, so the ~50x cold/warm compile gap is paid before traffic
  lands.  Warm requests de-duplicate by key.

**The determinism seam.**  All scheduler state advances only inside
:meth:`step` — one *round* = (at most one warm task) + (one admission
sweep) + (one service launch) + (completion sweep) — and the only clock is
the injected :class:`~repro.serve.qos.Clock`.  Tests construct the
frontend with a :class:`~repro.serve.qos.ManualClock` and call ``step()``
/ ``clock.advance()`` explicitly: every rate-limit decision, admission
order, launch, and completion is reproducible with zero wall-clock sleeps
(see ``tests/test_frontend.py`` and docs/serving.md).  Production calls
``start()``, which runs the *same* ``step()`` from one daemon scheduler
thread; ``submit``/``cancel``/``progress`` are thread-safe entry points
that only touch frontend queues under the lock, so the underlying service
still sees strictly single-threaded access — its bit-exactness guarantee
(same (graph, templates, seed) => same counts, however queries are batched
or interleaved) survives concurrency untouched.
"""

from __future__ import annotations

import threading
from collections import deque
from concurrent.futures import CancelledError
from dataclasses import dataclass
from typing import Deque, Dict, List, Optional, Set, Tuple

from repro.testing import faults as _faults

from .counting import CountingService, Query
from .qos import (
    DEFAULT_MAX_PENDING,
    Clock,
    ManualClock,
    SystemClock,
    TenantPolicy,
    TenantState,
)
from .resilience import ServiceError

__all__ = [
    "ServiceFrontend",
    "QueryFuture",
    "TemplateProgress",
    "QoSRejected",
    "make_frontend",
    "DEFAULT_ADMISSION_BUDGET_FACTOR",
    "DEFAULT_WATCHDOG_INTERVAL_S",
]

#: Scheduler-staleness threshold for :meth:`ServiceFrontend.health`: a
#: started frontend whose last round is older than this (with work
#: pending) is reported unhealthy.
DEFAULT_WATCHDOG_INTERVAL_S = 1.0

#: Default admission budget = this factor x the service's per-engine memory
#: budget — i.e. "at most N full-budget launches resident at once".
DEFAULT_ADMISSION_BUDGET_FACTOR = 4


class QoSRejected(RuntimeError):
    """Backpressure rejection at submit time.

    ``reason`` is machine-readable: ``"queue_full"`` (tenant past its
    ``max_pending`` cap) or ``"over_budget"`` (the cost model prices one
    launch of this query above the whole admission budget — it could
    never be admitted, so it is shed immediately).
    """

    def __init__(self, reason: str, detail: str = ""):
        super().__init__(f"{reason}: {detail}" if detail else reason)
        self.reason = reason


@dataclass(frozen=True)
class TemplateProgress:
    """One template's streaming partial result (see ``QueryFuture.progress``)."""

    template: str
    status: str  # queued | pending | running | done | cancelled
    iterations: int
    mean: float
    std: float
    halfwidth: float  # the stopping rule's halfwidth (0.0 for fixed-N)
    halfwidth_normal: float  # CLT z-interval, always computed once n >= 2
    halfwidth_bernstein: float  # empirical-Bernstein, always computed once n >= 2
    lower: float  # mean - halfwidth under the query's configured bound
    upper: float  # mean + halfwidth under the query's configured bound
    converged: bool


class QueryFuture:
    """Handle returned by :meth:`ServiceFrontend.submit`.

    Thread-safe; resolves exactly once — with a result (``result()``
    returns the service's per-template ``QueryEstimate`` list) or as
    cancelled (``result()`` raises :class:`concurrent.futures.CancelledError`).
    ``progress()`` never blocks and is monotone: ``iterations`` only grows,
    and a terminal status stays terminal.
    """

    def __init__(
        self,
        frontend: "ServiceFrontend",
        tenant: str,
        graph_ref: str,
        templates,
        submit_kwargs: Dict,
        admission_bytes: int,
        deadline_at: Optional[float] = None,
    ):
        self._frontend = frontend
        self.tenant = tenant
        self.graph_ref = graph_ref
        self.templates = templates  # resolved Template tuple
        self.submit_kwargs = submit_kwargs
        self.admission_bytes = int(admission_bytes)
        self.deadline_at = deadline_at  # frontend-clock absolute deadline
        self._event = threading.Event()
        self._query: Optional[Query] = None
        self._error: Optional[ServiceError] = None
        self._state = "queued"  # queued -> admitted -> done | cancelled | failed
        # clock timestamps + scheduler-round indices (fairness accounting)
        self.submitted_at: float = frontend._clock.now()
        self.admitted_at: Optional[float] = None
        self.resolved_at: Optional[float] = None
        self.submitted_round: int = frontend._rounds
        self.admitted_round: Optional[int] = None
        self.resolved_round: Optional[int] = None

    # -- inspection (any thread) --------------------------------------------

    def done(self) -> bool:
        """Resolved either way (result ready or cancelled)."""
        return self._event.is_set()

    def cancelled(self) -> bool:
        return self._event.is_set() and self._state == "cancelled"

    def failed(self) -> bool:
        return self._event.is_set() and self._state == "failed"

    def exception(self) -> Optional[ServiceError]:
        """The structured failure, or ``None`` (does not block)."""
        return self._error

    @property
    def state(self) -> str:
        return self._state

    @property
    def iterations(self) -> int:
        q = self._query
        return 0 if q is None else q.iterations

    def progress(self) -> List[TemplateProgress]:
        """Streaming partial results; valid at every lifecycle point."""
        return self._frontend._progress(self)

    def result(self, timeout: Optional[float] = None):
        """Block until resolved; the per-template ``QueryEstimate`` list.

        Raises ``TimeoutError`` if ``timeout`` elapses first,
        :class:`concurrent.futures.CancelledError` if the query was
        cancelled, and the structured
        :class:`~repro.serve.resilience.ServiceError` if it failed
        (retries exhausted, ladder exhausted, deadline with no samples,
        quarantined key, or a tripped scheduler).  In manual-clock test
        mode drive the scheduler with ``frontend.step()``/``drain()``
        before calling.
        """
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"query for tenant {self.tenant!r} unresolved after {timeout}s"
            )
        if self._state == "cancelled":
            raise CancelledError(f"query for tenant {self.tenant!r} was cancelled")
        if self._state == "failed":
            raise self._error
        return self._query.result()

    def cancel(self) -> bool:
        """Cancel if not yet resolved; True when this call cancelled it."""
        return self._frontend._cancel(self)


class ServiceFrontend:
    """The async, QoS-aware front door of a :class:`CountingService`.

    Two driving modes over the same scheduler:

    * **manual** (default): nothing runs until :meth:`step` (one round) or
      :meth:`drain` — fully deterministic with a
      :class:`~repro.serve.qos.ManualClock`.
    * **threaded**: :meth:`start` spawns one daemon scheduler thread that
      loops ``step()`` whenever work is pending (also via ``with
      frontend: ...``).  ``submit()`` stays non-blocking either way.

    Args:
      service: the synchronous service to drive (exclusively owned — do
        not call its ``run()``/``step()`` directly while a frontend is
        attached).
      clock: time source for rate limits and latency stamps.
      admission_budget_bytes: total predicted launch residency allowed in
        flight (cost-model priced); ``None`` derives
        ``DEFAULT_ADMISSION_BUDGET_FACTOR x service.memory_budget_bytes``.
      default_max_pending: queue cap for auto-registered tenants.
      poll_interval: scheduler-thread idle/parked wait (threaded mode only).
      watchdog_interval: staleness threshold for :meth:`health` — a
        started frontend with pending work whose last completed round is
        older than this reports ``healthy=False``.
    """

    def __init__(
        self,
        service: CountingService,
        *,
        clock: Optional[Clock] = None,
        admission_budget_bytes: Optional[int] = None,
        default_max_pending: int = DEFAULT_MAX_PENDING,
        poll_interval: float = 0.005,
        watchdog_interval: float = DEFAULT_WATCHDOG_INTERVAL_S,
    ):
        self._svc = service
        self._clock = clock if clock is not None else SystemClock()
        # one clock for the whole stack: deadlines stamped here are swept
        # by the service, so a manual frontend clock must drive the
        # service's timers too (explicitly configured clocks are kept)
        if isinstance(service.clock, SystemClock) and not isinstance(
            self._clock, SystemClock
        ):
            service.clock = self._clock
        self._lock = threading.RLock()
        self._work = threading.Condition(self._lock)
        self.admission_budget_bytes = (
            int(admission_budget_bytes)
            if admission_budget_bytes is not None
            else DEFAULT_ADMISSION_BUDGET_FACTOR * service.memory_budget_bytes
        )
        self.default_max_pending = int(default_max_pending)
        self.poll_interval = float(poll_interval)
        self._tenants: Dict[str, TenantState] = {}
        self._tier_rings: Dict[int, Deque[str]] = {}  # priority -> tenant ring
        self._admitted: List[QueryFuture] = []  # in flight, unresolved
        self._inflight_bytes = 0
        self._rounds = 0
        self._warm_queue: Deque[Tuple[Tuple, str, tuple]] = deque()
        self._warm_done: Set[Tuple] = set()
        self._tune_queue: Deque[Tuple[str, tuple]] = deque()
        self._tune_done: Set[Tuple] = set()
        self.tunes_run = 0
        self.rejections: Dict[str, int] = {
            "queue_full": 0,
            "over_budget": 0,
            "draining": 0,
        }
        self._thread: Optional[threading.Thread] = None
        self._stop_flag = False
        self.watchdog_interval = float(watchdog_interval)
        self._state = "running"  # running -> draining (watchdog tripped)
        self._last_error: Optional[ServiceError] = None
        self._last_round_at: Optional[float] = None
        self.queries_failed = 0

    @property
    def service(self) -> CountingService:
        return self._svc

    @property
    def clock(self) -> Clock:
        return self._clock

    # ------------------------------------------------------------------
    # Tenants
    # ------------------------------------------------------------------

    def register_tenant(
        self,
        name: str,
        *,
        priority: int = 0,
        rate_qps: Optional[float] = None,
        burst: Optional[float] = None,
        max_pending: Optional[int] = None,
    ) -> TenantPolicy:
        """Declare a tenant's QoS policy (idempotent only for new names)."""
        policy = TenantPolicy(
            name=name,
            priority=int(priority),
            rate_qps=rate_qps,
            burst=burst,
            max_pending=(
                self.default_max_pending if max_pending is None else int(max_pending)
            ),
        )
        with self._lock:
            if name in self._tenants:
                raise ValueError(f"tenant {name!r} already registered")
            self._tenants[name] = TenantState(
                policy=policy, bucket=policy.make_bucket(self._clock)
            )
            self._tier_rings.setdefault(policy.priority, deque()).append(name)
        return policy

    def _tenant(self, name: str) -> TenantState:
        if name not in self._tenants:
            # unknown tenants get the default policy — submit stays one call
            self.register_tenant(name)
        return self._tenants[name]

    # ------------------------------------------------------------------
    # Submission / cancellation / warming (any thread)
    # ------------------------------------------------------------------

    def submit(
        self, tenant: str, graph_ref: str, templates, **submit_kwargs
    ) -> QueryFuture:
        """Enqueue a query for ``tenant``; returns its future immediately.

        ``submit_kwargs`` go verbatim to :meth:`CountingService.submit`
        (epsilon / delta / iterations / seed / bound / record_rows /
        retry_policy) — except ``deadline=`` (seconds from now), which the
        frontend owns: the clock starts at *this* call, covering queue
        wait as well as execution, and a future whose deadline expires
        while still queued fails with a structured ``kind="deadline"``
        :class:`~repro.serve.resilience.ServiceError` without ever
        entering the service.  Raises :class:`QoSRejected` instead of
        queuing when backpressure applies (see the class docstring) or
        the frontend is draining after a watchdog trip; otherwise never
        blocks on the scheduler.
        """
        submit_kwargs.pop("tenant", None)  # stamped by the scheduler
        deadline = submit_kwargs.pop("deadline", None)
        # price the query BEFORE taking the queue slot: resolving templates
        # and planning are pure host work, safe outside the lock
        tset = self._svc._resolve_templates(templates)
        est = self._svc.admission_bytes(graph_ref, tset)
        with self._work:
            if self._state == "draining":
                self.rejections["draining"] += 1
                raise QoSRejected(
                    "draining",
                    f"frontend is draining after a scheduler failure: "
                    f"{self._last_error}",
                )
            state = self._tenant(tenant)
            if est > self.admission_budget_bytes:
                self.rejections["over_budget"] += 1
                state.counters["rejected"] += 1
                raise QoSRejected(
                    "over_budget",
                    f"predicted launch residency {est}b exceeds the "
                    f"admission budget {self.admission_budget_bytes}b",
                )
            if state.pending >= state.policy.max_pending:
                self.rejections["queue_full"] += 1
                state.counters["rejected"] += 1
                raise QoSRejected(
                    "queue_full",
                    f"tenant {tenant!r} at max_pending="
                    f"{state.policy.max_pending}",
                )
            fut = QueryFuture(
                self,
                tenant,
                graph_ref,
                tset,
                dict(submit_kwargs),
                est,
                deadline_at=(
                    None if deadline is None else self._clock.now() + float(deadline)
                ),
            )
            state.queue.append(fut)
            state.counters["submitted"] += 1
            self._work.notify_all()
        return fut

    def prewarm(self, graph_ref: str, templates) -> Tuple:
        """Queue a background engine build+compile; returns the engine key.

        De-duplicated by key (graph signature + plan-IR template canons +
        backend/dtype/chunk config): re-warming a warm or already-queued
        key is a no-op.  The work itself runs inside a scheduler round —
        never on this caller's thread.
        """
        tset = self._svc._resolve_templates(templates)
        key = self._svc.engine_key_for(graph_ref, tset)
        with self._work:
            queued = {k for k, _, _ in self._warm_queue}
            if key not in self._warm_done and key not in queued:
                self._warm_queue.append((key, graph_ref, tset))
                self._work.notify_all()
        return key

    def tune(self, graph_ref: str, templates) -> Tuple[str, tuple]:
        """Queue a background autotune for ``(graph_ref, templates)``.

        Like :meth:`prewarm`, the measurement work itself runs inside a
        scheduler round (at most one warm *or* tune task per round), never
        on this caller's thread.  De-duplicated against already-queued and
        already-completed tune tasks.  The service also self-queues tunes
        for unseen workloads when ``REPRO_TUNE=full`` — those drain
        through the same per-round slot.
        """
        tset = self._svc._resolve_templates(templates)
        task = (graph_ref, tset)
        with self._work:
            if task not in self._tune_done and task not in self._tune_queue:
                self._tune_queue.append(task)
                self._work.notify_all()
        return task

    def _cancel(self, fut: QueryFuture) -> bool:
        with self._lock:
            if fut.done():
                return False
            state = self._tenants[fut.tenant]
            if fut._state == "queued":
                try:
                    state.queue.remove(fut)
                except ValueError:  # pragma: no cover - defensive
                    return False
            else:  # admitted: drop it from the service's merge lists
                self._svc.cancel(fut._query)
                self._admitted.remove(fut)
                state.inflight -= 1
                self._inflight_bytes -= fut.admission_bytes
            state.counters["cancelled"] += 1
            self._resolve(fut, "cancelled")
            return True

    # ------------------------------------------------------------------
    # The scheduler (one round per step; single-stepped in tests)
    # ------------------------------------------------------------------

    def step(self) -> Dict:
        """Run ONE scheduler round; returns what it did.

        A round, in order: (1) at most one queued warm task (engine
        build+compile) OR — when no warm task ran — one queued tune task
        (a measurement sweep from :meth:`tune` or the service's
        ``REPRO_TUNE=full`` self-queue); (2) one admission sweep — priority tiers high to
        low, one query per tenant per round, gated by the token bucket and
        the admission-budget headroom; (3) one service launch
        (``CountingService.step()`` — the engine-key round-robin); (4) a
        completion sweep resolving futures whose queries finished.  The
        returned dict (``warmed`` / ``tuned`` / ``admitted`` /
        ``launched`` / ``completed`` / ``failed`` / ``progressed``) is the
        observability
        record the deterministic tests assert on.

        **Supervision.**  Per-query failures (retries exhausted, ladder
        exhausted, quarantined key, deadline) resolve just that future
        with its structured error — the round continues.  An exception
        that escapes the round itself is a scheduler fault: the watchdog
        fails *every* queued and in-flight future with a
        ``kind="scheduler"`` :class:`ServiceError` (cause + round index),
        transitions the frontend to ``draining`` (submits rejected), and
        re-raises the structured error to the caller / scheduler thread.
        """
        with self._lock:
            if self._state == "draining":
                raise ServiceError(
                    "scheduler",
                    "frontend is draining after a scheduler failure",
                    round_index=self._rounds,
                    cause=self._last_error,
                )
            self._rounds += 1
            try:
                return self._step_round()
            except ServiceError:
                raise  # a prior trip re-surfacing; already handled
            except BaseException as exc:
                raise self._trip(exc) from exc

    def _step_round(self) -> Dict:
        """One round's body; runs under the lock, supervised by step()."""
        # ONE fault-checkable clock read per round: the injected-fault
        # harness can skew it (deadline chaos) or raise through it
        # (watchdog-trip drills).  submit()/cancel() timestamps stay on
        # the plain clock — only the scheduler is supervised.
        now = _faults.clock_read(self._clock.now())
        info = {
            "round": self._rounds,
            "warmed": None,
            "tuned": None,
            "admitted": [],
            "launched": None,
            "completed": [],
            "failed": [],
            "progressed": False,
        }

        # deadline sweep over *queued* futures: a query whose deadline
        # passed while waiting for admission fails here, before it can
        # take a service slot it can no longer use
        for state in self._tenants.values():
            expired = [
                f
                for f in state.queue
                if f.deadline_at is not None and now >= f.deadline_at
            ]
            for fut in expired:
                state.queue.remove(fut)
                self._fail_future(
                    fut,
                    ServiceError(
                        "deadline",
                        f"deadline expired before admission "
                        f"(queued {now - fut.submitted_at:.3f}s)",
                        round_index=self._rounds,
                    ),
                )
                info["failed"].append((fut.tenant, "deadline"))

        if self._warm_queue:
            key, graph_ref, tset = self._warm_queue.popleft()
            if key not in self._warm_done:
                self._svc.prewarm(graph_ref, tset)
                self._warm_done.add(key)
                info["warmed"] = key

        # background autotuning shares the warm slot: at most one heavy
        # off-path task (engine build OR measurement sweep) per round, so
        # admission latency stays bounded while tuning drains
        if info["warmed"] is None:
            if not self._tune_queue:
                pending = self._svc.pop_pending_tune()
                if pending is not None:
                    self._tune_queue.append(pending)
            while self._tune_queue:
                task = self._tune_queue.popleft()
                if task in self._tune_done:
                    continue
                graph_ref, tset = task
                self._svc.tune(graph_ref, tset)
                self._tune_done.add(task)
                self.tunes_run += 1
                info["tuned"] = (graph_ref, tuple(t.name for t in tset))
                break

        for tier in sorted(self._tier_rings, reverse=True):
            ring = self._tier_rings[tier]
            for _ in range(len(ring)):
                name = ring[0]
                ring.rotate(-1)
                state = self._tenants[name]
                if not state.queue:
                    continue
                fut = state.queue[0]
                if (
                    self._inflight_bytes + fut.admission_bytes
                    > self.admission_budget_bytes
                ):
                    continue  # waits for in-flight bytes to retire
                if state.bucket is not None and not state.bucket.try_acquire():
                    continue  # rate-limited: try again next round
                state.queue.popleft()
                kwargs = dict(fut.submit_kwargs)
                if fut.deadline_at is not None:
                    # clocks are aligned (see __init__), so the remaining
                    # frontend budget is the service-relative deadline
                    kwargs["deadline"] = fut.deadline_at - now
                try:
                    fut._query = self._svc.submit(
                        fut.graph_ref,
                        fut.templates,
                        tenant=name,
                        **kwargs,
                    )
                except ServiceError as exc:
                    # per-query rejection (e.g. a quarantined engine key):
                    # fail THIS future; the scheduler itself is healthy
                    self._fail_future(fut, exc)
                    info["failed"].append((name, exc.kind))
                    continue
                fut._state = "admitted"
                fut.admitted_at = self._clock.now()
                fut.admitted_round = self._rounds
                state.inflight += 1
                state.counters["admitted"] += 1
                self._inflight_bytes += fut.admission_bytes
                self._admitted.append(fut)
                info["admitted"].append((name, fut._query.qid))

        info["launched"] = self._svc.step()

        still = []
        for fut in self._admitted:
            if fut._query.finished:
                state = self._tenants[fut.tenant]
                state.inflight -= 1
                self._inflight_bytes -= fut.admission_bytes
                if fut._query.failed:
                    state.counters["failed"] += 1
                    fut._error = fut._query.error
                    self.queries_failed += 1
                    self._resolve(fut, "failed")
                    info["failed"].append((fut.tenant, fut._query.error.kind))
                else:
                    state.counters["completed"] += 1
                    self._resolve(fut, "done")
                    info["completed"].append((fut.tenant, fut._query.qid))
            else:
                still.append(fut)
        self._admitted = still

        self._last_round_at = self._clock.now()
        info["progressed"] = bool(
            info["warmed"] is not None
            or info["tuned"] is not None
            or info["admitted"]
            or info["launched"] is not None
            or info["completed"]
            or info["failed"]
        )
        return info

    def _fail_future(self, fut: QueryFuture, error: ServiceError) -> None:
        """Resolve one future as failed (caller holds the lock)."""
        fut._error = error
        self.queries_failed += 1
        state = self._tenants.get(fut.tenant)
        if state is not None:
            state.counters["failed"] += 1
        self._resolve(fut, "failed")

    def _trip(self, exc: BaseException) -> ServiceError:
        """Watchdog: a scheduler-fatal exception escaped a round.

        Every queued and in-flight future is failed with a structured
        ``kind="scheduler"`` error carrying the cause, the engine key (if
        the failure identified one), and the round index; the frontend
        transitions to ``draining`` (submits rejected, rounds refused)
        and the scheduler thread — if any — exits its loop.  Returns the
        error for step() to raise.
        """
        engine_key = getattr(exc, "engine_key", None)
        err = ServiceError(
            "scheduler",
            f"scheduler round {self._rounds} failed: {exc}",
            engine_key=engine_key,
            round_index=self._rounds,
            cause=exc,
        )
        self._last_error = err
        self._state = "draining"
        self._stop_flag = True  # a threaded scheduler exits its loop
        for state in self._tenants.values():
            while state.queue:
                self._fail_future(state.queue.popleft(), err)
        for fut in self._admitted:
            if fut._query is not None and not fut._query.finished:
                self._svc.cancel(fut._query)
            state = self._tenants[fut.tenant]
            state.inflight -= 1
            self._fail_future(fut, err)
        self._admitted = []
        self._inflight_bytes = 0
        self._work.notify_all()
        return err

    def _resolve(self, fut: QueryFuture, state: str) -> None:
        fut._state = state
        fut.resolved_at = self._clock.now()
        fut.resolved_round = self._rounds
        fut._event.set()

    def _unresolved(self) -> int:
        with self._lock:
            queued = sum(len(s.queue) for s in self._tenants.values())
            return queued + len(self._admitted)

    def drain(self, max_rounds: int = 10_000) -> int:
        """Step until every submitted future resolves; returns rounds used.

        Raises ``RuntimeError`` past ``max_rounds`` — with a
        ``ManualClock``, work parked behind a rate limit needs the test to
        ``clock.advance()`` between rounds, and this cap turns a would-be
        hang into a diagnosable failure (the no-deadlock guarantee the
        stress tests lean on).
        """
        rounds = 0
        while self._unresolved():
            self.step()
            rounds += 1
            if rounds >= max_rounds:
                raise RuntimeError(
                    f"drain() still has {self._unresolved()} unresolved "
                    f"futures after {rounds} rounds — rate-limited work "
                    f"with a frozen clock, or a scheduler bug"
                )
        return rounds

    # ------------------------------------------------------------------
    # Threaded mode
    # ------------------------------------------------------------------

    def start(self) -> "ServiceFrontend":
        """Spawn the daemon scheduler thread (idempotent)."""
        with self._lock:
            if self._thread is not None:
                return self
            self._stop_flag = False
            self._thread = threading.Thread(
                target=self._loop, name="repro-serve-frontend", daemon=True
            )
            self._thread.start()
        return self

    def stop(self, timeout: Optional[float] = 10.0) -> None:
        """Stop the scheduler thread (pending work stays queued)."""
        with self._work:
            if self._thread is None:
                return
            self._stop_flag = True
            self._work.notify_all()
        self._thread.join(timeout)
        self._thread = None

    def __enter__(self) -> "ServiceFrontend":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def _has_work_locked(self) -> bool:
        return bool(
            self._warm_queue
            or self._tune_queue
            or self._svc._tune_pending
            or self._admitted
            or any(s.queue for s in self._tenants.values())
        )

    def _loop(self) -> None:
        while True:
            with self._work:
                if self._stop_flag:
                    return
                if not self._has_work_locked():
                    self._work.wait(self.poll_interval)
                    continue
            try:
                info = self.step()
            except ServiceError:
                # the watchdog already failed every future and moved the
                # frontend to draining — the thread's job is done; exit
                # cleanly so health() can report thread_alive=False
                return
            if not info["progressed"]:
                # only rate-/budget-parked work: let buckets refill
                with self._work:
                    if self._stop_flag:
                        return
                    self._work.wait(self.poll_interval)

    # ------------------------------------------------------------------
    # Progress & observability
    # ------------------------------------------------------------------

    def _progress(self, fut: QueryFuture) -> List[TemplateProgress]:
        with self._lock:
            q = fut._query
            if q is None:  # not admitted yet: an empty-but-typed snapshot
                status = fut._state  # queued (or cancelled pre-admission)
                return [
                    TemplateProgress(
                        template=t.name,
                        status=status,
                        iterations=0,
                        mean=0.0,
                        std=0.0,
                        halfwidth=float("inf"),
                        halfwidth_normal=float("inf"),
                        halfwidth_bernstein=float("inf"),
                        lower=float("-inf"),
                        upper=float("inf"),
                        converged=False,
                    )
                    for t in fut.templates
                ]
            status = "cancelled" if fut._state == "cancelled" else q.status
            return [
                TemplateProgress(
                    template=t.name,
                    status=status,
                    iterations=q.stopper.iterations,
                    mean=ci.mean,
                    std=ci.std,
                    halfwidth=ci.halfwidth,
                    halfwidth_normal=ci.halfwidth_normal,
                    halfwidth_bernstein=ci.halfwidth_bernstein,
                    lower=ci.lower,
                    upper=ci.upper,
                    converged=ci.converged,
                )
                for t, ci in zip(q.templates, q.progress())
            ]

    def health(self) -> Dict:
        """Liveness + failure snapshot for external supervision.

        ``healthy`` means: not draining, and — when started with pending
        work — the scheduler thread is alive and its last completed round
        is no staler than ``watchdog_interval``.  The rest is the failure
        surface: the last scheduler error, the service's quarantined
        engine keys, and cumulative retry / fault counters.
        """
        with self._lock:
            thread_alive = self._thread is not None and self._thread.is_alive()
            pending = self._unresolved()
            now = self._clock.now()
            stale = bool(
                self._thread is not None
                and pending
                and (
                    self._last_round_at is None
                    or now - self._last_round_at > self.watchdog_interval
                )
            )
            svc_faults = self._svc.stats()["faults"]
            return {
                "state": self._state,
                "healthy": (
                    self._state == "running"
                    and not stale
                    and (self._thread is None or thread_alive)
                ),
                "thread_alive": thread_alive,
                "scheduler_stale": stale,
                "rounds": self._rounds,
                "last_round_at": self._last_round_at,
                "unresolved": pending,
                "queries_failed": self.queries_failed,
                "last_error": (
                    None if self._last_error is None else self._last_error.describe()
                ),
                "quarantined_keys": svc_faults["quarantined_keys"],
                "retries": svc_faults["retries"],
                "fault_counters": {
                    k: svc_faults[k]
                    for k in ("transient", "memory", "deterministic", "non_finite")
                },
            }

    def stats(self) -> Dict:
        """Scheduler + per-tenant + service counters, one snapshot."""
        with self._lock:
            return {
                "rounds": self._rounds,
                "state": self._state,
                "inflight_bytes": self._inflight_bytes,
                "admission_budget_bytes": self.admission_budget_bytes,
                "queries_failed": self.queries_failed,
                "rejections": dict(self.rejections),
                "warm": {
                    "queued": len(self._warm_queue),
                    "completed": len(self._warm_done),
                },
                "tune": {
                    "queued": len(self._tune_queue),
                    "completed": self.tunes_run,
                },
                "tenants": {
                    name: state.describe() for name, state in self._tenants.items()
                },
                "service": self._svc.stats(),
            }


def make_frontend(
    service: Optional[CountingService] = None,
    *,
    manual: bool = False,
    **frontend_kwargs,
) -> ServiceFrontend:
    """Convenience constructor: ``manual=True`` wires a ManualClock.

    With no ``service`` a default :class:`CountingService` is built —
    register graphs via ``frontend.service.register_graph``.
    """
    svc = service if service is not None else CountingService()
    if manual and "clock" not in frontend_kwargs:
        frontend_kwargs["clock"] = ManualClock()
    return ServiceFrontend(svc, **frontend_kwargs)
