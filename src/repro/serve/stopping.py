"""Adaptive (epsilon, delta) stopping for color-coding estimation runs.

The color-coding estimate is a mean of i.i.d. per-coloring counts, so the
blind a-priori iteration bound ``N = ceil(e^k log(1/delta) / eps^2)`` (Alon
et al.; ``estimator.required_iterations``) is wildly conservative — it must
cover the worst-case variance of *any* graph.  The serving layer replaces it
with sequential stopping on the *observed* variance: run an increment of
colorings, fold the per-coloring estimates into a running mean/variance
(Welford), and stop as soon as the normal-approximation confidence interval
is relatively tight enough::

    halfwidth = z_{1 - delta/2} * sqrt(var_sample / n)
    stop when  halfwidth <= epsilon * |mean|   (for every template)

or when the iteration budget runs out.  With ~dozens of increments the CLT
approximation is solid (the paper's estimates need >= tens of iterations for
useful accuracy anyway), and empirically the stopper lands 3-5 orders of
magnitude below the blind bound at the same (epsilon, delta) target.

For very small iteration counts or heavy-tailed per-coloring counts the
normal CI can under-cover (the sample variance lags the true tail);
``AdaptiveStopper(bound="bernstein")`` switches the halfwidth to the
**empirical-Bernstein** bound (Audibert et al. 2007; Maurer & Pontil 2009)

    halfwidth = sqrt(2 * var_sample * ln(3/delta) / n)
                + 3 * range_n * ln(3/delta) / n

which is variance-adaptive AND range-guarded: the second term keeps the
interval honest while the variance estimate is still warming up, at the
price of stopping later (never earlier) than the normal CI on the same
stream.  ``range_n`` is the *observed* sample range — the classical bound
assumes a known a-priori range, which per-coloring counts do not have, so
this is the standard plug-in variant (still a far heavier tail guard than
the CLT).  The normal CI stays the default.

Everything here is host-side float64 NumPy — deterministic under a fixed
seed and independent of how iterations were batched into launches.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional

import numpy as np

__all__ = [
    "normal_quantile",
    "AdaptiveStopper",
    "TemplateCI",
    "adaptive_estimate",
]

#: Guard against stopping on the degenerate variance of the first couple of
#: samples: the CI test only arms after this many iterations.
DEFAULT_MIN_ITERATIONS = 8


def normal_quantile(p: float) -> float:
    """Inverse standard-normal CDF (Acklam's rational approximation).

    Max absolute error ~1.15e-9 over (0, 1) — far below what a stopping
    rule can feel — with no SciPy dependency.
    """
    if not 0.0 < p < 1.0:
        raise ValueError(f"quantile needs p in (0, 1), got {p}")
    # coefficients: P. Acklam, "An algorithm for computing the inverse
    # normal cumulative distribution function"
    a = (-3.969683028665376e01, 2.209460984245205e02, -2.759285104469687e02,
         1.383577518672690e02, -3.066479806614716e01, 2.506628277459239e00)
    b = (-5.447609879822406e01, 1.615858368580409e02, -1.556989798598866e02,
         6.680131188771972e01, -1.328068155288572e01)
    c = (-7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e00,
         -2.549732539343734e00, 4.374664141464968e00, 2.938163982698783e00)
    d = (7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e00,
         3.754408661907416e00)
    p_low, p_high = 0.02425, 1 - 0.02425
    if p < p_low:
        q = math.sqrt(-2 * math.log(p))
        return (((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4]) * q + c[5]) / (
            (((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1
        )
    if p <= p_high:
        q = p - 0.5
        r = q * q
        return (((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4]) * r + a[5]) * q / (
            ((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r + b[4]) * r + 1
        )
    q = math.sqrt(-2 * math.log(1 - p))
    return -(((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4]) * q + c[5]) / (
        (((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1
    )


@dataclass
class TemplateCI:
    """Per-template running estimate at the moment of inspection.

    ``halfwidth``/``converged`` are the *stopping rule's* view (inf / False
    until the CI arms; 0.0 halfwidth for fixed-N queries — unchanged
    semantics).  The trailing fields are the *streaming-progress* view used
    by ``ServiceFrontend`` futures: BOTH CI halfwidths — the CLT z-interval
    (``halfwidth_normal``) and the empirical-Bernstein bound
    (``halfwidth_bernstein``) — are always computed once two samples exist,
    whatever bound the stopper tests and even for fixed-N queries, plus the
    ``lower``/``upper`` interval edges under the stopper's configured
    bound (``mean ∓ halfwidth``; ±inf before two samples).
    """

    mean: float
    std: float  # sample std (ddof=1); 0.0 before two samples
    halfwidth: float  # z * std / sqrt(n); inf before the CI arms
    converged: bool
    halfwidth_normal: float = math.inf
    halfwidth_bernstein: float = math.inf
    lower: float = -math.inf
    upper: float = math.inf


class AdaptiveStopper:
    """Running mean/variance + the relative-halfwidth stopping rule.

    One stopper per query; feed it ``(m, T)`` blocks of per-coloring
    normalized estimates in iteration order (`update`) and poll ``done``.
    A query stops when EVERY template's CI halfwidth is within
    ``epsilon * |mean|`` (after ``min_iterations``), or at ``budget``
    iterations.  ``epsilon=None`` disables the CI rule — the stopper
    degenerates to a fixed-``budget`` run, so fixed-N and adaptive queries
    drive through one code path.  ``bound`` picks the CI: ``"normal"``
    (default, CLT z-interval) or ``"bernstein"`` (empirical-Bernstein —
    variance-adaptive with an observed-range guard, sequentially more
    conservative; see the module docstring).

    State is a vectorized Welford accumulation in float64: deterministic,
    O(T) memory, and independent of launch batching (the same sample
    sequence gives the same stop decision however it was chunked —
    decisions are only TAKEN at increment boundaries, so coarser batching
    can only overshoot, never diverge).
    """

    def __init__(
        self,
        num_templates: int,
        *,
        epsilon: Optional[float] = None,
        delta: float = 0.05,
        budget: int = 1024,
        min_iterations: int = DEFAULT_MIN_ITERATIONS,
        bound: str = "normal",
    ):
        if epsilon is not None and epsilon <= 0:
            raise ValueError(f"epsilon must be positive, got {epsilon}")
        if not 0 < delta < 1:
            raise ValueError(f"delta must be in (0, 1), got {delta}")
        if budget < 1:
            raise ValueError(f"budget must be >= 1, got {budget}")
        if bound not in ("normal", "bernstein"):
            raise ValueError(f"unknown CI bound {bound!r} (normal | bernstein)")
        self.num_templates = int(num_templates)
        self.epsilon = epsilon
        self.delta = float(delta)
        self.budget = int(budget)
        self.min_iterations = max(2, int(min_iterations))
        self.bound = bound
        self.z = normal_quantile(1 - self.delta / 2) if epsilon is not None else None
        # reporting quantile: progress snapshots carry a CI even for
        # fixed-N queries (self.z stays None so the STOPPING rule is
        # untouched — fixed-N queries still never converge early)
        self._z_report = normal_quantile(1 - self.delta / 2)
        # ln(3/delta) — the empirical-Bernstein confidence term
        self._log3d = math.log(3.0 / self.delta)
        self.count = 0
        self._mean = np.zeros(self.num_templates, np.float64)
        self._m2 = np.zeros(self.num_templates, np.float64)
        # observed per-template sample range (the bernstein range guard);
        # tracked unconditionally — it is O(T) and makes bound switches in
        # tests/debugging honest
        self._min = np.full(self.num_templates, np.inf)
        self._max = np.full(self.num_templates, -np.inf)

    # -- accumulation --------------------------------------------------------

    def update(self, rows: np.ndarray) -> None:
        """Fold ``(m, T)`` per-coloring estimates into the running moments.

        Rejects any block containing a non-finite value (NaN/Inf) *before*
        touching the Welford state: one NaN would silently poison the
        running mean AND the variance — and a NaN variance makes the CI
        halfwidth NaN, whose ``<=`` comparison is False-but-plausible, so
        a corrupted stream could fake convergence or never stop.  The
        whole block is refused atomically (state unchanged), so the
        serving layer can fail just the affected query and keep going.
        """
        rows = np.asarray(rows, np.float64)
        if rows.ndim != 2 or rows.shape[1] != self.num_templates:
            raise ValueError(f"expected (m, {self.num_templates}) rows, got {rows.shape}")
        if not np.isfinite(rows).all():
            bad = [tuple(map(int, cell)) for cell in np.argwhere(~np.isfinite(rows))[:4]]
            raise ValueError(
                f"non-finite per-coloring estimate at (row, template) "
                f"{bad} — rejecting the block; Welford state is unchanged"
            )
        for row in rows:
            self.count += 1
            delta = row - self._mean
            self._mean += delta / self.count
            self._m2 += delta * (row - self._mean)
        if rows.shape[0]:
            np.minimum(self._min, rows.min(axis=0), out=self._min)
            np.maximum(self._max, rows.max(axis=0), out=self._max)

    # -- inspection ----------------------------------------------------------

    @property
    def iterations(self) -> int:
        return self.count

    def _halfwidth_normal(self, std: float) -> float:
        z = self.z if self.z is not None else self._z_report
        return z * std / math.sqrt(self.count)

    def _halfwidth_bernstein(self, t: int, std: float) -> float:
        n = self.count
        rng = float(self._max[t] - self._min[t]) if n >= 1 else 0.0
        return (
            math.sqrt(2.0 * std * std * self._log3d / n)
            + 3.0 * rng * self._log3d / n
        )

    def _halfwidth(self, t: int, std: float) -> float:
        """CI halfwidth for template ``t`` under the configured bound."""
        if self.bound == "bernstein":
            return self._halfwidth_bernstein(t, std)
        return self._halfwidth_normal(std)

    def estimates(self) -> List[TemplateCI]:
        """Current per-template mean / std / CI halfwidth."""
        out = []
        for t in range(self.num_templates):
            if self.count >= 2:
                var = self._m2[t] / (self.count - 1)
                std = math.sqrt(max(var, 0.0))
            else:
                std = 0.0
            if self.epsilon is not None and self.count >= self.min_iterations:
                half = self._halfwidth(t, std)
                conv = half <= self.epsilon * abs(self._mean[t])
            else:
                half = math.inf if self.epsilon is not None else 0.0
                conv = False
            mean = float(self._mean[t])
            if self.count >= 2:
                hw_n = self._halfwidth_normal(std)
                hw_b = self._halfwidth_bernstein(t, std)
                hw_used = hw_b if self.bound == "bernstein" else hw_n
                lower, upper = mean - hw_used, mean + hw_used
            else:
                hw_n = hw_b = math.inf
                lower, upper = -math.inf, math.inf
            out.append(
                TemplateCI(
                    mean=mean,
                    std=std,
                    halfwidth=half,
                    converged=conv,
                    halfwidth_normal=hw_n,
                    halfwidth_bernstein=hw_b,
                    lower=lower,
                    upper=upper,
                )
            )
        return out

    @property
    def converged(self) -> bool:
        """Every template's relative CI target met (False without a target)."""
        if self.z is None or self.count < self.min_iterations:
            return False
        return all(e.converged for e in self.estimates())

    @property
    def done(self) -> bool:
        return self.converged or self.count >= self.budget

    def remaining_budget(self) -> int:
        return max(0, self.budget - self.count)


def adaptive_estimate(
    engine,
    *,
    epsilon: float,
    delta: float = 0.05,
    seed: int = 0,
    max_iterations: int = 1024,
    min_iterations: int = DEFAULT_MIN_ITERATIONS,
    bound: str = "normal",
):
    """Drive one :class:`~repro.core.engine.CountingEngine` adaptively.

    Streams ``chunk_size``-wide increments through the engine's fixed-shape
    :meth:`~repro.core.engine.CountingEngine.count_keys_chunk` launch,
    folding each into an :class:`AdaptiveStopper`, until the relative
    ``(epsilon, delta)`` CI target is met or ``max_iterations`` is spent.
    Iteration ``i``'s coloring key is ``fold_in(PRNGKey(seed), i)`` —
    stable under any increment size, so the run is deterministic for a
    fixed seed.

    Returns one ``estimator.EstimateResult``-compatible object per template
    (``per_iteration`` holds exactly the iterations actually run).
    """
    import jax

    from repro.core.engine import EstimateResult

    stopper = AdaptiveStopper(
        len(engine.templates),
        epsilon=epsilon,
        delta=delta,
        budget=max_iterations,
        min_iterations=min_iterations,
        bound=bound,
    )
    import jax.numpy as jnp

    base = jax.random.PRNGKey(seed)
    fold = jax.vmap(lambda i: jax.random.fold_in(base, i))
    rows: List[np.ndarray] = []
    drawn = 0
    while not stopper.done:
        width = min(engine.chunk_size, stopper.remaining_budget())
        # one vmapped dispatch per increment (bit-identical to per-call
        # fold_in, which the cross-query equality tests draw independently)
        keys = np.asarray(fold(jnp.arange(drawn, drawn + width, dtype=jnp.uint32)))
        vals = engine.count_keys_chunk(keys)  # (width, T) float64
        drawn += width
        rows.append(vals)
        stopper.update(vals)
    per_iter = np.concatenate(rows, axis=0) if rows else np.zeros((0, len(engine.templates)))
    return [
        EstimateResult(
            mean=float(per_iter[:, t].mean()),
            std=float(per_iter[:, t].std()),
            per_iteration=per_iter[:, t],
            iterations=int(per_iter.shape[0]),
        )
        for t in range(len(engine.templates))
    ]
