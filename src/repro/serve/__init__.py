"""Serving layer: multi-tenant counting queries over cached engines.

``repro.serve.counting`` is the synchronous subgraph-counting service
(engine cache + cross-query batching + adaptive stopping);
``repro.serve.frontend`` is the async production front door above it
(futures, per-tenant QoS tiers and rate limits, cost-model backpressure,
streaming progress, background engine warming) with its QoS primitives in
``repro.serve.qos``.  ``repro.serve.engine`` is the unrelated LM
continuous-batching demo and is NOT imported here (it pulls in the
transformer stack — import it explicitly if you want it).
"""

from .cache import EngineCache
from .counting import CountingService, Query, QueryEstimate
from .frontend import (
    QoSRejected,
    QueryFuture,
    ServiceFrontend,
    TemplateProgress,
    make_frontend,
)
from .qos import ManualClock, SystemClock, TenantPolicy, TokenBucket
from .resilience import (
    QuarantinedError,
    RetryPolicy,
    ServiceError,
    classify_failure,
)
from .stopping import AdaptiveStopper, TemplateCI, adaptive_estimate, normal_quantile

__all__ = [
    "EngineCache",
    "CountingService",
    "Query",
    "QueryEstimate",
    "ServiceFrontend",
    "QueryFuture",
    "TemplateProgress",
    "QoSRejected",
    "make_frontend",
    "ManualClock",
    "SystemClock",
    "TenantPolicy",
    "TokenBucket",
    "ServiceError",
    "QuarantinedError",
    "RetryPolicy",
    "classify_failure",
    "AdaptiveStopper",
    "TemplateCI",
    "adaptive_estimate",
    "normal_quantile",
]
