"""Serving layer: multi-tenant counting queries over cached engines.

``repro.serve.counting`` is the subgraph-counting service (engine cache +
cross-query batching + adaptive stopping); ``repro.serve.engine`` is the
unrelated LM continuous-batching demo and is NOT imported here (it pulls in
the transformer stack — import it explicitly if you want it).
"""

from .cache import EngineCache
from .counting import CountingService, Query, QueryEstimate
from .stopping import AdaptiveStopper, TemplateCI, adaptive_estimate, normal_quantile

__all__ = [
    "EngineCache",
    "CountingService",
    "Query",
    "QueryEstimate",
    "AdaptiveStopper",
    "TemplateCI",
    "adaptive_estimate",
    "normal_quantile",
]
