"""Batched LM serving engine: continuous-batching-lite on a fixed slot pool.

A ``ServeEngine`` owns one jitted prefill and one jitted decode step over a
fixed (max_batch, max_len) KV cache.  Requests are admitted into free slots
(prefill writes their prompt into the cache at position 0 of the slot) and
all active slots decode together; finished slots (EOS or length budget) are
reaped and refilled — the standard continuous-batching structure without the
scheduler bells.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig
from repro.models import transformer as T

__all__ = ["Request", "ServeEngine"]


@dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (prompt_len,) int32
    max_new_tokens: int
    generated: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: LMConfig, params, max_batch: int = 8, max_len: int = 256):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.caches = T.init_kv_cache(cfg, max_batch, max_len)
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        self.slot_pos = np.zeros(max_batch, dtype=np.int32)

        cfg_ = cfg

        @jax.jit
        def _decode(params, caches, tokens, index_per_slot):
            # per-slot positions: run one step with per-slot cache index via
            # the max index (slots are kept aligned by greedy batching)
            logits, new_caches = T.decode_step(params, cfg_, tokens, caches, index_per_slot)
            return logits, new_caches

        self._decode = _decode

    # -- admission -----------------------------------------------------------

    def _prefill_one(self, slot: int, req: Request) -> None:
        """Prefill a single slot (slot-isolated cache update)."""
        prompt = jnp.asarray(req.prompt)[None, :]
        sub_cache = jax.tree.map(lambda c: c[:, slot : slot + 1], self.caches)
        logits, new_sub = T.prefill(self.params, self.cfg, prompt, sub_cache)
        self.caches = jax.tree.map(
            lambda full, sub: jax.lax.dynamic_update_slice_in_dim(full, sub, slot, axis=1),
            self.caches,
            new_sub,
        )
        self.slot_pos[slot] = len(req.prompt)
        tok = int(jnp.argmax(logits[0, -1]))
        req.generated.append(tok)

    def admit(self, requests: List[Request]) -> List[Request]:
        """Fill free slots; returns the requests that were admitted."""
        admitted = []
        for req in requests:
            free = [i for i, r in enumerate(self.slot_req) if r is None]
            if not free:
                break
            slot = free[0]
            self.slot_req[slot] = req
            self._prefill_one(slot, req)
            admitted.append(req)
        return admitted

    # -- decode loop ---------------------------------------------------------

    def step(self) -> int:
        """One batched decode step over all active slots; returns #active."""
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        tokens = np.zeros((self.max_batch, 1), dtype=np.int32)
        for i in active:
            tokens[i, 0] = self.slot_req[i].generated[-1]
        # all active slots share a write index = max position (aligned pool)
        index = int(self.slot_pos[active].max())
        logits, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(tokens), jnp.int32(index)
        )
        logits = np.asarray(logits)
        for i in active:
            req = self.slot_req[i]
            tok = int(np.argmax(logits[i]))
            req.generated.append(tok)
            self.slot_pos[i] = index + 1
            if len(req.generated) >= req.max_new_tokens or self.slot_pos[i] >= self.max_len - 1:
                req.done = True
                self.slot_req[i] = None
        return len(active)

    def run(self, requests: List[Request]) -> List[Request]:
        """Serve a request list to completion (admit + decode until drained)."""
        pending = list(requests)
        while pending or any(r is not None for r in self.slot_req):
            admitted = self.admit(pending)
            pending = [r for r in pending if r not in admitted]
            if self.step() == 0 and not pending:
                break
        return requests
