"""Per-tenant QoS primitives for the async serving front-end.

Everything here is deliberately *clock-injected*: the front-end scheduler
(:mod:`repro.serve.frontend`) never reads wall time directly — it asks the
:class:`Clock` it was constructed with.  Production uses
:class:`SystemClock` (monotonic); the deterministic test harness uses
:class:`ManualClock` and advances time explicitly, so rate limits, bucket
refills, and latency accounting are all single-steppable with zero sleeps.

Three layers:

* :class:`TokenBucket` — the classic leaky/token bucket: ``rate_qps``
  tokens per second refill up to ``burst`` capacity; an admission consumes
  one token.  Refill is lazy (computed from the clock on each inspection),
  so the bucket has no thread of its own.
* :class:`TenantPolicy` — the declarative per-tenant knobs: ``priority``
  tier (higher tiers admit first each scheduler round), ``rate_qps`` /
  ``burst`` (token bucket; ``None`` = unlimited), and ``max_pending``
  (queue cap — submissions beyond it are rejected with backpressure).
* :class:`TenantState` — the scheduler's live bookkeeping for one tenant:
  the FIFO of not-yet-admitted submissions, the bucket, and counters
  (submitted / admitted / completed / cancelled / failed / rejected)
  surfaced by ``frontend.stats()``.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, Optional

__all__ = [
    "Clock",
    "SystemClock",
    "ManualClock",
    "TokenBucket",
    "TenantPolicy",
    "TenantState",
    "DEFAULT_MAX_PENDING",
]

#: Default per-tenant queue cap (queued + in-flight) before submissions are
#: rejected with ``queue_full`` backpressure.
DEFAULT_MAX_PENDING = 64


class Clock:
    """Time source seam: the front-end only ever calls :meth:`now`."""

    def now(self) -> float:  # pragma: no cover - interface
        raise NotImplementedError


class SystemClock(Clock):
    """Monotonic wall clock (production default)."""

    def now(self) -> float:
        return time.monotonic()


class ManualClock(Clock):
    """Explicitly-advanced clock for deterministic scheduler tests.

    ``now()`` returns the last value set; nothing moves until the test
    calls :meth:`advance`.  Never sleeps, never drifts.
    """

    def __init__(self, start: float = 0.0):
        self._now = float(start)

    def now(self) -> float:
        return self._now

    def advance(self, seconds: float) -> float:
        if seconds < 0:
            raise ValueError(f"cannot advance time backwards ({seconds})")
        self._now += float(seconds)
        return self._now


class TokenBucket:
    """Lazy-refill token bucket driven by an injected clock.

    ``rate`` tokens accrue per clock-second up to ``burst`` capacity; the
    bucket starts full (a fresh tenant can burst immediately).  All state
    changes happen inside the caller's lock — the bucket itself is not
    thread-safe and does not need to be.
    """

    def __init__(self, rate: float, burst: float, clock: Clock):
        if rate <= 0:
            raise ValueError(f"rate must be positive, got {rate}")
        if burst < 1:
            raise ValueError(f"burst must be >= 1, got {burst}")
        self.rate = float(rate)
        self.burst = float(burst)
        self._clock = clock
        self._tokens = float(burst)
        self._last = clock.now()

    def _refill(self) -> None:
        now = self._clock.now()
        if now > self._last:
            self._tokens = min(self.burst, self._tokens + (now - self._last) * self.rate)
        self._last = now

    def available(self) -> float:
        """Current token balance (after lazy refill)."""
        self._refill()
        return self._tokens

    def try_acquire(self, tokens: float = 1.0) -> bool:
        """Consume ``tokens`` if the balance allows; never blocks."""
        self._refill()
        if self._tokens + 1e-9 >= tokens:
            self._tokens -= tokens
            return True
        return False


@dataclass(frozen=True)
class TenantPolicy:
    """Declarative QoS knobs for one tenant.

    ``priority``: higher tiers are offered admission first every scheduler
    round (within a tier tenants round-robin, so a flooding tenant cannot
    starve its peers).  ``rate_qps``/``burst``: token-bucket admission rate
    (``None`` disables the bucket).  ``max_pending``: hard cap on queued +
    in-flight queries; beyond it :meth:`ServiceFrontend.submit` rejects
    with ``queue_full``.
    """

    name: str
    priority: int = 0
    rate_qps: Optional[float] = None
    burst: Optional[float] = None
    max_pending: int = DEFAULT_MAX_PENDING

    def make_bucket(self, clock: Clock) -> Optional[TokenBucket]:
        if self.rate_qps is None:
            return None
        burst = self.burst if self.burst is not None else max(1.0, self.rate_qps)
        return TokenBucket(self.rate_qps, burst, clock)


@dataclass
class TenantState:
    """Live scheduler bookkeeping for one tenant (guarded by the
    front-end lock)."""

    policy: TenantPolicy
    bucket: Optional[TokenBucket]
    queue: Deque = field(default_factory=deque)  # not-yet-admitted futures
    inflight: int = 0  # admitted, not yet resolved
    counters: Dict[str, int] = field(
        default_factory=lambda: {
            "submitted": 0,
            "admitted": 0,
            "completed": 0,
            "cancelled": 0,
            "failed": 0,
            "rejected": 0,
        }
    )

    @property
    def pending(self) -> int:
        """Queued + in-flight load counted against ``max_pending``."""
        return len(self.queue) + self.inflight

    def describe(self) -> Dict:
        return {
            "priority": self.policy.priority,
            "rate_qps": self.policy.rate_qps,
            "max_pending": self.policy.max_pending,
            "queued": len(self.queue),
            "inflight": self.inflight,
            "tokens": None if self.bucket is None else self.bucket.available(),
            **self.counters,
        }
