"""Compiled-engine LRU cache for the counting service.

A :class:`~repro.core.engine.CountingEngine` is expensive twice over: device
operand construction (edge lists / SELL tables / dense adjacency shipped to
the device) and the jit trace+compile of its run programs.  Both are keyed
entirely by :func:`repro.core.engine.engine_cache_key` — graph signature,
template-set canonical forms, backend, dtype policy, and the chunk spec —
so repeat and near-repeat queries (same key, different seeds / iteration
targets / epsilon) must never pay them again.  The cache holds the warm
engines behind that key with LRU eviction and hit/miss/evict counters for
observability.

Thread safety: every operation (get/peek/keys/counters/clear) runs under
one internal re-entrant lock, so the cache can be shared between the
front-end scheduler thread, background pre-warming, and ad-hoc inspection
without torn LRU order or drifting counters.  The lock is held *across the
miss-path* ``factory()`` call on purpose: two threads racing on the same
cold key must build the engine once, not twice — the second thread blocks
and then hits.  (Engine builds for *different* keys therefore serialize
too; the front-end routes all builds through its single scheduler thread,
so this costs nothing there.)
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Callable, Dict, Hashable, Optional, Tuple

__all__ = ["EngineCache"]


class EngineCache:
    """LRU map ``engine_cache_key -> warm CountingEngine``.

    ``get(key, factory)`` returns the cached engine (hit: moves it to the
    MRU end) or builds one via ``factory()`` (miss: inserts, evicting the
    LRU entry beyond ``capacity``).  Evicted engines are simply dropped —
    JAX frees their device operands with the last reference, and a
    re-query rebuilds through the same factory path.
    """

    def __init__(self, capacity: int = 8):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._store: "OrderedDict[Hashable, object]" = OrderedDict()
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.build_failures = 0
        self.invalidations = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._store)

    def __contains__(self, key: Hashable) -> bool:
        with self._lock:
            return key in self._store

    def get(self, key: Hashable, factory: Callable[[], object]):
        """Cached engine for ``key``, building (and possibly evicting) on miss.

        Atomic under the cache lock — concurrent gets for one cold key
        build exactly once (the losers of the race block, then hit).

        A ``factory()`` that raises leaves the cache exactly as it was: no
        entry under ``key`` (the next ``get`` re-runs a fresh factory), no
        held lock state (the RLock unwinds with the exception), and only
        the ``build_failures`` counter advanced.
        """
        with self._lock:
            if key in self._store:
                self.hits += 1
                self._store.move_to_end(key)
                return self._store[key]
            self.misses += 1
            try:
                engine = factory()
            except BaseException:
                # miss-path poisoning guard: never insert a placeholder or
                # partial entry for a build that failed
                self.build_failures += 1
                raise
            self._store[key] = engine
            while len(self._store) > self.capacity:
                self._store.popitem(last=False)
                self.evictions += 1
            return engine

    def peek(self, key: Hashable) -> Optional[object]:
        """The cached engine without touching counters or LRU order."""
        with self._lock:
            return self._store.get(key)

    def invalidate(self, key: Hashable) -> bool:
        """Drop ``key``'s entry (True if one existed).

        The degradation-ladder path uses this: a memory-failed engine is
        evicted so the next ``get`` rebuilds it at the new rung's
        chunk/column-batch/backend configuration.
        """
        with self._lock:
            existed = self._store.pop(key, None) is not None
            if existed:
                self.invalidations += 1
            return existed

    def keys(self) -> Tuple[Hashable, ...]:
        """Cached keys, LRU first."""
        with self._lock:
            return tuple(self._store.keys())

    def counters(self) -> Dict[str, int]:
        with self._lock:
            return {
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "build_failures": self.build_failures,
                "invalidations": self.invalidations,
                "size": len(self._store),
                "capacity": self.capacity,
            }

    def clear(self) -> None:
        with self._lock:
            self._store.clear()
