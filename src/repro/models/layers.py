"""Transformer building blocks: RMSNorm, RoPE, GQA/MQA/MLA attention, dense
and MoE feed-forward.  Pure functional JAX — params are nested dicts.

All matmul-bearing ops accept a ``dtype`` for activations (bf16 on TPU) and
keep params in fp32 (mixed-precision convention); reductions (softmax, norm)
run in fp32.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig

__all__ = [
    "rmsnorm",
    "rope_frequencies",
    "apply_rope",
    "init_attention",
    "attention_apply",
    "init_ffn",
    "ffn_apply",
    "init_moe",
    "moe_apply",
]

Params = Dict[str, jnp.ndarray]


def _dense_init(key, shape, scale_axis=0):
    scale = 1.0 / np.sqrt(shape[scale_axis])
    return jax.random.normal(key, shape, dtype=jnp.float32) * scale


def rmsnorm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    rms = jnp.sqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return ((xf / rms) * gamma).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, n_heads, d); positions: (..., seq)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)  # (d/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, d/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA / MQA / MLA)
# ---------------------------------------------------------------------------


def init_attention(key, cfg: LMConfig) -> Params:
    d = cfg.d_model
    if cfg.attention == "mla":
        ks = jax.random.split(key, 6)
        qd = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
        return {
            "w_q": _dense_init(ks[0], (d, cfg.n_heads, qd)),
            "w_dkv": _dense_init(ks[1], (d, cfg.kv_lora_rank)),
            "w_krope": _dense_init(ks[2], (d, cfg.qk_rope_head_dim)),
            "w_uk": _dense_init(ks[3], (cfg.kv_lora_rank, cfg.n_heads, cfg.qk_nope_head_dim)),
            "w_uv": _dense_init(ks[4], (cfg.kv_lora_rank, cfg.n_heads, cfg.v_head_dim)),
            "w_o": _dense_init(ks[5], (cfg.n_heads, cfg.v_head_dim, d), scale_axis=1),
            "kv_norm": jnp.ones((cfg.kv_lora_rank,), jnp.float32),
        }
    ks = jax.random.split(key, 4)
    return {
        "w_q": _dense_init(ks[0], (d, cfg.n_heads, cfg.d_head)),
        "w_k": _dense_init(ks[1], (d, cfg.n_kv_heads, cfg.d_head)),
        "w_v": _dense_init(ks[2], (d, cfg.n_kv_heads, cfg.d_head)),
        "w_o": _dense_init(ks[3], (cfg.n_heads, cfg.d_head, d), scale_axis=1),
    }


def _sdpa_chunked(
    q: jnp.ndarray,  # (b, sq, h, d)
    k: jnp.ndarray,  # (b, sk, h_kv, d)
    v: jnp.ndarray,  # (b, sk, h_kv, dv)
    q_positions: jnp.ndarray,  # (sq,) absolute positions of queries
    kv_len: Optional[jnp.ndarray],  # scalar valid kv length (decode) or None (=sk)
    causal: bool,
    q_chunk: int,
) -> jnp.ndarray:
    """Query-chunked causal attention with fp32 softmax.

    Memory: O(q_chunk * sk) per chunk instead of O(sq * sk) — the XLA-level
    analogue of flash attention's outer loop (inner loop left to fusion).
    """
    b, sq, h, d = q.shape
    h_kv = k.shape[2]
    group = h // h_kv
    scale = 1.0 / np.sqrt(d)
    kv_pos = jnp.arange(k.shape[1])

    qg = q.reshape(b, sq, h_kv, group, d)

    def one_chunk(args):
        qc, qpos = args  # (b, c, h_kv, g, d), (c,)
        logits = jnp.einsum("bchgd,bshd->bchgs", qc.astype(jnp.float32), k.astype(jnp.float32)) * scale
        mask = jnp.ones((qc.shape[1], k.shape[1]), bool)
        if causal:
            mask = qpos[:, None] >= kv_pos[None, :]
        if kv_len is not None:
            mask = mask & (kv_pos[None, :] < kv_len)
        logits = jnp.where(mask[None, :, None, None, :], logits, -1e30)
        p = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bchgs,bshe->bchge", p, v.astype(jnp.float32))

    if sq <= q_chunk:
        out = one_chunk((qg, q_positions))
    else:
        pad = (-sq) % q_chunk
        if pad:
            qg = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
            q_positions = jnp.pad(q_positions, (0, pad))
        n_chunks = (sq + pad) // q_chunk
        qg_c = qg.reshape(b, n_chunks, q_chunk, h_kv, group, d).swapaxes(0, 1)
        pos_c = q_positions.reshape(n_chunks, q_chunk)
        out = jax.lax.map(one_chunk, (qg_c, pos_c))  # (n, b, c, h_kv, g, dv)
        out = out.swapaxes(0, 1).reshape(b, sq + pad, h_kv, group, v.shape[-1])[:, :sq]
    return out.reshape(b, sq, h, v.shape[-1]).astype(q.dtype)


def attention_apply(
    params: Params,
    cfg: LMConfig,
    x: jnp.ndarray,  # (b, s, d)
    positions: jnp.ndarray,  # (s,)
    cache: Optional[Dict[str, jnp.ndarray]] = None,
    cache_index: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """Causal self-attention. With ``cache`` (decode), ``x`` is the new-token
    slice and ``cache_index`` the write offset; returns updated cache."""
    if cfg.attention == "mla":
        return _mla_apply(params, cfg, x, positions, cache, cache_index)

    q = jnp.einsum("bsd,dhe->bshe", x, params["w_q"].astype(x.dtype))
    k = jnp.einsum("bsd,dhe->bshe", x, params["w_k"].astype(x.dtype))
    v = jnp.einsum("bsd,dhe->bshe", x, params["w_v"].astype(x.dtype))
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        k_cache = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, cache_index, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, cache_index, 0, 0))
        new_cache = {"k": k_cache, "v": v_cache}
        kv_len = cache_index + x.shape[1]
        out = _sdpa_chunked(q, k_cache, v_cache, positions, kv_len, causal=True, q_chunk=cfg.attn_q_chunk)
    elif cfg.attn_impl == "flash":
        # Pallas flash-attention kernel (interpret-mode on CPU hosts)
        from repro.kernels.flash_attention.ops import flash_attention

        interpret = jax.devices()[0].platform != "tpu"
        out = flash_attention(q, k, v, causal=True, interpret=interpret)
    else:
        out = _sdpa_chunked(q, k, v, positions, None, causal=True, q_chunk=cfg.attn_q_chunk)
    return jnp.einsum("bshe,hed->bsd", out, params["w_o"].astype(x.dtype)), new_cache


def _mla_apply(params, cfg: LMConfig, x, positions, cache, cache_index):
    """DeepSeek-V2 Multi-head Latent Attention.

    KV state is compressed to ``c_kv`` (kv_lora_rank) + a shared rope key —
    only those are cached; per-head K/V are decompressed on the fly.
    """
    b, s, d = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim

    q = jnp.einsum("bsd,dhe->bshe", x, params["w_q"].astype(x.dtype))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    c_kv = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"].astype(x.dtype))
    c_kv = rmsnorm(c_kv, params["kv_norm"], cfg.norm_eps)
    k_rope = jnp.einsum("bsd,de->bse", x, params["w_krope"].astype(x.dtype))
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]

    new_cache = None
    if cache is not None:
        c_cache = jax.lax.dynamic_update_slice(cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, cache_index, 0))
        r_cache = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), (0, cache_index, 0))
        new_cache = {"c_kv": c_cache, "k_rope": r_cache}
        c_all, r_all = c_cache, r_cache
        kv_len = cache_index + s
        causal = True
    else:
        c_all, r_all = c_kv, k_rope
        kv_len = None
        causal = True

    if cache is not None and s == 1:
        # ABSORBED decode (DeepSeek-V2 §2.1): fold w_uk into q and w_uv into
        # the output so attention runs entirely in the latent space — no
        # (b, s_kv, h, d) K/V decompression (17 GB/layer at 32k x 128).
        q_lat = jnp.einsum("bshe,rhe->bshr", q_nope, params["w_uk"].astype(x.dtype))
        logits_nope = jnp.einsum("bshr,btr->bhst", q_lat, c_all)
        logits_rope = jnp.einsum("bshe,bte->bhst", q_rope, r_all)
        scale = 1.0 / np.sqrt(dn + dr)
        logits_full = (logits_nope + logits_rope).astype(jnp.float32) * scale
        kv_pos = jnp.arange(c_all.shape[1])
        mask = kv_pos < kv_len  # (t,)
        logits_full = jnp.where(mask[None, None, None, :], logits_full, -1e30)
        p = jax.nn.softmax(logits_full, axis=-1)
        out_lat = jnp.einsum("bhst,btr->bshr", p.astype(x.dtype), c_all)
        out = jnp.einsum("bshr,rhe->bshe", out_lat, params["w_uv"].astype(x.dtype))
        return jnp.einsum("bshe,hed->bsd", out, params["w_o"].astype(x.dtype)), new_cache

    # Decompress K/V from the latent (prefill/train).
    k_nope = jnp.einsum("bsr,rhe->bshe", c_all, params["w_uk"].astype(x.dtype))
    v = jnp.einsum("bsr,rhe->bshe", c_all, params["w_uv"].astype(x.dtype))
    k = jnp.concatenate([k_nope, jnp.broadcast_to(r_all[:, :, None, :], (*r_all.shape[:2], h, dr))], axis=-1)
    qq = jnp.concatenate([q_nope, q_rope], axis=-1)

    out = _sdpa_chunked(qq, k, v, positions, kv_len, causal=causal, q_chunk=cfg.attn_q_chunk)
    return jnp.einsum("bshe,hed->bsd", out, params["w_o"].astype(x.dtype)), new_cache


# ---------------------------------------------------------------------------
# Feed-forward: dense + MoE
# ---------------------------------------------------------------------------


def init_ffn(key, d_model: int, d_ff: int, activation: str) -> Params:
    if activation in ("swiglu", "geglu"):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "w_gate": _dense_init(k1, (d_model, d_ff)),
            "w_up": _dense_init(k2, (d_model, d_ff)),
            "w_down": _dense_init(k3, (d_ff, d_model)),
        }
    k1, k2 = jax.random.split(key, 2)
    return {"w_up": _dense_init(k1, (d_model, d_ff)), "w_down": _dense_init(k2, (d_ff, d_model))}


def _activate(gate: jnp.ndarray, up: Optional[jnp.ndarray], activation: str) -> jnp.ndarray:
    if activation == "swiglu":
        return jax.nn.silu(gate) * up
    if activation == "geglu":
        return jax.nn.gelu(gate) * up
    if activation == "squared_relu":  # Primer / Nemotron-4
        r = jax.nn.relu(gate)
        return r * r
    if activation == "gelu":  # GPT-BigCode / Granite-20B
        return jax.nn.gelu(gate)
    raise ValueError(f"unknown activation {activation!r}")


def ffn_apply(params: Params, activation: str, x: jnp.ndarray) -> jnp.ndarray:
    if activation in ("swiglu", "geglu"):
        h = _activate(
            x @ params["w_gate"].astype(x.dtype), x @ params["w_up"].astype(x.dtype), activation
        )
    else:
        h = _activate(x @ params["w_up"].astype(x.dtype), None, activation)
    return h @ params["w_down"].astype(x.dtype)


def init_moe(key, cfg: LMConfig) -> Params:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    gated = cfg.ffn_activation in ("swiglu", "geglu")
    params: Params = {
        "router": _dense_init(ks[0], (d, e)),
        "w_up": _dense_init(ks[1], (e, d, f)) / np.sqrt(1),
        "w_down": _dense_init(ks[2], (e, f, d)),
    }
    if gated:
        params["w_gate"] = _dense_init(ks[3], (e, d, f))
    if cfg.n_shared_experts:
        params["shared"] = init_ffn(ks[4], d, cfg.moe_d_ff * cfg.n_shared_experts, cfg.ffn_activation)
    return params


def _moe_apply_ep(params: Params, cfg: LMConfig, x: jnp.ndarray, act_spec) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Expert-parallel MoE via shard_map (the Switch/DeepSeek production path).

    Routing, position-cumsum, and capacity are **local per shard** (each
    device drops independently — standard EP semantics), eliminating the
    global-token cumsum/scatter of the pjit path.  Expert exchange is two
    ``all_to_all``s over the "model" axis:

        local buf (e, cap_l, d) --a2a--> (e/m, m*cap_l, d) -- expert FFN -->
        --a2a back--> (e, cap_l, d) --> local gather/combine.
    """
    from jax.sharding import PartitionSpec as P

    from .. import compat

    mesh = compat.current_mesh()
    model_ax = "model"
    m_size = mesh.shape[model_ax]
    e, k = cfg.n_experts, cfg.moe_top_k
    gated = cfg.ffn_activation in ("swiglu", "geglu")

    def local_fn(router, w_up, w_gate, w_down, xl):
        b_l, s_l, d = xl.shape
        tokens = xl.reshape(b_l * s_l, d)
        t_l = b_l * s_l
        logits = tokens.astype(jnp.float32) @ router.astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

        flat_e = expert_idx.reshape(-1)
        tokens_per_expert = (
            jax.ops.segment_sum(jnp.ones_like(flat_e, jnp.float32), flat_e, num_segments=e)
            / (t_l * k)
        )
        aux_local = e * jnp.sum(tokens_per_expert * probs.mean(0)) * cfg.router_aux_coef
        aux = jax.lax.pmean(aux_local, tuple(mesh.axis_names))

        cap_l = max(int(t_l * k * cfg.capacity_factor / e), 4)
        onehot_flat = jax.nn.one_hot(flat_e, e, dtype=jnp.float32)
        pos_flat = (
            (jnp.cumsum(onehot_flat, axis=0) - onehot_flat)[jnp.arange(t_l * k), flat_e]
        ).astype(jnp.int32)
        keep = pos_flat < cap_l
        safe_pos = jnp.where(keep, pos_flat, cap_l)
        gate_flat = gate_vals.reshape(-1) * keep

        tok_of_slot = jnp.arange(t_l * k) // k
        buf = jnp.zeros((e, cap_l + 1, d), dtype=xl.dtype)
        buf = buf.at[flat_e, safe_pos].add(tokens[tok_of_slot] * keep[:, None].astype(xl.dtype))
        buf = buf[:, :cap_l]

        # EP exchange: experts home to their shard
        buf = jax.lax.all_to_all(buf, model_ax, split_axis=0, concat_axis=1, tiled=True)
        # buf: (e/m, m*cap_l, d); w_up local: (e/m, d, f)
        up = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(xl.dtype))
        if gated:
            gh = jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(xl.dtype))
            hh = _activate(gh, up, cfg.ffn_activation)
        else:
            hh = _activate(up, None, cfg.ffn_activation)
        eo = jnp.einsum("ecf,efd->ecd", hh, w_down.astype(xl.dtype))
        eo = jax.lax.all_to_all(eo, model_ax, split_axis=1, concat_axis=0, tiled=True)
        # eo: (e, cap_l, d) — back on the token-home shard
        back = eo[flat_e, jnp.minimum(safe_pos, cap_l - 1)]
        back = back * gate_flat[:, None].astype(xl.dtype)
        out = jax.ops.segment_sum(back, tok_of_slot, num_segments=t_l, indices_are_sorted=True)
        return out.reshape(b_l, s_l, d), aux

    w_gate = params.get("w_gate", params["w_up"])
    out, aux = compat.shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            P(None, None),
            P(model_ax, None, None),
            P(model_ax, None, None),
            P(model_ax, None, None),
            act_spec,
        ),
        out_specs=(act_spec, P()),
    )(params["router"], params["w_up"], w_gate, params["w_down"], x)
    if cfg.n_shared_experts:
        out = out + ffn_apply(params["shared"], cfg.ffn_activation, x)
    return out, aux


def _flat_token_spec(act_spec):
    """(b, s, d) residual spec -> (tokens, d) spec for the flattened MoE view."""
    if act_spec is None:
        return None
    from jax.sharding import PartitionSpec as P

    def axes(entry):
        if entry is None:
            return ()
        return tuple(entry) if isinstance(entry, (tuple, list)) else (entry,)

    return P(axes(act_spec[0]) + axes(act_spec[1]), act_spec[2])


def moe_apply(params: Params, cfg: LMConfig, x: jnp.ndarray, act_spec=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k MoE with capacity + scatter/gather dispatch.

    Returns (output, aux_loss).  Instead of the GShard one-hot dispatch
    tensor ``(tokens, experts, capacity)`` — O(t*e*c) memory, infeasible at
    1M-token global batches — tokens are scattered into a dense per-expert
    buffer ``(e, capacity, d)`` with ``.at[].add`` (each slot receives at most
    one token) and gathered back after the expert FFN.  Expert weights carry
    a leading expert axis sharded over "model" (expert parallelism); the
    scatter/gather lower to all-to-all-style collectives under pjit.
    """
    from jax.sharding import PartitionSpec as P

    if act_spec is not None:
        return _moe_apply_ep(params, cfg, x, act_spec)

    b, s, d = x.shape
    tokens = x.reshape(b * s, d)
    n_tok = b * s
    e, k = cfg.n_experts, cfg.moe_top_k
    tok_spec = _flat_token_spec(act_spec)

    def wsc(v, spec):
        return jax.lax.with_sharding_constraint(v, spec) if act_spec is not None else v

    tokens = wsc(tokens, tok_spec)
    logits = tokens.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (t, e)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (t, k)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    flat_e = expert_idx.reshape(-1)  # (t*k,)
    # load-balancing aux loss (Switch): e * sum_e frac_tokens_e * frac_prob_e
    tokens_per_expert = (
        jax.ops.segment_sum(jnp.ones_like(flat_e, jnp.float32), flat_e, num_segments=e)
        / (n_tok * k)
    )
    aux = e * jnp.sum(tokens_per_expert * probs.mean(0)) * cfg.router_aux_coef

    capacity = max(int(n_tok * k * cfg.capacity_factor / e), 4)
    # position of each (token, slot) within its expert queue (cumsum order)
    onehot_flat = jax.nn.one_hot(flat_e, e, dtype=jnp.float32)  # (t*k, e)
    pos_flat = (
        (jnp.cumsum(onehot_flat, axis=0) - onehot_flat)[jnp.arange(n_tok * k), flat_e]
    ).astype(jnp.int32)
    keep = pos_flat < capacity
    safe_pos = jnp.where(keep, pos_flat, capacity)  # overflow -> scratch slot
    gate_flat = gate_vals.reshape(-1) * keep

    tok_of_slot = jnp.arange(n_tok * k) // k
    buf = jnp.zeros((e, capacity + 1, d), dtype=x.dtype)
    buf = buf.at[flat_e, safe_pos].add(tokens[tok_of_slot] * keep[:, None].astype(x.dtype))
    # expert buffers live sharded over the expert axis (EP) — without the
    # constraint the partitioner replicates the scatter target (30+ GB/dev)
    buf = wsc(buf, P("model", None, None))
    expert_in = buf[:, :capacity]  # (e, cap, d)

    gated = cfg.ffn_activation in ("swiglu", "geglu")
    up = jnp.einsum("ecd,edf->ecf", expert_in, params["w_up"].astype(x.dtype))
    if gated:
        gate_h = jnp.einsum("ecd,edf->ecf", expert_in, params["w_gate"].astype(x.dtype))
        h = _activate(gate_h, up, cfg.ffn_activation)
    else:
        h = _activate(up, None, cfg.ffn_activation)
    expert_out = wsc(
        jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(x.dtype)), P("model", None, None)
    )

    # gather back + weighted combine over the k slots of each token
    back = expert_out[flat_e, jnp.minimum(safe_pos, capacity - 1)]  # (t*k, d)
    back = back * gate_flat[:, None].astype(x.dtype)
    out = jax.ops.segment_sum(back, tok_of_slot, num_segments=n_tok, indices_are_sorted=True)
    out = wsc(out, tok_spec)
    if cfg.n_shared_experts:
        out = out + ffn_apply(params["shared"], cfg.ffn_activation, tokens)
    return out.reshape(b, s, d), aux
