"""Decoder-only transformer LM covering all five assigned LM architectures.

Supports: GQA / MQA (``n_kv_heads``), DeepSeek-V2 MLA, dense and MoE FFN
(fine-grained + shared experts), SwiGLU / squared-ReLU / GELU, RoPE,
per-layer activation checkpointing, KV-cache prefill/decode.

**Layer stacking**: layers are stored stacked in homogeneous *groups*
(e.g. DeepSeek's dense prefix + MoE body) and executed with ``jax.lax.scan``
— one compiled layer body per group instead of ``n_layers`` HLO copies.
This keeps 512-device lowering tractable and is the standard production
pattern (MaxText-style).  ``cfg.scan_layers=False`` unrolls (smoke tests).

Entry points:
  * ``init_params(key, cfg)`` / ``param_shapes(cfg)`` (eval_shape, no alloc)
  * ``forward(params, cfg, tokens)``            -> (logits, aux, caches)
  * ``loss_fn(params, cfg, tokens, labels)``
  * ``init_kv_cache(cfg, batch, max_len)`` / ``kv_cache_shapes``
  * ``prefill`` / ``decode_step``
  * ``param_pspecs(cfg)`` / ``kv_cache_pspecs(cfg)`` for pjit.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import LMConfig
from repro.models import layers as L

__all__ = [
    "init_params",
    "param_shapes",
    "forward",
    "loss_fn",
    "init_kv_cache",
    "prefill",
    "decode_step",
    "param_pspecs",
    "kv_cache_pspecs",
    "layer_groups",
]

Params = Dict


def layer_groups(cfg: LMConfig) -> List[Tuple[int, bool]]:
    """[(n_layers_in_group, is_moe_group)] — homogeneous scan groups."""
    if cfg.moe and cfg.first_k_dense > 0:
        return [(cfg.first_k_dense, False), (cfg.n_layers - cfg.first_k_dense, True)]
    return [(cfg.n_layers, cfg.moe)]


def _init_layer(key, cfg: LMConfig, moe: bool) -> Params:
    k_attn, k_ffn = jax.random.split(key)
    layer = {
        "attn_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "ffn_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "attn": L.init_attention(k_attn, cfg),
    }
    if moe:
        layer["moe"] = L.init_moe(k_ffn, cfg)
    else:
        layer["ffn"] = L.init_ffn(k_ffn, cfg.d_model, cfg.d_ff, cfg.ffn_activation)
    return layer


def init_params(key: jax.Array, cfg: LMConfig) -> Params:
    ks = jax.random.split(key, 3)
    groups = []
    for g, (n, moe) in enumerate(layer_groups(cfg)):
        layer_params = [
            _init_layer(jax.random.fold_in(ks[0], g * 1000 + i), cfg, moe) for i in range(n)
        ]
        groups.append(jax.tree.map(lambda *xs: jnp.stack(xs), *layer_params))
    params = {
        "embed": jax.random.normal(ks[1], (cfg.vocab_size, cfg.d_model), jnp.float32) * 0.02,
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "groups": groups,
    }
    if not cfg.tie_embeddings:
        params["unembed"] = (
            jax.random.normal(ks[2], (cfg.d_model, cfg.vocab_size), jnp.float32)
            / np.sqrt(cfg.d_model)
        )
    return params


def param_shapes(cfg: LMConfig):
    """ShapeDtypeStruct pytree without allocating (dry-run input specs)."""
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


def _layer_apply(cfg: LMConfig, moe: bool, layer: Params, x, positions, cache, cache_index, act_spec=None):
    h, new_cache = L.attention_apply(
        layer["attn"], cfg, L.rmsnorm(x, layer["attn_norm"], cfg.norm_eps), positions, cache, cache_index
    )
    x = x + h
    hn = L.rmsnorm(x, layer["ffn_norm"], cfg.norm_eps)
    if moe:
        h, aux = L.moe_apply(layer["moe"], cfg, hn, act_spec=act_spec)
    else:
        h, aux = L.ffn_apply(layer["ffn"], cfg.ffn_activation, hn), jnp.zeros((), jnp.float32)
    return x + h, aux, new_cache


def _constrain(x, spec):
    """Residual-stream sharding constraint (None = let XLA choose).

    Training/prefill cells pass ``P(dp, "model", None)`` — batch over the
    data axes plus Megatron-style sequence parallelism over "model" — which
    pins the scan carry (the per-layer saved activation under remat) to its
    minimal footprint instead of letting the partitioner propagate weight
    shardings onto it."""
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def _run_group(cfg, moe, stacked, n, x, positions, cache, cache_index, act_spec=None):
    """Scan (or unroll) one homogeneous group.  Returns (x, aux, new_cache)."""
    if cfg.scan_layers and n > 1:

        def body(carry, inp):
            xc = carry
            layer, cache_l = inp
            fn = _layer_apply
            if cfg.remat:
                fn = jax.checkpoint(_layer_apply, static_argnums=(0, 1, 7))
            xc, aux, new_cache_l = fn(cfg, moe, layer, xc, positions, cache_l, cache_index, act_spec)
            xc = _constrain(xc, act_spec)
            return xc, (aux, new_cache_l)

        x, (auxs, new_cache) = jax.lax.scan(body, x, (stacked, cache))
        return x, jnp.sum(auxs), new_cache

    aux_total = jnp.zeros((), jnp.float32)
    new_layers = []
    for i in range(n):
        layer = jax.tree.map(lambda p: p[i], stacked)
        cache_l = None if cache is None else jax.tree.map(lambda c: c[i], cache)
        fn = _layer_apply
        if cfg.remat and cache is None:
            fn = jax.checkpoint(_layer_apply, static_argnums=(0, 1, 7))
        x, aux, new_cache_l = fn(cfg, moe, layer, x, positions, cache_l, cache_index, act_spec)
        x = _constrain(x, act_spec)
        aux_total = aux_total + aux
        new_layers.append(new_cache_l)
    new_cache = None
    if cache is not None:
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *new_layers)
    return x, aux_total, new_cache


def forward(
    params: Params,
    cfg: LMConfig,
    tokens: jnp.ndarray,  # (b, s) int32
    caches: Optional[list] = None,
    cache_index: Optional[jnp.ndarray] = None,
    positions: Optional[jnp.ndarray] = None,
    act_spec=None,
    return_hidden: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, Optional[list]]:
    """Returns (logits, aux_loss, new_caches); final hidden states instead of
    logits when ``return_hidden`` (chunked-loss path)."""
    dtype = jnp.dtype(cfg.dtype)
    x = _constrain(params["embed"][tokens].astype(dtype), act_spec)
    s = tokens.shape[1]
    if positions is None:
        positions = jnp.arange(s)

    aux_total = jnp.zeros((), jnp.float32)
    new_caches = [] if caches is not None else None
    for g, (n, moe) in enumerate(layer_groups(cfg)):
        cache_g = caches[g] if caches is not None else None
        x, aux, new_cache_g = _run_group(
            cfg, moe, params["groups"][g], n, x, positions, cache_g, cache_index,
            act_spec=act_spec,
        )
        aux_total = aux_total + aux
        if new_caches is not None:
            new_caches.append(new_cache_g)

    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x, aux_total, new_caches
    unembed = params.get("unembed")
    if unembed is None:
        unembed = params["embed"].T
    logits = jnp.einsum("bsd,dv->bsv", x, unembed.astype(dtype))
    return logits, aux_total, new_caches


def loss_fn(
    params: Params,
    cfg: LMConfig,
    tokens: jnp.ndarray,
    labels: jnp.ndarray,
    act_spec=None,
    loss_chunk: int = 0,
) -> jnp.ndarray:
    """Next-token cross entropy.  ``loss_chunk > 0`` computes the vocab
    projection + softmax in sequence chunks (lax.map) so the full
    (b, s, vocab) fp32 logits tensor is never materialized — required for the
    256k-vocab archs at 65k tokens/device."""
    if loss_chunk and tokens.shape[1] > loss_chunk and tokens.shape[1] % loss_chunk == 0:
        x, aux, _ = forward(params, cfg, tokens, act_spec=act_spec, return_hidden=True)
        unembed = params.get("unembed")
        if unembed is None:
            unembed = params["embed"].T
        unembed = unembed.astype(x.dtype)
        b, s, d = x.shape
        n_chunks = s // loss_chunk
        x_c = x.reshape(b, n_chunks, loss_chunk, d).swapaxes(0, 1)
        l_c = labels.reshape(b, n_chunks, loss_chunk).swapaxes(0, 1)

        def chunk_nll(args):
            xc, lc = args
            logits = jnp.einsum("bsd,dv->bsv", xc, unembed)
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            return -jnp.take_along_axis(logp, lc[..., None], axis=-1)[..., 0]

        nll = jax.lax.map(chunk_nll, (x_c, l_c))
        return nll.mean() + aux
    logits, aux, _ = forward(params, cfg, tokens, act_spec=act_spec)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return nll.mean() + aux


# ---------------------------------------------------------------------------
# KV cache / serving
# ---------------------------------------------------------------------------


def _cache_layer_shape(cfg: LMConfig, batch: int, max_len: int):
    if cfg.attention == "mla":
        return {
            "c_kv": (batch, max_len, cfg.kv_lora_rank),
            "k_rope": (batch, max_len, cfg.qk_rope_head_dim),
        }
    return {
        "k": (batch, max_len, cfg.n_kv_heads, cfg.d_head),
        "v": (batch, max_len, cfg.n_kv_heads, cfg.d_head),
    }


def init_kv_cache(cfg: LMConfig, batch: int, max_len: int, dtype=None) -> list:
    dtype = dtype or jnp.dtype(cfg.dtype)
    shapes = _cache_layer_shape(cfg, batch, max_len)
    return [
        {k: jnp.zeros((n,) + s, dtype) for k, s in shapes.items()}
        for (n, _) in layer_groups(cfg)
    ]


def kv_cache_shapes(cfg: LMConfig, batch: int, max_len: int, dtype=None) -> list:
    dtype = dtype or jnp.dtype(cfg.dtype)
    shapes = _cache_layer_shape(cfg, batch, max_len)
    return [
        {k: jax.ShapeDtypeStruct((n,) + s, dtype) for k, s in shapes.items()}
        for (n, _) in layer_groups(cfg)
    ]


def prefill(params: Params, cfg: LMConfig, tokens: jnp.ndarray, caches: list, act_spec=None):
    logits, _, new_caches = forward(
        params, cfg, tokens, caches=caches, cache_index=jnp.int32(0), act_spec=act_spec
    )
    return logits, new_caches


def decode_step(params: Params, cfg: LMConfig, token: jnp.ndarray, caches: list, index: jnp.ndarray):
    positions = jnp.asarray(index)[None]
    logits, _, new_caches = forward(
        params, cfg, token, caches=caches, cache_index=index, positions=positions
    )
    return logits[:, -1], new_caches


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------


def _attn_specs(cfg: LMConfig, l: Optional[str], model_size: int):
    """l is the stacked-layer leading axis (None entry prepended)."""
    mp = "model"

    def s(*axes):
        return P(l, *axes)

    if cfg.attention == "mla":
        return {
            "w_q": s(None, mp, None),
            "w_dkv": s(None, None),
            "w_krope": s(None, None),
            "w_uk": s(None, mp, None),
            "w_uv": s(None, mp, None),
            "w_o": s(mp, None, None),
            "kv_norm": s(None),
        }
    kv_shardable = cfg.n_kv_heads % model_size == 0
    # GQA with few kv heads: shard K/V projections on d_model instead
    return {
        "w_q": s(None, mp, None),
        "w_k": s(None, mp, None) if kv_shardable else s(mp, None, None),
        "w_v": s(None, mp, None) if kv_shardable else s(mp, None, None),
        "w_o": s(mp, None, None),
    }


def _ffn_specs(cfg: LMConfig, l: Optional[str]):
    gated = cfg.ffn_activation in ("swiglu", "geglu")
    specs = {"w_up": P(l, None, "model"), "w_down": P(l, "model", None)}
    if gated:
        specs["w_gate"] = P(l, None, "model")
    return specs


def _moe_specs(cfg: LMConfig, l: Optional[str]):
    gated = cfg.ffn_activation in ("swiglu", "geglu")
    moe = {
        "router": P(l, None, None),
        "w_up": P(l, "model", None, None),
        "w_down": P(l, "model", None, None),
    }
    if gated:
        moe["w_gate"] = P(l, "model", None, None)
    if cfg.n_shared_experts:
        moe["shared"] = _ffn_specs(cfg, l)
    return moe


def param_pspecs(cfg: LMConfig, model_size: int = 16) -> Params:
    """Megatron-style TP over "model": attention heads + FFN hidden + vocab;
    experts sharded over "model" (EP); stacked layer axis replicated."""
    l = None  # stacked leading axis: replicated
    groups = []
    for (n, moe) in layer_groups(cfg):
        g = {
            "attn_norm": P(l, None),
            "ffn_norm": P(l, None),
            "attn": _attn_specs(cfg, l, model_size),
        }
        if moe:
            g["moe"] = _moe_specs(cfg, l)
        else:
            g["ffn"] = _ffn_specs(cfg, l)
        groups.append(g)
    specs = {"embed": P("model", None), "final_norm": P(None), "groups": groups}
    if not cfg.tie_embeddings:
        specs["unembed"] = P(None, "model")
    return specs


def kv_cache_pspecs(cfg: LMConfig, dp_axes: Tuple[str, ...], shard_seq: bool = False, model_size: int = 16) -> list:
    """Cache shardings (stacked: leading layer axis).

    * default: batch over data axes; kv heads (GQA) or latent (replicated)
      over model.
    * ``shard_seq=True``: sequence axis sharded over every mesh axis — the
      split-K layout for ``long_500k`` (batch=1).
    """
    dp = dp_axes
    seq_axes = tuple(dp) + ("model",)
    specs = []
    for _ in layer_groups(cfg):
        if cfg.attention == "mla":
            if shard_seq:
                specs.append({"c_kv": P(None, None, seq_axes, None), "k_rope": P(None, None, seq_axes, None)})
            else:
                # batch over data axes AND sequence over model: the latent
                # cache is the whole decode working set — sharding seq keeps
                # the per-device slice (and its update copies) small
                specs.append({"c_kv": P(None, dp, "model", None), "k_rope": P(None, dp, "model", None)})
        else:
            if shard_seq:
                specs.append(
                    {"k": P(None, None, seq_axes, None, None), "v": P(None, None, seq_axes, None, None)}
                )
            elif cfg.n_kv_heads % model_size == 0:
                specs.append(
                    {"k": P(None, dp, None, "model", None), "v": P(None, dp, None, "model", None)}
                )
            else:  # few kv heads (GQA/MQA) — shard the sequence over model
                specs.append(
                    {"k": P(None, dp, "model", None, None), "v": P(None, dp, "model", None, None)}
                )
    return specs
