"""Two-tower retrieval model (Yi et al., RecSys'19 / Covington RecSys'16).

The hot path is the **EmbeddingBag** over huge sparse tables (10^6..10^8 rows
per field).  JAX has no native EmbeddingBag — it is built here from
``jnp.take`` + ``jax.ops.segment_sum`` (the same ragged gather-reduce regime
as the paper's SpMM; DESIGN.md §4).

Components:
  * ``embedding_bag``      — multi-hot sum/mean lookup per field.
  * ``tower_apply``        — field embeddings -> MLP -> L2-normalized vector.
  * ``loss_fn``            — in-batch sampled softmax with logQ correction.
  * ``retrieval_scores``   — one query against N candidates (batched dot).
  * ``retrieval_topk``     — sharded top-k.

Sharding: each table row-sharded ("model" axis — vocab dimension); batch over
data axes.  Lookups into a row-sharded table lower to all-gather/collective
gathers under pjit; the perf notes discuss the all-to-all alternative.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import RecsysConfig

__all__ = [
    "init_params",
    "embedding_bag",
    "tower_apply",
    "forward",
    "loss_fn",
    "retrieval_scores",
    "retrieval_topk",
    "param_pspecs",
]


def _pad_vocab(v: int, multiple: int = 512) -> int:
    return ((v + multiple - 1) // multiple) * multiple


def init_params(key: jax.Array, cfg: RecsysConfig, vocab_scale: float = 1.0) -> Dict:
    """``vocab_scale`` < 1 shrinks tables for smoke tests."""
    def tables(key, sizes):
        out = []
        for i, v in enumerate(sizes):
            rows = _pad_vocab(max(int(v * vocab_scale), 8))
            out.append(
                jax.random.normal(jax.random.fold_in(key, i), (rows, cfg.embed_dim), jnp.float32)
                * 0.01
            )
        return out

    def tower(key, d_in):
        dims = [d_in] + list(cfg.tower_mlp)
        layers = []
        for i in range(len(dims) - 1):
            k = jax.random.fold_in(key, i)
            layers.append(
                {
                    "w": jax.random.normal(k, (dims[i], dims[i + 1]), jnp.float32)
                    / np.sqrt(dims[i]),
                    "b": jnp.zeros((dims[i + 1],)),
                }
            )
        return layers

    ku, ki, ktu, kti = jax.random.split(key, 4)
    d_user = cfg.embed_dim * cfg.n_user_fields
    d_item = cfg.embed_dim * cfg.n_item_fields
    return {
        "user_tables": tables(ku, cfg.user_vocab_sizes),
        "item_tables": tables(ki, cfg.item_vocab_sizes),
        "user_tower": tower(ktu, d_user),
        "item_tower": tower(kti, d_item),
    }


def embedding_bag(
    table: jnp.ndarray,      # (vocab, d)
    indices: jnp.ndarray,    # (batch, bag) int32
    weights: jnp.ndarray = None,  # (batch, bag) or None
    combiner: str = "mean",
) -> jnp.ndarray:
    """EmbeddingBag(sum/mean) = ragged gather + reduce, built from take +
    segment-sum semantics (here the bag axis is dense/padded so the segment
    reduce collapses to a masked sum along axis 1)."""
    gathered = jnp.take(table, indices, axis=0)  # (batch, bag, d)
    if weights is None:
        weights = jnp.ones(indices.shape, table.dtype)
    out = jnp.einsum("bkd,bk->bd", gathered, weights.astype(table.dtype))
    if combiner == "mean":
        out = out / jnp.maximum(weights.sum(-1, keepdims=True), 1.0)
    return out


def tower_apply(layers: List[Dict], fields: jnp.ndarray) -> jnp.ndarray:
    """fields: (batch, n_fields * d) concat of bag outputs -> unit vector."""
    h = fields
    for i, l in enumerate(layers):
        h = h @ l["w"] + l["b"]
        if i < len(layers) - 1:
            h = jax.nn.relu(h)
    return h / jnp.maximum(jnp.linalg.norm(h, axis=-1, keepdims=True), 1e-9)


def _encode(tables, tower, idx, weights=None):
    bags = [
        embedding_bag(t, idx[:, f], None if weights is None else weights[:, f])
        for f, t in enumerate(tables)
    ]
    return tower_apply(tower, jnp.concatenate(bags, axis=-1))


def forward(params: Dict, cfg: RecsysConfig, user_idx: jnp.ndarray, item_idx: jnp.ndarray):
    """user_idx: (b, n_user_fields, bag); item_idx: (b, n_item_fields, bag).
    Returns (user_vec, item_vec) each (b, tower_out)."""
    u = _encode(params["user_tables"], params["user_tower"], user_idx)
    i = _encode(params["item_tables"], params["item_tower"], item_idx)
    return u, i


def loss_fn(
    params: Dict,
    cfg: RecsysConfig,
    user_idx: jnp.ndarray,
    item_idx: jnp.ndarray,
    log_q: jnp.ndarray = None,  # (b,) sampling log-probabilities of items
) -> jnp.ndarray:
    """In-batch sampled softmax with logQ correction (Yi et al. 2019)."""
    u, i = forward(params, cfg, user_idx, item_idx)
    logits = (u @ i.T) / cfg.temperature  # (b, b)
    if log_q is not None:
        logits = logits - log_q[None, :]
    labels = jnp.arange(u.shape[0])
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()


def serve_scores(params: Dict, cfg: RecsysConfig, user_idx, item_idx) -> jnp.ndarray:
    """Pointwise user-item scores for a serving batch (dot interaction)."""
    u, i = forward(params, cfg, user_idx, item_idx)
    return jnp.sum(u * i, axis=-1) / cfg.temperature


def retrieval_scores(
    params: Dict,
    cfg: RecsysConfig,
    user_idx: jnp.ndarray,        # (1, n_user_fields, bag)
    candidate_vecs: jnp.ndarray,  # (n_candidates, d) — precomputed item vecs
) -> jnp.ndarray:
    """Score one query against the full candidate corpus: a (1,d)x(d,N) GEMV
    — batched-dot, not a loop; candidates stay sharded."""
    u = _encode(params["user_tables"], params["user_tower"], user_idx)
    return (u @ candidate_vecs.T)[0]


def retrieval_topk(scores: jnp.ndarray, k: int = 100):
    return jax.lax.top_k(scores, k)


def param_pspecs(cfg: RecsysConfig, dp=()) -> Dict:
    """Vocab(row)-sharded tables over every mesh axis (177 GB of tables split
    512 ways); towers replicated."""
    rows = ("model",) + tuple(dp)

    def tower_specs(layers):
        return [{"w": P(None, None), "b": P(None)} for _ in layers]

    return {
        "user_tables": [P(rows, None) for _ in cfg.user_vocab_sizes],
        "item_tables": [P(rows, None) for _ in cfg.item_vocab_sizes],
        "user_tower": tower_specs(cfg.tower_mlp),
        "item_tower": tower_specs(cfg.tower_mlp),
    }
