"""GCN (Kipf & Welling 2017) and GAT (Velickovic et al. 2018).

Both run on the shared segment-sum message-passing primitives — the same
SpMM regime as the paper's counting kernel (kernel taxonomy §GNN:
SpMM / SDDMM family).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GNNConfig
from .message import GraphBatch, aggregate_sum, edge_softmax, sym_norm_coeffs

__all__ = ["init_gcn", "gcn_forward", "init_gat", "gat_forward"]


def _glorot(key, shape):
    fan_in, fan_out = shape[0], shape[-1]
    scale = np.sqrt(2.0 / (fan_in + fan_out))
    return jax.random.normal(key, shape, jnp.float32) * scale


# ---------------------------------------------------------------------------
# GCN
# ---------------------------------------------------------------------------


def init_gcn(key, cfg: GNNConfig, d_in: int) -> Dict:
    dims = [d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    keys = jax.random.split(key, cfg.n_layers)
    return {
        "layers": [
            {"w": _glorot(keys[i], (dims[i], dims[i + 1])), "b": jnp.zeros((dims[i + 1],))}
            for i in range(cfg.n_layers)
        ]
    }


def _wsc_nodes(x, node_spec):
    if node_spec is None:
        return x
    from jax.sharding import PartitionSpec as P

    return jax.lax.with_sharding_constraint(x, P(node_spec, *([None] * (x.ndim - 1))))


def gcn_forward(params: Dict, cfg: GNNConfig, batch: GraphBatch, node_spec=None) -> jnp.ndarray:
    """Returns (n, n_classes) logits.  ``Ã X W`` with symmetric normalization
    and implicit self-loops (added via the normalized self term)."""
    h = batch.node_feat
    n = batch.n_nodes
    coef = sym_norm_coeffs(batch.src, batch.dst, n, batch.edge_mask)
    deg_inv = 1.0 / jnp.maximum(
        jax.ops.segment_sum(batch.edge_mask, batch.dst, num_segments=n) + 1.0, 1.0
    )
    for i, layer in enumerate(params["layers"]):
        hw = h @ layer["w"]
        msg = hw[batch.src] * coef[:, None]
        agg = aggregate_sum(msg, batch.dst, n, batch.edge_mask)
        # self-loop term of the renormalized adjacency
        agg = agg + hw * deg_inv[:, None]
        h = agg + layer["b"]
        if i < cfg.n_layers - 1:
            h = jax.nn.relu(h)
        h = _wsc_nodes(h, node_spec)
    return h * batch.node_mask[:, None]


# ---------------------------------------------------------------------------
# GAT
# ---------------------------------------------------------------------------


def init_gat(key, cfg: GNNConfig, d_in: int) -> Dict:
    layers = []
    d_prev = d_in
    for i in range(cfg.n_layers):
        k1, k2, k3, key = jax.random.split(key, 4)
        last = i == cfg.n_layers - 1
        heads = 1 if last else cfg.n_heads
        d_out = cfg.n_classes if last else cfg.d_hidden
        layers.append(
            {
                "w": _glorot(k1, (d_prev, heads, d_out)),
                "a_src": _glorot(k2, (heads, d_out)),
                "a_dst": _glorot(k3, (heads, d_out)),
            }
        )
        d_prev = heads * d_out if not last else d_out
    return {"layers": layers}


def gat_forward(params: Dict, cfg: GNNConfig, batch: GraphBatch, node_spec=None) -> jnp.ndarray:
    """SDDMM (edge scores) -> edge softmax -> SpMM, per head."""
    h = batch.node_feat
    n = batch.n_nodes
    for i, layer in enumerate(params["layers"]):
        last = i == len(params["layers"]) - 1
        hw = jnp.einsum("nd,dhe->nhe", h, layer["w"])  # (n, heads, d_out)
        # attention logits per edge (GATv1 split form)
        alpha_src = jnp.einsum("nhe,he->nh", hw, layer["a_src"])
        alpha_dst = jnp.einsum("nhe,he->nh", hw, layer["a_dst"])
        logits = jax.nn.leaky_relu(alpha_src[batch.src] + alpha_dst[batch.dst], 0.2)
        att = edge_softmax(logits, batch.dst, n, batch.edge_mask)  # (e, heads)
        msg = hw[batch.src] * att[..., None]
        agg = aggregate_sum(msg, batch.dst, n, batch.edge_mask)  # (n, heads, d_out)
        if last:
            h = agg.mean(axis=1)
        else:
            h = jax.nn.elu(agg).reshape(n, -1)
        h = _wsc_nodes(h, node_spec)
    return h * batch.node_mask[:, None]
