"""GNN model zoo: GCN, GAT (SpMM/SDDMM regime) and NequIP, MACE (equivariant
tensor-product regime) with a unified init/forward/loss interface."""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import GNNConfig

from .equivariant import Irreps
from .message import GraphBatch, aggregate_max, aggregate_mean, aggregate_sum, edge_softmax
from .potentials import init_mace, init_nequip, mace_forward, nequip_forward
from .sampler import NodeFlow, node_flow_to_batch, sample_node_flow
from .spectral import gat_forward, gcn_forward, init_gat, init_gcn

__all__ = [
    "GraphBatch",
    "NodeFlow",
    "aggregate_sum",
    "aggregate_mean",
    "aggregate_max",
    "edge_softmax",
    "sample_node_flow",
    "node_flow_to_batch",
    "init_model",
    "forward",
    "loss_fn",
]

_INITS = {"gcn": init_gcn, "gat": init_gat, "nequip": init_nequip, "mace": init_mace}
_FWDS = {"gcn": gcn_forward, "gat": gat_forward, "nequip": nequip_forward, "mace": mace_forward}


def init_model(key: jax.Array, cfg: GNNConfig, d_in: int) -> Dict:
    return _INITS[cfg.model](key, cfg, d_in)


def forward(params: Dict, cfg: GNNConfig, batch: GraphBatch, node_spec=None, chan_spec=None) -> jnp.ndarray:
    """Node logits (gcn/gat) or per-graph energies (nequip/mace).

    ``node_spec`` (a PartitionSpec prefix for the node axis) pins per-node
    activations to the data axes under pjit — without it the SPMD partitioner
    replicates scatter outputs (hundreds of GB on the 2.4M-node cells)."""
    if cfg.model in ("nequip", "mace"):
        return _FWDS[cfg.model](params, cfg, batch, node_spec=node_spec, chan_spec=chan_spec)
    return _FWDS[cfg.model](params, cfg, batch, node_spec=node_spec)


def loss_fn(params: Dict, cfg: GNNConfig, batch: GraphBatch, labels: jnp.ndarray, node_spec=None, chan_spec=None) -> jnp.ndarray:
    out = forward(params, cfg, batch, node_spec=node_spec, chan_spec=chan_spec)
    if cfg.model in ("gcn", "gat"):
        logp = jax.nn.log_softmax(out.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
        denom = jnp.maximum(batch.node_mask.sum(), 1.0)
        return (nll * batch.node_mask).sum() / denom
    # energy regression (labels: per-graph energies)
    err = out.astype(jnp.float32) - labels.astype(jnp.float32)
    return jnp.mean(err * err)
