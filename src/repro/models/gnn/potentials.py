"""NequIP (arXiv:2101.03164) and MACE (arXiv:2206.07697) interatomic
potentials on the Cartesian l<=2 irrep stack.

Both follow the published architecture shape:

* **NequIP**: ``n_layers`` interaction blocks.  Each block builds edge
  messages as (radial-MLP-weighted) tensor products of neighbor features with
  the edge spherical harmonics, segment-sums them, then applies an
  equivariant linear + gate.  Energy readout from final scalars.
* **MACE**: 2 layers; each builds the one-particle basis ``A_i`` (same
  message as NequIP), then the higher-order ACE basis ``B_i`` via repeated
  tensor products of ``A_i`` with itself up to ``correlation_order`` (=3),
  linearly mixed — message passing is cheap, the power is in the product
  basis.  Per-layer energy readouts are summed.

Inputs are ``GraphBatch`` with ``positions``; node features seed the l=0
channels.  Predicts per-graph energy (and forces via ``jax.grad`` w.r.t.
positions in the training substrate).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GNNConfig
from .equivariant import (
    Irreps,
    bessel_basis,
    cutoff_envelope,
    gate,
    init_linear_mix,
    linear_mix,
    spherical_l1,
    spherical_l2,
    tp_paths_order2,
)
from .message import GraphBatch, aggregate_sum

__all__ = ["init_nequip", "nequip_forward", "init_mace", "mace_forward"]


def _wsc_irreps(x: Irreps, node_spec, chan_spec=None) -> Irreps:
    """Pin node irreps under pjit.  Two layouts:

    * ``node_spec`` (tuple of mesh axes): shard the node axis — right when
      per-node state dominates and edges align with nodes.
    * ``chan_spec`` (mesh axis name): shard the CHANNEL axis instead — right
      for huge graphs where edge gathers index arbitrary nodes: gathers hit
      the replicated node axis (collective-free) and every tensor-product
      path is channel-local (DESIGN.md §5 / EXPERIMENTS.md §Perf cell 3).
    """
    if node_spec is None and chan_spec is None:
        return x
    from jax.sharding import PartitionSpec as P

    def c(v):
        spec = (node_spec, chan_spec) + (None,) * (v.ndim - 2)
        return jax.lax.with_sharding_constraint(v, P(*spec))

    return Irreps(s=c(x.s), v=c(x.v), t=c(x.t))


def _mlp_init(key, dims):
    keys = jax.random.split(key, len(dims) - 1)
    return [
        {
            "w": jax.random.normal(k, (dims[i], dims[i + 1]), jnp.float32) / np.sqrt(dims[i]),
            "b": jnp.zeros((dims[i + 1],)),
        }
        for i, k in enumerate(keys)
    ]


def _mlp_apply(layers, x):
    for i, l in enumerate(layers):
        x = x @ l["w"] + l["b"]
        if i < len(layers) - 1:
            x = jax.nn.silu(x)
    return x


def _edge_geometry(batch: GraphBatch, cfg: GNNConfig):
    rel = batch.positions[batch.dst] - batch.positions[batch.src]  # (e, 3)
    r = jnp.sqrt(jnp.sum(rel * rel, axis=-1) + 1e-18)
    unit = rel / r[:, None]
    rbf = bessel_basis(r, cfg.n_rbf, cfg.cutoff)
    env = cutoff_envelope(r, cfg.cutoff) * batch.edge_mask
    return unit, rbf * env[:, None], env


def _edge_messages(params, cfg: GNNConfig, feats: Irreps, positions, src, dst, edge_mask):
    """Per-edge tensor-product messages for one edge (chunk)."""
    rel = positions[dst] - positions[src]
    r = jnp.sqrt(jnp.sum(rel * rel, axis=-1) + 1e-18)
    unit = rel / r[:, None]
    rbf = bessel_basis(r, cfg.n_rbf, cfg.cutoff)
    env = cutoff_envelope(r, cfg.cutoff) * edge_mask
    rbf = rbf * env[:, None]
    y1 = spherical_l1(unit)
    y2 = spherical_l2(unit)
    rw = _mlp_apply(params["radial"], rbf)  # (e, 3*c)
    w0, w1, w2 = jnp.split(rw, 3, axis=-1)
    h_src = Irreps(s=feats.s[src], v=feats.v[src], t=feats.t[src])
    edge = Irreps(
        s=w0,
        v=w1[..., None] * y1[:, None, :],
        t=w2[..., None, None] * y2[:, None, :, :],
    )
    return tp_paths_order2(h_src, edge)


def _message_block(params, cfg: GNNConfig, batch: GraphBatch, feats: Irreps, node_spec=None, chan_spec=None) -> Irreps:
    """One-particle basis: A_i = sum_j R(r_ij) * (Y(r_ij) (x) h_j).

    With ``cfg.edge_chunk > 0`` the per-edge message tensors are built and
    reduced one chunk at a time under ``lax.scan`` — peak edge-message memory
    becomes O(edge_chunk * channels) instead of O(n_edges * channels), which
    is what makes the 61M-edge full-graph cells fit HBM.
    """
    n = batch.n_nodes
    e_total = batch.n_edges
    chunk = cfg.edge_chunk
    if chunk <= 0 or e_total <= chunk or e_total % chunk != 0:
        msg = _edge_messages(params, cfg, feats, batch.positions, batch.src, batch.dst, batch.edge_mask)
        return Irreps(
            s=aggregate_sum(msg.s, batch.dst, n, batch.edge_mask),
            v=aggregate_sum(msg.v, batch.dst, n, batch.edge_mask),
            t=aggregate_sum(msg.t, batch.dst, n, batch.edge_mask),
        )

    n_chunks = e_total // chunk
    src_c = batch.src.reshape(n_chunks, chunk)
    dst_c = batch.dst.reshape(n_chunks, chunk)
    mask_c = batch.edge_mask.reshape(n_chunks, chunk)
    c = feats.v.shape[1]
    init = _wsc_irreps(
        Irreps(
            s=jnp.zeros((n, 3 * c), jnp.float32),
            v=jnp.zeros((n, 5 * c, 3), jnp.float32),
            t=jnp.zeros((n, 4 * c, 3, 3), jnp.float32),
        ),
        node_spec,
        chan_spec,
    )

    # remat the chunk body: without it the scan saves every chunk's edge
    # messages for backward (29 x ~3.5 GB/device at ogb scale); with it the
    # messages are recomputed during the backward sweep.
    @jax.checkpoint
    def chunk_update(acc, src_i, dst_i, mask_i):
        msg = _edge_messages(params, cfg, feats, batch.positions, src_i, dst_i, mask_i)
        # constrain INSIDE the scan: the carry (the accumulated node irreps)
        # otherwise replicates per device (100+ GB on the 2.4M-node cells)
        return _wsc_irreps(
            Irreps(
                s=acc.s + aggregate_sum(msg.s, dst_i, n, mask_i),
                v=acc.v + aggregate_sum(msg.v, dst_i, n, mask_i),
                t=acc.t + aggregate_sum(msg.t, dst_i, n, mask_i),
            ),
            node_spec,
            chan_spec,
        )

    def body(acc, inp):
        src_i, dst_i, mask_i = inp
        return chunk_update(acc, src_i, dst_i, mask_i), None

    agg, _ = jax.lax.scan(body, init, (src_c, dst_c, mask_c))
    return agg


def _tp_out_channels(c: int) -> Tuple[int, int, int]:
    """Channel counts produced by tp_paths_order2 on equal-width inputs."""
    return (3 * c, 5 * c, 4 * c)


# ---------------------------------------------------------------------------
# NequIP
# ---------------------------------------------------------------------------


def init_nequip(key, cfg: GNNConfig, d_in: int) -> Dict:
    c = cfg.d_hidden
    keys = jax.random.split(key, cfg.n_layers * 3 + 2)
    params: Dict = {
        "embed": _mlp_init(keys[0], [d_in, c]),
        "blocks": [],
        "readout": _mlp_init(keys[1], [c, c, 1]),
    }
    for i in range(cfg.n_layers):
        kb = keys[2 + i * 3 : 2 + i * 3 + 3]
        block = {
            "radial": _mlp_init(kb[0], [cfg.n_rbf, c, 3 * c]),
            # scalar output width 3c: c features + c vector gates + c tensor gates
            "mix": init_linear_mix(kb[1], _tp_out_channels(c), (3 * c, c, c)),
            "self": init_linear_mix(kb[2], (c, c, c), (3 * c, c, c)),
        }
        params["blocks"].append(block)
    return params


def nequip_forward(params: Dict, cfg: GNNConfig, batch: GraphBatch, node_spec=None, chan_spec=None) -> jnp.ndarray:
    """Per-graph energies (n_graphs,)."""
    n, c = batch.n_nodes, cfg.d_hidden
    feats = Irreps(
        s=_mlp_apply(params["embed"], batch.node_feat),
        v=jnp.zeros((n, c, 3), jnp.float32),
        t=jnp.zeros((n, c, 3, 3), jnp.float32),
    )
    feats = _wsc_irreps(feats, node_spec, chan_spec)
    for block in params["blocks"]:
        agg = _wsc_irreps(
            _message_block(block, cfg, batch, feats, node_spec, chan_spec), node_spec, chan_spec
        )
        mixed = linear_mix(block["mix"], agg)
        res = linear_mix(block["self"], feats)
        feats = _wsc_irreps(
            gate(Irreps(s=mixed.s + res.s, v=mixed.v + res.v, t=mixed.t + res.t)),
            node_spec,
            chan_spec,
        )
    node_e = _mlp_apply(params["readout"], feats.s)[:, 0] * batch.node_mask
    gid = batch.graph_id if batch.graph_id is not None else jnp.zeros((n,), jnp.int32)
    return jax.ops.segment_sum(node_e, gid, num_segments=batch.n_graphs)


# ---------------------------------------------------------------------------
# MACE
# ---------------------------------------------------------------------------


def init_mace(key, cfg: GNNConfig, d_in: int) -> Dict:
    c = cfg.d_hidden
    n_keys = cfg.n_layers * 5 + 2
    keys = jax.random.split(key, n_keys)
    params: Dict = {"embed": _mlp_init(keys[0], [d_in, c]), "blocks": []}
    for i in range(cfg.n_layers):
        kb = keys[1 + i * 5 : 1 + i * 5 + 5]
        block = {
            "radial": _mlp_init(kb[0], [cfg.n_rbf, c, 3 * c]),
            "mix_a": init_linear_mix(kb[1], _tp_out_channels(c), (c, c, c)),
            # symmetric contractions: A^2 and A^3 mixed back to width c
            "mix_b2": init_linear_mix(kb[2], _tp_out_channels(c), (c, c, c)),
            "mix_b3": init_linear_mix(kb[3], _tp_out_channels(c), (c, c, c)),
            # scalar width 3c for the gate (c features + c + c gates)
            "update": init_linear_mix(kb[4], (3 * c, 3 * c, 3 * c), (3 * c, c, c)),
            "readout": _mlp_init(jax.random.fold_in(kb[4], 7), [c, 1]),
        }
        params["blocks"].append(block)
    return params


def mace_forward(params: Dict, cfg: GNNConfig, batch: GraphBatch, node_spec=None, chan_spec=None) -> jnp.ndarray:
    """Per-graph energies; higher-order ACE basis up to correlation order."""
    n, c = batch.n_nodes, cfg.d_hidden
    feats = Irreps(
        s=_mlp_apply(params["embed"], batch.node_feat),
        v=jnp.zeros((n, c, 3), jnp.float32),
        t=jnp.zeros((n, c, 3, 3), jnp.float32),
    )
    energy = None
    feats = _wsc_irreps(feats, node_spec, chan_spec)
    for block in params["blocks"]:
        a = linear_mix(
            block["mix_a"],
            _wsc_irreps(_message_block(block, cfg, batch, feats, node_spec, chan_spec), node_spec, chan_spec),
        )
        a = _wsc_irreps(a, node_spec, chan_spec)
        # ACE product basis: B1 = A, B2 = mix(A (x) A), B3 = mix(B2 (x) A)
        basis = [a]
        if cfg.correlation_order >= 2:
            b2 = linear_mix(block["mix_b2"], tp_paths_order2(a, a))
            basis.append(b2)
        if cfg.correlation_order >= 3:
            b3 = linear_mix(block["mix_b3"], tp_paths_order2(basis[-1], a))
            basis.append(b3)
        while len(basis) < 3:
            basis.append(basis[-1])
        stacked = Irreps(
            s=jnp.concatenate([b.s for b in basis], axis=-1),
            v=jnp.concatenate([b.v for b in basis], axis=-2),
            t=jnp.concatenate([b.t for b in basis], axis=-3),
        )
        feats = _wsc_irreps(gate(linear_mix(block["update"], stacked)), node_spec, chan_spec)
        node_e = _mlp_apply(block["readout"], feats.s)[:, 0] * batch.node_mask
        gid = batch.graph_id if batch.graph_id is not None else jnp.zeros((n,), jnp.int32)
        e = jax.ops.segment_sum(node_e, gid, num_segments=batch.n_graphs)
        energy = e if energy is None else energy + e
    return energy
