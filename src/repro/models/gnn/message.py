"""Message-passing primitives: segment reductions over an edge index.

JAX has no native sparse message passing (BCOO only) — per the assignment,
scatter/gather aggregation is built from ``jax.ops.segment_sum`` /
``segment_max`` and IS part of the system.  These primitives are shared with
the paper core: ``aggregate_sum`` over an edge list is exactly the SpMM
``B = A_G @ M`` of SUBGRAPH2VEC (DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "GraphBatch",
    "aggregate_sum",
    "aggregate_mean",
    "aggregate_max",
    "edge_softmax",
    "degree",
    "sym_norm_coeffs",
]


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class GraphBatch:
    """Padded device-ready graph batch.

    ``src``/``dst`` are edge endpoints (messages flow src -> dst); invalid
    (padding) edges carry ``edge_mask == 0`` and point at node 0.  Batched
    small graphs (molecule cells) are block-diagonal with ``graph_id`` used
    for per-graph readout.
    """

    node_feat: jnp.ndarray          # (n, d) float
    positions: Optional[jnp.ndarray]  # (n, 3) or None
    src: jnp.ndarray                # (e,) int32
    dst: jnp.ndarray                # (e,) int32
    edge_mask: jnp.ndarray          # (e,) float32
    node_mask: jnp.ndarray          # (n,) float32
    graph_id: Optional[jnp.ndarray] = None  # (n,) int32 for batched graphs
    n_graphs: int = dataclasses.field(default=1, metadata=dict(static=True))

    @property
    def n_nodes(self) -> int:
        return self.node_feat.shape[0]

    @property
    def n_edges(self) -> int:
        return self.src.shape[0]


def aggregate_sum(messages: jnp.ndarray, dst: jnp.ndarray, n: int, edge_mask=None) -> jnp.ndarray:
    if edge_mask is not None:
        shape = (-1,) + (1,) * (messages.ndim - 1)
        messages = messages * edge_mask.reshape(shape).astype(messages.dtype)
    return jax.ops.segment_sum(messages, dst, num_segments=n)


def aggregate_mean(messages: jnp.ndarray, dst: jnp.ndarray, n: int, edge_mask=None) -> jnp.ndarray:
    total = aggregate_sum(messages, dst, n, edge_mask)
    ones = jnp.ones((messages.shape[0],), messages.dtype) if edge_mask is None else edge_mask.astype(messages.dtype)
    deg = jax.ops.segment_sum(ones, dst, num_segments=n)
    shape = (-1,) + (1,) * (messages.ndim - 1)
    return total / jnp.maximum(deg, 1.0).reshape(shape)


def aggregate_max(messages: jnp.ndarray, dst: jnp.ndarray, n: int, edge_mask=None) -> jnp.ndarray:
    if edge_mask is not None:
        shape = (-1,) + (1,) * (messages.ndim - 1)
        messages = jnp.where(edge_mask.reshape(shape) > 0, messages, -jnp.inf)
    out = jax.ops.segment_max(messages, dst, num_segments=n)
    return jnp.where(jnp.isfinite(out), out, 0.0)


def edge_softmax(logits: jnp.ndarray, dst: jnp.ndarray, n: int, edge_mask=None) -> jnp.ndarray:
    """Numerically-stable softmax over incoming edges of each dst node.

    logits: (e, ...) — per-edge scores; returns same-shape weights summing to
    one per destination (the GAT attention normalizer).
    """
    if edge_mask is not None:
        shape = (-1,) + (1,) * (logits.ndim - 1)
        logits = jnp.where(edge_mask.reshape(shape) > 0, logits, -1e30)
    seg_max = jax.ops.segment_max(logits, dst, num_segments=n)
    seg_max = jnp.where(jnp.isfinite(seg_max), seg_max, 0.0)
    shifted = jnp.exp(logits - seg_max[dst])
    if edge_mask is not None:
        shape = (-1,) + (1,) * (logits.ndim - 1)
        shifted = shifted * edge_mask.reshape(shape)
    denom = jax.ops.segment_sum(shifted, dst, num_segments=n)
    return shifted / jnp.maximum(denom[dst], 1e-16)


def degree(dst: jnp.ndarray, n: int, edge_mask=None) -> jnp.ndarray:
    ones = jnp.ones_like(dst, jnp.float32) if edge_mask is None else edge_mask.astype(jnp.float32)
    return jax.ops.segment_sum(ones, dst, num_segments=n)


def sym_norm_coeffs(src, dst, n, edge_mask=None) -> jnp.ndarray:
    """GCN symmetric normalization ``1/sqrt(d_i d_j)`` per edge (self-loops
    are the caller's responsibility)."""
    deg = degree(dst, n, edge_mask)
    inv_sqrt = 1.0 / jnp.sqrt(jnp.maximum(deg, 1.0))
    return inv_sqrt[src] * inv_sqrt[dst]
