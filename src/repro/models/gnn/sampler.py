"""GraphSAGE-style layered fanout neighbor sampler (jit-able).

``minibatch_lg`` cells train on node-flows sampled with fanouts (15, 10):
layer 0 = ``batch_nodes`` seeds, layer l+1 = ``fanout_l`` uniformly sampled
neighbors per layer-l node (with replacement, masked for isolated nodes).
The resulting subgraph has a *static* shape — sizes depend only on the
fanouts — so it jits/lowers cleanly.

Sampling runs over a flat CSR (row_ptr, col_idx): per frontier node draw a
position in ``[0, deg)`` and gather ``col_idx[row_ptr + pos]``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .message import GraphBatch

__all__ = ["NodeFlow", "sample_node_flow", "node_flow_to_batch"]


@dataclass(frozen=True)
class NodeFlow:
    """Layered sampling forest.  ``layer_nodes[l]`` are global node ids; layer
    l+1 has ``len(layer_nodes[l]) * fanout_l`` entries; ``layer_valid`` masks
    slots whose source node had no neighbors."""

    layer_nodes: Tuple[jnp.ndarray, ...]
    layer_valid: Tuple[jnp.ndarray, ...]
    fanouts: Tuple[int, ...]


def sample_node_flow(
    key: jax.Array,
    row_ptr: jnp.ndarray,   # (n+1,) int
    col_idx: jnp.ndarray,   # (2E,) int
    seeds: jnp.ndarray,     # (batch_nodes,) int
    fanouts: Sequence[int],
) -> NodeFlow:
    layer_nodes = [seeds]
    layer_valid = [jnp.ones_like(seeds, jnp.float32)]
    frontier = seeds
    fvalid = layer_valid[0]
    for l, fanout in enumerate(fanouts):
        key, sub = jax.random.split(key)
        deg = (row_ptr[frontier + 1] - row_ptr[frontier]).astype(jnp.int32)
        pos = jax.random.randint(sub, (frontier.shape[0], fanout), 0, 1 << 30)
        pos = pos % jnp.maximum(deg, 1)[:, None]
        nbrs = col_idx[row_ptr[frontier][:, None] + pos]  # (m, fanout)
        valid = jnp.broadcast_to(
            ((deg > 0).astype(jnp.float32) * fvalid)[:, None], nbrs.shape
        )
        frontier = nbrs.reshape(-1)
        fvalid = valid.reshape(-1)
        layer_nodes.append(frontier)
        layer_valid.append(fvalid)
    return NodeFlow(tuple(layer_nodes), tuple(layer_valid), tuple(fanouts))


def node_flow_to_batch(
    flow: NodeFlow,
    features: jnp.ndarray,        # (n_global, d) — gathered per sampled node
    positions: jnp.ndarray = None,  # (n_global, 3) optional
) -> GraphBatch:
    """Flatten a node-flow into a block GraphBatch.

    Edges point child -> parent (messages flow toward the seeds), plus the
    reverse direction so symmetric models (GCN norm) behave; local node ids
    are layer-major.
    """
    sizes = [int(x.shape[0]) for x in flow.layer_nodes]
    offsets = np.concatenate([[0], np.cumsum(sizes)])
    n_local = int(offsets[-1])

    src_parts, dst_parts, mask_parts = [], [], []
    for l, fanout in enumerate(flow.fanouts):
        parents = jnp.arange(sizes[l], dtype=jnp.int32) + int(offsets[l])
        children = jnp.arange(sizes[l + 1], dtype=jnp.int32) + int(offsets[l + 1])
        par_rep = jnp.repeat(parents, fanout)
        src_parts += [children, par_rep]
        dst_parts += [par_rep, children]
        m = flow.layer_valid[l + 1]
        mask_parts += [m, m]

    all_nodes = jnp.concatenate(flow.layer_nodes)
    node_mask = jnp.concatenate(flow.layer_valid)
    return GraphBatch(
        node_feat=features[all_nodes],
        positions=None if positions is None else positions[all_nodes],
        src=jnp.concatenate(src_parts),
        dst=jnp.concatenate(dst_parts),
        edge_mask=jnp.concatenate(mask_parts),
        node_mask=node_mask,
        graph_id=jnp.zeros((n_local,), jnp.int32),
        n_graphs=1,
    )
