"""E(3)-equivariant building blocks in Cartesian form (l <= 2).

Irreps are represented as Cartesian tensors — mathematically equivalent to
real spherical-harmonic irreps for l <= 2 and far more TPU-friendly (all
ops are einsums, no Wigner machinery):

* l=0 scalars:  ``(n, c0)``
* l=1 vectors:  ``(n, c1, 3)``         — transform as ``R v``
* l=2 tensors:  ``(n, c2, 3, 3)``      — symmetric traceless, ``R T R^T``

Tensor-product contractions (the Clebsch-Gordan paths of NequIP/MACE) become
dot / cross / outer products; equivariance is verified numerically in tests
by conjugating with random rotations.  See DESIGN.md §4 (hardware-adaptation
note: the O(L^6) CG contraction collapses to dense einsums at L=2).
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "Irreps",
    "spherical_l1",
    "spherical_l2",
    "bessel_basis",
    "cutoff_envelope",
    "tp_paths_order2",
    "linear_mix",
    "gate",
]


class Irreps(NamedTuple):
    """A bundle of l=0,1,2 feature channels."""

    s: jnp.ndarray  # (n, c0)
    v: jnp.ndarray  # (n, c1, 3)
    t: jnp.ndarray  # (n, c2, 3, 3) symmetric traceless

    def rotate(self, r: jnp.ndarray) -> "Irreps":
        """Apply a global rotation (test utility)."""
        return Irreps(
            s=self.s,
            v=jnp.einsum("ij,ncj->nci", r, self.v),
            t=jnp.einsum("ij,ncjk,lk->ncil", r, self.t, r),
        )


def spherical_l1(unit: jnp.ndarray) -> jnp.ndarray:
    """Y1 = r_hat; (e, 3)."""
    return unit


def spherical_l2(unit: jnp.ndarray) -> jnp.ndarray:
    """Y2 = r_hat r_hat^T - I/3 (symmetric traceless); (e, 3, 3)."""
    eye = jnp.eye(3, dtype=unit.dtype)
    return unit[:, :, None] * unit[:, None, :] - eye / 3.0


def bessel_basis(r: jnp.ndarray, n_rbf: int, cutoff: float) -> jnp.ndarray:
    """NequIP radial basis: sin(n pi r / r_c) / r, n = 1..n_rbf; (e, n_rbf)."""
    n = jnp.arange(1, n_rbf + 1, dtype=r.dtype)
    rs = jnp.maximum(r, 1e-9)[:, None]
    return jnp.sqrt(2.0 / cutoff) * jnp.sin(n * np.pi * rs / cutoff) / rs


def cutoff_envelope(r: jnp.ndarray, cutoff: float, p: int = 6) -> jnp.ndarray:
    """Polynomial cutoff (smooth to p-th order) — zero outside the cutoff."""
    x = jnp.clip(r / cutoff, 0.0, 1.0)
    out = (
        1.0
        - ((p + 1.0) * (p + 2.0) / 2.0) * x**p
        + p * (p + 2.0) * x ** (p + 1)
        - (p * (p + 1.0) / 2.0) * x ** (p + 2)
    )
    return jnp.where(r < cutoff, out, 0.0)


# ---------------------------------------------------------------------------
# Tensor-product contraction paths (order 2): all CG-allowed combinations of
# two irreps (a from set A, b from set B) into l=0/1/2 outputs.
# ---------------------------------------------------------------------------


def _sym_traceless(m: jnp.ndarray) -> jnp.ndarray:
    sym = 0.5 * (m + jnp.swapaxes(m, -1, -2))
    tr = jnp.trace(sym, axis1=-2, axis2=-1)[..., None, None]
    return sym - tr * jnp.eye(3, dtype=m.dtype) / 3.0


def tp_paths_order2(a: Irreps, b: Irreps) -> Irreps:
    """Channel-aligned tensor product a (x) b -> irreps.

    Channels are contracted elementwise (requires equal channel counts — the
    "uvu" mode of e3nn); outputs concatenate every allowed path per l.
    """
    # --- l = 0 outputs ---
    s_parts = [
        a.s * b.s,                                        # 0x0 -> 0
        jnp.einsum("nci,nci->nc", a.v, b.v),              # 1x1 -> 0
        jnp.einsum("ncij,ncij->nc", a.t, b.t),            # 2x2 -> 0
    ]
    # --- l = 1 outputs ---
    v_parts = [
        a.s[..., None] * b.v,                             # 0x1 -> 1
        b.s[..., None] * a.v,                             # 1x0 -> 1
        jnp.cross(a.v, b.v),                              # 1x1 -> 1
        jnp.einsum("ncij,ncj->nci", a.t, b.v),            # 2x1 -> 1
        jnp.einsum("ncij,ncj->nci", b.t, a.v),            # 1x2 -> 1
    ]
    # --- l = 2 outputs ---
    t_parts = [
        a.s[..., None, None] * b.t,                       # 0x2 -> 2
        b.s[..., None, None] * a.t,                       # 2x0 -> 2
        _sym_traceless(a.v[..., :, None] * b.v[..., None, :]),  # 1x1 -> 2
        _sym_traceless(jnp.einsum("ncik,nckj->ncij", a.t, b.t)),  # 2x2 -> 2
    ]
    return Irreps(
        s=jnp.concatenate(s_parts, axis=-1),
        v=jnp.concatenate(v_parts, axis=-2),
        t=jnp.concatenate(t_parts, axis=-3),
    )


def linear_mix(params: Dict[str, jnp.ndarray], x: Irreps) -> Irreps:
    """Per-l channel mixing (the equivariant 'self-interaction' linear)."""
    return Irreps(
        s=jnp.einsum("nc,cd->nd", x.s, params["w_s"]),
        v=jnp.einsum("nci,cd->ndi", x.v, params["w_v"]),
        t=jnp.einsum("ncij,cd->ndij", x.t, params["w_t"]),
    )


def init_linear_mix(key, c_in: Tuple[int, int, int], c_out: Tuple[int, int, int]) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)

    def w(k, ci, co):
        return jax.random.normal(k, (ci, co), jnp.float32) / np.sqrt(max(ci, 1))

    return {"w_s": w(k1, c_in[0], c_out[0]), "w_v": w(k2, c_in[1], c_out[1]), "w_t": w(k3, c_in[2], c_out[2])}


def gate(x: Irreps) -> Irreps:
    """Equivariant gate (NequIP): the trailing ``c1 + c2`` scalar channels are
    consumed as sigmoid gates for the vector / tensor channels; the leading
    channels pass through silu.  The pre-gate linear must therefore emit
    ``feat + c1 + c2`` scalars."""
    c1, c2 = x.v.shape[1], x.t.shape[1]
    feat = x.s.shape[1] - c1 - c2
    if feat <= 0:
        raise ValueError(f"gate needs {c1 + c2} gate scalars on top of features; got s width {x.s.shape[1]}")
    gates_v = jax.nn.sigmoid(x.s[:, feat : feat + c1])
    gates_t = jax.nn.sigmoid(x.s[:, feat + c1 :])
    return Irreps(
        s=jax.nn.silu(x.s[:, :feat]),
        v=x.v * gates_v[..., None],
        t=x.t * gates_t[..., None, None],
    )
