"""repro.exec: execution backends that bind a TemplatePlan to devices.

The third layer of the plan -> cost -> exec pipeline (see
``docs/architecture.md``).  Backends never derive a schedule themselves:
stage order, canonical sharing, exec groups, and liveness all come from
the :class:`~repro.plan.ir.TemplatePlan` the engine binds them to; the
memory-model formulas come from :class:`~repro.plan.cost.CostModel`.
"""

# Import-cycle anchor: repro.core.engine imports this package — entering
# here first must finish loading the core submodules our modules read.
# The assignment keeps it visible to linters (pyflakes has no noqa).
import repro.core

# `repro` (not `repro.core`): mid-cycle the submodule is in sys.modules
# but not yet bound as an attribute on the parent package
_CYCLE_ANCHOR = repro

from .base import EngineBackend, StageTables, build_stage_tables, make_backend
from .local import (
    SELL_GROUP_SIZE,
    BlockedEllBackend,
    CustomBackend,
    DenseBackend,
    EdgesBackend,
    EllBackend,
    LocalBackend,
    SellBackend,
)
from .mesh import MeshBackend

__all__ = [
    "EngineBackend",
    "StageTables",
    "build_stage_tables",
    "LocalBackend",
    "EdgesBackend",
    "EllBackend",
    "SellBackend",
    "DenseBackend",
    "BlockedEllBackend",
    "CustomBackend",
    "MeshBackend",
    "SELL_GROUP_SIZE",
    "make_backend",
]
