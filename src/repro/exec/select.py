"""Backend auto-selection: graph statistics -> execution strategy name.

The dispatch half of the exec layer: given a graph (and the platform), pick
which local SpMM strategy the engine should bind its plan to.  Decisions
are logged on the ``repro.engine`` logger (the engine façade's channel, so
existing log-capture consumers keep working).
"""

from __future__ import annotations

import logging
import os
from typing import Optional, Tuple

import jax

__all__ = [
    "select_backend",
    "ENGINE_BACKENDS",
    "BACKEND_ENV_VAR",
    "DENSE_MAX_VERTICES",
    "ELL_PAD_FACTOR",
    "BLOCKED_MIN_VERTICES",
    "SELL_MIN_SCATTER_WORK",
    "DENSE_WORK_ADVANTAGE",
]

logger = logging.getLogger("repro.engine")

#: Graphs at or below this vertex count use the dense-adjacency backend.
DENSE_MAX_VERTICES = 256

#: ELL is chosen only when padding waste is bounded: ``n * max_deg`` must not
#: exceed this factor times the true directed edge count.
ELL_PAD_FACTOR = 1.5

#: On TPU, graphs at least this large route to the Pallas blocked-ELL kernel.
BLOCKED_MIN_VERTICES = 4096

#: Environment variable overriding the auto-selected local backend.
BACKEND_ENV_VAR = "REPRO_ENGINE_BACKEND"

#: Above this ``n * |E_directed|`` product, skewed graphs route to the
#: scatter-free SELL backend: XLA:CPU's scatter lowering falls off a cliff
#: in this regime (observed ~200x on 8k vertices / 130k directed edges)
#: while degree-bucketed gathers stay on the |E|-proportional cost curve.
SELL_MIN_SCATTER_WORK = 5 * 10**8

#: Dense adjacency wins only when the gather path's per-column element work
#: (``|E|``) is within this factor of the dense matmul's per-column ``n^2``
#: MACs — the throughput advantage of regular matmuls over irregular
#: gathers.  (The column count cancels: both paths scale linearly in it.)
DENSE_WORK_ADVANTAGE = 16

ENGINE_BACKENDS = ("edges", "ell", "sell", "dense", "blocked", "mesh", "custom")


def select_backend(graph, platform: Optional[str] = None, explain: bool = False):
    """Pick the local SpMM backend from graph statistics.

    * env override — ``REPRO_ENGINE_BACKEND=<name>`` forces any local
      backend (a bad auto-pick used to be silent and undiagnosable).
    * ``dense``   — tiny graphs, or work-dense graphs where the gather
      path's per-column element work ``|E|`` reaches
      ``n^2 / DENSE_WORK_ADVANTAGE`` (avg degree ``>= n / 16``): one
      (n, n) matmul beats gather/scatter.  The DP column count cancels
      from the comparison — both paths scale linearly in it.
    * ``blocked`` — large graphs on TPU: the fused Pallas blocked-ELL
      SpMM+eMA kernel.
    * ``ell``     — flat degree distributions where row padding is cheap.
    * ``sell``    — rmat8k-class graphs (``n * |E|`` beyond
      ``SELL_MIN_SCATTER_WORK``): scatter-free degree-bucketed gathers;
      XLA:CPU's scatter collapses in this regime.
    * ``edges``   — everything else (small skewed / power-law graphs: a hub
      row would blow the ELL padding up to ``n * max_deg``).

    The ``mesh`` backend is never auto-selected from graph statistics — it
    is chosen by passing ``mesh=`` to ``CountingEngine``.

    The decision and its reason are logged on the ``repro.engine`` logger
    (DEBUG) so callers capture it with standard logging config;
    ``explain=True`` additionally returns ``(name, reason)`` for
    structured consumers (``CountingEngine.describe()``).
    """
    name, reason = _select_backend_reason(graph, platform)
    logger.debug(
        "select_backend: %s for n=%d edges=%d (%s)",
        name,
        graph.n,
        graph.num_directed,
        reason,
    )
    return (name, reason) if explain else name


def _select_backend_reason(graph, platform: Optional[str]) -> Tuple[str, str]:
    env = os.environ.get(BACKEND_ENV_VAR, "").strip()
    if env:
        if env not in ("edges", "ell", "sell", "dense", "blocked"):
            raise ValueError(
                f"{BACKEND_ENV_VAR}={env!r} is not a local backend "
                "(edges | ell | sell | dense | blocked)"
            )
        return env, f"{BACKEND_ENV_VAR} env override"
    platform = platform or jax.default_backend()
    if graph.n <= DENSE_MAX_VERTICES:
        return "dense", f"n={graph.n} <= {DENSE_MAX_VERTICES} (tiny graph)"
    if platform == "tpu" and graph.n >= BLOCKED_MIN_VERTICES:
        return "blocked", f"tpu and n={graph.n} >= {BLOCKED_MIN_VERTICES}"
    edges = max(graph.num_directed, 1)
    if DENSE_WORK_ADVANTAGE * edges >= graph.n**2:
        return "dense", (
            f"{DENSE_WORK_ADVANTAGE}*|E|={DENSE_WORK_ADVANTAGE * edges} >= "
            f"n^2={graph.n**2} (work-dense graph)"
        )
    max_deg = graph.max_degree()
    if graph.n * max_deg <= ELL_PAD_FACTOR * edges:
        return "ell", (
            f"n*max_deg={graph.n * max_deg} <= {ELL_PAD_FACTOR}*|E| "
            "(flat degrees, padding bounded)"
        )
    if graph.n * edges >= SELL_MIN_SCATTER_WORK:
        return "sell", (
            f"n*|E|={graph.n * edges} >= {SELL_MIN_SCATTER_WORK} "
            "(XLA:CPU scatter-cliff regime)"
        )
    return "edges", "skewed degrees below the scatter-cliff regime"
