"""Backend auto-selection: graph statistics -> execution strategy name.

The dispatch half of the exec layer: given a graph (and the platform), pick
which local SpMM strategy the engine should bind its plan to.  Decisions
are logged on the ``repro.engine`` logger (the engine façade's channel, so
existing log-capture consumers keep working).
"""

from __future__ import annotations

import logging
import os
from typing import Optional, Tuple

import jax

__all__ = [
    "select_backend",
    "heuristic_backend",
    "resolve_backend_config",
    "consult_tuning",
    "tune_mode",
    "mesh_comm_mode",
    "ENGINE_BACKENDS",
    "BACKEND_ENV_VAR",
    "TUNE_MODE_ENV_VAR",
    "TUNE_MODES",
    "MESH_COMM_ENV_VAR",
    "MESH_COMM_MODES",
    "DENSE_MAX_VERTICES",
    "ELL_PAD_FACTOR",
    "BLOCKED_MIN_VERTICES",
    "SELL_MIN_SCATTER_WORK",
    "DENSE_WORK_ADVANTAGE",
]

logger = logging.getLogger("repro.engine")

#: Graphs at or below this vertex count use the dense-adjacency backend.
DENSE_MAX_VERTICES = 256

#: ELL is chosen only when padding waste is bounded: ``n * max_deg`` must not
#: exceed this factor times the true directed edge count.
ELL_PAD_FACTOR = 1.5

#: On TPU, graphs at least this large route to the Pallas blocked-ELL kernel.
BLOCKED_MIN_VERTICES = 4096

#: Environment variable overriding the auto-selected local backend.
BACKEND_ENV_VAR = "REPRO_ENGINE_BACKEND"

#: Above this ``n * |E_directed|`` product, skewed graphs route to the
#: scatter-free SELL backend: XLA:CPU's scatter lowering falls off a cliff
#: in this regime (observed ~200x on 8k vertices / 130k directed edges)
#: while degree-bucketed gathers stay on the |E|-proportional cost curve.
SELL_MIN_SCATTER_WORK = 5 * 10**8

#: Dense adjacency wins only when the gather path's per-column element work
#: (``|E|``) is within this factor of the dense matmul's per-column ``n^2``
#: MACs — the throughput advantage of regular matmuls over irregular
#: gathers.  (The column count cancels: both paths scale linearly in it.)
DENSE_WORK_ADVANTAGE = 16

#: How engine builds use the tuning cache: ``off`` never consults it,
#: ``cached`` (default) applies persisted winners, ``full`` additionally
#: lets the serving layer schedule background tunes for un-tuned keys.
TUNE_MODE_ENV_VAR = "REPRO_TUNE"

TUNE_MODES = ("off", "cached", "full")

#: Environment override forcing the mesh backend's collective scheme:
#: ``blocking`` (one all-gather per column batch) or ``pipelined`` (the
#: double-buffered ring).  Unset = the cost model's per-stage decision.
MESH_COMM_ENV_VAR = "REPRO_MESH_COMM"

MESH_COMM_MODES = ("blocking", "pipelined")

ENGINE_BACKENDS = (
    "edges", "ell", "sell", "dense", "blocked", "mixed", "mesh", "custom"
)

#: Local backend names an env override / explicit ``backend=`` may name
#: without extra context (``mixed`` additionally needs a TuningConfig).
_LOCAL_BACKENDS = ("edges", "ell", "sell", "dense", "blocked")


def select_backend(graph, platform: Optional[str] = None, explain: bool = False):
    """Pick the local SpMM backend from graph statistics.

    * env override — ``REPRO_ENGINE_BACKEND=<name>`` forces any local
      backend (a bad auto-pick used to be silent and undiagnosable).
    * ``dense``   — tiny graphs, or work-dense graphs where the gather
      path's per-column element work ``|E|`` reaches
      ``n^2 / DENSE_WORK_ADVANTAGE`` (avg degree ``>= n / 16``): one
      (n, n) matmul beats gather/scatter.  The DP column count cancels
      from the comparison — both paths scale linearly in it.
    * ``blocked`` — large graphs on TPU: the fused Pallas blocked-ELL
      SpMM+eMA kernel.
    * ``ell``     — flat degree distributions where row padding is cheap.
    * ``sell``    — rmat8k-class graphs (``n * |E|`` beyond
      ``SELL_MIN_SCATTER_WORK``): scatter-free degree-bucketed gathers;
      XLA:CPU's scatter collapses in this regime.
    * ``edges``   — everything else (small skewed / power-law graphs: a hub
      row would blow the ELL padding up to ``n * max_deg``).

    The ``mesh`` backend is never auto-selected from graph statistics — it
    is chosen by passing ``mesh=`` to ``CountingEngine``.

    The decision and its reason are logged on the ``repro.engine`` logger
    (DEBUG) so callers capture it with standard logging config;
    ``explain=True`` additionally returns ``(name, reason)`` for
    structured consumers (``CountingEngine.describe()``).
    """
    name, reason = _select_backend_reason(graph, platform)
    logger.debug(
        "select_backend: %s for n=%d edges=%d (%s)",
        name,
        graph.n,
        graph.num_directed,
        reason,
    )
    return (name, reason) if explain else name


def _env_backend() -> Optional[str]:
    """The validated ``REPRO_ENGINE_BACKEND`` override, or ``None``."""
    env = os.environ.get(BACKEND_ENV_VAR, "").strip()
    if not env:
        return None
    if env not in _LOCAL_BACKENDS:
        raise ValueError(
            f"{BACKEND_ENV_VAR}={env!r} is not a local backend "
            "(edges | ell | sell | dense | blocked)"
        )
    return env


def heuristic_backend(graph, platform: Optional[str] = None) -> Tuple[str, str]:
    """The pure analytic pick — ``(name, reason)`` from graph statistics
    alone, ignoring both the env override and the tuning cache.  This is
    the bottom of the resolution ladder (and what the tuner benches its
    winners against)."""
    return _heuristic_reason(graph, platform)


def tune_mode() -> str:
    """The ``REPRO_TUNE`` mode (``off`` | ``cached`` | ``full``).

    An unrecognized value warns once and behaves as ``cached`` — engine
    builds and service stats must never crash on a typo'd env var."""
    raw = os.environ.get(TUNE_MODE_ENV_VAR, "").strip().lower()
    if not raw:
        return "cached"
    if raw in TUNE_MODES:
        return raw
    if raw not in _BAD_TUNE_MODES_WARNED:
        _BAD_TUNE_MODES_WARNED.add(raw)
        logger.warning(
            "%s=%r is not one of %s — defaulting to 'cached'",
            TUNE_MODE_ENV_VAR, raw, "|".join(TUNE_MODES),
        )
    return "cached"


_BAD_TUNE_MODES_WARNED: set = set()


def mesh_comm_mode() -> Optional[str]:
    """The validated ``REPRO_MESH_COMM`` override, or ``None`` (let the
    cost model's per-stage ``comm_schedule`` decide).

    An unrecognized value warns once and behaves as unset — like
    :func:`tune_mode`, engine builds must never crash on a typo'd env
    var."""
    raw = os.environ.get(MESH_COMM_ENV_VAR, "").strip().lower()
    if not raw:
        return None
    if raw in MESH_COMM_MODES:
        return raw
    if raw not in _BAD_MESH_COMM_WARNED:
        _BAD_MESH_COMM_WARNED.add(raw)
        logger.warning(
            "%s=%r is not one of %s — ignoring the override",
            MESH_COMM_ENV_VAR, raw, "|".join(MESH_COMM_MODES),
        )
    return None


_BAD_MESH_COMM_WARNED: set = set()


def consult_tuning(graph, canons, *, signature=None, path=None):
    """Tuned config for ``(graph, canons)`` on this device, or ``None``.

    Honors ``REPRO_TUNE=off``; any cache trouble (missing, corrupt, wrong
    version, unreadable) degrades to ``None`` — the caller then falls
    through to the heuristic."""
    if canons is None or tune_mode() == "off":
        return None
    try:
        # local import: repro.tune.cache is downstream of the exec layer
        from repro.tune.cache import consult

        sig = signature if signature is not None else graph.signature()
        return consult(sig, canons, path=path)
    except Exception as exc:  # pragma: no cover - defensive
        logger.debug("tuning consult failed (%s) — using heuristic", exc)
        return None


def resolve_backend_config(
    graph,
    *,
    backend: str = "auto",
    canons=None,
    tuning=None,
    platform: Optional[str] = None,
    signature=None,
):
    """The full backend resolution ladder: ``(name, source, reason, config)``.

    Precedence (strongest first):

    1. **explicit** — a concrete ``backend=`` argument (engine callers and
       the degradation ladder's rung overrides must always win).
       ``backend="mixed"`` requires ``tuning`` (the per-group bindings).
    2. **env** — ``REPRO_ENGINE_BACKEND`` beats tuned configs too: the
       operator's escape hatch must not be overridable by a cache file.
    3. **tuned** — a :class:`~repro.tune.config.TuningConfig` passed as
       ``tuning`` or found in the tuning cache for ``(graph, canons)``.
    4. **heuristic** — the analytic pick from graph statistics.

    ``config`` is the :class:`TuningConfig` to bind (``None`` for
    env/heuristic/plain-explicit resolutions).
    """
    if backend != "auto":
        if backend == "mixed" and tuning is None:
            raise ValueError(
                "backend='mixed' needs a TuningConfig (tuning=...) for its "
                "per-group bindings"
            )
        cfg = tuning if backend == "mixed" else None
        return backend, "explicit", "backend= given by caller", cfg
    env = _env_backend()
    if env is not None:
        return env, "env", f"{BACKEND_ENV_VAR} env override", None
    cfg = tuning
    if cfg is None:
        cfg = consult_tuning(graph, canons, signature=signature)
    if cfg is not None:
        reason = (
            f"tuned config (default={cfg.default_backend}, "
            f"{len(cfg.group_backends)} group bindings, "
            f"column_batch={cfg.column_batch}, chunk_size={cfg.chunk_size})"
        )
        return cfg.backend_name, "tuned", reason, cfg
    name, reason = heuristic_backend(graph, platform)
    return name, "heuristic", reason, None


def _select_backend_reason(graph, platform: Optional[str]) -> Tuple[str, str]:
    env = _env_backend()
    if env is not None:
        return env, f"{BACKEND_ENV_VAR} env override"
    return _heuristic_reason(graph, platform)


def _heuristic_reason(graph, platform: Optional[str]) -> Tuple[str, str]:
    platform = platform or jax.default_backend()
    if graph.n <= DENSE_MAX_VERTICES:
        return "dense", f"n={graph.n} <= {DENSE_MAX_VERTICES} (tiny graph)"
    if platform == "tpu" and graph.n >= BLOCKED_MIN_VERTICES:
        return "blocked", f"tpu and n={graph.n} >= {BLOCKED_MIN_VERTICES}"
    edges = max(graph.num_directed, 1)
    if DENSE_WORK_ADVANTAGE * edges >= graph.n**2:
        return "dense", (
            f"{DENSE_WORK_ADVANTAGE}*|E|={DENSE_WORK_ADVANTAGE * edges} >= "
            f"n^2={graph.n**2} (work-dense graph)"
        )
    max_deg = graph.max_degree()
    if graph.n * max_deg <= ELL_PAD_FACTOR * edges:
        return "ell", (
            f"n*max_deg={graph.n * max_deg} <= {ELL_PAD_FACTOR}*|E| "
            "(flat degrees, padding bounded)"
        )
    if graph.n * edges >= SELL_MIN_SCATTER_WORK:
        return "sell", (
            f"n*|E|={graph.n * edges} >= {SELL_MIN_SCATTER_WORK} "
            "(XLA:CPU scatter-cliff regime)"
        )
    return "edges", "skewed degrees below the scatter-cliff regime"
