"""Single-device execution backends for the fused counting pipeline.

Every local backend shares one DP executor (:class:`LocalBackend.
counts_for_colors`) that walks the engine's bound
:class:`~repro.plan.ir.TemplatePlan` — stage order, canonical sharing,
shared-passive exec groups, and the liveness schedule all come from the
plan IR; subclasses only supply the column-slice neighbor reduction
:meth:`LocalBackend.spmm` (or, for the fused Pallas kernel, override
:meth:`~repro.exec.base.EngineBackend.aggregate_ema` outright).
"""

from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.core.counting import fused_aggregate_ema_grouped
from repro.core.graph import build_sell

from .base import (
    BagStageTables,
    EngineBackend,
    StageTables,
    build_bag_tables,
    build_stage_tables,
)

__all__ = [
    "LocalBackend",
    "EdgesBackend",
    "EllBackend",
    "SellBackend",
    "DenseBackend",
    "BlockedEllBackend",
    "CustomBackend",
    "MixedBackend",
    "LOCAL_BACKEND_CLASSES",
    "SELL_GROUP_SIZE",
]

#: Degree-sorted rows per SELL group (smaller = tighter padding).
SELL_GROUP_SIZE = 128


class LocalBackend(EngineBackend):
    """Shared single-device fused DP: subclasses only supply :meth:`spmm`.

    The multi-template DP walks every plan's stages with DP states memoized
    by rooted canonical form, all M matrices in the fused ``(n, B, C)``
    layout.  Each stage runs through the shared streamed
    :meth:`aggregate_ema` (passive column batches aggregated and consumed
    one at a time), and states are dropped at their liveness-scheduled last
    read — the aggregate product ``A_G @ M_p`` never exists.
    """

    def __init__(self, engine, shared: "LocalBackend" = None):
        super().__init__(engine)
        # Bucketed per-batch tables feed the local fused executor and the
        # Pallas kernel (the mesh backend builds its own streamed tables
        # at its own all-gather column batch).  A MixedBackend's sub-impls
        # pass ``shared=`` to alias the owner's tables instead of shipping
        # a second copy of every split table to the device.
        if shared is not None:
            self.stage_tables: Dict = shared.stage_tables
            self.bag_tables: Dict = shared.bag_tables
            self._bag_adj = shared._bag_adj
            return
        self.stage_tables = build_stage_tables(engine.plan_ir, engine.column_batch)
        self.bag_tables = build_bag_tables(engine.plan_ir)
        self._bag_adj = None
        if engine.plan_ir.has_bag_stages:
            # Edge masks of bag-extend steps multiply by A[u_w, u_x]; the
            # dense adjacency broadcasts scatter-free against any state rank.
            self._bag_adj = jnp.asarray(engine.graph.dense_adjacency())

    def spmm(self, m: jnp.ndarray) -> jnp.ndarray:
        """One neighbor reduction over a fused ``(n, B, c)`` column slice
        (the fused pipeline only ever passes ``column_batch``-wide slices);
        returns accum dtype."""
        raise NotImplementedError

    def _spmm_counted(self, m: jnp.ndarray) -> jnp.ndarray:
        # the Python-level counter runs once per traced aggregation launch
        self.engine.counters["passive_aggregations"] += 1
        return self.spmm(m)

    def aggregate_ema(self, m_p, m_a, tables: StageTables):
        return self.aggregate_ema_grouped(m_p, [(m_a, tables)])[0]

    def aggregate_ema_grouped(self, m_p, stage_inputs):
        pol = self.engine.policy
        return fused_aggregate_ema_grouped(
            m_p,
            [(m_a, tables.batches, tables.n_out) for m_a, tables in stage_inputs],
            self._spmm_counted,
            pol.accum_dtype,
        )

    def _group_aggregate(self, leader, m_p, stage_inputs):
        """Per-exec-group dispatch seam: ``leader`` is the group's
        ``(plan_idx, sub_idx)`` address.  Uniform backends ignore it;
        :class:`MixedBackend` routes each group to its bound sub-impl."""
        return self.aggregate_ema_grouped(m_p, stage_inputs)

    def counts_for_colors(self, colors: jnp.ndarray) -> jnp.ndarray:
        """(B, n) colorings -> (B, T) un-normalized colorful totals.

        The walk *is* the plan: sub-template states are memoized by
        canonical form, freed at the plan's liveness-scheduled last reads
        (Algorithm 5's in-place storage), and stages reading the same
        passive canonical form execute as one plan exec group — the
        group's passive column-batch sweep aggregates each slice once for
        all of them.  Bag plans (non-tree templates) walk their bag
        programs through the same slot/liveness discipline; single-axis
        bag states share slots with tree stages whenever canons agree.
        """
        eng = self.engine
        ir = eng.plan_ir
        pol = eng.policy
        leaf = jax.nn.one_hot(colors.T, eng.k, dtype=pol.store_dtype)  # (n, B, k)
        free_at = ir.free_at
        slots: Dict[str, jnp.ndarray] = {}
        totals = []
        executed = set()
        pos = 0
        for p_idx, cplan in enumerate(ir.counting_plans):
            canons = ir.canons[p_idx]
            if cplan.partition is None:
                ops = cplan.bag_program.ops
                for i, op in enumerate(ops):
                    key = canons[i]
                    if key in executed:
                        continue
                    executed.add(key)
                    if op.kind == "leaf":
                        slots[key] = leaf
                    elif key not in slots:
                        slots[key] = self._run_bag_op(
                            cplan, canons, p_idx, i, op, leaf, slots
                        ).astype(pol.store_dtype)
                    for dead in free_at.get(pos, ()):
                        slots.pop(dead, None)
                    pos += 1
                # final op has no vertex axes: state is (B, 1) — the single
                # C(k, k) colorset column holds the full colorful total
                root = slots[canons[len(ops) - 1]].astype(pol.accum_dtype)
                totals.append(root.sum(axis=-1).astype(jnp.float32))
                for dead in free_at.get(pos, ()):
                    slots.pop(dead, None)
                pos += 1
                continue
            for i, sub in enumerate(cplan.partition.subs):
                key = canons[i]
                if key in executed:
                    continue
                executed.add(key)
                if sub.is_leaf:
                    slots[key] = leaf
                elif key not in slots:
                    # group leader: execute every stage sharing this passive
                    # canon over one column-batch sweep (members whose active
                    # state is already live; singleton group otherwise)
                    members = ir.exec_groups[(p_idx, i)]
                    stage_inputs = []
                    for q, j in members:
                        sub_m = ir.counting_plans[q].partition.subs[j]
                        stage_inputs.append(
                            (
                                slots[ir.canons[q][sub_m.active]],
                                self.stage_tables[(q, j)],
                            )
                        )
                    outs = self._group_aggregate(
                        (p_idx, i), slots[canons[sub.passive]], stage_inputs
                    )
                    for (q, j), m_s in zip(members, outs):
                        slots[ir.canons[q][j]] = m_s.astype(pol.store_dtype)
                # else: already produced early as a member of a prior group
                for dead in free_at.get(pos, ()):
                    slots.pop(dead, None)
                pos += 1
            root = slots[canons[cplan.partition.root_index]].astype(pol.accum_dtype)
            # reduce color sets first, then vertices: the per-coloring order
            # is independent of the batch size (bit-exact across chunkings)
            totals.append(root.sum(axis=2).sum(axis=0).astype(jnp.float32))
            for dead in free_at.get(pos, ()):
                slots.pop(dead, None)
            pos += 1
        return jnp.stack(totals, axis=1)  # (B, T)

    # -- bag-program execution ------------------------------------------------

    def _run_bag_op(self, cplan, canons, p_idx, i, op, leaf, slots) -> jnp.ndarray:
        """Execute one extend / forget / join bag op on the fused layout.

        States are ``(n,)*r + (B, C)`` tensors — vertex axes (sorted by
        template vertex id) in front of the tree family's fused ``(B, C)``
        tail, so single-axis states are layout-identical to tree states.
        """
        if op.kind == "extend":
            return self._bag_extend(cplan, canons, p_idx, i, op, leaf, slots)
        if op.kind == "forget":
            in_op = cplan.bag_program.ops[op.inputs[0]]
            state = slots[canons[op.inputs[0]]]
            return self._bag_forget(state, list(in_op.axes), op.forget_vertices)[0]
        if op.kind == "join":
            return self._bag_join(canons, p_idx, i, op, slots)
        raise ValueError(f"unknown bag op kind {op.kind!r}")

    @staticmethod
    def _bag_forget(state, axes_now, forget_vertices):
        for x in forget_vertices:
            ax = axes_now.index(x)
            state = state.sum(axis=ax)
            axes_now.pop(ax)
        return state, axes_now

    def _bag_extend(self, cplan, canons, p_idx, i, op, leaf, slots) -> jnp.ndarray:
        eng = self.engine
        pol = eng.policy
        n = eng.graph.n
        tables: BagStageTables = self.bag_tables[(p_idx, i)]
        in_op = cplan.bag_program.ops[op.inputs[0]]
        state = slots[canons[op.inputs[0]]]
        axes_now = list(in_op.axes)
        w = op.vertex
        if op.spmm_vertex is not None:
            # Contract the eliminated axis through the adjacency: apply edge
            # (spmm_vertex, w) with the backend's neighbor reduction (the
            # state is flattened to the (n, B', C) layout spmm expects).
            ax = axes_now.index(op.spmm_vertex)
            state = jnp.moveaxis(state, ax, 0)
            rest = state.shape[1:]
            flat = state.reshape(n, -1, state.shape[-1])
            state = self._spmm_counted(flat).reshape((n,) + rest)
            axes_now.pop(ax)
            axes_now = [w] + axes_now
        else:
            # Broadcast introduction: the new vertex has no eliminated
            # neighbor; its edges (if any) arrive as masks below.
            state = jnp.broadcast_to(state[None, ...], (n,) + state.shape)
            axes_now = [w] + axes_now
        for x in op.mask_vertices:
            ax = axes_now.index(x)
            mask = self._bag_adj.reshape(
                (n,) + (1,) * (ax - 1) + (n,) + (1,) * (state.ndim - 1 - ax)
            )
            state = state * mask.astype(state.dtype)
        # Colorset update against the new vertex's one-hot leaf:
        # SplitTable(k, m, 1) — exactly the tree eMA with a width-1 active.
        accum = pol.accum_dtype
        r = state.ndim
        idx_a, idx_p = tables.idx_a, tables.idx_p

        def body(t, acc):
            ia = jax.lax.dynamic_index_in_dim(idx_a, t, axis=1, keepdims=False)
            ip = jax.lax.dynamic_index_in_dim(idx_p, t, axis=1, keepdims=False)
            la = jnp.take(leaf, ia, axis=2).astype(accum)  # (n, B, n_out)
            la = la.reshape((n,) + (1,) * (r - 3) + la.shape[1:])
            gp = jnp.take(state, ip, axis=-1).astype(accum)
            return acc + la * gp

        out = jax.lax.fori_loop(
            0,
            tables.n_terms,
            body,
            jnp.zeros(state.shape[:-1] + (tables.n_out,), accum),
        )
        out, axes_now = self._bag_forget(out, axes_now, op.forget_vertices)
        # Restore sorted-axis order (the new vertex axis sits in front).
        order = sorted(range(len(axes_now)), key=lambda idx: axes_now[idx])
        if order != list(range(len(axes_now))):
            perm = order + list(range(len(axes_now), out.ndim))
            out = jnp.transpose(out, perm)
        return out

    def _bag_join(self, canons, p_idx, i, op, slots) -> jnp.ndarray:
        pol = self.engine.policy
        tables: BagStageTables = self.bag_tables[(p_idx, i)]
        s1 = slots[canons[op.inputs[0]]]
        s2 = slots[canons[op.inputs[1]]]
        accum = pol.accum_dtype
        idx_a, idx_p = tables.idx_a, tables.idx_p

        def body(t, acc):
            ia = jax.lax.dynamic_index_in_dim(idx_a, t, axis=1, keepdims=False)
            ip = jax.lax.dynamic_index_in_dim(idx_p, t, axis=1, keepdims=False)
            g1 = jnp.take(s1, ia, axis=-1).astype(accum)
            g2 = jnp.take(s2, ip, axis=-1).astype(accum)
            return acc + g1 * g2

        return jax.lax.fori_loop(
            0,
            tables.n_terms,
            body,
            jnp.zeros(s1.shape[:-1] + (tables.n_out,), accum),
        )


class EdgesBackend(LocalBackend):
    """Edge-list gather + segment-sum (the skew-robust default)."""

    name = "edges"

    def __init__(self, engine, shared=None):
        super().__init__(engine, shared=shared)
        g = engine.graph
        self._src = jnp.asarray(g.src)
        self._dst = jnp.asarray(g.dst)

    def spmm(self, m):
        return jax.ops.segment_sum(
            m[self._src].astype(self.engine.policy.accum_dtype),
            self._dst,
            num_segments=self.engine.graph.n,
            indices_are_sorted=True,
        )


class EllBackend(LocalBackend):
    """Padded-row neighbor gather (flat degree distributions)."""

    name = "ell"

    def __init__(self, engine, shared=None):
        super().__init__(engine, shared=shared)
        nbr, mask = engine.graph.ell()
        self._nbr = jnp.asarray(nbr)
        self._ell_mask = jnp.asarray(mask)

    def spmm(self, m):
        pol = self.engine.policy
        gathered = m[self._nbr].astype(pol.accum_dtype)  # (n, max_deg, B, c)
        return jnp.einsum("ndbc,nd->nbc", gathered, self._ell_mask.astype(pol.accum_dtype))


class SellBackend(LocalBackend):
    """Degree-bucketed sliced-ELL gather — scatter-free (rmat8k-class graphs).

    Vertices are degree-sorted into :data:`SELL_GROUP_SIZE`-row groups,
    each padded only to its own max degree (:func:`repro.core.graph.
    build_sell`); the neighbor reduction is a padded row gather + masked
    einsum per group, stitched back through one inverse-permutation gather.
    No scatter appears anywhere — this sidesteps the XLA:CPU scatter cliff
    that made the edge-list ``segment_sum`` 5–10x *slower* than the scalar
    traversal baseline on rmat8k, while keeping padding bounded on
    power-law degree distributions (unlike plain ELL).
    """

    name = "sell"

    def __init__(self, engine, group_size: int = SELL_GROUP_SIZE, shared=None):
        super().__init__(engine, shared=shared)
        sell = build_sell(engine.graph, group_size=group_size)
        self._sell_padded_slots = sell.padded_slots
        self._groups = tuple(
            (jnp.asarray(nbr), jnp.asarray(mask))
            for nbr, mask in zip(sell.group_nbr, sell.group_mask)
        )
        self._inv_order = jnp.asarray(sell.inv_order)

    def spmm(self, m):
        pol = self.engine.policy
        parts = [
            jnp.einsum(
                "rdbc,rd->rbc",
                m[nbr].astype(pol.accum_dtype),
                mask.astype(pol.accum_dtype),
            )
            for nbr, mask in self._groups
        ]
        return jnp.concatenate(parts, axis=0)[self._inv_order]

    def transient_elements(self) -> int:
        eng = self.engine
        return eng.cost.transient_elements(
            self.name, eng.column_batch, sell_padded_slots=self._sell_padded_slots
        )


class DenseBackend(LocalBackend):
    """Dense-adjacency matmul (tiny graphs)."""

    name = "dense"

    def __init__(self, engine, shared=None):
        super().__init__(engine, shared=shared)
        self._adj = jnp.asarray(engine.graph.dense_adjacency())

    def spmm(self, m):
        pol = self.engine.policy
        n, b, c = m.shape
        out = jnp.matmul(
            self._adj.astype(pol.store_dtype),
            m.reshape(n, b * c),
            preferred_element_type=pol.accum_dtype,
        )
        return out.reshape(n, b, c).astype(pol.accum_dtype)


class BlockedEllBackend(LocalBackend):
    """Fused Pallas SpMM+eMA kernel over blocked-ELL (large graphs on TPU).

    Each stage is ONE :func:`repro.kernels.spmm_ema.ops.spmm_ema` call: per
    destination vertex block the kernel accumulates that block's aggregate
    columns in VMEM scratch and consumes them in the eMA FMA against the
    resident ``M_a`` tile the moment the block's last edge pair lands —
    the aggregate product never reaches HBM.
    """

    name = "blocked"

    def __init__(self, engine, block_size: int = 256, shared=None):
        super().__init__(engine, shared=shared)
        from repro.kernels.spmm_ema.ops import prepare_fused_operand

        self._fused_op = prepare_fused_operand(engine.graph, block_size=block_size)

    def spmm(self, m):
        # kernel is 2-D (n, C) — fuse batch into columns
        from repro.kernels.spmm_blocked.ops import spmm_blocked

        n, b, c = m.shape
        out = spmm_blocked(
            self._fused_op.blocked,
            m.reshape(n, b * c).astype(jnp.float32),
            interpret=self.engine.interpret,
        )
        return out.reshape(n, b, c).astype(self.engine.policy.accum_dtype)

    def aggregate_ema(self, m_p, m_a, tables: StageTables):
        from repro.kernels.spmm_ema.ops import spmm_ema_batched

        self.engine.counters["passive_aggregations"] += 1
        return spmm_ema_batched(
            self._fused_op,
            m_p,
            m_a,
            tables.idx_a_host,
            tables.idx_p_host,
            interpret=self.engine.interpret,
        ).astype(self.engine.policy.accum_dtype)

    def aggregate_ema_grouped(self, m_p, stage_inputs):
        # the Pallas kernel fuses SpMM+eMA per stage inside one launch; a
        # cross-stage sweep cannot share its VMEM aggregate scratch, so the
        # group degrades to the per-stage loop (counted per launch)
        return [self.aggregate_ema(m_p, m_a, tables) for m_a, tables in stage_inputs]


class CustomBackend(LocalBackend):
    """Caller-supplied ``(n, C) -> (n, C)`` neighbor-sum kernel."""

    name = "custom"

    def __init__(self, engine, spmm_fn: Callable):
        super().__init__(engine)
        self._spmm_fn = spmm_fn

    def spmm(self, m):
        n, b, c = m.shape
        out = self._spmm_fn(m.reshape(n, b * c))
        return out.reshape(n, b, c).astype(self.engine.policy.accum_dtype)


#: name -> class for the uniform single-device strategies (what a
#: TuningConfig's per-group bindings may name).
LOCAL_BACKEND_CLASSES = {
    "edges": EdgesBackend,
    "ell": EllBackend,
    "sell": SellBackend,
    "dense": DenseBackend,
    "blocked": BlockedEllBackend,
}


class MixedBackend(LocalBackend):
    """Per-exec-group backend dispatch from a tuned configuration.

    One sub-implementation per distinct backend the
    :class:`~repro.tune.config.TuningConfig` names, all sharing this
    owner's stage/bag tables (``shared=`` — split tables ship to the
    device once).  The DP walk stays the inherited one; only the
    :meth:`_group_aggregate` seam routes each shared-passive exec group to
    its bound sub-impl's column-batch sweep.  Bag ops and ungrouped
    ``spmm`` calls run on the config's ``default_backend``.

    Measurement-driven existence proof: on skewed graphs the hub-touching
    wide-passive groups want SELL's scatter-free gathers while narrow
    early stages amortize better on the edge list — a single engine-wide
    backend leaves one of the two on the wrong cost curve.
    """

    name = "mixed"

    def __init__(self, engine, tuning):
        super().__init__(engine)
        if tuning is None:
            raise ValueError("MixedBackend needs a TuningConfig (tuning=...)")
        self._tuning = tuning
        self._bindings = tuning.bindings()
        names = {tuning.default_backend, *self._bindings.values()}
        unknown = names - set(LOCAL_BACKEND_CLASSES)
        if unknown:
            raise ValueError(
                f"mixed backend binds unknown local backends {sorted(unknown)}"
            )
        self._impls = {
            name: LOCAL_BACKEND_CLASSES[name](engine, shared=self)
            for name in sorted(names)
        }
        self._default = self._impls[tuning.default_backend]

    def spmm(self, m):
        return self._default.spmm(m)

    def _group_aggregate(self, leader, m_p, stage_inputs):
        name = self._bindings.get(leader, self._tuning.default_backend)
        return self._impls[name].aggregate_ema_grouped(m_p, stage_inputs)

    def transient_elements(self) -> int:
        # one chunk's scratch peaks at the widest sub-impl's slice
        return max(impl.transient_elements() for impl in self._impls.values())
