"""EngineBackend interface: how a TemplatePlan binds to devices and runs.

The third layer of the plan -> cost -> exec pipeline.  A backend owns:

* **operand construction** — its device-resident graph representation,
  built once in ``__init__`` (edge lists, ELL/SELL tables, dense
  adjacency, Pallas blocked operands, or the sharded edge partition +
  collective schedule for the mesh backend);
* **the DP execution** — :meth:`EngineBackend.counts_for_colors` maps a
  ``(B, n)`` chunk of colorings to ``(B, T)`` raw colorful totals by
  walking the engine's :class:`~repro.plan.ir.TemplatePlan` (stages,
  liveness, shared-passive groups — the backend never re-derives a
  schedule).  The per-stage primitive is :meth:`aggregate_ema`: ONE fused
  neighbor-aggregate + eMA step that never materializes the full
  ``A_G @ M_p`` product;
* **the memory-model geometry** — :meth:`transient_elements` /
  :meth:`resident_elements` feed the operand measurements into the plan
  layer's :class:`~repro.plan.cost.CostModel` formulas.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.colorsets import bucketed_split_entries

__all__ = [
    "StageTables",
    "BagStageTables",
    "EngineBackend",
    "build_stage_tables",
    "build_bag_tables",
    "make_backend",
]


def make_backend(engine, **kwargs) -> "EngineBackend":
    """Bind ``engine``'s resolved backend name to an implementation.

    ``kwargs`` carries the backend-specific knobs the engine collected
    (``spmm_fn``, ``block_size``, mesh parameters).  Imports are local so
    this module stays import-cycle-safe whichever package loads first.
    """
    from .local import (
        BlockedEllBackend,
        CustomBackend,
        DenseBackend,
        EdgesBackend,
        EllBackend,
        MixedBackend,
        SellBackend,
    )
    from .mesh import MeshBackend

    name = engine.backend
    if name == "custom":
        return CustomBackend(engine, kwargs["spmm_fn"])
    if name == "mixed":
        return MixedBackend(engine, kwargs.get("tuning"))
    if name == "edges":
        return EdgesBackend(engine)
    if name == "ell":
        return EllBackend(engine)
    if name == "sell":
        return SellBackend(engine)
    if name == "dense":
        return DenseBackend(engine)
    if name == "blocked":
        return BlockedEllBackend(engine, block_size=kwargs.get("block_size", 256))
    if name == "mesh":
        return MeshBackend(
            engine,
            kwargs.get("mesh"),
            column_batch=kwargs.get("column_batch"),
            ema_mode=kwargs.get("ema_mode", "streamed"),
            gather_dtype=kwargs.get("gather_dtype"),
            balance_degrees=kwargs.get("balance_degrees", True),
            comm=kwargs.get("mesh_comm"),
        )
    raise ValueError(f"unknown backend {name!r}")


@dataclass(frozen=True)
class StageTables:
    """Split tables for one DP stage, in both shapes the fused pipeline needs.

    ``idx_a_host`` / ``idx_p_host`` are the plain ``(n_out, n_splits)`` rank
    tables, kept host-side: the fused Pallas kernel expands them per
    coloring chunk at trace time (``spmm_ema_batched``).  ``batches`` are
    the same entries re-bucketed by passive-column batch and shipped to the
    device (:func:`repro.core.colorsets.bucketed_split_entries`) for the
    streamed pure-JAX executor.  De-duplicated across stages by
    ``(k, m, m_a)``.
    """

    n_out: int
    column_batch: int
    idx_a_host: np.ndarray
    idx_p_host: np.ndarray
    batches: Tuple[Tuple[int, int, jnp.ndarray, jnp.ndarray, jnp.ndarray], ...]


def build_stage_tables(plan, column_batch: int) -> Dict[Tuple[int, int], StageTables]:
    """Bind a :class:`~repro.plan.ir.TemplatePlan`'s split tables to the
    device at one fused-slice width.

    Returns ``(plan_idx, sub_idx) -> StageTables`` for every non-leaf
    stage of every counting plan (duplicates included — aliases of one
    shared, de-duplicated-by-``(k, m, m_a)`` device table), so executors
    can look tables up by the stage address the schedule hands them.
    """
    cache: Dict[Tuple[int, int, int], StageTables] = {}
    out: Dict[Tuple[int, int], StageTables] = {}
    for p_idx, cplan in enumerate(plan.counting_plans):
        if cplan.partition is None:
            continue  # bag plans bind through build_bag_tables
        for i, table in enumerate(cplan.tables):
            if table is None:
                continue
            key = (table.k, table.m, table.m_a)
            if key not in cache:
                cache[key] = StageTables(
                    n_out=table.n_out,
                    column_batch=column_batch,
                    idx_a_host=table.idx_a,
                    idx_p_host=table.idx_p,
                    batches=tuple(
                        (
                            lo,
                            width,
                            jnp.asarray(ia),
                            jnp.asarray(ip),
                            None if va is None else jnp.asarray(va),
                        )
                        for lo, width, ia, ip, va in bucketed_split_entries(
                            table, column_batch
                        )
                    ),
                )
            out[(p_idx, i)] = cache[key]
    return out


@dataclass(frozen=True)
class BagStageTables:
    """Device-resident color tables for one bag op.

    ``extend`` ops carry a :class:`~repro.core.colorsets.SplitTable` with
    ``m_a = 1`` (the new vertex's one-hot color against the input's
    colorsets); ``join`` ops carry a
    :class:`~repro.core.colorsets.UnionSplitTable` (color-subset
    convolution).  Both reduce to the same gather-FMA loop over the term
    axis, so the executor only needs ``(idx_a, idx_p, n_out, n_terms)``.
    """

    kind: str  # "extend" | "join"
    n_out: int
    n_terms: int
    idx_a: jnp.ndarray  # (n_out, n_terms) int32, device
    idx_p: jnp.ndarray  # (n_out, n_terms) int32, device


def build_bag_tables(plan) -> Dict[Tuple[int, int], BagStageTables]:
    """Bind every bag plan's extend/join tables to the device.

    Returns ``(plan_idx, op_idx) -> BagStageTables`` for every extend and
    join op of every bag counting plan, de-duplicated by table identity so
    shared widths ship once (mirror of :func:`build_stage_tables` for the
    tree family).
    """
    cache: Dict[Tuple, BagStageTables] = {}
    out: Dict[Tuple[int, int], BagStageTables] = {}
    for p_idx, cplan in enumerate(plan.counting_plans):
        if cplan.partition is not None:
            continue
        for i, op in enumerate(cplan.bag_program.ops):
            table = cplan.tables[i]
            if table is None:
                continue
            if op.kind == "extend":
                key = ("extend", table.k, table.m, table.m_a)
                n_terms = table.n_splits
            else:
                key = ("join", table.k, table.m1, table.m2, table.overlap)
                n_terms = table.n_pairs
            if key not in cache:
                cache[key] = BagStageTables(
                    kind=op.kind,
                    n_out=table.n_out,
                    n_terms=n_terms,
                    idx_a=jnp.asarray(table.idx_a),
                    idx_p=jnp.asarray(table.idx_p),
                )
            out[(p_idx, i)] = cache[key]
    return out


class EngineBackend:
    """One fused SpMM+eMA execution strategy behind ``CountingEngine``.

    Backends keep a reference to the engine façade, which exposes the
    bound :class:`~repro.plan.ir.TemplatePlan` (``engine.plan_ir``), the
    :class:`~repro.plan.cost.CostModel` (``engine.cost``), the dtype
    policy, and the observability counters.
    """

    name: str = "abstract"

    #: Which fault-injection sites apply at this backend's launch boundary
    #: (``repro.testing.faults``; checked by ``CountingEngine.
    #: count_keys_chunk`` — Python-level, outside the jitted body).  The
    #: mesh backend adds ``"collective"`` for its all-gather dispatch.
    fault_sites: Tuple[str, ...] = ("launch",)

    def __init__(self, engine):
        self.engine = engine

    # -- execution ----------------------------------------------------------

    def aggregate_ema(
        self, m_p: jnp.ndarray, m_a: jnp.ndarray, tables: StageTables
    ) -> jnp.ndarray:
        """Fused per-stage step: ``(n, B, C_p), (n, B, C_a) -> (n, B, n_out)``
        in accum dtype, without materializing ``A_G @ M_p``."""
        raise NotImplementedError

    def aggregate_ema_grouped(
        self, m_p: jnp.ndarray, stage_inputs: Sequence[Tuple[jnp.ndarray, StageTables]]
    ) -> List[jnp.ndarray]:
        """Run several stages that share the passive state ``m_p``.

        Backends that can share the neighbor aggregation across the group
        override this (the streamed local pipeline computes each passive
        column-batch aggregate once for the whole group); the default is
        the unshared per-stage loop.
        """
        return [self.aggregate_ema(m_p, m_a, tables) for m_a, tables in stage_inputs]

    def counts_for_colors(self, colors: jnp.ndarray) -> jnp.ndarray:
        """``(B, n)`` colorings -> ``(B, T)`` un-normalized colorful totals."""
        raise NotImplementedError

    def counts_for_keys_chunk(self, keys_chunk: jnp.ndarray) -> jnp.ndarray:
        """``(B, 2)`` PRNG keys -> ``(B, T)`` normalized estimates.

        The coloring draw is identical across backends (one ``randint`` per
        key over the *original* vertex ids), so the same keys produce the
        same colorings — and therefore fp-tolerance-comparable estimates —
        on every backend, mesh included.
        """
        eng = self.engine
        colors = jax.vmap(
            lambda key: jax.random.randint(key, (eng.graph.n,), 0, eng.k)
        )(keys_chunk)
        return self.counts_for_colors(colors) * eng._norm_factors[None, :]

    def make_run_fn(self) -> Callable:
        """One jit for the whole run: ``lax.map`` over key chunks.

        Tracing bumps the engine's ``trace_count`` (a Python side effect
        runs once per trace, i.e. per new compilation), so tests and the
        serving cache can assert that a warm engine never re-compiles.
        """
        engine = self.engine

        def run(keys):
            engine.trace_count += 1
            return jax.lax.map(self.counts_for_keys_chunk, keys)

        return jax.jit(run)

    # -- memory-model geometry ----------------------------------------------

    def transient_elements(self) -> int:
        """Widest per-stage scratch one coloring needs, in store-dtype
        elements — the cost-model formula fed with this backend's built
        operand geometry."""
        eng = self.engine
        return eng.cost.transient_elements(self.name, eng.column_batch)

    def resident_elements(self) -> int:
        """Live M-matrix elements one coloring keeps resident."""
        return self.engine.cost.resident_elements()

    def bytes_per_coloring(self) -> int:
        """Calibrated live bytes one coloring contributes to a chunk."""
        return self.engine.cost.bytes_per_coloring(
            self.transient_elements(), self.resident_elements()
        )
