"""Mesh execution backend: the fused DP under ``shard_map`` on a device mesh.

Wraps the column-batched all-gather SpMM and streamed eMA of
:mod:`repro.core.distributed`: vertices are 1-D row-partitioned across
every mesh axis, each DP stage broadcasts the passive M matrix in
``column_batch``-column slices (each collective serving all ``B`` chunked
colorings at once), and the eMA stays vertex-local.  The DP schedule —
canonical sharing and the liveness plan — comes from the engine's bound
:class:`~repro.plan.ir.TemplatePlan`; split tables are built once per plan
at construction, de-duplicated by ``(k, m, m_a)``, and closure-captured by
the shard_map program.

Each stage's collective runs in one of two modes, decided at plan time by
``CostModel.comm_schedule`` (overridable via ``REPRO_MESH_COMM`` or the
``mesh_comm=`` engine kwarg):

* ``blocking`` — one ``all_gather`` per column batch, then the edge
  segment-sum consumes the full buffer (the paper's synchronous scheme);
* ``pipelined`` — the double-buffered ring: per-shard row slices of the
  batch circulate via ``lax.ppermute``, the next slice in flight while the
  current one's edge bucket is consumed as a partial segment-sum.  Counts
  are bit-exact vs blocking: on bucketed single-axis meshes BOTH modes
  fold the same per-source-shard partial sums in the same ring order,
  blocking merely reading each owner's rows out of its one all-gathered
  buffer (see ``repro.core.distributed.make_batched_count_fn``).
"""

from __future__ import annotations

from typing import Optional

import numpy as np

import jax.numpy as jnp

from .base import EngineBackend
from .select import mesh_comm_mode

__all__ = ["MeshBackend", "BagPlanUnsupported"]


class BagPlanUnsupported(NotImplementedError):
    """The mesh backend cannot execute bag (non-tree) plans.

    Structured for the serving layer: ``invalid_request`` routes it to the
    ``invalid`` failure family (``serve.resilience.classify_failure``) — a
    malformed *query*, not a poisoned engine key, so quarantine never
    strikes for it.
    """

    invalid_request = True

    def __init__(self, decomposition_widths):
        self.decomposition_widths = tuple(decomposition_widths)
        super().__init__(
            "backend='mesh' does not execute bag (non-tree) plans yet — "
            f"plan decomposition widths {self.decomposition_widths} include "
            "non-tree bags (width > 1); multi-axis bag states need a 2-D "
            "sharding story. Use a local backend for non-tree templates."
        )


class MeshBackend(EngineBackend):
    """Distributed backend (see module docstring).

    Args (via ``CountingEngine(...)``):
      mesh: the ``jax.sharding.Mesh`` to run on (required).
      column_batch: passive columns per collective; ``None`` auto-sizes via
        the cost model (``min(128, max passive columns)``).
      ema_mode: ``"streamed"`` (default — fused per-batch SpMM->eMA, the B
        matrix never materializes) or ``"loop"`` (paper-faithful Algorithm
        5 with the SpMM product memoized per canonical passive form).
      gather_dtype: optional wire dtype for compressed collectives
        (e.g. ``jnp.bfloat16``); accumulation stays fp32.
      balance_degrees: relabel vertices round-robin by degree rank before
        sharding (spreads hub rows; colorings are permuted to follow, so
        counts are unchanged).  Default True: the always-on src-bucketed
        edge layout pads every shard's buckets to the largest one, and on
        skewed graphs an unbalanced hub shard inflates that stride several
        fold — balancing makes the bucketed layout *smaller* than the
        unbucketed unbalanced one.
      comm: ``"blocking"`` | ``"pipelined"`` | ``None`` (auto).  Explicit
        beats the ``REPRO_MESH_COMM`` env override beats the cost model's
        per-stage ``comm_schedule`` decision.  A forced ``pipelined`` that
        the geometry cannot support (single shard, multi-axis mesh,
        non-streamed eMA) falls back to blocking with the reason recorded
        in ``describe_comm()``.
    """

    name = "mesh"

    # every chunk launch dispatches collectives, so the mesh backend
    # exposes the extra failure surface to the fault seam; the pipelined
    # path visits the site once per ring step (collective_dispatches)
    fault_sites = ("launch", "collective")

    def __init__(
        self,
        engine,
        mesh,
        *,
        column_batch: Optional[int] = None,
        ema_mode: str = "streamed",
        gather_dtype=None,
        balance_degrees: bool = True,
        comm: Optional[str] = None,
    ):
        super().__init__(engine)
        if engine.plan_ir.has_bag_stages:
            raise BagPlanUnsupported(engine.plan_ir.decomposition_widths)
        if mesh is None:
            raise ValueError("backend='mesh' needs a jax.sharding.Mesh (mesh=...)")
        if comm not in (None, "blocking", "pipelined"):
            raise ValueError(f"unknown mesh comm mode {comm!r}")
        from repro.core.distributed import make_batched_count_fn, shard_graph

        self.mesh = mesh
        self.ema_mode = ema_mode
        self.gather_dtype = gather_dtype
        n_shards = int(np.prod(mesh.devices.shape))
        # always the src-bucketed layout: blocking and pipelined engines
        # then run over literally the same edge arrays (the precondition
        # for their bit-exact A/B) and either mode can bind per stage
        self.sharded = shard_graph(
            engine.graph, n_shards, balance_degrees=balance_degrees,
            bucket_by_src=True,
        )
        if column_batch is None:
            column_batch = engine.cost.pick_mesh_column_batch()
        self.column_batch = int(column_batch)

        # -- comm resolution: explicit > env > cost model ---------------------
        forced = comm
        source = "explicit" if comm is not None else None
        if forced is None:
            forced = mesh_comm_mode()
            if forced is not None:
                source = "env"
        if source is None:
            source = "cost-model"
        eligible, why = self._pipeline_eligibility(n_shards)
        self.comm_fallback_reason = None
        if forced == "pipelined" and not eligible:
            self.comm_fallback_reason = why
            forced = "blocking"
        schedules = engine.cost.mesh_comm_schedules(
            n_shards,
            column_batch=self.column_batch,
            rows_per_shard=self.sharded.rows_per_shard,
            edges_per_shard=self.sharded.edges_per_shard,
            forced=forced,
        )
        if forced is None and not eligible:
            # the auto decision may not pick pipelined for ineligible
            # geometry either — re-force blocking and record why
            if any(s.mode == "pipelined" for s in schedules.values()):
                self.comm_fallback_reason = why
            schedules = engine.cost.mesh_comm_schedules(
                n_shards,
                column_batch=self.column_batch,
                rows_per_shard=self.sharded.rows_per_shard,
                edges_per_shard=self.sharded.edges_per_shard,
                forced="blocking",
            )
        self.comm_source = source
        self.comm_schedules = schedules
        # leader decisions expand to every member stage (one sweep each on
        # the mesh target; members inherit their leader's mode)
        stage_modes = {}
        for leader, sched in schedules.items():
            for member in engine.plan_ir.exec_groups[leader]:
                stage_modes[member] = sched.mode
        self.stage_comm_modes = stage_modes
        any_pipelined = any(m == "pipelined" for m in stage_modes.values())
        self.comm = "pipelined" if any_pipelined else "blocking"
        #: fault-seam dispatch multiplicity: the pipelined path crosses the
        #: ``collective`` injection site once per ring step
        self.collective_dispatches = n_shards if any_pipelined else 1

        self._count_fn = make_batched_count_fn(
            engine.plans,
            mesh,
            self.sharded.n_padded,
            self.sharded.edges_per_shard,
            column_batch=self.column_batch,
            ema_mode=ema_mode,
            gather_dtype=gather_dtype,
            plan_ir=engine.plan_ir,
            store_dtype=engine.policy.store_dtype,
            accum_dtype=engine.policy.accum_dtype,
            comm_mode="blocking",
            comm_schedule=stage_modes,
            bucket_stride=self.sharded.bucket_stride,
        )
        self._src = jnp.asarray(self.sharded.src)
        self._dst_local = jnp.asarray(self.sharded.dst_local)
        self._edge_mask = jnp.asarray(self.sharded.edge_mask)
        # colorings follow the degree-balancing relabel (scatter old -> new;
        # new ids range over [0, n_padded) with pad slots interleaved)
        self._perm = (
            jnp.asarray(self.sharded.perm) if self.sharded.perm is not None else None
        )

    def _pipeline_eligibility(self, n_shards: int):
        """Whether this geometry can run the ring at all — ``(ok, why)``."""
        if self.ema_mode != "streamed":
            return False, (
                f"ema_mode={self.ema_mode!r} — the ring consumes slices "
                "inside the fused streamed sweep only"
            )
        if len(self.mesh.axis_names) != 1:
            return False, (
                f"mesh axes {tuple(self.mesh.axis_names)} — the ring "
                "circulates a single axis"
            )
        if n_shards < 2:
            return False, "single shard — nothing to overlap"
        return True, None

    def describe_comm(self) -> dict:
        """The resolved comm plan, for ``describe()`` / the plan
        inspector."""
        out = {
            "mode": self.comm,
            "source": self.comm_source,
            "collective_dispatches": self.collective_dispatches,
            "bucket_stride": self.sharded.bucket_stride,
            "schedule": [s.describe() for _, s in sorted(self.comm_schedules.items())],
        }
        if self.comm_fallback_reason:
            out["fallback_reason"] = self.comm_fallback_reason
        return out

    def counts_for_colors(self, colors: jnp.ndarray) -> jnp.ndarray:
        colors = jnp.asarray(colors)
        if self._perm is not None:
            padded = jnp.zeros((colors.shape[0], self.sharded.n_padded), colors.dtype)
            colors = padded.at[:, self._perm].set(colors)
        else:
            pad = self.sharded.n_padded - colors.shape[1]
            if pad:
                colors = jnp.pad(colors, ((0, 0), (0, pad)))
        return self._count_fn(colors, self._src, self._dst_local, self._edge_mask)

    # -- memory-model geometry (per shard!) ----------------------------------

    def transient_elements(self) -> int:
        """Per-shard collective scratch.

        Blocking: one all-gathered column batch (``n_padded *
        column_batch``) plus the per-shard edge message gather
        (``edges_per_shard * column_batch``).  Pipelined: the gathered
        buffer shrinks to the two ring slots (``2 * rows_per_shard *
        column_batch``) and the edge scratch to one source-shard bucket's
        partial messages (``edges_per_shard / n_shards``, dead after each
        per-bucket segment-sum) — the per-shard byte win the fig13 rows
        track.
        """
        if self.comm == "pipelined":
            return self.engine.cost.mesh_transient_elements(
                2 * self.sharded.rows_per_shard,
                max(1, self.sharded.edges_per_shard // self.sharded.n_shards),
                self.column_batch,
            )
        return self.engine.cost.mesh_transient_elements(
            self.sharded.n_padded, self.sharded.edges_per_shard, self.column_batch
        )

    def resident_elements(self) -> int:
        """Per-shard live DP state: local rows times the liveness-aware
        peak of padded M columns under the shared multi-template schedule."""
        return self.engine.cost.mesh_resident_elements(
            self.sharded.rows_per_shard, self.column_batch, self.ema_mode
        )
