"""Mesh execution backend: the fused DP under ``shard_map`` on a device mesh.

Wraps the column-batched all-gather SpMM and streamed eMA of
:mod:`repro.core.distributed`: vertices are 1-D row-partitioned across
every mesh axis, each DP stage all-gathers the passive M matrix in
``column_batch``-column slices (each collective serving all ``B`` chunked
colorings at once), and the eMA stays vertex-local.  The DP schedule —
canonical sharing and the liveness plan — comes from the engine's bound
:class:`~repro.plan.ir.TemplatePlan`; split tables are built once per plan
at construction, de-duplicated by ``(k, m, m_a)``, and closure-captured by
the shard_map program.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

import jax.numpy as jnp

from .base import EngineBackend

__all__ = ["MeshBackend"]


class MeshBackend(EngineBackend):
    """Distributed backend (see module docstring).

    Args (via ``CountingEngine(...)``):
      mesh: the ``jax.sharding.Mesh`` to run on (required).
      column_batch: passive columns per all-gather; ``None`` auto-sizes via
        the cost model (``min(128, max passive columns)``).
      ema_mode: ``"streamed"`` (default — fused per-batch SpMM->eMA, the B
        matrix never materializes) or ``"loop"`` (paper-faithful Algorithm
        5 with the SpMM product memoized per canonical passive form).
      gather_dtype: optional wire dtype for compressed all-gathers
        (e.g. ``jnp.bfloat16``); accumulation stays fp32.
      balance_degrees: relabel vertices round-robin by degree rank before
        sharding (spreads hub rows; colorings are permuted to follow, so
        counts are unchanged).
    """

    name = "mesh"

    # every chunk launch dispatches all-gather collectives, so the mesh
    # backend exposes the extra failure surface to the fault seam
    fault_sites = ("launch", "collective")

    def __init__(
        self,
        engine,
        mesh,
        *,
        column_batch: Optional[int] = None,
        ema_mode: str = "streamed",
        gather_dtype=None,
        balance_degrees: bool = False,
    ):
        super().__init__(engine)
        if engine.plan_ir.has_bag_stages:
            raise NotImplementedError(
                "backend='mesh' does not execute bag (non-tree) plans yet — "
                "multi-axis bag states need a 2-D sharding story; use a "
                "local backend for non-tree templates"
            )
        if mesh is None:
            raise ValueError("backend='mesh' needs a jax.sharding.Mesh (mesh=...)")
        from repro.core.distributed import make_batched_count_fn, shard_graph

        self.mesh = mesh
        self.ema_mode = ema_mode
        self.gather_dtype = gather_dtype
        n_shards = int(np.prod(mesh.devices.shape))
        self.sharded = shard_graph(engine.graph, n_shards, balance_degrees=balance_degrees)
        if column_batch is None:
            column_batch = engine.cost.pick_mesh_column_batch()
        self.column_batch = int(column_batch)
        self._count_fn = make_batched_count_fn(
            engine.plans,
            mesh,
            self.sharded.n_padded,
            self.sharded.edges_per_shard,
            column_batch=self.column_batch,
            ema_mode=ema_mode,
            gather_dtype=gather_dtype,
            plan_ir=engine.plan_ir,
            store_dtype=engine.policy.store_dtype,
            accum_dtype=engine.policy.accum_dtype,
        )
        self._src = jnp.asarray(self.sharded.src)
        self._dst_local = jnp.asarray(self.sharded.dst_local)
        self._edge_mask = jnp.asarray(self.sharded.edge_mask)
        # colorings follow the degree-balancing relabel (scatter old -> new;
        # new ids range over [0, n_padded) with pad slots interleaved)
        self._perm = (
            jnp.asarray(self.sharded.perm) if self.sharded.perm is not None else None
        )

    def counts_for_colors(self, colors: jnp.ndarray) -> jnp.ndarray:
        colors = jnp.asarray(colors)
        if self._perm is not None:
            padded = jnp.zeros((colors.shape[0], self.sharded.n_padded), colors.dtype)
            colors = padded.at[:, self._perm].set(colors)
        else:
            pad = self.sharded.n_padded - colors.shape[1]
            if pad:
                colors = jnp.pad(colors, ((0, 0), (0, pad)))
        return self._count_fn(colors, self._src, self._dst_local, self._edge_mask)

    # -- memory-model geometry (per shard!) ----------------------------------

    def transient_elements(self) -> int:
        """Per-shard collective scratch: one all-gathered column batch
        (``n_padded * column_batch``) plus the per-shard edge message gather
        (``edges_per_shard * column_batch``)."""
        return self.engine.cost.mesh_transient_elements(
            self.sharded.n_padded, self.sharded.edges_per_shard, self.column_batch
        )

    def resident_elements(self) -> int:
        """Per-shard live DP state: local rows times the liveness-aware
        peak of padded M columns under the shared multi-template schedule."""
        return self.engine.cost.mesh_resident_elements(
            self.sharded.rows_per_shard, self.column_batch, self.ema_mode
        )
