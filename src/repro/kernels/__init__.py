"""Pallas TPU kernels for the paper's two compute hot-spots (DESIGN.md §2):

* ``spmm_blocked/`` — "CSC-Split, TPU edition": blocked-ELL SpMM over
  (dst-block, src-block) tile pairs; MXU one-hot gather/scatter or VPU
  edge-loop inner modes.  kernel.py (pl.pallas_call + BlockSpec) / ops.py
  (jit wrapper + host preprocessing) / ref.py (pure-jnp oracle).
* ``ema/`` — fused eMA count update in the paper's column-major layout
  (vertex axis on lanes, split tables in SMEM via scalar prefetch).

Both validated against their oracles over shape/dtype sweeps in interpret
mode (tests/test_kernels.py).
"""
