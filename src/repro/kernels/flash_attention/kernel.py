"""Causal flash attention Pallas TPU kernel (prefill shape).

Classic FlashAttention-2 structure mapped to TPU tiles: grid over
``(batch*heads, q_blocks, kv_blocks)`` with the kv axis innermost; the
running max / normalizer / un-normalized accumulator live in VMEM scratch
and persist across kv steps; causal blocks strictly above the diagonal are
skipped with ``pl.when``.  Softmax statistics are fp32 regardless of the
input dtype; both matmuls hit the MXU with ``preferred_element_type=f32``.

This is the TPU analogue of the paper's methodology applied to the LM archs'
hot-spot: restructure the memory-bound op so the working set tiles through
VMEM exactly once (scores never round-trip HBM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_kernel", "flash_attention_call"]

_NEG_INF = -1e30


def flash_attention_kernel(
    q_ref, k_ref, v_ref,          # (1, bq, d), (1, bk, d), (1, bk, dv)
    o_ref,                        # (1, bq, dv)
    m_scr, l_scr, acc_scr,        # VMEM scratch: (bq, 1), (bq, 1), (bq, dv)
    *,
    scale: float,
    block_q: int,
    block_k: int,
    causal: bool,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    n_k = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def compute():
        q = q_ref[0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0].astype(jnp.float32)  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)

        if causal:
            q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            s = jnp.where(q_pos >= k_pos, s, _NEG_INF)

        m_prev = m_scr[...]                       # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)           # (bq, 1)
        p = jnp.exp(s - m_new)                    # (bq, bk)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = m_new

    if causal:
        # skip blocks strictly above the diagonal
        @pl.when(ki * block_k <= qi * block_q + (block_q - 1))
        def _():
            compute()
    else:
        compute()

    @pl.when(ki == n_k - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_call(
    q: jnp.ndarray,  # (bh, sq, d)
    k: jnp.ndarray,  # (bh, sk, d)
    v: jnp.ndarray,  # (bh, sk, dv)
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    bh, sq, d = q.shape
    _, sk, dv = v.shape
    if sq % block_q or sk % block_k:
        raise ValueError(f"seq lens ({sq},{sk}) must be multiples of blocks ({block_q},{block_k})")
    scale = 1.0 / np.sqrt(d)
    grid = (bh, sq // block_q, sk // block_k)

    kernel = functools.partial(
        flash_attention_kernel,
        scale=scale,
        block_q=block_q,
        block_k=block_k,
        causal=causal,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_k, dv), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dv), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
