"""jit'd GQA-aware wrapper for the flash attention kernel.

Accepts model-layout tensors ``q: (b, s, h, d)``, ``k/v: (b, s, h_kv, d)``
(post-RoPE), broadcasts kv heads to query groups, pads sequence lengths to
block multiples, and restores the layout.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_call

__all__ = ["flash_attention"]


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(
    q: jnp.ndarray,  # (b, sq, h, d)
    k: jnp.ndarray,  # (b, sk, h_kv, d)
    v: jnp.ndarray,  # (b, sk, h_kv, dv)
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, sq, h, d = q.shape
    _, sk, h_kv, dv = v.shape
    group = h // h_kv
    k = jnp.repeat(k, group, axis=2)
    v = jnp.repeat(v, group, axis=2)

    def to_bh(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, x.shape[1], x.shape[3])

    qb, kb, vb = to_bh(q), to_bh(k), to_bh(v)
    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k
    if pad_q:
        qb = jnp.pad(qb, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kb = jnp.pad(kb, ((0, 0), (0, pad_k), (0, 0)))
        vb = jnp.pad(vb, ((0, 0), (0, pad_k), (0, 0)))
    out = flash_attention_call(
        qb, kb, vb, causal=causal, block_q=block_q, block_k=block_k, interpret=interpret
    )
    out = out[:, :sq]
    return out.reshape(b, h, sq, dv).transpose(0, 2, 1, 3)
