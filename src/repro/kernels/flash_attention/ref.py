"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["attention_ref"]


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, causal: bool = True) -> jnp.ndarray:
    """(bh, sq, d) x (bh, sk, d) x (bh, sk, dv) -> (bh, sq, dv), fp32 softmax."""
    scale = 1.0 / np.sqrt(q.shape[-1])
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if causal:
        sq, sk = s.shape[-2:]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bke->bqe", p, v.astype(jnp.float32)).astype(q.dtype)
