"""Fused eMA (element-wise multiply-add) Pallas TPU kernel.

.. deprecated::
    Superseded by :mod:`repro.kernels.spmm_ema`, which fuses the SpMM half
    into the same kernel so the aggregate product ``B`` never reaches HBM
    (this kernel still reads a fully materialized ``B``).  The engine's
    ``blocked`` backend routes through ``spmm_ema``; this module is kept
    only as an eMA-in-isolation reference for tests and kernel benchmarks.

Computes the count-update stage of SUBGRAPH2VEC (Algorithm 5, line 13):

    M_s[o, :] = sum_t  M_a[idx_a[o, t], :] * B[idx_p[o, t], :]

in the **transposed** ``(colorsets, vertices)`` layout — the paper's
column-major design (§V-B): the vectorized axis is the vertex axis (lanes,
length |V|), the combinatorial axes (output color set ``o``, split ``t``) are
loops.  Everything is vertex-local: no neighbor traversal, no HBM gathers —
``M_a`` and ``B`` tiles are VMEM-resident per vertex tile, the split tables
live in SMEM (scalar prefetch), and each inner step is one VPU FMA of a full
vertex tile.

Grid: ``(num_out_tiles, num_vertex_tiles)``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ema_kernel", "ema_call"]


def ema_kernel(
    # scalar prefetch (SMEM)
    idx_a_ref, idx_p_ref,
    # inputs (VMEM)
    ma_ref, b_ref,
    # output
    out_ref,
    *,
    out_tile: int,
    n_splits: int,
):
    o_base = pl.program_id(0) * out_tile
    v_tile = ma_ref.shape[1]

    for oo in range(out_tile):  # static unroll over the output tile rows

        def body(t, acc):
            ia = idx_a_ref[o_base + oo, t]
            ip = idx_p_ref[o_base + oo, t]
            ra = ma_ref[pl.dslice(ia, 1), :]  # (1, v_tile) dynamic row
            rp = b_ref[pl.dslice(ip, 1), :]
            return acc + ra * rp

        acc = jax.lax.fori_loop(
            0, n_splits, body, jnp.zeros((1, v_tile), dtype=out_ref.dtype)
        )
        out_ref[pl.dslice(oo, 1), :] = acc


def ema_call(
    ma_t: jnp.ndarray,    # (Ca_pad, n_pad)
    b_t: jnp.ndarray,     # (Cp_pad, n_pad)
    idx_a: jnp.ndarray,   # (n_out_pad, n_splits) int32
    idx_p: jnp.ndarray,   # (n_out_pad, n_splits) int32
    *,
    out_tile: int = 8,
    vertex_tile: int = 1024,
    interpret: bool = False,
) -> jnp.ndarray:
    """Transposed-layout fused eMA.  ``n_out_pad % out_tile == 0`` and
    ``n_pad % vertex_tile == 0`` (pad host-side)."""
    n_out_pad, n_splits = idx_a.shape
    ca, n_pad = ma_t.shape
    if n_out_pad % out_tile:
        raise ValueError(f"n_out={n_out_pad} not a multiple of out_tile={out_tile}")
    if n_pad % vertex_tile:
        raise ValueError(f"n={n_pad} not a multiple of vertex_tile={vertex_tile}")
    grid = (n_out_pad // out_tile, n_pad // vertex_tile)

    kernel = functools.partial(ema_kernel, out_tile=out_tile, n_splits=n_splits)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ca, vertex_tile), lambda o, v, ia, ip: (0, v)),
            pl.BlockSpec((b_t.shape[0], vertex_tile), lambda o, v, ia, ip: (0, v)),
        ],
        out_specs=pl.BlockSpec((out_tile, vertex_tile), lambda o, v, ia, ip: (o, v)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_out_pad, n_pad), ma_t.dtype),
        interpret=interpret,
    )(idx_a, idx_p, ma_t, b_t)
