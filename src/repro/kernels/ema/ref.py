"""Pure-jnp oracle for the fused eMA kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ema_ref", "ema_ref_transposed"]


def ema_ref(m_a: jnp.ndarray, b: jnp.ndarray, idx_a: jnp.ndarray, idx_p: jnp.ndarray) -> jnp.ndarray:
    """Row-major oracle: ``out[:, o] = sum_t M_a[:, idx_a[o,t]] * B[:, idx_p[o,t]]``."""
    n = m_a.shape[0]
    n_out, n_splits = idx_a.shape

    def body(t, acc):
        return acc + jnp.take(m_a, idx_a[:, t], axis=1) * jnp.take(b, idx_p[:, t], axis=1)

    return jax.lax.fori_loop(0, n_splits, body, jnp.zeros((n, n_out), dtype=m_a.dtype))


def ema_ref_transposed(ma_t, b_t, idx_a, idx_p) -> jnp.ndarray:
    return ema_ref(ma_t.T, b_t.T, idx_a, idx_p).T
