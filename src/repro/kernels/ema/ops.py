"""jit'd wrapper around the fused eMA Pallas kernel (row-major interface).

.. deprecated:: superseded by :mod:`repro.kernels.spmm_ema` (SpMM+eMA in one
   kernel); kept as an eMA-in-isolation reference for tests/benchmarks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import ema_call

__all__ = ["ema_blocked"]


def _pad_to(x: jnp.ndarray, axis: int, multiple: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("out_tile", "vertex_tile", "interpret"))
def ema_blocked(
    m_a: jnp.ndarray,   # (n, Ca)
    b: jnp.ndarray,     # (n, Cp)
    idx_a: jnp.ndarray,  # (n_out, S) int32
    idx_p: jnp.ndarray,  # (n_out, S) int32
    *,
    out_tile: int = 8,
    vertex_tile: int = 1024,
    interpret: bool = False,
) -> jnp.ndarray:
    """``M_s = eMA(M_a, B)`` with row-major ``(n, C)`` orientation."""
    n, _ = m_a.shape
    n_out = idx_a.shape[0]
    ma_t = _pad_to(_pad_to(m_a.T, 0, 8), 1, vertex_tile)
    b_t = _pad_to(_pad_to(b.T, 0, 8), 1, vertex_tile)
    idx_a_p = _pad_to(idx_a.astype(jnp.int32), 0, out_tile)
    idx_p_p = _pad_to(idx_p.astype(jnp.int32), 0, out_tile)
    out_t = ema_call(
        ma_t, b_t, idx_a_p, idx_p_p,
        out_tile=out_tile, vertex_tile=vertex_tile, interpret=interpret,
    )
    return out_t[:n_out, :n].T
