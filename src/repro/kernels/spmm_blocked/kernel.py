"""Blocked-ELL SpMM Pallas TPU kernel — "CSC-Split, TPU edition".

Computes ``B = A_G @ M`` for a 0/1 sparse adjacency ``A_G`` and a dense count
matrix ``M``, with both ``M`` and ``B`` stored **transposed** ``(C, n)`` —
the TPU mapping of the paper's column-major layout (§V-B): the vectorized
axis is the vertex axis (lanes), the combinatorial color-set axis is tiled.

Sparse structure (preprocessed host-side, ``repro.core.graph.build_blocked_ell``):
vertices are tiled into blocks of ``block_size``; edges are grouped by
(dst-block, src-block) pairs, padded to ``pair_capacity``, and pairs are
sorted by destination block.  Per grid step the kernel holds one source tile
of ``M^T`` and one destination accumulator tile of ``B^T`` in VMEM.

Two inner-loop strategies:

* ``mode="mxu"`` (default) — gather/scatter as two MXU matmuls per edge
  chunk: ``acc += (M_tile @ onehot_srcᵀ) @ onehot_dst``.  One-hot matrices are
  built in-register from an iota comparison.  This converts the irregular
  per-edge access into dense systolic work — the TPU analogue of the paper's
  observation that SpMM beats pointer chasing even at higher nominal FLOPs.
* ``mode="loop"`` — per-edge dynamic-slice FMA on the VPU (closer to the
  CPU CSC-Split inner loop; used as a structural cross-check).

Grid: ``(num_col_tiles, n_pairs)`` — pair axis innermost so all pairs sharing
a destination block are visited consecutively and the output tile stays
resident in VMEM (accumulation-safe; zeroed at each pair-run head via the
``is_first`` scalar-prefetch flag).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["spmm_blocked_kernel", "spmm_blocked_call"]


def _mxu_chunk(m_blk, src_ids, dst_ids, valid, block_size, acc):
    """acc += onehot(dst)ᵀ-scatter( onehot(src)-gather(m_blk) ) for one chunk."""
    e = src_ids.shape[0]
    lanes = jax.lax.broadcasted_iota(jnp.int32, (e, block_size), 1)
    onehot_src = jnp.where(src_ids[:, None] == lanes, valid[:, None], 0.0)
    onehot_dst = jnp.where(dst_ids[:, None] == lanes, 1.0, 0.0)
    # gather: (C_tile, bs) @ (bs, e) -> (C_tile, e)
    gathered = jax.lax.dot_general(
        m_blk, onehot_src,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    # scatter: (C_tile, e) @ (e, bs) -> (C_tile, bs)
    return acc + jax.lax.dot_general(
        gathered, onehot_dst,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def spmm_blocked_kernel(
    # scalar prefetch
    src_blk_ref, dst_blk_ref, first_ref,
    # inputs
    m_ref, dst_loc_ref, src_loc_ref, valid_ref,
    # output
    out_ref,
    *,
    block_size: int,
    edge_chunk: int,
    mode: str,
):
    p = pl.program_id(1)

    @pl.when(first_ref[p] == 1)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    n_chunks = src_loc_ref.shape[1] // edge_chunk
    m_blk = m_ref[...]  # (C_tile, block_size)

    if mode == "mxu":
        def body(i, acc):
            start = i * edge_chunk
            src_ids = src_loc_ref[0, pl.dslice(start, edge_chunk)]
            dst_ids = dst_loc_ref[0, pl.dslice(start, edge_chunk)]
            valid = valid_ref[0, pl.dslice(start, edge_chunk)]
            return _mxu_chunk(m_blk, src_ids, dst_ids, valid, block_size, acc)

        acc = jax.lax.fori_loop(
            0, n_chunks, body, jnp.zeros_like(out_ref[...]), unroll=False
        )
        out_ref[...] += acc
    elif mode == "loop":
        total = src_loc_ref.shape[1]

        def body(e, acc):
            s = src_loc_ref[0, e]
            d = dst_loc_ref[0, e]
            v = valid_ref[0, e]
            col = jax.lax.dynamic_slice(m_blk, (0, s), (m_blk.shape[0], 1))
            upd = jax.lax.dynamic_slice(acc, (0, d), (acc.shape[0], 1)) + v * col
            return jax.lax.dynamic_update_slice(acc, upd, (0, d))

        acc = jax.lax.fori_loop(0, total, body, jnp.zeros_like(out_ref[...]))
        out_ref[...] += acc
    else:  # pragma: no cover
        raise ValueError(f"unknown mode {mode!r}")


def spmm_blocked_call(
    mt: jnp.ndarray,           # (C, n_padded) transposed dense counts
    pair_src_block: jnp.ndarray,   # (n_pairs,) int32
    pair_dst_block: jnp.ndarray,   # (n_pairs,) int32
    pair_is_first: jnp.ndarray,    # (n_pairs,) int32 — 1 at head of a dst-run
    edge_dst_local: jnp.ndarray,   # (n_pairs, capacity) int32
    edge_src_local: jnp.ndarray,   # (n_pairs, capacity) int32
    edge_valid: jnp.ndarray,       # (n_pairs, capacity) f32
    *,
    block_size: int,
    col_tile: int = 128,
    edge_chunk: int = 256,
    mode: str = "mxu",
    interpret: bool = False,
) -> jnp.ndarray:
    """``B^T = (A_G @ M)^T`` via the blocked-ELL kernel.  Shapes must satisfy
    ``C % col_tile == 0``, ``n_padded % block_size == 0``,
    ``capacity % edge_chunk == 0`` (pad host-side)."""
    c, n_padded = mt.shape
    n_pairs, capacity = edge_dst_local.shape
    if c % col_tile:
        raise ValueError(f"C={c} not a multiple of col_tile={col_tile}")
    if capacity % edge_chunk:
        raise ValueError(f"capacity={capacity} not a multiple of edge_chunk={edge_chunk}")
    grid = (c // col_tile, n_pairs)

    kernel = functools.partial(
        spmm_blocked_kernel, block_size=block_size, edge_chunk=edge_chunk, mode=mode
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((col_tile, block_size), lambda ci, p, sb, db, fi: (ci, sb[p])),
            pl.BlockSpec((1, capacity), lambda ci, p, sb, db, fi: (p, 0)),
            pl.BlockSpec((1, capacity), lambda ci, p, sb, db, fi: (p, 0)),
            pl.BlockSpec((1, capacity), lambda ci, p, sb, db, fi: (p, 0)),
        ],
        out_specs=pl.BlockSpec((col_tile, block_size), lambda ci, p, sb, db, fi: (ci, db[p])),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((c, n_padded), mt.dtype),
        interpret=interpret,
    )(pair_src_block, pair_dst_block, pair_is_first, mt, edge_dst_local, edge_src_local, edge_valid)
