"""jit'd wrapper around the blocked-ELL SpMM Pallas kernel.

Handles host-side preprocessing (blocked-ELL build, padding to kernel tile
alignment) and the row-major <-> transposed layout conversion so callers can
stay in the ``(n, C)`` orientation used by the high-level DP.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import BlockedELL, Graph, build_blocked_ell

from .kernel import spmm_blocked_call

__all__ = ["BlockedSpmmOperand", "prepare_operand", "spmm_blocked"]


@dataclass(frozen=True)
class BlockedSpmmOperand:
    """Device-ready blocked-ELL arrays (+ static geometry)."""

    n: int
    n_padded: int
    block_size: int
    edge_chunk: int
    pair_src_block: jnp.ndarray
    pair_dst_block: jnp.ndarray
    pair_is_first: jnp.ndarray
    edge_dst_local: jnp.ndarray
    edge_src_local: jnp.ndarray
    edge_valid: jnp.ndarray


def prepare_operand(
    graph: Graph, block_size: int = 256, edge_chunk: int = 256
) -> BlockedSpmmOperand:
    """Blocked-ELL build + dummy pairs for empty destination blocks + padding."""
    bell = build_blocked_ell(graph, block_size=block_size)
    n_blocks = bell.n_blocks
    pair_dst = bell.pair_dst_block
    pair_src = bell.pair_src_block
    cap = bell.pair_capacity
    cap_pad = ((cap + edge_chunk - 1) // edge_chunk) * edge_chunk

    dst_loc = np.zeros((bell.n_pairs, cap_pad), dtype=np.int32)
    src_loc = np.zeros((bell.n_pairs, cap_pad), dtype=np.int32)
    valid = np.zeros((bell.n_pairs, cap_pad), dtype=np.float32)
    dst_loc[:, :cap] = bell.edge_dst_local
    src_loc[:, :cap] = bell.edge_src_local
    valid[:, :cap] = bell.edge_valid

    # Every destination block must appear in >= 1 pair so its output tile is
    # zeroed (kernel writes only visited tiles).  Add all-invalid dummy pairs.
    present = np.zeros(n_blocks, dtype=bool)
    present[pair_dst] = True
    missing = np.nonzero(~present)[0].astype(np.int32)
    if missing.size:
        pair_dst = np.concatenate([pair_dst, missing])
        pair_src = np.concatenate([pair_src, np.zeros_like(missing)])
        dst_loc = np.concatenate([dst_loc, np.zeros((missing.size, cap_pad), np.int32)])
        src_loc = np.concatenate([src_loc, np.zeros((missing.size, cap_pad), np.int32)])
        valid = np.concatenate([valid, np.zeros((missing.size, cap_pad), np.float32)])
        order = np.argsort(pair_dst, kind="stable")
        pair_dst, pair_src = pair_dst[order], pair_src[order]
        dst_loc, src_loc, valid = dst_loc[order], src_loc[order], valid[order]

    is_first = np.ones(len(pair_dst), dtype=np.int32)
    is_first[1:] = (pair_dst[1:] != pair_dst[:-1]).astype(np.int32)

    return BlockedSpmmOperand(
        n=graph.n,
        n_padded=bell.n_padded,
        block_size=block_size,
        edge_chunk=edge_chunk,
        pair_src_block=jnp.asarray(pair_src),
        pair_dst_block=jnp.asarray(pair_dst),
        pair_is_first=jnp.asarray(is_first),
        edge_dst_local=jnp.asarray(dst_loc),
        edge_src_local=jnp.asarray(src_loc),
        edge_valid=jnp.asarray(valid),
    )


@functools.partial(
    jax.jit,
    static_argnames=("n", "n_padded", "block_size", "edge_chunk", "col_tile", "mode", "interpret"),
)
def _spmm_blocked_jit(
    m: jnp.ndarray,
    pair_src_block, pair_dst_block, pair_is_first,
    edge_dst_local, edge_src_local, edge_valid,
    *, n, n_padded, block_size, edge_chunk, col_tile, mode, interpret,
):
    c = m.shape[1]
    c_pad = ((c + col_tile - 1) // col_tile) * col_tile
    mt = jnp.zeros((c_pad, n_padded), dtype=m.dtype)
    mt = mt.at[:c, :n].set(m.T)
    bt = spmm_blocked_call(
        mt,
        pair_src_block, pair_dst_block, pair_is_first,
        edge_dst_local, edge_src_local, edge_valid,
        block_size=block_size,
        col_tile=col_tile,
        edge_chunk=edge_chunk,
        mode=mode,
        interpret=interpret,
    )
    return bt[:c, :n].T


def spmm_blocked(
    operand: BlockedSpmmOperand,
    m: jnp.ndarray,
    *,
    col_tile: int = 128,
    mode: str = "mxu",
    interpret: bool = False,
) -> jnp.ndarray:
    """``B = A_G @ M`` with ``M`` in row-major ``(n, C)`` orientation."""
    return _spmm_blocked_jit(
        m,
        operand.pair_src_block, operand.pair_dst_block, operand.pair_is_first,
        operand.edge_dst_local, operand.edge_src_local, operand.edge_valid,
        n=operand.n,
        n_padded=operand.n_padded,
        block_size=operand.block_size,
        edge_chunk=operand.edge_chunk,
        col_tile=col_tile,
        mode=mode,
        interpret=interpret,
    )
