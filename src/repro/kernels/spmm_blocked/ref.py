"""Pure-jnp oracle for the blocked-ELL SpMM kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["spmm_ref", "spmm_ref_transposed"]


def spmm_ref(src: jnp.ndarray, dst: jnp.ndarray, n: int, m: jnp.ndarray) -> jnp.ndarray:
    """``B[i] = sum_{j in N(i)} M[j]`` — edge-list segment-sum oracle, (n, C)."""
    return jax.ops.segment_sum(m[src], dst, num_segments=n)


def spmm_ref_transposed(src: jnp.ndarray, dst: jnp.ndarray, n: int, mt: jnp.ndarray) -> jnp.ndarray:
    """Transposed-layout oracle: ``(C, n) -> (C, n)``."""
    return spmm_ref(src, dst, n, mt.T).T
