"""Pure-jnp oracle for the fused SpMM+eMA kernel (two-pass by construction)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["spmm_ema_ref"]


def spmm_ema_ref(
    src: jnp.ndarray,
    dst: jnp.ndarray,
    n: int,
    m_p: jnp.ndarray,
    m_a: jnp.ndarray,
    idx_a: jnp.ndarray,
    idx_p: jnp.ndarray,
) -> jnp.ndarray:
    """Legacy two-pass reference: materialize ``B = A_G @ M_p``, then
    ``out[:, o] = sum_t M_a[:, idx_a[o,t]] * B[:, idx_p[o,t]]``."""
    b = jax.ops.segment_sum(m_p[src], dst, num_segments=n, indices_are_sorted=True)
    n_out, n_splits = idx_a.shape

    def body(t, acc):
        return acc + jnp.take(m_a, idx_a[:, t], axis=1) * jnp.take(b, idx_p[:, t], axis=1)

    return jax.lax.fori_loop(
        0, n_splits, body, jnp.zeros((m_a.shape[0], n_out), dtype=m_a.dtype)
    )
