"""Fused SpMM+eMA Pallas TPU kernel — the whole DP stage in one pass.

Computes, for one stage of SUBGRAPH2VEC's Algorithm 5,

    M_s[o, :] = sum_t  M_a[idx_a[o, t], :] * (A_G @ M_p)[idx_p[o, t], :]

WITHOUT ever materializing the aggregate product ``B = A_G @ M_p``: per
destination vertex block, the aggregate columns live only in a VMEM scratch
tile that is consumed by the eMA FMA the moment the block's last edge pair
has been accumulated.  This subsumed (and replaced — the package is gone)
the standalone eMA kernel that once lived at ``repro.kernels.ema``, which
fused only the multiply-add half and still read a full HBM-resident ``B``.

Layout is the paper's column-major design (§V-B) transposed for TPU: all
matrices are ``(colorsets, vertices)`` with the vertex axis on lanes.  The
sparse structure is the blocked-ELL build of ``repro.kernels.spmm_blocked``
(edges grouped by (dst-block, src-block) pair, pairs sorted by destination
block) plus an ``is_last`` flag marking the final pair of each
destination-block run.

Grid: ``(n_pairs,)``.  Per step the kernel

1. zeroes the scratch aggregate tile at a run head (``is_first``),
2. accumulates the pair's edges into it with the MXU one-hot gather/scatter
   trick shared with the blocked SpMM kernel,
3. at the run tail (``is_last``) applies the eMA against the VMEM-resident
   ``M_a^T`` destination tile and writes the ``M_s^T`` output tile — the
   only thing that ever reaches HBM.

Everything accumulates in fp32; the split tables ride in SMEM via scalar
prefetch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.spmm_blocked.kernel import _mxu_chunk

__all__ = ["spmm_ema_kernel", "spmm_ema_call"]


def spmm_ema_kernel(
    # scalar prefetch (SMEM)
    src_blk_ref, dst_blk_ref, first_ref, last_ref, idx_a_ref, idx_p_ref,
    # inputs (VMEM)
    mp_ref,       # (Cp_tot, block_size) — source block of M_p^T
    ma_ref,       # (Ca_tot, block_size) — destination block of M_a^T
    dst_loc_ref, src_loc_ref, valid_ref,  # (1, capacity) per pair
    # output
    out_ref,      # (Nout_tot, block_size) — destination block of M_s^T
    # scratch
    bcol_ref,     # VMEM (Cp_tot, block_size) fp32 aggregate tile
    *,
    block_size: int,
    edge_chunk: int,
    n_splits: int,
):
    p = pl.program_id(0)

    @pl.when(first_ref[p] == 1)
    def _zero_aggregate():
        bcol_ref[...] = jnp.zeros_like(bcol_ref)

    # -- SpMM half: fold this pair's edges into the aggregate scratch tile.
    m_blk = mp_ref[...]
    n_chunks = src_loc_ref.shape[1] // edge_chunk

    def chunk_body(i, acc):
        start = i * edge_chunk
        src_ids = src_loc_ref[0, pl.dslice(start, edge_chunk)]
        dst_ids = dst_loc_ref[0, pl.dslice(start, edge_chunk)]
        valid = valid_ref[0, pl.dslice(start, edge_chunk)]
        return _mxu_chunk(m_blk, src_ids, dst_ids, valid, block_size, acc)

    acc = jax.lax.fori_loop(
        0, n_chunks, chunk_body, jnp.zeros_like(bcol_ref[...]), unroll=False
    )
    bcol_ref[...] += acc

    # -- eMA half: the block's aggregate is complete — consume it in place.
    @pl.when(last_ref[p] == 1)
    def _ema_consume():
        n_out_tot = out_ref.shape[0]
        v_tile = out_ref.shape[1]

        def out_row(o, carry):
            def split_body(t, acc):
                ia = idx_a_ref[o, t]
                ip = idx_p_ref[o, t]
                ra = ma_ref[pl.dslice(ia, 1), :]
                rb = bcol_ref[pl.dslice(ip, 1), :]
                return acc + ra * rb

            row = jax.lax.fori_loop(
                0, n_splits, split_body, jnp.zeros((1, v_tile), out_ref.dtype)
            )
            out_ref[pl.dslice(o, 1), :] = row
            return carry

        jax.lax.fori_loop(0, n_out_tot, out_row, 0)


def spmm_ema_call(
    mp_t: jnp.ndarray,             # (Cp_tot, n_padded) transposed passive state
    ma_t: jnp.ndarray,             # (Ca_tot, n_padded) transposed active state
    idx_a: jnp.ndarray,            # (Nout_tot, n_splits) int32
    idx_p: jnp.ndarray,            # (Nout_tot, n_splits) int32
    pair_src_block: jnp.ndarray,   # (n_pairs,) int32
    pair_dst_block: jnp.ndarray,   # (n_pairs,) int32
    pair_is_first: jnp.ndarray,    # (n_pairs,) int32 — head of a dst-block run
    pair_is_last: jnp.ndarray,     # (n_pairs,) int32 — tail of a dst-block run
    edge_dst_local: jnp.ndarray,   # (n_pairs, capacity) int32
    edge_src_local: jnp.ndarray,   # (n_pairs, capacity) int32
    edge_valid: jnp.ndarray,       # (n_pairs, capacity) f32
    *,
    block_size: int,
    edge_chunk: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """``M_s^T = eMA(M_a^T, A_G @ M_p^T)`` fused per destination block.

    ``capacity % edge_chunk == 0`` and ``n_padded % block_size == 0`` (pad
    host-side; see ``repro.kernels.spmm_ema.ops``).  Returns
    ``(Nout_tot, n_padded)`` in ``mp_t``'s dtype (use fp32: the aggregate
    scratch accumulates in fp32 regardless).
    """
    cp_tot, n_padded = mp_t.shape
    ca_tot = ma_t.shape[0]
    n_out_tot, n_splits = idx_a.shape
    n_pairs, capacity = edge_dst_local.shape
    if capacity % edge_chunk:
        raise ValueError(f"capacity={capacity} not a multiple of edge_chunk={edge_chunk}")
    if n_padded % block_size:
        raise ValueError(f"n_padded={n_padded} not a multiple of block_size={block_size}")

    kernel = functools.partial(
        spmm_ema_kernel,
        block_size=block_size,
        edge_chunk=edge_chunk,
        n_splits=n_splits,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=6,
        grid=(n_pairs,),
        in_specs=[
            pl.BlockSpec((cp_tot, block_size), lambda p, sb, db, fi, la, ia, ip: (0, sb[p])),
            pl.BlockSpec((ca_tot, block_size), lambda p, sb, db, fi, la, ia, ip: (0, db[p])),
            pl.BlockSpec((1, capacity), lambda p, sb, db, fi, la, ia, ip: (p, 0)),
            pl.BlockSpec((1, capacity), lambda p, sb, db, fi, la, ia, ip: (p, 0)),
            pl.BlockSpec((1, capacity), lambda p, sb, db, fi, la, ia, ip: (p, 0)),
        ],
        out_specs=pl.BlockSpec(
            (n_out_tot, block_size), lambda p, sb, db, fi, la, ia, ip: (0, db[p])
        ),
        scratch_shapes=[pltpu.VMEM((cp_tot, block_size), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_out_tot, n_padded), mp_t.dtype),
        interpret=interpret,
    )(
        pair_src_block, pair_dst_block, pair_is_first, pair_is_last, idx_a, idx_p,
        mp_t, ma_t, edge_dst_local, edge_src_local, edge_valid,
    )
