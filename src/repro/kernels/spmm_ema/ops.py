"""Host-side wrappers for the fused SpMM+eMA Pallas kernel.

Handles blocked-ELL preprocessing (+ the per-pair ``is_last`` run-tail
flags), padding to kernel tile alignment, the row-major ``(n, C)`` <->
transposed ``(C, n)`` conversion, and the engine's fused ``(n, B, C)``
coloring-batch layout: a chunk of ``B`` colorings is folded into the
*row* axis of the transposed operands with the split tables offset per
coloring, so one kernel launch serves the whole chunk.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph
from repro.kernels.spmm_blocked.ops import BlockedSpmmOperand, prepare_operand

from .kernel import spmm_ema_call

__all__ = [
    "FusedSpmmEmaOperand",
    "prepare_fused_operand",
    "spmm_ema",
    "spmm_ema_batched",
]


@dataclass(frozen=True)
class FusedSpmmEmaOperand:
    """Blocked-ELL arrays plus destination-run tail flags."""

    blocked: BlockedSpmmOperand
    pair_is_last: jnp.ndarray  # (n_pairs,) int32


def prepare_fused_operand(
    graph: Graph, block_size: int = 256, edge_chunk: int = 256
) -> FusedSpmmEmaOperand:
    """Blocked-ELL build + the ``is_last`` flag ending each dst-block run."""
    blocked = prepare_operand(graph, block_size=block_size, edge_chunk=edge_chunk)
    pair_dst = np.asarray(blocked.pair_dst_block)
    is_last = np.ones(pair_dst.shape[0], dtype=np.int32)
    if pair_dst.shape[0] > 1:
        is_last[:-1] = (pair_dst[1:] != pair_dst[:-1]).astype(np.int32)
    return FusedSpmmEmaOperand(blocked=blocked, pair_is_last=jnp.asarray(is_last))


def _pad_rows(x: np.ndarray, multiple: int = 8) -> int:
    return ((x + multiple - 1) // multiple) * multiple


def spmm_ema(
    operand: FusedSpmmEmaOperand,
    m_p: jnp.ndarray,    # (n, C_p)
    m_a: jnp.ndarray,    # (n, C_a)
    idx_a: np.ndarray,   # (n_out, n_splits) host-side int32
    idx_p: np.ndarray,   # (n_out, n_splits) host-side int32
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused ``M_s = eMA(M_a, A_G @ M_p)`` with row-major ``(n, C)`` operands."""
    out = spmm_ema_batched(
        operand, m_p[:, None, :], m_a[:, None, :], idx_a, idx_p, interpret=interpret
    )
    return out[:, 0, :]


def spmm_ema_batched(
    operand: FusedSpmmEmaOperand,
    m_p: jnp.ndarray,    # (n, B, C_p)
    m_a: jnp.ndarray,    # (n, B, C_a)
    idx_a: np.ndarray,   # (n_out, n_splits) host-side int32
    idx_p: np.ndarray,   # (n_out, n_splits) host-side int32
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused stage over a chunk of ``B`` colorings -> ``(n, B, n_out)`` fp32.

    Each coloring's columns become an 8-row-aligned band of the transposed
    operands, and the split tables are replicated per coloring with the
    matching row offsets — the aggregate scratch stays one VMEM tile per
    destination block for the whole chunk.
    """
    blocked = operand.blocked
    n, bsz, c_p = m_p.shape
    c_a = m_a.shape[2]
    idx_a = np.asarray(idx_a, dtype=np.int32)
    idx_p = np.asarray(idx_p, dtype=np.int32)
    n_out, n_splits = idx_a.shape

    cp_pad = _pad_rows(c_p)
    ca_pad = _pad_rows(c_a)
    nout_pad = _pad_rows(n_out)

    def to_bands(m, c, c_pad):
        # (n, B, c) -> (B * c_pad, n_padded), coloring b in rows [b*c_pad, ...)
        mt = jnp.moveaxis(m.astype(jnp.float32), 0, 2)  # (B, c, n)
        mt = jnp.pad(mt, ((0, 0), (0, c_pad - c), (0, blocked.n_padded - n)))
        return mt.reshape(bsz * c_pad, blocked.n_padded)

    mp_t = to_bands(m_p, c_p, cp_pad)
    ma_t = to_bands(m_a, c_a, ca_pad)

    # Per-coloring table replication: rows [b*nout_pad, b*nout_pad + n_out)
    # read M_a band b and aggregate band b (pad rows re-read row 0 of band 0;
    # their output is sliced away below).
    offs = np.arange(bsz, dtype=np.int32)
    idx_a_full = np.zeros((bsz, nout_pad, n_splits), dtype=np.int32)
    idx_p_full = np.zeros((bsz, nout_pad, n_splits), dtype=np.int32)
    idx_a_full[:, :n_out, :] = idx_a[None] + (offs * ca_pad)[:, None, None]
    idx_p_full[:, :n_out, :] = idx_p[None] + (offs * cp_pad)[:, None, None]

    out_t = spmm_ema_call(
        mp_t,
        ma_t,
        jnp.asarray(idx_a_full.reshape(bsz * nout_pad, n_splits)),
        jnp.asarray(idx_p_full.reshape(bsz * nout_pad, n_splits)),
        blocked.pair_src_block,
        blocked.pair_dst_block,
        blocked.pair_is_first,
        operand.pair_is_last,
        blocked.edge_dst_local,
        blocked.edge_src_local,
        blocked.edge_valid,
        block_size=blocked.block_size,
        edge_chunk=blocked.edge_chunk,
        interpret=interpret,
    )  # (B * nout_pad, n_padded)
    out = out_t.reshape(bsz, nout_pad, blocked.n_padded)[:, :n_out, :n]
    return out.transpose(2, 0, 1)  # (n, B, n_out)
