"""Cross-version JAX compatibility shims (0.4.x <-> >= 0.5).

The repo targets the newest public JAX API (``jax.shard_map``,
``jax.set_mesh``, ``jax.typeof(...).vma``, ``jax.lax.pvary``), but CI and
laptop environments routinely pin older 0.4.x releases where those names
either live under ``jax.experimental`` or do not exist at all.  Every
mesh/shard_map call site in the repo goes through this module so the same
code runs on both API generations.

Resolution rules (checked once at import):

* ``shard_map``    — ``jax.shard_map`` if present, else
  ``jax.experimental.shard_map.shard_map``.  Replication/vma checking is
  disabled on the legacy path: the callers annotate varying-ness with
  :func:`pvary`, which is an identity on 0.4.x where the vma type system
  does not exist.
* ``set_mesh``     — ``jax.set_mesh`` > ``jax.sharding.use_mesh`` > the
  legacy ``with mesh:`` context (Mesh has been a context manager since
  the xmap era, and NamedSharding-carrying code never needed the global
  mesh anyway).
* ``current_mesh`` — ``jax.sharding.get_abstract_mesh()`` when available
  and non-trivial, else the thread-resident physical mesh set by the
  legacy context.
* ``pvary`` / ``varying_axes`` — no-ops on JAX without the vma system.
"""

from __future__ import annotations

import contextlib
from typing import Any, Callable, FrozenSet, Sequence

import jax

__all__ = [
    "HAS_VMA",
    "shard_map",
    "set_mesh",
    "current_mesh",
    "pvary",
    "varying_axes",
]


def _resolve_shard_map():
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn, True
    from jax.experimental.shard_map import shard_map as legacy_fn

    return legacy_fn, False


_SHARD_MAP, _SHARD_MAP_IS_PUBLIC = _resolve_shard_map()

#: True when this JAX has the varying-manual-axes type system (jax.typeof().vma).
HAS_VMA = hasattr(jax, "typeof") and hasattr(jax.lax, "pvary")


def shard_map(f: Callable, *, mesh, in_specs, out_specs) -> Callable:
    """``jax.shard_map`` portable across the public/experimental split."""
    if _SHARD_MAP_IS_PUBLIC:
        return _SHARD_MAP(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    # Legacy (jax.experimental) path: no vma types, so static replication
    # checking would reject loop carries our pvary() cannot annotate.
    return _SHARD_MAP(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )


def set_mesh(mesh):
    """Context manager activating ``mesh`` for the enclosed block."""
    setter = getattr(jax, "set_mesh", None)
    if setter is not None:
        return setter(mesh)
    use_mesh = getattr(jax.sharding, "use_mesh", None)
    if use_mesh is not None:
        return use_mesh(mesh)
    return _legacy_mesh_context(mesh)


@contextlib.contextmanager
def _legacy_mesh_context(mesh):
    with mesh:
        yield mesh


def current_mesh():
    """The mesh activated by :func:`set_mesh` (abstract or physical)."""
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is not None:
        m = get_abstract()
        if m is not None and getattr(m, "axis_names", ()):
            return m
    from jax.interpreters import pxla

    return pxla.thread_resources.env.physical_mesh


def pvary(x: Any, axes: Sequence[Any]):
    """``jax.lax.pvary`` where it exists; identity on pre-vma JAX."""
    fn = getattr(jax.lax, "pvary", None)
    if fn is None or not axes:
        return x
    return fn(x, tuple(axes))


def varying_axes(x: Any) -> FrozenSet[Any]:
    """Mesh axes ``x`` is varying over (empty set on pre-vma JAX)."""
    typeof = getattr(jax, "typeof", None)
    if typeof is None:
        return frozenset()
    return frozenset(getattr(typeof(x), "vma", frozenset()))
