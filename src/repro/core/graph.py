"""Graph containers, sparse formats, and synthetic generators.

The counting DP only needs undirected, unweighted simple graphs.  Three device
layouts are supported, mirroring the paper's CSR / CSC-Split discussion but
re-thought for the TPU memory hierarchy (DESIGN.md §2):

* **edge list** — ``(src, dst)`` int32 pairs with both directions present; the
  high-level SpMM is ``segment_sum(M[src], dst)``.  This is the layout used by
  the distributed path (edges shard cleanly).
* **ELL** — ``(n, max_deg)`` padded neighbor table + validity mask; SpMM is a
  row gather + masked sum (best when the degree distribution is flat).
* **blocked-ELL ("CSC-Split, TPU edition")** — vertices tiled into blocks of
  ``block_size`` rows; edges grouped by (dst-block, src-block) tile pair and
  padded; the Pallas kernel streams one source tile of ``M`` into VMEM per
  pair and accumulates into the destination tile.  The per-row-range grouping
  is exactly the locality trick of the paper's CSC-Split format.

Generators: RMAT (the paper's synthetic workhorse), Erdos-Renyi, and a tiny
deterministic PPIN-like graph for examples.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

__all__ = [
    "Graph",
    "BlockedELL",
    "SellGraph",
    "build_blocked_ell",
    "build_sell",
    "rmat_graph",
    "erdos_renyi_graph",
    "grid_graph",
]


@dataclass(frozen=True)
class Graph:
    """Undirected simple graph in canonical edge-list form.

    ``src``/``dst`` contain *both* directions of every undirected edge and are
    sorted by ``(dst, src)`` so that segment reductions over ``dst`` are
    contiguous.  ``n`` is the vertex count; ``num_undirected`` the number of
    undirected edges (``len(src) == 2 * num_undirected``).
    """

    n: int
    src: np.ndarray  # (2E,) int32
    dst: np.ndarray  # (2E,) int32

    @property
    def num_directed(self) -> int:
        return int(self.src.shape[0])

    @property
    def num_undirected(self) -> int:
        return self.num_directed // 2

    @property
    def avg_degree(self) -> float:
        return self.num_directed / max(self.n, 1)

    def degrees(self) -> np.ndarray:
        return np.bincount(self.dst, minlength=self.n).astype(np.int32)

    def max_degree(self) -> int:
        return int(self.degrees().max(initial=0))

    def csr(self) -> Tuple[np.ndarray, np.ndarray]:
        """(row_ptr, col_idx) over destination-major ordering."""
        deg = self.degrees()
        row_ptr = np.zeros(self.n + 1, dtype=np.int64)
        np.cumsum(deg, out=row_ptr[1:])
        return row_ptr, self.src.astype(np.int32)

    def ell(self, max_deg: Optional[int] = None) -> Tuple[np.ndarray, np.ndarray]:
        """Padded neighbor table ``(n, max_deg)`` + bool mask.

        Padded slots point at vertex 0 and are masked out.
        """
        deg = self.degrees()
        md = int(max_deg if max_deg is not None else deg.max(initial=1))
        nbr = np.zeros((self.n, md), dtype=np.int32)
        mask = np.zeros((self.n, md), dtype=bool)
        row_ptr, col_idx = self.csr()
        for i in range(self.n):
            lo, hi = int(row_ptr[i]), int(row_ptr[i + 1])
            d = min(hi - lo, md)
            nbr[i, :d] = col_idx[lo : lo + d]
            mask[i, :d] = True
        return nbr, mask

    def dense_adjacency(self) -> np.ndarray:
        a = np.zeros((self.n, self.n), dtype=np.float32)
        a[self.dst, self.src] = 1.0
        return a

    def signature(self) -> str:
        """Content hash of ``(n, src, dst)`` — the graph half of the engine
        cache key.  Graphs in canonical form (sorted, symmetrized) with the
        same structure hash identically regardless of construction route.
        """
        h = hashlib.sha1()
        h.update(np.int64(self.n).tobytes())
        h.update(np.ascontiguousarray(self.src, dtype=np.int64).tobytes())
        h.update(np.ascontiguousarray(self.dst, dtype=np.int64).tobytes())
        return h.hexdigest()


def _canonicalize(n: int, u: np.ndarray, v: np.ndarray) -> Graph:
    """Dedup, drop self-loops, symmetrize, and sort by (dst, src)."""
    keep = u != v
    u, v = u[keep], v[keep]
    lo, hi = np.minimum(u, v), np.maximum(u, v)
    und = np.unique(lo.astype(np.int64) * n + hi.astype(np.int64))
    lo = (und // n).astype(np.int32)
    hi = (und % n).astype(np.int32)
    src = np.concatenate([lo, hi])
    dst = np.concatenate([hi, lo])
    order = np.lexsort((src, dst))
    return Graph(n=n, src=src[order], dst=dst[order])


def rmat_graph(
    n: int,
    num_edges: int,
    seed: int = 0,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
) -> Graph:
    """R-MAT generator (Chakrabarti et al. 2004), the paper's synthetic data.

    ``a + b + c + d = 1`` with ``d = 1 - a - b - c``; larger ``a`` skews the
    degree distribution (the paper's ``K`` parameter sweeps this skew).
    """
    scale = int(np.ceil(np.log2(max(n, 2))))
    n_pow = 1 << scale
    rng = np.random.default_rng(seed)
    # Vectorized bit-by-bit quadrant descent for all edges at once.
    u = np.zeros(num_edges, dtype=np.int64)
    v = np.zeros(num_edges, dtype=np.int64)
    for _ in range(scale):
        r = rng.random(num_edges)
        right = (r >= a + b) & (r < a + b + c) | (r >= a + b + c)
        down = (r >= a) & (r < a + b) | (r >= a + b + c)
        u = (u << 1) | down.astype(np.int64)
        v = (v << 1) | right.astype(np.int64)
    u, v = (u % n).astype(np.int32), (v % n).astype(np.int32)
    return _canonicalize(n, u, v)


def erdos_renyi_graph(n: int, num_edges: int, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    u = rng.integers(0, n, size=num_edges).astype(np.int32)
    v = rng.integers(0, n, size=num_edges).astype(np.int32)
    return _canonicalize(n, u, v)


def grid_graph(rows: int, cols: int) -> Graph:
    """Deterministic 2-D grid — handy exact-count test fixture."""
    idx = np.arange(rows * cols).reshape(rows, cols)
    edges = []
    edges.append(np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], axis=1))
    edges.append(np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()], axis=1))
    e = np.concatenate(edges, axis=0)
    return _canonicalize(rows * cols, e[:, 0].astype(np.int32), e[:, 1].astype(np.int32))


# ---------------------------------------------------------------------------
# SELL (sliced, degree-sorted ELL) — scatter-free CPU neighbor gather.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SellGraph:
    """Degree-sorted sliced-ELL layout: a *scatter-free* SpMM for skewed graphs.

    Vertices are sorted by descending degree and cut into groups of
    ``group_size`` rows; each group's neighbor lists are padded only to that
    group's own max degree (classic SELL-C-sigma with a full sort).  The
    neighbor reduction is then a padded row gather + masked sum per group —
    pure gathers and dense reductions, no scatter at all; results come back
    to original vertex order through one inverse-permutation gather.

    This exists because XLA:CPU's scatter (``segment_sum``) falls off a
    performance cliff on large edge lists (observed: ~2 ms at |E|≈30k/n=2k
    but ~400–600 ms at |E|≈130k/n=8k regardless of column count) and carries
    an |E|-proportional fixed cost per call that the fused column-batched
    pipeline would multiply.  Degree sorting bounds the padding waste that
    plain ELL suffers on power-law graphs (one hub row would pad every row
    to ``max_degree``).

    Attributes:
      group_rows: per group, (rows,) int32 — vertex ids in degree order
        (concatenating all groups gives the full degree-sorted order).
      group_nbr:  per group, (rows, d_group) int32 padded neighbor table.
      group_mask: per group, (rows, d_group) float32 validity mask.
      inv_order:  (n,) int32 — position of each degree-rank slot for the
        inverse gather: ``out = concat(group results)[inv_order]``.
      padded_slots: total padded neighbor slots across groups (the memory
        model's transient unit; ``>= num_directed``).
    """

    n: int
    group_size: int
    group_rows: Tuple[np.ndarray, ...]
    group_nbr: Tuple[np.ndarray, ...]
    group_mask: Tuple[np.ndarray, ...]
    inv_order: np.ndarray
    padded_slots: int


def build_sell(graph: Graph, group_size: int = 128) -> SellGraph:
    """Degree-sort vertices and build per-group padded neighbor tables."""
    deg = graph.degrees()
    row_ptr, col_idx = graph.csr()
    order = np.argsort(-deg, kind="stable")
    groups_rows = []
    groups_nbr = []
    groups_mask = []
    padded = 0
    for lo in range(0, graph.n, group_size):
        rows = order[lo : lo + group_size]
        d_max = max(int(deg[rows].max(initial=0)), 1)
        nbr = np.zeros((rows.size, d_max), dtype=np.int32)
        mask = np.zeros((rows.size, d_max), dtype=np.float32)
        for r, v in enumerate(rows):
            a, b = int(row_ptr[v]), int(row_ptr[v + 1])
            nbr[r, : b - a] = col_idx[a:b]
            mask[r, : b - a] = 1.0
        groups_rows.append(rows.astype(np.int32))
        groups_nbr.append(nbr)
        groups_mask.append(mask)
        padded += nbr.size
    inv_order = np.empty(graph.n, dtype=np.int32)
    inv_order[order] = np.arange(graph.n, dtype=np.int32)
    return SellGraph(
        n=graph.n,
        group_size=group_size,
        group_rows=tuple(groups_rows),
        group_nbr=tuple(groups_nbr),
        group_mask=tuple(groups_mask),
        inv_order=inv_order,
        padded_slots=padded,
    )


# ---------------------------------------------------------------------------
# Blocked-ELL (CSC-Split, TPU edition) — preprocessing for the Pallas SpMM.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class BlockedELL:
    """Edges grouped by (dst-block, src-block) tile pairs.

    Attributes:
      n_padded: vertex count padded to a multiple of ``block_size``.
      block_size: tile edge (rows of M resident in VMEM per step).
      pair_dst_block: (n_pairs,) int32 — destination block id per pair.
      pair_src_block: (n_pairs,) int32 — source block id per pair.
      edge_dst_local: (n_pairs, pair_capacity) int32 — dst row within block.
      edge_src_local: (n_pairs, pair_capacity) int32 — src row within block.
      edge_valid:     (n_pairs, pair_capacity) float32 — 1.0 valid / 0.0 pad.
      row_block_ptr:  (n_blocks + 1,) int32 — pairs are sorted by dst block;
        pairs for dst block b live in ``[row_block_ptr[b], row_block_ptr[b+1])``.
    """

    n_padded: int
    block_size: int
    pair_dst_block: np.ndarray
    pair_src_block: np.ndarray
    edge_dst_local: np.ndarray
    edge_src_local: np.ndarray
    edge_valid: np.ndarray
    row_block_ptr: np.ndarray

    @property
    def n_blocks(self) -> int:
        return self.n_padded // self.block_size

    @property
    def n_pairs(self) -> int:
        return int(self.pair_dst_block.shape[0])

    @property
    def pair_capacity(self) -> int:
        return int(self.edge_dst_local.shape[1])


def build_blocked_ell(graph: Graph, block_size: int = 256, pair_capacity: Optional[int] = None) -> BlockedELL:
    """Group edges into (dst-block, src-block) pairs, padded to a capacity.

    ``pair_capacity`` defaults to the max edges in any pair rounded up to a
    multiple of 8 (sublane alignment).  Pairs are sorted by destination block
    so the kernel can keep one VMEM accumulator per destination tile.
    """
    bs = block_size
    n_padded = ((graph.n + bs - 1) // bs) * bs
    dst_b = graph.dst // bs
    src_b = graph.src // bs
    pair_key = dst_b.astype(np.int64) * (n_padded // bs) + src_b
    order = np.argsort(pair_key, kind="stable")
    pair_key_s = pair_key[order]
    uniq, starts, counts = np.unique(pair_key_s, return_index=True, return_counts=True)
    n_pairs = len(uniq)
    cap = int(counts.max(initial=1)) if pair_capacity is None else pair_capacity
    cap = ((cap + 7) // 8) * 8
    edge_dst_local = np.zeros((n_pairs, cap), dtype=np.int32)
    edge_src_local = np.zeros((n_pairs, cap), dtype=np.int32)
    edge_valid = np.zeros((n_pairs, cap), dtype=np.float32)
    dst_s, src_s = graph.dst[order], graph.src[order]
    for p in range(n_pairs):
        lo = int(starts[p])
        c = min(int(counts[p]), cap)
        edge_dst_local[p, :c] = dst_s[lo : lo + c] % bs
        edge_src_local[p, :c] = src_s[lo : lo + c] % bs
        edge_valid[p, :c] = 1.0
    pair_dst_block = (uniq // (n_padded // bs)).astype(np.int32)
    pair_src_block = (uniq % (n_padded // bs)).astype(np.int32)
    n_blocks = n_padded // bs
    row_block_ptr = np.zeros(n_blocks + 1, dtype=np.int32)
    np.add.at(row_block_ptr[1:], pair_dst_block, 1)
    row_block_ptr = np.cumsum(row_block_ptr).astype(np.int32)
    return BlockedELL(
        n_padded=n_padded,
        block_size=bs,
        pair_dst_block=pair_dst_block,
        pair_src_block=pair_src_block,
        edge_dst_local=edge_dst_local,
        edge_src_local=edge_src_local,
        edge_valid=edge_valid,
        row_block_ptr=row_block_ptr,
    )
