"""Multi-iteration (epsilon, delta) color-coding estimator (Algorithm 1).

Runs ``N`` independent random colorings of the network, counts colorful
embeddings with the vectorized DP, and averages the normalized counts.  The
iteration count for an (epsilon, delta) guarantee is
``N = ceil(e^k * log(1/delta) / epsilon^2)`` (Alon et al.); in practice far
fewer iterations suffice (paper §VI-H: ~100 iterations for <1% error).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .counting import CountingPlan, build_counting_plan, count_colorful_vectorized, normalize_count, spmm_edges
from .graph import Graph
from .templates import Template

__all__ = ["required_iterations", "EstimateResult", "estimate_embeddings", "make_count_step"]


def required_iterations(k: int, epsilon: float, delta: float) -> int:
    """Alon et al. iteration bound ``O(e^k log(1/delta) / eps^2)``."""
    return int(math.ceil(math.exp(k) * math.log(1.0 / delta) / (epsilon**2)))


@dataclass
class EstimateResult:
    mean: float
    std: float
    per_iteration: np.ndarray
    iterations: int


def make_count_step(
    plan: CountingPlan,
    n: int,
    spmm_fn: Callable[[jnp.ndarray], jnp.ndarray],
    ema_fn=None,
    dtype=jnp.float32,
):
    """jit'd one-iteration step: key -> normalized embedding estimate."""

    @jax.jit
    def step(key: jax.Array) -> jnp.ndarray:
        colors = jax.random.randint(key, (n,), 0, plan.k)
        raw = count_colorful_vectorized(plan, colors, spmm_fn, ema_fn=ema_fn, dtype=dtype)
        return normalize_count(raw, plan)

    return step


def estimate_embeddings(
    graph: Graph,
    template: Template,
    iterations: int = 32,
    seed: int = 0,
    spmm_fn: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None,
    plan: Optional[CountingPlan] = None,
    dtype=jnp.float32,
) -> EstimateResult:
    """End-to-end single-host estimator (examples & tests)."""
    plan = plan or build_counting_plan(template)
    if spmm_fn is None:
        src = jnp.asarray(graph.src)
        dst = jnp.asarray(graph.dst)
        spmm_fn = partial(spmm_edges, src, dst, graph.n)
    step = make_count_step(plan, graph.n, spmm_fn, dtype=dtype)
    keys = jax.random.split(jax.random.PRNGKey(seed), iterations)
    vals = np.array([float(step(key)) for key in keys])
    return EstimateResult(mean=float(vals.mean()), std=float(vals.std()), per_iteration=vals, iterations=iterations)
