"""Multi-iteration (epsilon, delta) color-coding estimator (Algorithm 1).

Runs ``N`` independent random colorings of the network, counts colorful
embeddings with the vectorized DP, and averages the normalized counts.  The
iteration count for an (epsilon, delta) guarantee is
``N = ceil(e^k * log(1/delta) / epsilon^2)`` (Alon et al.); in practice far
fewer iterations suffice (paper §VI-H: ~100 iterations for <1% error).

This module is a thin wrapper over :class:`repro.core.engine.CountingEngine`,
which batches colorings into fused-column chunks inside one jit (no
per-iteration dispatch, no per-iteration host sync, tables shipped once).
``make_count_step`` is kept for callers that want the legacy one-coloring
jitted step.
"""

from __future__ import annotations

import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .colorsets import colorful_probability
from .counting import CountingPlan, build_counting_plan, count_colorful_vectorized, normalize_count
from .engine import CountingEngine, EstimateResult
from .graph import Graph
from .templates import Template

__all__ = ["required_iterations", "EstimateResult", "estimate_embeddings", "make_count_step"]


def required_iterations(template_or_k, epsilon: float, delta: float) -> int:
    """Alon et al. iteration bound ``ceil(p^-1 log(1/delta) / eps^2)``.

    ``p = k!/k^k`` is the colorful-hit probability of ONE random coloring
    for *any* k-vertex template — it depends only on the vertex count, not
    on tree shape, so the same bound serves trees, cycles, cliques, and
    every bag-compiled graphlet.  Accepts a :class:`Template` (its ``k`` is
    used) or the vertex count directly.  The exact ``k^k/k!`` factor is a
    ``sqrt(2 pi k)`` improvement over the classical ``e^k`` form.
    """
    k = template_or_k.k if isinstance(template_or_k, Template) else int(template_or_k)
    inv_p = 1.0 / colorful_probability(k)
    return int(math.ceil(inv_p * math.log(1.0 / delta) / (epsilon**2)))


def make_count_step(
    plan: CountingPlan,
    n: int,
    spmm_fn: Callable[[jnp.ndarray], jnp.ndarray],
    ema_fn=None,
    dtype=jnp.float32,
):
    """Legacy jit'd one-iteration step: key -> normalized embedding estimate.

    Prefer :class:`CountingEngine` — one dispatch per chunk instead of one
    per coloring — unless a custom ``ema_fn`` or per-key control is needed.
    """

    @jax.jit
    def step(key: jax.Array) -> jnp.ndarray:
        colors = jax.random.randint(key, (n,), 0, plan.k)
        raw = count_colorful_vectorized(plan, colors, spmm_fn, ema_fn=ema_fn, dtype=dtype)
        return normalize_count(raw, plan)

    return step


def estimate_embeddings(
    graph: Graph,
    template: Template,
    iterations: Optional[int] = None,
    seed: int = 0,
    spmm_fn: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None,
    plan: Optional[CountingPlan] = None,
    dtype=jnp.float32,
    backend: str = "auto",
    chunk_size: Optional[int] = None,
    memory_budget_bytes: Optional[int] = None,
    mesh=None,
    column_batch: Optional[int] = None,
    gather_dtype=None,
    balance_degrees: bool = True,
    epsilon: Optional[float] = None,
    delta: Optional[float] = None,
    max_iterations: Optional[int] = None,
    bound: str = "normal",
) -> EstimateResult:
    """End-to-end estimator (examples & tests), single-host or mesh.

    All iterations execute batched on-device through the engine; the
    per-iteration values come back in one transfer (no ``float()``
    round-trip per coloring).

    Args:
      graph / template: the network and the template to count — a tree or
        any connected graphlet (non-trees compile via tree decomposition).
      iterations / seed: number of independent random colorings (default
        32) + PRNG seed.  With an ``epsilon``/``delta`` target,
        ``iterations`` becomes the adaptive run's budget cap instead —
        the same semantics as ``CountingService.submit``.
      spmm_fn: custom neighbor-sum kernel (forces the ``custom`` backend).
      plan: pre-built :class:`CountingPlan` (rebuilt from the template when
        omitted).
      dtype: dtype policy — ``"fp32"`` | ``"bf16"`` | a dtype.
      backend: engine backend name, or ``"auto"`` (graph statistics; resolves
        to ``"mesh"`` when ``mesh`` is given).
      chunk_size / memory_budget_bytes: chunk-picker overrides.
      mesh: a ``jax.sharding.Mesh`` — run distributed on the engine's mesh
        backend (column-batched all-gather SpMM + streamed eMA).
      column_batch / gather_dtype / balance_degrees: mesh-backend knobs, see
        :class:`repro.core.engine.MeshBackend`.
      epsilon / delta: relative-accuracy target.  When either is given the
        run goes through the serving layer's adaptive stopper
        (:func:`repro.serve.stopping.adaptive_estimate`): iterations stream
        in engine-chunk increments and stop as soon as the estimate's
        normal CI halfwidth is within ``epsilon * |mean|`` at confidence
        ``1 - delta`` (defaults 0.05 / 0.05) — replacing the blind fixed-N
        choice end to end.
      bound: adaptive CI family — ``"normal"`` (default) or the more
        conservative ``"bernstein"`` (empirical-Bernstein; heavy tails).
      max_iterations: alias for the adaptive budget cap, taking precedence
        over ``iterations`` (default 1024; compare ``required_iterations``
        for the a-priori bound the stopper undercuts).
    """
    kwargs = {}
    if memory_budget_bytes is not None:
        kwargs["memory_budget_bytes"] = memory_budget_bytes
    if mesh is not None:
        kwargs.update(
            mesh=mesh,
            column_batch=column_batch,
            gather_dtype=gather_dtype,
            balance_degrees=balance_degrees,
        )
    engine = CountingEngine(
        graph,
        [template],
        backend=backend,
        spmm_fn=spmm_fn,
        dtype_policy=dtype,
        chunk_size=chunk_size,
        plans=None if plan is None else [plan],
        **kwargs,
    )
    if epsilon is not None or delta is not None:
        # lazy import: the serving layer sits above core and imports it
        from repro.serve.stopping import adaptive_estimate

        budget = int(max_iterations or iterations or 1024)
        return adaptive_estimate(
            engine,
            epsilon=0.05 if epsilon is None else float(epsilon),
            delta=0.05 if delta is None else float(delta),
            seed=seed,
            max_iterations=budget,
            bound=bound,
        )[0]
    return engine.estimate(iterations=iterations or 32, seed=seed)[0]
