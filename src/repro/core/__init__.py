"""SubGraph2Vec core: color-coding tree subgraph counting as SpMM + eMA."""

from .colorsets import (
    SplitTable,
    binom,
    bucketed_split_entries,
    build_split_table,
    colorful_probability,
    enumerate_subsets,
    rank_subsets,
    unrank_subsets,
)
from .counting import (
    CountingPlan,
    brute_force_colorful,
    brute_force_embeddings,
    build_counting_plan,
    count_colorful_traversal,
    count_colorful_vectorized,
    fused_aggregate_ema,
    fused_aggregate_ema_grouped,
    liveness_peak_columns,
    liveness_peak_elements,
    normalize_count,
    schedule_liveness,
    spmm_edges,
    spmm_ell,
)
from .engine import (
    BACKEND_ENV_VAR,
    ENGINE_BACKENDS,
    CountingEngine,
    DtypePolicy,
    EngineBackend,
    StageTables,
    engine_cache_key,
    pick_chunk_size,
    select_backend,
    sub_template_canonical,
    template_set_canons,
)
from .estimator import EstimateResult, estimate_embeddings, make_count_step, required_iterations
from .graph import (
    BlockedELL,
    Graph,
    SellGraph,
    build_blocked_ell,
    build_sell,
    erdos_renyi_graph,
    grid_graph,
    rmat_graph,
)
from .templates import (
    GRAPHLET_TEMPLATES,
    PAPER_TEMPLATES,
    Template,
    TemplatePartition,
    TreeDecomposition,
    build_bag_program,
    build_tree_decomposition,
    connected_graphlets,
    get_template,
    graph_automorphisms,
    partition_template,
    path_template,
    random_tree_template,
    star_template,
    binary_tree_template,
    tree_automorphisms,
)

__all__ = [name for name in dir() if not name.startswith("_")]
