"""Combinadic color-set indexing and split tables.

Color coding assigns each vertex a color in ``[0, k)``.  The dynamic program
stores, for a sub-template ``T_s`` with ``m = |T_s|`` vertices, a dense count
matrix ``M_s`` of shape ``(n_vertices, C(k, m))`` whose columns are indexed by
the *rank* of the size-``m`` color set ``C_s``.

This module provides:

* a vectorized colexicographic ranking of fixed-size subsets of ``[0, k)``
  (``rank_subsets`` / ``unrank_subsets``),
* the *split tables* ``(idx_a, idx_p)`` used by the eMA stage: for every output
  color set ``C_s`` (row) and every split of ``C_s`` into an active subset of
  size ``m_a`` and a passive subset of size ``m_p`` (column), the column ranks
  into ``M_{s,a}`` and ``M_{s,p}``.

Everything here is static host-side preprocessing (NumPy); the tables are
shipped to the device as int32 arrays and reused across color-coding
iterations.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from functools import lru_cache

import numpy as np

__all__ = [
    "binom",
    "binom_table",
    "enumerate_subsets",
    "rank_subsets",
    "unrank_subsets",
    "SplitTable",
    "build_split_table",
    "UnionSplitTable",
    "build_union_split_table",
    "bucketed_split_entries",
    "colorful_probability",
]


@lru_cache(maxsize=None)
def binom_table(n_max: int) -> np.ndarray:
    """Pascal triangle ``C[n, r]`` for ``0 <= n, r <= n_max`` (int64)."""
    c = np.zeros((n_max + 1, n_max + 1), dtype=np.int64)
    c[:, 0] = 1
    for n in range(1, n_max + 1):
        for r in range(1, n + 1):
            c[n, r] = c[n - 1, r - 1] + c[n - 1, r]
    return c


def binom(n: int, r: int) -> int:
    """``C(n, r)`` with the usual out-of-range zeros."""
    if r < 0 or r > n or n < 0:
        return 0
    return int(binom_table(max(n, 1))[n, r])


def enumerate_subsets(k: int, m: int) -> np.ndarray:
    """All size-``m`` subsets of ``[0, k)`` in colex rank order.

    Returns an ``(C(k, m), m)`` int32 array with each row sorted ascending.
    Row ``r`` is exactly the subset with ``rank_subsets(row) == r``.
    """
    if m == 0:
        return np.zeros((1, 0), dtype=np.int32)
    combos = np.array(list(itertools.combinations(range(k), m)), dtype=np.int32)
    ranks = rank_subsets(combos)
    order = np.argsort(ranks, kind="stable")
    return combos[order]


def rank_subsets(subsets: np.ndarray) -> np.ndarray:
    """Colex rank of each row of a ``(..., m)`` array of sorted subsets.

    ``rank(c_0 < c_1 < ... < c_{m-1}) = sum_i C(c_i, i + 1)``.
    Vectorized over leading dimensions.
    """
    subsets = np.asarray(subsets)
    if subsets.shape[-1] == 0:
        return np.zeros(subsets.shape[:-1], dtype=np.int64)
    cmax = int(subsets.max(initial=0))
    table = binom_table(max(cmax, subsets.shape[-1], 1))
    idx_r = np.arange(1, subsets.shape[-1] + 1)
    return table[subsets, idx_r].sum(axis=-1)


def unrank_subsets(ranks: np.ndarray, k: int, m: int) -> np.ndarray:
    """Inverse of :func:`rank_subsets` (loop over ranks; test helper only)."""
    table = binom_table(max(k, 1))
    out = np.zeros((len(ranks), m), dtype=np.int32)
    for row, rank in enumerate(np.asarray(ranks, dtype=np.int64)):
        r = int(rank)
        for i in range(m, 0, -1):
            # Largest c with C(c, i) <= r.
            c = i - 1
            while c + 1 < k and table[c + 1, i] <= r:
                c += 1
            out[row, i - 1] = c
            r -= int(table[c, i])
    return out


@dataclass(frozen=True)
class SplitTable:
    """eMA split table for one sub-template.

    Attributes:
      idx_a: ``(n_out, n_splits)`` int32 — column ranks into ``M_{s,a}``.
      idx_p: ``(n_out, n_splits)`` int32 — column ranks into ``M_{s,p}``.
      n_out: number of output color sets, ``C(k, m)``.
      n_splits: splits per output color set, ``C(m, m_a)``.
    """

    idx_a: np.ndarray
    idx_p: np.ndarray
    n_out: int
    n_splits: int
    k: int
    m: int
    m_a: int

    @property
    def m_p(self) -> int:
        return self.m - self.m_a


def build_split_table(k: int, m: int, m_a: int) -> SplitTable:
    """Build the eMA split table for color sets of size ``m`` split ``m_a|m_p``.

    For every size-``m`` color set ``C`` (in colex rank order) and every way of
    choosing ``m_a`` of its elements as the *active* subset, records the colex
    ranks of the active subset (among size-``m_a`` subsets of ``[0, k)``) and of
    the complementary passive subset (among size-``m_p`` subsets).

    Fully vectorized over the ``C(k, m)`` color sets: the combinatorial loop is
    only over the ``C(m, m_a)`` position masks.
    """
    if not (0 <= m_a <= m <= k):
        raise ValueError(f"invalid split sizes k={k} m={m} m_a={m_a}")
    sets_m = enumerate_subsets(k, m)  # (n_out, m), colex order
    n_out = sets_m.shape[0]
    masks = list(itertools.combinations(range(m), m_a))
    n_splits = len(masks)
    idx_a = np.zeros((n_out, n_splits), dtype=np.int32)
    idx_p = np.zeros((n_out, n_splits), dtype=np.int32)
    all_pos = set(range(m))
    for t, mask in enumerate(masks):
        pos_a = np.array(mask, dtype=np.int64).reshape(1, -1)
        pos_p = np.array(sorted(all_pos - set(mask)), dtype=np.int64).reshape(1, -1)
        sub_a = np.take_along_axis(sets_m, np.broadcast_to(pos_a, (n_out, m_a)), axis=1) if m_a else np.zeros((n_out, 0), np.int32)
        sub_p = np.take_along_axis(sets_m, np.broadcast_to(pos_p, (n_out, m - m_a)), axis=1) if m - m_a else np.zeros((n_out, 0), np.int32)
        idx_a[:, t] = rank_subsets(sub_a).astype(np.int32)
        idx_p[:, t] = rank_subsets(sub_p).astype(np.int32)
    return SplitTable(idx_a=idx_a, idx_p=idx_p, n_out=n_out, n_splits=n_splits, k=k, m=m, m_a=m_a)


@dataclass(frozen=True)
class UnionSplitTable:
    """Color-subset convolution table for a bag-join step.

    A bag join multiplies two DP states whose covered vertex sets overlap
    in exactly the join bag: color sets of sizes ``m1`` and ``m2`` sharing
    exactly ``overlap`` colors combine into an output set of size
    ``m = m1 + m2 - overlap``.  For every output color set ``S`` (row, in
    colex rank order) the columns enumerate every admissible pair
    ``(S1, S2)`` with ``S1 ∪ S2 = S``, ``|S1| = m1``, ``|S2| = m2`` and
    ``|S1 ∩ S2| = overlap``, as colex ranks into the two input states.

    Attributes:
      idx_a: ``(n_out, n_pairs)`` int32 — ranks of ``S1`` into state 1.
      idx_p: ``(n_out, n_pairs)`` int32 — ranks of ``S2`` into state 2.
      n_out: ``C(k, m)`` output color sets.
      n_pairs: pairs per output set, ``C(m, overlap) * C(m - overlap,
        m1 - overlap)`` (uniform across rows — the join stays a dense
        gather-FMA exactly like the eMA split tables).
    """

    idx_a: np.ndarray
    idx_p: np.ndarray
    n_out: int
    n_pairs: int
    k: int
    m1: int
    m2: int
    overlap: int

    @property
    def m(self) -> int:
        return self.m1 + self.m2 - self.overlap


def build_union_split_table(k: int, m1: int, m2: int, overlap: int) -> UnionSplitTable:
    """Build the join table for color sets of sizes ``m1``/``m2`` overlapping
    in exactly ``overlap`` colors.

    Each pair is generated once: pick the ``overlap`` positions of ``S`` that
    form the intersection, then the ``m1 - overlap`` positions that belong
    only to ``S1`` (the rest belong only to ``S2``).  Vectorized over the
    ``C(k, m)`` output color sets like :func:`build_split_table` — the
    combinatorial loop is only over position masks.

    With ``overlap == 0`` and ``m_a = m1`` this degenerates to the disjoint
    eMA split table (same entries as ``build_split_table(k, m, m1)``), which
    is the treewidth-1 special case of the color-subset convolution.
    """
    m = m1 + m2 - overlap
    if not (0 <= overlap <= min(m1, m2) and 0 < m1 <= k and 0 < m2 <= k and m <= k):
        raise ValueError(
            f"invalid union split sizes k={k} m1={m1} m2={m2} overlap={overlap}"
        )
    sets_m = enumerate_subsets(k, m)  # (n_out, m), colex order
    n_out = sets_m.shape[0]
    combos = []
    positions = range(m)
    for inter in itertools.combinations(positions, overlap):
        rest = [p for p in positions if p not in inter]
        for extra1 in itertools.combinations(rest, m1 - overlap):
            pos1 = tuple(sorted(inter + extra1))
            pos2 = tuple(sorted(set(positions) - set(extra1)))
            combos.append((pos1, pos2))
    n_pairs = len(combos)
    idx_a = np.zeros((n_out, n_pairs), dtype=np.int32)
    idx_p = np.zeros((n_out, n_pairs), dtype=np.int32)
    for t, (pos1, pos2) in enumerate(combos):
        sub1 = sets_m[:, pos1]
        sub2 = sets_m[:, pos2]
        idx_a[:, t] = rank_subsets(sub1).astype(np.int32)
        idx_p[:, t] = rank_subsets(sub2).astype(np.int32)
    return UnionSplitTable(
        idx_a=idx_a,
        idx_p=idx_p,
        n_out=n_out,
        n_pairs=n_pairs,
        k=k,
        m1=m1,
        m2=m2,
        overlap=overlap,
    )


def bucketed_split_entries(table: SplitTable, column_batch: int):
    """Re-bucket a split table by passive-column batch, dense per output row.

    The fused SpMM+eMA pipeline walks the passive matrix in
    ``column_batch``-column slices and must apply, for each slice, exactly
    the (output, split) entries whose passive column falls inside it —
    without ever materializing the full aggregate product.  For batch ``b``
    covering passive columns ``[lo, lo + width)`` this returns entries
    *bucketed per output row* so the eMA update stays a dense gather-FMA
    (no scatter):

        ``m_s[:, o] += sum_j m_a[:, idx_a[b][o, j]] * bcol[:, idx_p[b][o, j]]
                       * valid[b][o, j]``

    Returns a list over batches of ``(lo, width, idx_a, idx_p_local,
    valid)`` with ``idx_a / idx_p_local / valid`` shaped ``(n_out, cap_b)``
    (``cap_b`` = the batch's max entries per output row; padded entries are
    zero-index, zero-valid; ``valid`` is ``None`` when every slot is real —
    the executor then skips the masking multiply).  Every (output, split)
    entry of the table lands in exactly one batch, and the batch order is
    fixed, so the fused result is deterministic and equals the two-pass eMA
    up to fp summation order.
    """
    if column_batch <= 0:
        raise ValueError(f"column_batch must be positive, got {column_batch}")
    n_out, _ = table.idx_a.shape
    c_p = binom(table.k, table.m_p)
    batches = []
    for lo in range(0, c_p, column_batch):
        width = min(column_batch, c_p - lo)
        sel = (table.idx_p >= lo) & (table.idx_p < lo + width)  # (n_out, n_splits)
        cap = int(sel.sum(axis=1).max(initial=0))
        idx_a = np.zeros((n_out, max(cap, 1)), dtype=np.int32)
        idx_p = np.zeros((n_out, max(cap, 1)), dtype=np.int32)
        valid = np.zeros((n_out, max(cap, 1)), dtype=np.float32)
        for o in range(n_out):
            ts = np.nonzero(sel[o])[0]
            idx_a[o, : ts.size] = table.idx_a[o, ts]
            idx_p[o, : ts.size] = table.idx_p[o, ts] - lo
            valid[o, : ts.size] = 1.0
        batches.append((lo, width, idx_a, idx_p, valid if not valid.all() else None))
    return batches


def colorful_probability(k: int) -> float:
    """P(an embedding of a size-``k`` template is colorful) = k! / k**k."""
    p = 1.0
    for i in range(1, k + 1):
        p *= i / k
    return p
