"""Distributed SUBGRAPH2VEC: the paper's MPI scheme on a TPU mesh (shard_map).

Decomposition (DESIGN.md §5): vertices are 1-D row-partitioned across **all**
mesh axes (the paper's distributed layout), edges co-located with their
destination vertex.  Per DP stage:

* **SpMM** — the only communicating step.  The dense count matrix
  ``M_{s,p}`` is broadcast in **column batches** (the paper's batched SpMM,
  §V-C: "we also split columns of M_{s,p} into batches ... to save peak
  memory"): for each batch, ``all_gather`` the batch rows along the mesh,
  then a local edge segment-sum produces the batch of ``B``.
  Peak extra memory = one batch = ``n * column_batch * 4`` bytes.
* **eMA** — entirely vertex-local (Equation 1's whole point), zero
  communication.

The final count is a ``psum`` of local totals.  Column batching makes the
collective volume *independent* of the template size per batch; the batch
size is the knob the perf log (§Perf) tunes against the ICI roofline.

Edge-balance caveat: row-range partitions inherit degree skew (the paper's
Fig 10 observation); ``partition_vertices`` therefore supports the
degree-sorted balancing permutation as an option.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from .. import compat
from .colorsets import binom
from .counting import CountingPlan, _ema_apply
from .graph import Graph

__all__ = [
    "ShardedGraph",
    "shard_graph",
    "make_distributed_count_fn",
    "distributed_input_specs",
    "plan_tables",
    "plan_table_specs",
]


@dataclass(frozen=True)
class ShardedGraph:
    """Host-side edge partition: shard i owns vertex rows
    ``[i * rows_per_shard, (i+1) * rows_per_shard)`` and every edge whose dst
    lies in that range, padded to ``edges_per_shard``."""

    n: int
    n_padded: int
    n_shards: int
    rows_per_shard: int
    edges_per_shard: int
    src: np.ndarray        # (n_shards * edges_per_shard,) global src ids
    dst_local: np.ndarray  # (n_shards * edges_per_shard,) dst - shard offset
    edge_mask: np.ndarray  # (n_shards * edges_per_shard,) float32


def shard_graph(graph: Graph, n_shards: int, balance_degrees: bool = False) -> ShardedGraph:
    src, dst = graph.src, graph.dst
    perm = None
    if balance_degrees:
        # round-robin by degree rank: spreads hubs across shards
        order = np.argsort(-graph.degrees(), kind="stable")
        perm = np.empty(graph.n, dtype=np.int64)
        perm[order] = np.arange(graph.n)
        src, dst = perm[src].astype(np.int32), perm[dst].astype(np.int32)

    rows = -(-graph.n // n_shards)
    rows = max(rows, 1)
    n_padded = rows * n_shards
    shard_of = dst // rows
    counts = np.bincount(shard_of, minlength=n_shards)
    e_max = int(counts.max(initial=1))

    src_out = np.zeros((n_shards, e_max), dtype=np.int32)
    dst_out = np.zeros((n_shards, e_max), dtype=np.int32)
    mask_out = np.zeros((n_shards, e_max), dtype=np.float32)
    order = np.argsort(shard_of, kind="stable")
    src_s, dst_s, shard_s = src[order], dst[order], shard_of[order]
    starts = np.concatenate([[0], np.cumsum(np.bincount(shard_s, minlength=n_shards))])
    for s in range(n_shards):
        lo, hi = int(starts[s]), int(starts[s + 1])
        c = hi - lo
        src_out[s, :c] = src_s[lo:hi]
        dst_out[s, :c] = dst_s[lo:hi] - s * rows
        mask_out[s, :c] = 1.0
    return ShardedGraph(
        n=graph.n,
        n_padded=n_padded,
        n_shards=n_shards,
        rows_per_shard=rows,
        edges_per_shard=e_max,
        src=src_out.reshape(-1),
        dst_local=dst_out.reshape(-1),
        edge_mask=mask_out.reshape(-1),
    )


def _pad_cols(c: int, batch: int) -> int:
    return ((c + batch - 1) // batch) * batch


def _compressed_gather(x, axes, gather_dtype):
    """All-gather with the payload genuinely cast on the wire.

    ``optimization_barrier`` stops XLA from commuting the converts across the
    collective (observed on XLA:CPU: convert(bf16)->gather->convert(f32) gets
    folded back to an f32 gather, rounding values without saving bytes).
    """
    if gather_dtype is None:
        return jax.lax.all_gather(x, axes, axis=0, tiled=True)
    payload = jax.lax.optimization_barrier(x.astype(gather_dtype))
    full = jax.lax.all_gather(payload, axes, axis=0, tiled=True)
    return jax.lax.optimization_barrier(full).astype(jnp.float32)


def _pvary_missing(x, axes):
    """Mark ``x`` varying over any mesh axes it is not already varying on
    (loop-carry inits must match the varying type of the loop body).  On JAX
    without the vma type system this is an identity (compat shims)."""
    vma = compat.varying_axes(x)
    missing = tuple(a for a in axes if a not in vma)
    return compat.pvary(x, missing) if missing else x


def build_streamed_tables(plan: CountingPlan, column_batch: int):
    """Per-stage split tables re-bucketed by passive-column batch.

    The streamed schedule (§Perf beyond-paper optimization) consumes each
    all-gathered SpMM column batch immediately: for batch ``bi`` it applies
    every (out, split) entry whose passive column falls in the batch.  ``B``
    is never materialized — peak per-stage memory drops from
    ``M_a + M_p + B + M_s`` to ``M_a + M_p + M_s + one batch`` and the
    B write+read HBM round-trip disappears.

    Returns ``{stage: (ent_out, ent_ia, ent_ip_local, ent_valid)}`` with
    arrays shaped ``(n_batches, cap)`` (padded per batch).
    """
    out = {}
    for i, t in enumerate(plan.tables):
        if t is None:
            continue
        n_out, n_splits = t.idx_a.shape
        flat_out = np.repeat(np.arange(n_out, dtype=np.int32), n_splits)
        flat_ia = t.idx_a.reshape(-1).astype(np.int32)
        flat_ip = t.idx_p.reshape(-1).astype(np.int32)
        c_p = binom(plan.k, t.m_p)
        n_batches = (c_p + column_batch - 1) // column_batch
        bucket = flat_ip // column_batch
        order = np.argsort(bucket, kind="stable")
        flat_out, flat_ia, flat_ip, bucket = (
            flat_out[order], flat_ia[order], flat_ip[order], bucket[order],
        )
        counts = np.bincount(bucket, minlength=n_batches)
        cap = int(counts.max(initial=1))
        ent_out = np.zeros((n_batches, cap), np.int32)
        ent_ia = np.zeros((n_batches, cap), np.int32)
        ent_ip = np.zeros((n_batches, cap), np.int32)
        ent_valid = np.zeros((n_batches, cap), np.float32)
        starts = np.concatenate([[0], np.cumsum(counts)])
        for b in range(n_batches):
            lo, hi = int(starts[b]), int(starts[b + 1])
            c = hi - lo
            ent_out[b, :c] = flat_out[lo:hi]
            ent_ia[b, :c] = flat_ia[lo:hi]
            ent_ip[b, :c] = flat_ip[lo:hi] - b * column_batch
            ent_valid[b, :c] = 1.0
        out[i] = (
            jnp.asarray(ent_out),
            jnp.asarray(ent_ia),
            jnp.asarray(ent_ip),
            jnp.asarray(ent_valid),
        )
    return out


def make_distributed_count_fn(
    plan: CountingPlan,
    mesh: Mesh,
    n_padded: int,
    edges_per_shard: int,
    column_batch: Optional[int] = 128,
    ema_mode: str = "loop",
    gather_dtype=None,
):
    """Build the jit-able distributed one-coloring count.

    Signature of the returned fn:
      (colors (n_padded,) i32, src (S*E,) i32, dst_local (S*E,) i32,
       edge_mask (S*E,) f32, tables) -> scalar raw colorful total.

    ``ema_mode``:
      * "loop" — paper-faithful Algorithm 5: full batched SpMM into B, then
        the eMA pass (B materialized per stage).
      * "vectorized" — probe mode (single all-gather + einsum, loop-free).
      * "streamed" — beyond-paper fusion: every all-gathered column batch is
        consumed immediately by the eMA updates that read it (tables from
        :func:`build_streamed_tables`); B never exists.

    ``gather_dtype=jnp.bfloat16`` compresses the row all-gather payload 2x —
    the counting analogue of gradient compression.  Counts are an (eps,
    delta) ESTIMATOR, so the ~0.4% bf16 rounding is dominated by coloring
    variance; measured end-to-end count error is recorded in EXPERIMENTS.md
    §Perf.  Accumulation stays fp32.

    All tensor inputs are sharded over every mesh axis (1-D row partition of
    the vertex space).
    """
    axes = tuple(mesh.axis_names)
    n_shards = int(np.prod(mesh.devices.shape))
    rows = n_padded // n_shards
    k = plan.k

    def spmm_batched(m_p, src, dst_local, edge_mask):
        """Column-batched all-gather SpMM; m_p: (rows, C_pad) local.

        ``column_batch=None`` (probe mode): single full-width all-gather, no
        loop — lets ``cost_analysis`` see the full per-stage work (XLA counts
        while-loop bodies once)."""
        if column_batch is None:
            full = _compressed_gather(m_p, axes, gather_dtype)
            msgs = full[src] * edge_mask[:, None]
            return jax.ops.segment_sum(msgs, dst_local, num_segments=rows)
        c_pad = m_p.shape[1]
        n_batches = c_pad // column_batch

        def body(b_idx, acc):
            cols = jax.lax.dynamic_slice(
                m_p, (0, b_idx * column_batch), (rows, column_batch)
            )
            full = _compressed_gather(cols, axes, gather_dtype)
            msgs = full[src] * edge_mask[:, None]
            bcol = jax.ops.segment_sum(msgs, dst_local, num_segments=rows)
            return jax.lax.dynamic_update_slice(acc, bcol, (0, b_idx * column_batch))

        init = _pvary_missing(jnp.zeros_like(m_p), axes)
        return jax.lax.fori_loop(0, n_batches, body, init)

    def spmm_ema_streamed(m_p, m_a, src, dst_local, edge_mask, n_out, stream_tbl):
        """Fused per-batch SpMM -> eMA: gather a column batch, reduce it, and
        immediately scatter its contributions into M_s."""
        cb = column_batch or 128
        c_pad = m_p.shape[1]
        n_batches = c_pad // cb
        ent_out, ent_ia, ent_ip, ent_valid = stream_tbl

        def body(b_idx, m_s):
            cols = jax.lax.dynamic_slice(m_p, (0, b_idx * cb), (rows, cb))
            full = _compressed_gather(cols, axes, gather_dtype)
            msgs = full[src] * edge_mask[:, None]
            bcol = jax.ops.segment_sum(msgs, dst_local, num_segments=rows)  # (rows, cb)
            eo = jax.lax.dynamic_index_in_dim(ent_out, b_idx, keepdims=False)
            ia = jax.lax.dynamic_index_in_dim(ent_ia, b_idx, keepdims=False)
            ip = jax.lax.dynamic_index_in_dim(ent_ip, b_idx, keepdims=False)
            va = jax.lax.dynamic_index_in_dim(ent_valid, b_idx, keepdims=False)
            prod = jnp.take(m_a, ia, axis=1) * jnp.take(bcol, ip, axis=1) * va[None, :]
            return m_s.at[:, eo].add(prod)

        init = _pvary_missing(jnp.zeros((rows, n_out), jnp.float32), axes)
        return jax.lax.fori_loop(0, n_batches, body, init)

    def local_count(colors, src, dst_local, edge_mask, tables):
        leaf = jax.nn.one_hot(colors, k, dtype=jnp.float32)  # (rows, k)
        leaf = jnp.pad(leaf, ((0, 0), (0, _pad_cols(k, column_batch or 128) - k)))
        slots = {}
        for i, sub in enumerate(plan.partition.subs):
            if sub.is_leaf:
                slots[i] = leaf
                continue
            m_a, m_p = slots[sub.active], slots[sub.passive]
            if ema_mode == "streamed":
                n_out = plan.tables[i].n_out
                m_s = spmm_ema_streamed(
                    m_p, m_a, src, dst_local, edge_mask, n_out, tables[i]
                )
            else:
                idx_a, idx_p = tables[i]
                b = spmm_batched(m_p, src, dst_local, edge_mask)
                if ema_mode == "vectorized":
                    # probe mode: single gather-FMA einsum (no fori_loop) so
                    # the split-axis work is fully visible to cost_analysis
                    m_s = jnp.einsum(
                        "nos,nos->no", jnp.take(m_a, idx_a, axis=1), jnp.take(b, idx_p, axis=1)
                    )
                else:
                    init = _pvary_missing(jnp.zeros((rows, idx_a.shape[0]), jnp.float32), axes)
                    m_s = _ema_apply(m_a, b, idx_a, idx_p, init=init)  # (rows, n_out) — local!
            cb = column_batch or 128
            c_out_pad = _pad_cols(m_s.shape[1], cb)
            slots[i] = jnp.pad(m_s, ((0, 0), (0, c_out_pad - m_s.shape[1])))
            del slots[sub.active], slots[sub.passive]
        total_local = jnp.sum(slots[plan.partition.root_index])
        return jax.lax.psum(total_local, axes)

    sharded = P(axes)
    per_stage = 4 if ema_mode == "streamed" else 2
    table_specs = {
        i: (P(None, None),) * per_stage for i, t in enumerate(plan.tables) if t is not None
    }
    count = compat.shard_map(
        local_count,
        mesh=mesh,
        in_specs=(sharded, sharded, sharded, sharded, table_specs),
        out_specs=P(),
    )
    return count


def plan_tables(plan: CountingPlan):
    """Device table pytree matching the fn's ``tables`` argument."""
    return {
        i: (jnp.asarray(t.idx_a), jnp.asarray(t.idx_p))
        for i, t in enumerate(plan.tables)
        if t is not None
    }


def plan_table_specs(plan: CountingPlan):
    """ShapeDtypeStructs for the tables argument (dry-run)."""
    return {
        i: (
            jax.ShapeDtypeStruct(t.idx_a.shape, jnp.int32),
            jax.ShapeDtypeStruct(t.idx_p.shape, jnp.int32),
        )
        for i, t in enumerate(plan.tables)
        if t is not None
    }


def distributed_input_specs(n_padded: int, n_shards: int, edges_per_shard: int):
    """ShapeDtypeStructs for the distributed count (dry-run inputs)."""
    e_total = n_shards * edges_per_shard
    return (
        jax.ShapeDtypeStruct((n_padded,), jnp.int32),   # colors
        jax.ShapeDtypeStruct((e_total,), jnp.int32),    # src (global)
        jax.ShapeDtypeStruct((e_total,), jnp.int32),    # dst (local)
        jax.ShapeDtypeStruct((e_total,), jnp.float32),  # edge mask
    )
