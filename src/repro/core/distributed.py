"""Distributed SUBGRAPH2VEC: the paper's MPI scheme on a device mesh (shard_map).

This module is the device-mesh half of the :class:`~repro.core.engine.
CountingEngine` — the engine's ``mesh`` backend is a thin wrapper over
:func:`make_batched_count_fn` built here.  Decomposition (DESIGN.md §5):
vertices are 1-D row-partitioned across **all** mesh axes (the paper's
distributed layout), edges co-located with their destination vertex.  Per DP
stage:

* **SpMM** — the only communicating step.  The dense count matrix
  ``M_{s,p}`` is broadcast in **column batches** (the paper's batched SpMM,
  §V-C: "we also split columns of M_{s,p} into batches ... to save peak
  memory"): for each batch, ``all_gather`` the batch rows along the mesh,
  then a local edge segment-sum produces the batch of ``B``.
  Peak extra memory = one batch = ``n * batch_size * column_batch * 4`` bytes.
* **eMA** — entirely vertex-local (Equation 1's whole point), zero
  communication.

The final count is a ``psum`` of local totals.  Column batching makes the
collective volume *independent* of the template size per batch; the batch
size is the knob the perf log (§Perf) tunes against the ICI roofline.

Engine integration (PR 2): :func:`make_batched_count_fn` fuses a whole chunk
of ``B`` colorings into the batch dimension of the DP state — every local M
matrix is ``(rows, B, C)`` and each all-gathered column batch serves all
``B`` colorings at once — and counts several same-``k`` templates per
coloring with DP states shared by rooted canonical form.  Split tables are
built ONCE at construction (de-duplicated by ``(k, m, m_a)``) and
closure-captured, not re-shipped per call.

Edge-balance caveat: row-range partitions inherit degree skew (the paper's
Fig 10 observation); ``shard_graph`` therefore supports a round-robin
degree-rank balancing permutation as an option (``ShardedGraph.perm``
records the relabeling so colorings can follow it).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from .. import compat
from .colorsets import binom
from .counting import CountingPlan, _ema_apply_fused, schedule_liveness
from .graph import Graph

__all__ = [
    "ShardedGraph",
    "shard_graph",
    "make_batched_count_fn",
    "make_distributed_count_fn",
    "distributed_input_specs",
    "build_streamed_tables",
]


@dataclass(frozen=True)
class ShardedGraph:
    """Host-side edge partition: shard i owns vertex rows
    ``[i * rows_per_shard, (i+1) * rows_per_shard)`` and every edge whose dst
    lies in that range, padded to ``edges_per_shard``.

    ``perm`` is the old-id -> new-id vertex relabeling applied when
    ``balance_degrees=True`` (``None`` for the identity layout).  New ids
    range over ``[0, n_padded)`` (round-robin by degree rank leaves pad
    slots interleaved), so callers that fix per-vertex data (colors,
    features) must scatter it into an ``(n_padded,)`` array:
    ``data_new[perm] = data_old``.

    ``bucket_stride`` is set by ``bucket_by_src=True``: each shard's edge
    list is then grouped by *source* shard into ``n_shards`` contiguous
    buckets of exactly ``bucket_stride`` slots (the max (dst, src)-pair
    edge count; short buckets are mask-padded), so
    ``edges_per_shard == n_shards * bucket_stride`` and the ring pipeline
    can address the edges readable from one circulating row slice with a
    single ``dynamic_slice``.
    """

    n: int
    n_padded: int
    n_shards: int
    rows_per_shard: int
    edges_per_shard: int
    src: np.ndarray        # (n_shards * edges_per_shard,) global src ids
    dst_local: np.ndarray  # (n_shards * edges_per_shard,) dst - shard offset
    edge_mask: np.ndarray  # (n_shards * edges_per_shard,) float32
    perm: Optional[np.ndarray] = None  # (n,) old -> new id in [0, n_padded)
    bucket_stride: Optional[int] = None  # slots per src-shard bucket


def shard_graph(
    graph: Graph,
    n_shards: int,
    balance_degrees: bool = False,
    bucket_by_src: bool = False,
) -> ShardedGraph:
    """1-D row partition of ``graph`` over ``n_shards`` (edges follow dst).

    ``balance_degrees=True`` relabels vertices round-robin by degree rank
    before partitioning, so consecutive hubs land on different shards
    (reduces the max per-shard edge padding on skewed graphs).

    ``bucket_by_src=True`` additionally orders every shard's edges into
    ``n_shards`` uniform-stride buckets by *source* shard (see
    :class:`ShardedGraph`).  The mesh backend always uses this layout so the
    blocking and pipelined comm paths run over literally the same edge
    arrays — the precondition for their bit-exact equivalence.
    """
    src, dst = graph.src, graph.dst
    rows = max(-(-graph.n // n_shards), 1)
    n_padded = rows * n_shards
    perm = None
    if balance_degrees:
        # round-robin by degree rank: rank r lands on shard r % n_shards at
        # row r // n_shards, so consecutive hubs go to DIFFERENT shards.
        # New ids live in [0, n_padded); unassigned slots are pad vertices.
        order = np.argsort(-graph.degrees(), kind="stable")
        ranks = np.arange(graph.n)
        perm = np.empty(graph.n, dtype=np.int64)
        perm[order] = (ranks % n_shards) * rows + ranks // n_shards
        src, dst = perm[src].astype(np.int32), perm[dst].astype(np.int32)
    shard_of = dst // rows
    order = np.argsort(shard_of, kind="stable")
    src_s, dst_s, shard_s = src[order], dst[order], shard_of[order]

    if bucket_by_src:
        # sub-bucket each dst shard's edges by src shard with ONE uniform
        # stride: pair (s, o) lives at rows [o*stride, (o+1)*stride) of
        # shard s's edge list.  Pad slots keep mask 0 / src 0 / dst 0.
        pair = shard_s.astype(np.int64) * n_shards + src_s // rows
        pair_counts = np.bincount(pair, minlength=n_shards * n_shards)
        stride = int(pair_counts.max(initial=1))
        order2 = np.argsort(pair, kind="stable")
        src_p, dst_p, pair_p = src_s[order2], dst_s[order2], pair[order2]
        src_out = np.zeros((n_shards * n_shards, stride), dtype=np.int32)
        dst_out = np.zeros((n_shards * n_shards, stride), dtype=np.int32)
        mask_out = np.zeros((n_shards * n_shards, stride), dtype=np.float32)
        starts = np.concatenate([[0], np.cumsum(pair_counts)])
        for p in range(n_shards * n_shards):
            lo, hi = int(starts[p]), int(starts[p + 1])
            c = hi - lo
            src_out[p, :c] = src_p[lo:hi]
            dst_out[p, :c] = dst_p[lo:hi] - (p // n_shards) * rows
            mask_out[p, :c] = 1.0
        return ShardedGraph(
            n=graph.n,
            n_padded=n_padded,
            n_shards=n_shards,
            rows_per_shard=rows,
            edges_per_shard=n_shards * stride,
            src=src_out.reshape(-1),
            dst_local=dst_out.reshape(-1),
            edge_mask=mask_out.reshape(-1),
            perm=perm,
            bucket_stride=stride,
        )

    counts = np.bincount(shard_of, minlength=n_shards)
    e_max = int(counts.max(initial=1))
    src_out = np.zeros((n_shards, e_max), dtype=np.int32)
    dst_out = np.zeros((n_shards, e_max), dtype=np.int32)
    mask_out = np.zeros((n_shards, e_max), dtype=np.float32)
    starts = np.concatenate([[0], np.cumsum(np.bincount(shard_s, minlength=n_shards))])
    for s in range(n_shards):
        lo, hi = int(starts[s]), int(starts[s + 1])
        c = hi - lo
        src_out[s, :c] = src_s[lo:hi]
        dst_out[s, :c] = dst_s[lo:hi] - s * rows
        mask_out[s, :c] = 1.0
    return ShardedGraph(
        n=graph.n,
        n_padded=n_padded,
        n_shards=n_shards,
        rows_per_shard=rows,
        edges_per_shard=e_max,
        src=src_out.reshape(-1),
        dst_local=dst_out.reshape(-1),
        edge_mask=mask_out.reshape(-1),
        perm=perm,
    )


def _pad_cols(c: int, batch: int) -> int:
    return ((c + batch - 1) // batch) * batch


def _compressed_gather(x, axes, gather_dtype):
    """All-gather with the payload genuinely cast on the wire.

    ``optimization_barrier`` stops XLA from commuting the converts across the
    collective (observed on XLA:CPU: convert(bf16)->gather->convert(f32) gets
    folded back to an f32 gather, rounding values without saving bytes).
    """
    if gather_dtype is None:
        return jax.lax.all_gather(x, axes, axis=0, tiled=True)
    payload = jax.lax.optimization_barrier(x.astype(gather_dtype))
    full = jax.lax.all_gather(payload, axes, axis=0, tiled=True)
    return jax.lax.optimization_barrier(full).astype(jnp.float32)


def _pvary_missing(x, axes):
    """Mark ``x`` varying over any mesh axes it is not already varying on
    (loop-carry inits must match the varying type of the loop body).  On JAX
    without the vma type system this is an identity (compat shims)."""
    vma = compat.varying_axes(x)
    missing = tuple(a for a in axes if a not in vma)
    return compat.pvary(x, missing) if missing else x


def _streamed_stage_tables(table, column_batch: int):
    """Re-bucket one stage's split table by passive-column batch.

    Returns ``(ent_out, ent_ia, ent_ip_local, ent_valid)`` shaped
    ``(n_batches, cap)`` (padded per batch): for batch ``bi`` the streamed
    schedule applies exactly the (out, split) entries whose passive column
    falls in that batch.
    """
    n_out, n_splits = table.idx_a.shape
    flat_out = np.repeat(np.arange(n_out, dtype=np.int32), n_splits)
    flat_ia = table.idx_a.reshape(-1).astype(np.int32)
    flat_ip = table.idx_p.reshape(-1).astype(np.int32)
    c_p = binom(table.k, table.m_p)
    n_batches = (c_p + column_batch - 1) // column_batch
    bucket = flat_ip // column_batch
    order = np.argsort(bucket, kind="stable")
    flat_out, flat_ia, flat_ip, bucket = (
        flat_out[order], flat_ia[order], flat_ip[order], bucket[order],
    )
    counts = np.bincount(bucket, minlength=n_batches)
    cap = int(counts.max(initial=1))
    ent_out = np.zeros((n_batches, cap), np.int32)
    ent_ia = np.zeros((n_batches, cap), np.int32)
    ent_ip = np.zeros((n_batches, cap), np.int32)
    ent_valid = np.zeros((n_batches, cap), np.float32)
    starts = np.concatenate([[0], np.cumsum(counts)])
    for b in range(n_batches):
        lo, hi = int(starts[b]), int(starts[b + 1])
        c = hi - lo
        ent_out[b, :c] = flat_out[lo:hi]
        ent_ia[b, :c] = flat_ia[lo:hi]
        ent_ip[b, :c] = flat_ip[lo:hi] - b * column_batch
        ent_valid[b, :c] = 1.0
    return (
        jnp.asarray(ent_out),
        jnp.asarray(ent_ia),
        jnp.asarray(ent_ip),
        jnp.asarray(ent_valid),
    )


def build_streamed_tables(plan: CountingPlan, column_batch: int):
    """Per-stage split tables re-bucketed by passive-column batch.

    The streamed schedule (§Perf beyond-paper optimization) consumes each
    all-gathered SpMM column batch immediately: for batch ``bi`` it applies
    every (out, split) entry whose passive column falls in the batch.  ``B``
    is never materialized — peak per-stage memory drops from
    ``M_a + M_p + B + M_s`` to ``M_a + M_p + M_s + one batch`` and the
    B write+read HBM round-trip disappears.

    Returns ``{stage: (ent_out, ent_ia, ent_ip_local, ent_valid)}`` with
    arrays shaped ``(n_batches, cap)`` (padded per batch).
    """
    return {
        i: _streamed_stage_tables(t, column_batch)
        for i, t in enumerate(plan.tables)
        if t is not None
    }


def make_batched_count_fn(
    plans: Sequence[CountingPlan],
    mesh: Mesh,
    n_padded: int,
    edges_per_shard: int,
    *,
    column_batch: Optional[int] = 128,
    ema_mode: str = "streamed",
    gather_dtype=None,
    canons: Optional[Sequence[Sequence[str]]] = None,
    plan_ir=None,
    store_dtype=jnp.float32,
    accum_dtype=jnp.float32,
    comm_mode: str = "blocking",
    comm_schedule: Optional[Mapping[Tuple[int, int], str]] = None,
    bucket_stride: Optional[int] = None,
) -> Callable:
    """Build the jit-able mesh count over a batched chunk of colorings.

    This is the compute core of the engine's ``mesh`` backend.  Signature of
    the returned fn::

      (colors (B, n_padded) i32, src (S*E,) i32, dst_local (S*E,) i32,
       edge_mask (S*E,) f32) -> (B, T) f32 raw colorful totals

    where ``T == len(plans)``.  All split tables (plain or streamed) are
    built HERE, once, de-duplicated by ``(k, m, m_a)``, and closure-captured
    — they are never re-shipped per call.  A chunk of ``B`` colorings is
    fused into the batch dimension of the DP state so every all-gathered
    column batch serves all ``B`` colorings in one collective.

    Args:
      plans: one or more same-``k`` :class:`CountingPlan`; DP states are
        shared across plans by rooted canonical form (see ``canons``).
      mesh: the device mesh; tensors are sharded over every axis (1-D row
        partition of the vertex space).
      n_padded / edges_per_shard: the :class:`ShardedGraph` geometry.
      column_batch: passive columns all-gathered per collective.  ``None`` is
        probe mode: one full-width all-gather, no loop — lets
        ``cost_analysis`` see the full per-stage work (XLA counts while-loop
        bodies once).
      ema_mode: ``"streamed"`` (beyond-paper fusion: every all-gathered
        column batch is consumed immediately by the eMA updates that read
        it; ``B`` never exists), ``"loop"`` (paper-faithful Algorithm 5:
        full batched SpMM into B, then the eMA pass; B is memoized per
        passive canonical form, so templates sharing a passive sub-template
        share its SpMM), or ``"vectorized"`` (probe mode: loop-free
        gather-FMA einsum, fully visible to ``cost_analysis``).
      gather_dtype: ``jnp.bfloat16`` compresses the row all-gather payload 2x
        — the counting analogue of gradient compression.  Counts are an
        (eps, delta) ESTIMATOR, so the ~0.4% bf16 rounding is dominated by
        coloring variance.  Accumulation stays fp32.
      canons: per-plan, per-sub-template rooted canonical strings (legacy
        override; superseded by ``plan_ir``); equal strings share one DP
        state.
      plan_ir: optional :class:`repro.plan.ir.TemplatePlan` for the plan
        set — the engine's mesh backend passes its bound plan so the
        schedule (canonical sharing + liveness) is consumed, not
        re-derived.  Legacy callers omit it and one is planned here.
      store_dtype / accum_dtype: the engine's dtype policy — M matrices are
        kept (and all-gathered) in ``store_dtype``, reductions accumulate in
        ``accum_dtype``.
      comm_mode: ``"blocking"`` (one ``all_gather`` per column batch — the
        paper's synchronous scheme) or ``"pipelined"`` (double-buffered ring:
        each column batch circulates as per-shard row slices via
        ``lax.ppermute``, the NEXT slice in flight while the current one's
        edge bucket is consumed as a partial ``segment_sum``).  Pipelined
        requires the ``bucket_by_src`` edge layout, a single-axis mesh with
        >= 2 shards, and the streamed eMA mode; on such layouts the
        *blocking* streamed path runs the SAME per-source-shard bucket fold
        in the SAME ring order (reading each owner's rows out of its one
        all-gathered buffer), so counts are **bit-exact** across the two
        modes by construction.
      comm_schedule: optional per-stage override map ``(plan_idx, sub_idx)
        -> mode`` (the plan-time ``CostModel.comm_schedule`` decision);
        stages not in the map use ``comm_mode``.
      bucket_stride: the ``ShardedGraph.bucket_stride`` of the
        ``bucket_by_src`` layout (required whenever any stage is pipelined).
    """
    if not plans:
        raise ValueError("make_batched_count_fn needs at least one plan")
    ks = {p.k for p in plans}
    if len(ks) != 1:
        raise ValueError(f"all plans must share one k, got {sorted(ks)}")
    k = ks.pop()
    if ema_mode not in ("streamed", "loop", "vectorized"):
        raise ValueError(f"unknown ema_mode {ema_mode!r}")
    if comm_mode not in ("blocking", "pipelined"):
        raise ValueError(f"unknown comm_mode {comm_mode!r}")

    axes = tuple(mesh.axis_names)
    n_shards = int(np.prod(mesh.devices.shape))
    rows = n_padded // n_shards
    pad_unit = column_batch or 128

    comm_schedule = dict(comm_schedule or {})
    bad = {m for m in comm_schedule.values() if m not in ("blocking", "pipelined")}
    if bad:
        raise ValueError(f"unknown comm_schedule mode(s) {sorted(bad)}")
    any_pipelined = comm_mode == "pipelined" or "pipelined" in comm_schedule.values()
    if any_pipelined:
        if ema_mode != "streamed":
            raise ValueError(
                f"comm_mode='pipelined' requires ema_mode='streamed' "
                f"(got {ema_mode!r}) — the ring consumes each slice inside "
                "the fused SpMM+eMA sweep"
            )
        if column_batch is None:
            raise ValueError("comm_mode='pipelined' needs a finite column_batch")
        if len(axes) != 1:
            raise ValueError(
                f"comm_mode='pipelined' rings a single mesh axis (got {axes})"
            )
        if n_shards < 2:
            raise ValueError("comm_mode='pipelined' needs >= 2 shards")
        if bucket_stride is None or n_shards * bucket_stride != edges_per_shard:
            raise ValueError(
                "comm_mode='pipelined' needs the bucket_by_src edge layout: "
                f"bucket_stride={bucket_stride!r} with edges_per_shard="
                f"{edges_per_shard} and n_shards={n_shards}"
            )

    track_products = ema_mode != "streamed"
    if canons is not None:
        # legacy canons override: the DP walk keys states by THESE strings,
        # so the liveness schedule must be derived from them too (a plan's
        # schedule would disagree — don't build one)
        free_at = schedule_liveness(plans, canons, track_products=track_products)
    else:
        if plan_ir is None:
            # legacy surface (launch/cells probes, direct tests): plan the
            # set here — the schedule must come from ONE planner either way
            from repro.plan.ir import build_template_plan

            plan_ir = build_template_plan([p.template for p in plans], plans=plans)
        canons = plan_ir.canons
        # the plan's liveness schedule: only the non-streamed eMA modes
        # memoize aggregate products, so they free against that variant
        free_at = plan_ir.liveness(track_products=track_products)

    # --- split tables: built once, de-duplicated by (k, m, m_a).
    tables_dev = {}
    table_specs = {}
    stage_table_key = {}
    for p_idx, plan in enumerate(plans):
        for i, t in enumerate(plan.tables):
            if t is None:
                continue
            key = f"{t.k}.{t.m}.{t.m_a}"
            stage_table_key[(p_idx, i)] = key
            if key in tables_dev:
                continue
            if ema_mode == "streamed":
                tables_dev[key] = _streamed_stage_tables(t, pad_unit)
                table_specs[key] = (P(None, None),) * 4
            else:
                tables_dev[key] = (jnp.asarray(t.idx_a), jnp.asarray(t.idx_p))
                table_specs[key] = (P(None, None),) * 2

    def spmm_batched(m_p, src, dst_local, edge_mask):
        """Column-batched all-gather SpMM; m_p: (rows, B, C_pad) local."""
        bsz, c_pad = m_p.shape[1], m_p.shape[2]
        if column_batch is None:
            full = _compressed_gather(m_p, axes, gather_dtype)
            msgs = full[src].astype(accum_dtype) * edge_mask[:, None, None]
            return jax.ops.segment_sum(msgs, dst_local, num_segments=rows)
        n_batches = c_pad // column_batch

        def body(b_idx, acc):
            cols = jax.lax.dynamic_slice(
                m_p, (0, 0, b_idx * column_batch), (rows, bsz, column_batch)
            )
            full = _compressed_gather(cols, axes, gather_dtype)
            msgs = full[src].astype(accum_dtype) * edge_mask[:, None, None]
            bcol = jax.ops.segment_sum(msgs, dst_local, num_segments=rows)
            return jax.lax.dynamic_update_slice(acc, bcol, (0, 0, b_idx * column_batch))

        init = _pvary_missing(jnp.zeros(m_p.shape, accum_dtype), axes)
        return jax.lax.fori_loop(0, n_batches, body, init)

    # the bucketed consume is shared by the ring AND the single-axis
    # blocking path so the two modes fold bit-identically (see below)
    bucket_fold = bucket_stride is not None and len(axes) == 1 and n_shards >= 2

    def _bucket_partials(get_block, src, dst_local, edge_mask, bsz, cb):
        """Per-src-shard-bucket partial segment-sums, folded in ring step
        order (``owner = (my - d) mod D``).

        ``get_block(d, owner) -> (rows, B, cb)`` supplies src-shard
        ``owner``'s rows of the column batch — from the circulating ring
        slice (pipelined) or sliced out of the one all-gathered buffer
        (blocking).  Everything else — the bucket slices, the gather, the
        mask multiply, the per-bucket ``segment_sum``, and the fold order
        of the partials — is this one code path, shared by both modes.
        That sharing is the bit-exactness argument: the block values are
        elementwise identical (a gather reads the same stored floats
        whichever buffer holds them; ``ppermute`` moves bits verbatim), so
        every intermediate rounding happens on identical operands in an
        identical sequence.
        """
        ring = axes[0]
        my = jax.lax.axis_index(ring)
        bcol = _pvary_missing(jnp.zeros((rows, bsz, cb), accum_dtype), axes)
        for d in range(n_shards):
            owner = jnp.mod(my - d, n_shards)
            block = get_block(d, owner)
            b_src = jax.lax.dynamic_slice(
                src, (owner * bucket_stride,), (bucket_stride,)
            )
            b_dst = jax.lax.dynamic_slice(
                dst_local, (owner * bucket_stride,), (bucket_stride,)
            )
            b_mask = jax.lax.dynamic_slice(
                edge_mask, (owner * bucket_stride,), (bucket_stride,)
            )
            # valid slots sit in the owner's row range by the bucket
            # invariant; pad slots (mask 0) are clipped in-bounds and zeroed
            local = jnp.clip(b_src - owner * rows, 0, rows - 1)
            vals = block[local].astype(accum_dtype) * b_mask[:, None, None]
            bcol = bcol + jax.ops.segment_sum(
                vals, b_dst, num_segments=rows
            )
        return bcol

    def ring_spmm(cols, src, dst_local, edge_mask):
        """Double-buffered ring SpMM over one column batch.

        ``cols`` is this shard's ``(rows, B, cb)`` slice.  Slices circulate
        along the single mesh axis: after ``d`` hops device ``i`` holds
        shard ``(i - d) mod D``'s rows, and the ``ppermute`` for hop
        ``d + 1`` is issued BEFORE hop ``d``'s bucket is consumed, so the
        wire transfer hides under the edge gather + partial segment-sum
        (the expensive half of the SpMM).  Only two row slices are ever
        live — the full gathered buffer never materializes.
        """
        ring = axes[0]
        perm = [(j, (j + 1) % n_shards) for j in range(n_shards)]
        bsz, cb = cols.shape[1], cols.shape[2]
        state = {"cur": cols}
        if gather_dtype is not None:
            # cast to the wire dtype ONCE; hops circulate the compressed
            # payload (bf16 -> f32 -> bf16 would be lossless anyway, but
            # one cast keeps the barrier structure identical to blocking's)
            state["cur"] = jax.lax.optimization_barrier(
                cols.astype(gather_dtype)
            )

        def block(d, owner):
            cur = state["cur"]
            if d + 1 < n_shards:  # prefetch the next slice NOW
                state["cur"] = jax.lax.ppermute(cur, ring, perm)
            if gather_dtype is not None:
                return jax.lax.optimization_barrier(cur).astype(jnp.float32)
            return cur

        return _bucket_partials(block, src, dst_local, edge_mask, bsz, cb)

    def spmm_ema_streamed(
        m_p, m_a, src, dst_local, edge_mask, n_out, stream_tbl, mode="blocking"
    ):
        """Fused per-batch SpMM -> eMA: gather a column batch, reduce it, and
        immediately scatter its contributions into M_s (B never exists)."""
        cb = pad_unit
        bsz = m_p.shape[1]
        n_batches = m_p.shape[2] // cb
        ent_out, ent_ia, ent_ip, ent_valid = stream_tbl

        def body(b_idx, m_s):
            cols = jax.lax.dynamic_slice(m_p, (0, 0, b_idx * cb), (rows, bsz, cb))
            if mode == "pipelined":
                bcol = ring_spmm(cols, src, dst_local, edge_mask)
            elif bucket_fold:
                # single-axis bucketed blocking: one all-gather, then the
                # SAME per-bucket fold the ring runs — this is what makes
                # blocking and pipelined engines bit-exact A/B arms
                full = _compressed_gather(cols, axes, gather_dtype)
                bcol = _bucket_partials(
                    lambda d, owner: jax.lax.dynamic_slice(
                        full, (owner * rows, 0, 0), (rows, bsz, cb)
                    ),
                    src, dst_local, edge_mask, bsz, cb,
                )
            else:
                full = _compressed_gather(cols, axes, gather_dtype)
                msgs = full[src].astype(accum_dtype) * edge_mask[:, None, None]
                bcol = jax.ops.segment_sum(msgs, dst_local, num_segments=rows)
            eo = jax.lax.dynamic_index_in_dim(ent_out, b_idx, keepdims=False)
            ia = jax.lax.dynamic_index_in_dim(ent_ia, b_idx, keepdims=False)
            ip = jax.lax.dynamic_index_in_dim(ent_ip, b_idx, keepdims=False)
            va = jax.lax.dynamic_index_in_dim(ent_valid, b_idx, keepdims=False)
            prod = (
                jnp.take(m_a, ia, axis=2).astype(accum_dtype)
                * jnp.take(bcol, ip, axis=2)
                * va[None, None, :].astype(accum_dtype)
            )
            return m_s.at[:, :, eo].add(prod)

        init = _pvary_missing(jnp.zeros((rows, bsz, n_out), accum_dtype), axes)
        return jax.lax.fori_loop(0, n_batches, body, init)

    def ema_loop(m_a, b, idx_a, idx_p):
        """Vertex-local eMA over fused (rows, B, C) state (Algorithm 5)."""
        init = _pvary_missing(
            jnp.zeros((rows, m_a.shape[1], idx_a.shape[0]), accum_dtype), axes
        )
        return _ema_apply_fused(m_a, b, idx_a, idx_p, init)

    def local_count(colors, src, dst_local, edge_mask, tables):
        # colors: (B, rows) local slice of the (B, n_padded) coloring batch.
        def pad_c(m):
            c = m.shape[-1]
            return jnp.pad(m, ((0, 0), (0, 0), (0, _pad_cols(c, pad_unit) - c)))

        def free(pos, slots, prods):
            # Algorithm 5's in-place storage, liveness-scheduled: drop DP
            # states / memoized SpMM products after their last reader.
            for key in free_at.get(pos, ()):
                if isinstance(key, tuple):
                    prods.pop(key[1], None)
                else:
                    slots.pop(key, None)

        leaf = pad_c(jax.nn.one_hot(colors.T, k, dtype=store_dtype))  # (rows, B, k_pad)
        executed = set()
        slots = {}
        prods = {}
        totals = []
        pos = 0
        for p_idx, plan in enumerate(plans):
            pc = canons[p_idx]
            for i, sub in enumerate(plan.partition.subs):
                ckey = pc[i]
                if ckey in executed:
                    continue
                executed.add(ckey)
                if sub.is_leaf:
                    slots[ckey] = leaf
                else:
                    m_a, m_p = slots[pc[sub.active]], slots[pc[sub.passive]]
                    tkey = stage_table_key[(p_idx, i)]
                    if ema_mode == "streamed":
                        m_s = spmm_ema_streamed(
                            m_p, m_a, src, dst_local, edge_mask,
                            plan.tables[i].n_out, tables[tkey],
                            mode=comm_schedule.get((p_idx, i), comm_mode),
                        )
                    else:
                        p_key = pc[sub.passive]
                        if p_key not in prods:
                            prods[p_key] = spmm_batched(m_p, src, dst_local, edge_mask)
                        b = prods[p_key]
                        idx_a, idx_p = tables[tkey]
                        if ema_mode == "vectorized":
                            # probe mode: single gather-FMA einsum (no
                            # fori_loop) so the split-axis work is visible to
                            # cost_analysis
                            m_s = jnp.einsum(
                                "rbos,rbos->rbo",
                                jnp.take(m_a, idx_a, axis=2).astype(accum_dtype),
                                jnp.take(b, idx_p, axis=2),
                            )
                        else:
                            m_s = ema_loop(m_a, b, idx_a, idx_p)
                    slots[ckey] = pad_c(m_s.astype(store_dtype))
                free(pos, slots, prods)
                pos += 1
            root = slots[pc[plan.partition.root_index]].astype(accum_dtype)
            # reduce color sets first, then vertices, then shards: the local
            # order matches the single-host engine's per-coloring reduction
            total_local = root.sum(axis=2).sum(axis=0)
            totals.append(jax.lax.psum(total_local, axes))  # (B,), replicated
            free(pos, slots, prods)
            pos += 1
        return jnp.stack(totals, axis=1).astype(jnp.float32)  # (B, T)

    sharded = P(axes)
    mapped = compat.shard_map(
        local_count,
        mesh=mesh,
        in_specs=(P(None, axes), sharded, sharded, sharded, table_specs),
        out_specs=P(None, None),
    )

    def count(colors_batch, src, dst_local, edge_mask):
        return mapped(colors_batch, src, dst_local, edge_mask, tables_dev)

    return count


def make_distributed_count_fn(
    plan: CountingPlan,
    mesh: Mesh,
    n_padded: int,
    edges_per_shard: int,
    column_batch: Optional[int] = 128,
    ema_mode: str = "loop",
    gather_dtype=None,
):
    """One-coloring, one-template distributed count (compat / probe surface).

    A thin ``B=1`` wrapper over :func:`make_batched_count_fn` — kept for the
    dry-run/probe tooling (``launch.cells``) and ad-hoc single-coloring
    checks.  Estimation runs should use the engine's ``mesh`` backend
    (``CountingEngine(..., backend="mesh", mesh=mesh)``), which batches
    chunks of colorings into each collective.

    Signature of the returned fn::

      (colors (n_padded,) i32, src (S*E,) i32, dst_local (S*E,) i32,
       edge_mask (S*E,) f32) -> scalar f32 raw colorful total

    Split tables are built once here and closure-captured (they are no
    longer an argument).
    """
    batched = make_batched_count_fn(
        [plan],
        mesh,
        n_padded,
        edges_per_shard,
        column_batch=column_batch,
        ema_mode=ema_mode,
        gather_dtype=gather_dtype,
    )

    def count(colors, src, dst_local, edge_mask):
        return batched(colors[None, :], src, dst_local, edge_mask)[0, 0]

    return count


def distributed_input_specs(n_padded: int, n_shards: int, edges_per_shard: int):
    """ShapeDtypeStructs for the one-coloring distributed count (dry-run)."""
    e_total = n_shards * edges_per_shard
    return (
        jax.ShapeDtypeStruct((n_padded,), jnp.int32),   # colors
        jax.ShapeDtypeStruct((e_total,), jnp.int32),    # src (global)
        jax.ShapeDtypeStruct((e_total,), jnp.int32),    # dst (local)
        jax.ShapeDtypeStruct((e_total,), jnp.float32),  # edge mask
    )
