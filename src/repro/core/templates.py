"""Templates (trees AND general graphs), partitioning, tree decompositions.

A *template* is a connected graph on ``k`` vertices labeled ``0..k-1``.  Two
compilation routes feed the color-coding DP:

**Trees** (the paper's case) are partitioned into a binary recursion tree of
*sub-templates* (paper §II-C / Fig 2):

* pick a root ``rho`` of ``T``;
* cut one edge ``(rho, tau)`` adjacent to the root — the child keeping ``rho``
  is the **active** child, the child rooted at ``tau`` is the **passive**
  child;
* recurse until every sub-template is a single vertex.

``partition_template`` returns the sub-templates in *topological order*
(children before parents) so the DP can run as a single forward pass.

**General templates** (triangles, cycles, cliques, graphlets) compile through
a *tree decomposition* instead (Chakaravarthy et al., arXiv:1602.04478): the
colorful-counting recurrence runs over decomposition bags, and because a
colorful homomorphism is automatically injective (its ``k`` images carry
pairwise-distinct colors), counting colorful homs over the bags counts
colorful embeddings times ``|Aut(H)|`` — the same normalization as trees.
``build_tree_decomposition`` finds a (minimum-width for small ``k``) rooted
decomposition and ``build_bag_program`` lowers it to a linear *bag program*
of leaf / extend / forget / join ops whose states generalize the tree DP's
``M`` matrices to one vertex axis per live bag vertex.  Rooted trees are
exactly the treewidth-1 special case (single-axis states, no joins).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from functools import lru_cache
from math import factorial
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

import numpy as np

__all__ = [
    "Template",
    "SubTemplate",
    "TemplatePartition",
    "partition_template",
    "sub_template_canonical",
    "tree_automorphisms",
    "graph_automorphisms",
    "TreeDecomposition",
    "build_tree_decomposition",
    "BagOp",
    "BagProgram",
    "build_bag_program",
    "bag_state_canonical",
    "path_template",
    "star_template",
    "binary_tree_template",
    "random_tree_template",
    "cycle_template",
    "clique_template",
    "diamond_template",
    "connected_graphlets",
    "PAPER_TEMPLATES",
    "GRAPHLET_TEMPLATES",
    "get_template",
]


@dataclass(frozen=True)
class Template:
    """An unrooted connected template on ``k`` vertices (tree or not)."""

    name: str
    edges: Tuple[Tuple[int, int], ...]

    @property
    def k(self) -> int:
        # Connected ⇒ every vertex of a >=2-vertex template appears in an
        # edge, so the label range determines k (for trees this equals the
        # historical ``len(edges) + 1``).
        if not self.edges:
            return 1
        return max(max(u, v) for u, v in self.edges) + 1

    @property
    def is_tree(self) -> bool:
        """Acyclic (``|E| = k - 1``); ``validate()`` covers connectivity."""
        return len({frozenset(e) for e in self.edges}) == self.k - 1

    def adjacency(self) -> List[List[int]]:
        adj: List[List[int]] = [[] for _ in range(self.k)]
        for u, v in self.edges:
            adj[u].append(v)
            adj[v].append(u)
        return adj

    def edge_set(self) -> FrozenSet[FrozenSet[int]]:
        return frozenset(frozenset(e) for e in self.edges)

    def validate(self) -> None:
        k = self.k
        seen = {u for e in self.edges for u in e}
        if self.edges and (max(seen) >= k or min(seen) < 0):
            raise ValueError(f"template {self.name}: vertex labels must be 0..{k-1}")
        if self.edges and len(seen) != k:
            raise ValueError(f"template {self.name}: not connected")
        for u, v in self.edges:
            if u == v:
                raise ValueError(f"template {self.name}: self-loop at {u}")
        if len(self.edge_set()) != len(self.edges):
            raise ValueError(f"template {self.name}: duplicate edges")
        adj = self.adjacency()
        stack, visited = [0], {0}
        while stack:
            u = stack.pop()
            for v in adj[u]:
                if v not in visited:
                    visited.add(v)
                    stack.append(v)
        if len(visited) != k:
            raise ValueError(f"template {self.name}: not connected")


@dataclass(frozen=True)
class SubTemplate:
    """One node of the partition recursion tree.

    ``vertices`` is the subset of template vertices covered; ``root`` the
    rooted vertex.  Non-leaf sub-templates reference their active / passive
    children by index into ``TemplatePartition.subs``.
    """

    vertices: Tuple[int, ...]
    root: int
    active: Optional[int]  # index into partition list, or None for leaves
    passive: Optional[int]

    @property
    def size(self) -> int:
        return len(self.vertices)

    @property
    def is_leaf(self) -> bool:
        return self.active is None


@dataclass(frozen=True)
class TemplatePartition:
    """Topologically-ordered sub-template list; ``subs[-1]`` is the full T."""

    template: Template
    subs: Tuple[SubTemplate, ...]

    @property
    def root_index(self) -> int:
        return len(self.subs) - 1

    def stage_sizes(self) -> List[Tuple[int, int, int]]:
        """(m, m_a, m_p) for every non-leaf sub-template, in DP order."""
        out = []
        for s in self.subs:
            if not s.is_leaf:
                a = self.subs[s.active]
                p = self.subs[s.passive]
                out.append((s.size, a.size, p.size))
        return out


def partition_template(template: Template, root: Optional[int] = None) -> TemplatePartition:
    """FASCIA-style single-edge-cut partition into a binary recursion tree.

    The root defaults to a maximum-degree vertex (keeps the active chain long
    and passive subtrees small, which minimizes the number of distinct
    ``(m, m_p)`` SpMM column counts).
    """
    template.validate()
    if not template.is_tree:
        raise ValueError(
            f"template {template.name}: partition_template requires a tree; "
            "non-tree templates compile via build_bag_program"
        )
    adj = template.adjacency()
    if root is None:
        root = int(np.argmax([len(a) for a in adj]))

    subs: List[SubTemplate] = []

    def subtree_vertices(start: int, blocked: int) -> Tuple[int, ...]:
        """Vertices reachable from ``start`` without crossing ``blocked``."""
        out, stack, seen = [], [start], {start, blocked}
        while stack:
            u = stack.pop()
            out.append(u)
            for v in adj[u]:
                if v not in seen:
                    seen.add(v)
                    stack.append(v)
        return tuple(sorted(out))

    def rec(vertices: Tuple[int, ...], rho: int) -> int:
        if len(vertices) == 1:
            subs.append(SubTemplate(vertices=vertices, root=rho, active=None, passive=None))
            return len(subs) - 1
        vset = set(vertices)
        # Cut the first root-adjacent edge (deterministic: smallest neighbor).
        taus = sorted(v for v in adj[rho] if v in vset)
        tau = taus[0]
        passive_vertices = tuple(v for v in subtree_vertices(tau, rho) if v in vset)
        active_vertices = tuple(sorted(vset - set(passive_vertices)))
        a_idx = rec(active_vertices, rho)
        p_idx = rec(passive_vertices, tau)
        subs.append(SubTemplate(vertices=vertices, root=rho, active=a_idx, passive=p_idx))
        return len(subs) - 1

    rec(tuple(sorted(range(template.k))), root)
    return TemplatePartition(template=template, subs=tuple(subs))


def sub_template_canonical(template: Template, vertices: Tuple[int, ...], root: int) -> str:
    """AHU canonical string of the rooted sub-template induced by ``vertices``.

    Two sub-templates with equal strings have identical count matrices
    ``M_s`` for every coloring — the key used by the engine backends to share
    DP state and SpMM products across templates (and across stages within one
    template).
    """
    allowed = set(vertices)
    adj: Dict[int, List[int]] = {v: [] for v in vertices}
    for u, v in template.edges:
        if u in allowed and v in allowed:
            adj[u].append(v)
            adj[v].append(u)

    def canon(node: int, parent: int) -> str:
        forms = sorted(canon(c, node) for c in adj[node] if c != parent)
        return "(" + "".join(forms) + ")"

    return canon(root, -1)


# ---------------------------------------------------------------------------
# Automorphism counting (AHU canonical forms).
# ---------------------------------------------------------------------------


def _rooted_canon_and_aut(adj: Sequence[Sequence[int]], root: int, parent: int) -> Tuple[str, int]:
    """AHU canonical string + automorphism count of the subtree at ``root``."""
    forms: List[str] = []
    aut = 1
    for child in adj[root]:
        if child == parent:
            continue
        f, a = _rooted_canon_and_aut(adj, child, root)
        forms.append(f)
        aut *= a
    forms.sort()
    counts: Dict[str, int] = {}
    for f in forms:
        counts[f] = counts.get(f, 0) + 1
    for c in counts.values():
        aut *= factorial(c)
    return "(" + "".join(forms) + ")", aut


def tree_automorphisms(template: Template) -> int:
    """|Aut(T)| of an unrooted tree via its center(s)."""
    template.validate()
    k = template.k
    if k == 1:
        return 1
    adj = [list(a) for a in template.adjacency()]
    # Peel leaves to find the 1- or 2-vertex center.
    degree = [len(a) for a in adj]
    remaining = k
    layer = [v for v in range(k) if degree[v] <= 1]
    removed = [False] * k
    while remaining > 2:
        nxt = []
        for v in layer:
            removed[v] = True
            remaining -= 1
            for u in adj[v]:
                if not removed[u]:
                    degree[u] -= 1
                    if degree[u] == 1:
                        nxt.append(u)
        layer = nxt
    centers = [v for v in range(k) if not removed[v]]
    if len(centers) == 1:
        _, aut = _rooted_canon_and_aut(adj, centers[0], -1)
        return aut
    c1, c2 = centers
    f1, a1 = _rooted_canon_and_aut(adj, c1, c2)
    f2, a2 = _rooted_canon_and_aut(adj, c2, c1)
    aut = a1 * a2
    if f1 == f2:
        aut *= 2  # the edge flip
    return aut


@lru_cache(maxsize=None)
def graph_automorphisms(template: Template) -> int:
    """|Aut(H)| of a general connected template.

    Trees go through the linear-time AHU path; everything else brute-forces
    the k! vertex bijections (graphlet templates have k <= 8, where this is
    at most 40320 cheap set-membership checks).
    """
    template.validate()
    if template.is_tree:
        return tree_automorphisms(template)
    k = template.k
    if k > 8:
        raise ValueError(f"template {template.name}: automorphism search capped at k=8 (got k={k})")
    edges = template.edge_set()
    count = 0
    for perm in itertools.permutations(range(k)):
        if all(frozenset((perm[u], perm[v])) in edges for u, v in template.edges):
            count += 1
    return count


# ---------------------------------------------------------------------------
# Template constructors and the paper's template library.
# ---------------------------------------------------------------------------


def path_template(k: int, name: Optional[str] = None) -> Template:
    return Template(name or f"path{k}", tuple((i, i + 1) for i in range(k - 1)))


def star_template(k: int, name: Optional[str] = None) -> Template:
    return Template(name or f"star{k}", tuple((0, i) for i in range(1, k)))


def binary_tree_template(k: int, name: Optional[str] = None) -> Template:
    """Complete-ish binary tree on k vertices (heap numbering)."""
    return Template(name or f"bintree{k}", tuple(((i - 1) // 2, i) for i in range(1, k)))


def random_tree_template(k: int, seed: int, name: Optional[str] = None) -> Template:
    """Uniform random labeled tree from a Prüfer sequence (deterministic)."""
    rng = np.random.default_rng(seed)
    if k == 1:
        return Template(name or f"rand{k}", ())
    if k == 2:
        return Template(name or f"rand{k}", ((0, 1),))
    prufer = rng.integers(0, k, size=k - 2)
    degree = np.ones(k, dtype=np.int64)
    for x in prufer:
        degree[x] += 1
    edges = []
    import heapq

    leaves = [v for v in range(k) if degree[v] == 1]
    heapq.heapify(leaves)
    for x in prufer:
        leaf = heapq.heappop(leaves)
        edges.append((int(leaf), int(x)))
        degree[leaf] -= 1
        degree[x] -= 1
        if degree[x] == 1:
            heapq.heappush(leaves, int(x))
    u, v = [v for v in range(k) if degree[v] == 1][:2]
    edges.append((u, v))
    return Template(name or f"rand{k}", tuple(edges))


def cycle_template(k: int, name: Optional[str] = None) -> Template:
    if k < 3:
        raise ValueError(f"cycle requires k >= 3, got {k}")
    return Template(name or f"cycle{k}", tuple((i, i + 1) for i in range(k - 1)) + ((0, k - 1),))


def clique_template(k: int, name: Optional[str] = None) -> Template:
    return Template(name or f"clique{k}", tuple(itertools.combinations(range(k), 2)))


def diamond_template(name: str = "diamond") -> Template:
    """K4 minus one edge: two triangles sharing edge (1, 2)."""
    return Template(name, ((0, 1), (0, 2), (1, 2), (1, 3), (2, 3)))


def _graph_canonical_edges(k: int, edges: Tuple[Tuple[int, int], ...]) -> Tuple[Tuple[int, int], ...]:
    """Lexicographically-minimal relabeling of an edge set (graph canon)."""
    best = None
    for perm in itertools.permutations(range(k)):
        relabeled = tuple(sorted(tuple(sorted((perm[u], perm[v]))) for u, v in edges))
        if best is None or relabeled < best:
            best = relabeled
    return best


@lru_cache(maxsize=None)
def connected_graphlets(k: int) -> Tuple[Template, ...]:
    """All connected k-vertex templates up to isomorphism, deterministically
    labeled/ordered (by edge count, then canonical edge list).

    Sizes: k=2 -> 1, k=3 -> 2, k=4 -> 6, k=5 -> 21.
    """
    if not 1 <= k <= 6:
        raise ValueError(f"connected_graphlets supports 1 <= k <= 6, got {k}")
    if k == 1:
        return (Template("g1-0", ()),)
    all_edges = list(itertools.combinations(range(k), 2))
    canons: Set[Tuple[Tuple[int, int], ...]] = set()
    for bits in range(1 << len(all_edges)):
        edges = tuple(e for i, e in enumerate(all_edges) if (bits >> i) & 1)
        if len(edges) < k - 1:
            continue
        # Connectivity over all k vertices.
        adj: Dict[int, List[int]] = {v: [] for v in range(k)}
        for u, v in edges:
            adj[u].append(v)
            adj[v].append(u)
        stack, seen = [0], {0}
        while stack:
            u = stack.pop()
            for v in adj[u]:
                if v not in seen:
                    seen.add(v)
                    stack.append(v)
        if len(seen) != k:
            continue
        canons.add(_graph_canonical_edges(k, edges))
    ordered = sorted(canons, key=lambda es: (len(es), es))
    return tuple(Template(f"g{k}-{i}", es) for i, es in enumerate(ordered))


def _u5_2() -> Template:
    # 5-vertex "chair": path 0-1-2-3 with 4 hanging off 1.
    return Template("u5-2", ((0, 1), (1, 2), (2, 3), (1, 4)))


def _u7() -> Template:
    # FASCIA's u7: two cherries joined by a center path.
    return Template("u7", ((0, 1), (1, 2), (1, 3), (0, 4), (4, 5), (4, 6)))


def _u10() -> Template:
    return Template(
        "u10",
        ((0, 1), (1, 2), (2, 3), (1, 4), (4, 5), (0, 6), (6, 7), (6, 8), (8, 9)),
    )


def _u12() -> Template:
    # Paper Fig 6(b) family: balanced tree of depth ~3.
    return Template(
        "u12",
        (
            (0, 1), (0, 2), (1, 3), (1, 4), (2, 5), (2, 6),
            (3, 7), (4, 8), (5, 9), (6, 10), (10, 11),
        ),
    )


PAPER_TEMPLATES: Dict[str, Template] = {
    "u3": path_template(3, "u3"),
    "u5-1": path_template(5, "u5-1"),
    "u5-2": _u5_2(),
    "u6": binary_tree_template(6, "u6"),
    "u7": _u7(),
    "u10": _u10(),
    "u12": _u12(),
    "u13": random_tree_template(13, seed=13, name="u13"),
    "u14": random_tree_template(14, seed=14, name="u14"),
    "u15-1": random_tree_template(15, seed=151, name="u15-1"),
    "u15-2": random_tree_template(15, seed=152, name="u15-2"),
    "u16": random_tree_template(16, seed=16, name="u16"),
    "u17": random_tree_template(17, seed=17, name="u17"),
    "u18": random_tree_template(18, seed=18, name="u18"),
    "u20": random_tree_template(20, seed=20, name="u20"),
}


GRAPHLET_TEMPLATES: Dict[str, Template] = {
    "triangle": cycle_template(3, "triangle"),
    "square": cycle_template(4, "square"),
    "diamond": diamond_template(),
    "cycle5": cycle_template(5, "cycle5"),
    "clique4": clique_template(4, "clique4"),
    "clique5": clique_template(5, "clique5"),
}


def get_template(name: str) -> Template:
    if name in PAPER_TEMPLATES:
        return PAPER_TEMPLATES[name]
    if name in GRAPHLET_TEMPLATES:
        return GRAPHLET_TEMPLATES[name]
    if name.startswith("path"):
        return path_template(int(name[4:]))
    if name.startswith("star"):
        return star_template(int(name[4:]))
    if name.startswith("bintree"):
        return binary_tree_template(int(name[7:]))
    if name.startswith("cycle"):
        return cycle_template(int(name[5:]))
    if name.startswith("clique"):
        return clique_template(int(name[6:]))
    known = sorted(PAPER_TEMPLATES) + sorted(GRAPHLET_TEMPLATES)
    raise KeyError(f"unknown template {name!r}; known: {known}")


# ---------------------------------------------------------------------------
# Tree decompositions (general templates).
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TreeDecomposition:
    """A rooted tree decomposition of a template.

    ``bags[i]`` is a sorted vertex tuple; ``parent[i]`` indexes the parent
    bag (-1 for the root).  The standard properties hold: every template
    edge lies inside some bag, and for every vertex the bags containing it
    form a connected subtree.  ``width`` = max bag size - 1 (trees: 1).
    """

    template: Template
    bags: Tuple[Tuple[int, ...], ...]
    parent: Tuple[int, ...]
    width: int

    @property
    def root_index(self) -> int:
        return self.parent.index(-1)

    def children(self) -> List[List[int]]:
        out: List[List[int]] = [[] for _ in self.bags]
        for i, p in enumerate(self.parent):
            if p >= 0:
                out[p].append(i)
        return out


def _elimination_width(adj: Sequence[Set[int]], order: Sequence[int]) -> int:
    """Width of the elimination order (max |later-neighbors| after fill-in)."""
    fill = [set(a) for a in adj]
    eliminated: Set[int] = set()
    width = 0
    for v in order:
        nbrs = fill[v] - eliminated
        width = max(width, len(nbrs))
        for a in nbrs:
            fill[a].update(nbrs)
            fill[a].discard(a)
        eliminated.add(v)
    return width


def _min_fill_order(adj: Sequence[Set[int]]) -> List[int]:
    """Greedy min-fill elimination order (exact on chordal graphs and trees)."""
    k = len(adj)
    fill = [set(a) for a in adj]
    remaining = set(range(k))
    order: List[int] = []
    while remaining:
        best_v, best_cost = -1, None
        for v in sorted(remaining):
            nbrs = fill[v] & remaining - {v}
            cost = sum(1 for a, b in itertools.combinations(sorted(nbrs), 2) if b not in fill[a])
            if best_cost is None or cost < best_cost:
                best_v, best_cost = v, cost
        nbrs = fill[best_v] & remaining - {best_v}
        for a in nbrs:
            fill[a].update(nbrs)
            fill[a].discard(a)
        order.append(best_v)
        remaining.discard(best_v)
    return order


@lru_cache(maxsize=None)
def build_tree_decomposition(template: Template) -> TreeDecomposition:
    """Minimum-width rooted tree decomposition (exact for k <= 8).

    Elimination-order construction: min-fill greedy first; if that is not
    already optimal-by-construction (width 1, i.e. a tree) and the template
    is small, an exhaustive search over the k! orders finds the true
    treewidth (early exit at width 2, the minimum for any non-tree).
    Redundant bags (subsets of a neighbor) are pruned, so trees yield the
    familiar one-bag-per-edge decomposition.
    """
    template.validate()
    k = template.k
    adj = [set(a) for a in template.adjacency()]
    order = _min_fill_order(adj)
    width = _elimination_width(adj, order)
    if width > 1 and k <= 8:
        floor = 2  # non-trees can never do better than treewidth 2
        for perm in itertools.permutations(range(k)):
            w = _elimination_width(adj, perm)
            if w < width:
                order, width = list(perm), w
                if width <= floor:
                    break

    # Re-run the elimination to materialize bags.
    pos = {v: i for i, v in enumerate(order)}
    fill = [set(a) for a in adj]
    eliminated: Set[int] = set()
    bags: List[Tuple[int, ...]] = []
    for v in order:
        nbrs = fill[v] - eliminated
        bags.append(tuple(sorted({v} | nbrs)))
        for a in nbrs:
            fill[a].update(nbrs)
            fill[a].discard(a)
        eliminated.add(v)
    # parent(bag of v) = bag of the earliest-eliminated later-neighbor.
    parent: List[int] = []
    for i, v in enumerate(order):
        rest = [u for u in bags[i] if u != v]
        parent.append(min((pos[u] for u in rest), default=-1))

    # Prune bags subsumed by a tree-neighbor.
    bag_of: Dict[int, Set[int]] = {i: set(b) for i, b in enumerate(bags)}
    par: Dict[int, int] = {i: p for i, p in enumerate(parent)}
    changed = True
    while changed:
        changed = False
        for i in sorted(par):
            p = par[i]
            if p < 0:
                continue
            if bag_of[i] <= bag_of[p]:
                for j in par:
                    if par[j] == i:
                        par[j] = p
                del par[i], bag_of[i]
                changed = True
                break
            if bag_of[p] <= bag_of[i]:
                gp = par[p]
                for j in par:
                    if par[j] == p and j != i:
                        par[j] = i
                par[i] = gp
                del par[p], bag_of[p]
                changed = True
                break
    keep = sorted(par)
    remap = {old: new for new, old in enumerate(keep)}
    final_bags = tuple(tuple(sorted(bag_of[i])) for i in keep)
    final_parent = tuple(remap[par[i]] if par[i] >= 0 else -1 for i in keep)
    return TreeDecomposition(template=template, bags=final_bags, parent=final_parent, width=width)


# ---------------------------------------------------------------------------
# Bag programs: lowering a tree decomposition to a linear DP op sequence.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class BagOp:
    """One step of a bag program.

    The DP state after an op is a tensor of shape ``(n,) * len(axes) + (B,
    C(k, m))`` where ``axes`` is the sorted tuple of template vertices kept
    as graph-vertex axes and ``m = len(covered)`` is the colorset width —
    entry ``[u_a1, ..., u_ar, b, S]`` counts colorful homomorphisms of the
    subgraph induced by ``covered`` that map axis vertex ``a_i`` to graph
    vertex ``u_ai`` and use exactly the colors ``S`` under coloring ``b``.
    Rooted-tree DP states are the ``len(axes) == 1`` special case.

    Kinds:

    * ``"leaf"``   — materialize the one-hot state of single ``vertex``.
    * ``"extend"`` — introduce ``vertex`` as a new axis.  If ``spmm_vertex``
      is set, that input axis is contracted through the adjacency matrix
      (``backend.spmm``), applying edge ``(spmm_vertex, vertex)``; every
      edge ``(vertex, x)`` for ``x`` in ``mask_vertices`` is applied as a
      dense-adjacency mask; colorset columns are updated against the
      vertex's one-hot leaf via ``SplitTable(k, m, 1)``; finally
      ``forget_vertices`` axes (fully-applied, never needed again) are
      summed out.
    * ``"forget"`` — sum out ``forget_vertices`` (no color change).
    * ``"join"``   — color-subset convolution (``UnionSplitTable``) of two
      states whose axes agree exactly and whose covered sets intersect
      exactly in the bag; the distinct-colors constraint makes the product
      correct without any inclusion-exclusion.

    ``inputs`` index earlier ops in the program; ``canon`` is the state's
    canonical form (shared across templates, and with tree-partition
    sub-templates whenever the covered subgraph is a tree on one axis).
    """

    kind: str
    inputs: Tuple[int, ...]
    vertex: Optional[int]
    spmm_vertex: Optional[int]
    mask_vertices: Tuple[int, ...]
    forget_vertices: Tuple[int, ...]
    axes: Tuple[int, ...]
    covered: Tuple[int, ...]
    canon: str

    @property
    def m(self) -> int:
        return len(self.covered)


@dataclass(frozen=True)
class BagProgram:
    """Topologically-ordered bag ops; ``ops[-1]`` is the full template."""

    template: Template
    decomposition: TreeDecomposition
    ops: Tuple[BagOp, ...]

    @property
    def width(self) -> int:
        return self.decomposition.width

    @property
    def max_axes(self) -> int:
        """Peak tensor rank (vertex axes) over the program, pre-forget."""
        return max(len(op.axes) + len(op.forget_vertices) for op in self.ops)


@lru_cache(maxsize=None)
def bag_state_canonical(template: Template, covered: Tuple[int, ...], axes: Tuple[int, ...]) -> str:
    """Canonical form of a bag DP state.

    Two states with equal canons hold identical tensors for every graph and
    coloring.  When the covered-induced subgraph is a tree carried on a
    single axis, the rooted AHU string is used so the state shares canon
    (and therefore DP slots and SpMM products) with tree-partition
    sub-template states across template families.  Otherwise the canon is
    the lexicographically-minimal relabeling of ``(axes, induced edges)``
    over bijections ``covered -> 0..m-1``, prefixed with ``"bag:"`` so it
    can never collide with an AHU string.
    """
    cov = set(covered)
    m = len(covered)
    induced = tuple((u, v) for u, v in template.edges if u in cov and v in cov)
    if len(axes) == 1 and len(induced) == m - 1:
        adj: Dict[int, List[int]] = {v: [] for v in covered}
        for u, v in induced:
            adj[u].append(v)
            adj[v].append(u)
        stack, seen = [axes[0]], {axes[0]}
        while stack:
            u = stack.pop()
            for v in adj[u]:
                if v not in seen:
                    seen.add(v)
                    stack.append(v)
        if len(seen) == m:  # connected + |E| = m-1: a tree rooted at the axis
            return sub_template_canonical(template, covered, axes[0])
    if m > 9:
        raise ValueError(f"bag canonical form capped at m=9 states (got m={m})")
    best = None
    for perm in itertools.permutations(range(m)):
        sigma = dict(zip(covered, perm))
        es = tuple(sorted(tuple(sorted((sigma[u], sigma[v]))) for u, v in induced))
        ax = tuple(sigma[a] for a in axes)
        key = (ax, es)
        if best is None or key < best:
            best = key
    return f"bag:m={m};axes={best[0]};edges={best[1]}"


@dataclass
class _BagState:
    op: int
    axes: Tuple[int, ...]
    covered: FrozenSet[int]
    applied: FrozenSet[FrozenSet[int]]


class _BagCompiler:
    """Lowers a rooted tree decomposition into a ``BagProgram``.

    Invariant at every op boundary: ``applied`` equals the set of template
    edges with both endpoints covered (an endpoint is only ever summed out
    once all of its edges are applied), so ``(covered, axes)`` fully
    determines the state and its canonical form.
    """

    def __init__(self, template: Template, decomp: TreeDecomposition):
        self.t = template
        self.edges: Set[FrozenSet[int]] = {frozenset(e) for e in template.edges}
        self.adj = template.adjacency()
        self.decomp = decomp
        self.children = decomp.children()
        self.ops: List[BagOp] = []
        # outside_need[nd] = vertices appearing in bags outside subtree(nd):
        # those must survive nd's processing as live axes.
        n_nodes = len(decomp.bags)

        def node_set(nd: int) -> Set[int]:
            s = {nd}
            for c in self.children[nd]:
                s |= node_set(c)
            return s

        self.outside_need: Dict[int, FrozenSet[int]] = {}
        for nd in range(n_nodes):
            inside = node_set(nd)
            outside: Set[int] = set()
            for j in range(n_nodes):
                if j not in inside:
                    outside |= set(decomp.bags[j])
            self.outside_need[nd] = frozenset(outside)

    # -- helpers ----------------------------------------------------------

    def _edges_of(self, x: int) -> Set[FrozenSet[int]]:
        return {frozenset((x, y)) for y in self.adj[x]}

    def _unapplied(self, x: int, applied: FrozenSet[FrozenSet[int]]) -> Set[FrozenSet[int]]:
        return self._edges_of(x) - applied

    def _emit(self, kind, inputs, vertex, spmm_vertex, masks, forgets, axes, covered) -> int:
        covered_t = tuple(sorted(covered))
        canon = bag_state_canonical(self.t, covered_t, axes)
        self.ops.append(
            BagOp(
                kind=kind,
                inputs=tuple(inputs),
                vertex=vertex,
                spmm_vertex=spmm_vertex,
                mask_vertices=tuple(masks),
                forget_vertices=tuple(forgets),
                axes=axes,
                covered=covered_t,
                canon=canon,
            )
        )
        return len(self.ops) - 1

    def _intro_order(self, covered: Set[int], targets: Set[int]) -> List[int]:
        """Introduce bag vertices adjacent to the covered set first (keeps
        broadcast introductions — no incident edge yet — to a minimum)."""
        order: List[int] = []
        cov = set(covered)
        rest = set(targets)
        while rest:
            adjacent = sorted(x for x in rest if any(frozenset((x, y)) in self.edges for y in cov))
            pick = adjacent[0] if adjacent else min(rest)
            order.append(pick)
            cov.add(pick)
            rest.discard(pick)
        return order

    # -- op constructors --------------------------------------------------

    def _leaf(self, w: int) -> _BagState:
        idx = self._emit("leaf", (), w, None, (), (), (w,), {w})
        return _BagState(idx, (w,), frozenset({w}), frozenset())

    def _intro(self, st: _BagState, w: int, needed: FrozenSet[int], allow_elim: bool) -> _BagState:
        assert w not in st.covered, (w, st)
        w_nbr_axes = [x for x in st.axes if frozenset((x, w)) in self.edges]
        spmm_vertex: Optional[int] = None
        if allow_elim:
            for x in w_nbr_axes:
                if x not in needed and self._unapplied(x, st.applied) <= {frozenset((x, w))}:
                    spmm_vertex = x
                    break
        applied = set(st.applied)
        for x in w_nbr_axes:
            applied.add(frozenset((x, w)))
        applied_f = frozenset(applied)
        masks = tuple(x for x in w_nbr_axes if x != spmm_vertex)
        covered = st.covered | {w}
        mid_axes = tuple(sorted((set(st.axes) - {spmm_vertex}) | {w}))
        forgets: Tuple[int, ...] = ()
        if allow_elim:
            forgets = tuple(
                x for x in mid_axes if x not in needed and not self._unapplied(x, applied_f)
            )
        out_axes = tuple(x for x in mid_axes if x not in forgets)
        idx = self._emit("extend", (st.op,), w, spmm_vertex, masks, forgets, out_axes, covered)
        return _BagState(idx, out_axes, covered, applied_f)

    def _forget_to(self, st: _BagState, keep: Set[int]) -> _BagState:
        pending = tuple(x for x in st.axes if x not in keep)
        if not pending:
            return st
        for x in pending:
            assert not self._unapplied(x, st.applied), (x, self._unapplied(x, st.applied))
        out_axes = tuple(x for x in st.axes if x in keep)
        idx = self._emit("forget", (st.op,), None, None, (), pending, out_axes, st.covered)
        return _BagState(idx, out_axes, st.covered, st.applied)

    def _morph(self, st: _BagState, nd: int, strict: bool) -> _BagState:
        bag = set(self.decomp.bags[nd])
        needed = self.outside_need[nd] | (frozenset(bag) if strict else frozenset())
        st = self._forget_to(st, bag)
        for w in self._intro_order(set(st.covered), bag - st.covered):
            st = self._intro(st, w, needed, allow_elim=not strict)
        if strict:
            assert st.axes == tuple(sorted(bag)), (st.axes, bag)
        return st

    def _join(self, s1: _BagState, s2: _BagState, bag: Set[int]) -> _BagState:
        assert s1.axes == s2.axes == tuple(sorted(bag)), (s1.axes, s2.axes, bag)
        assert s1.covered & s2.covered == frozenset(bag), (s1.covered, s2.covered, bag)
        covered = s1.covered | s2.covered
        idx = self._emit("join", (s1.op, s2.op), None, None, (), (), s1.axes, covered)
        return _BagState(idx, s1.axes, covered, s1.applied | s2.applied)

    # -- driver -----------------------------------------------------------

    def _compile(self, nd: int) -> _BagState:
        kids = self.children[nd]
        bag = set(self.decomp.bags[nd])
        if not kids:
            order = self._intro_order(set(), bag)
            st = self._leaf(order[0])
            for w in order[1:]:
                st = self._intro(st, w, self.outside_need[nd], allow_elim=True)
            return st
        if len(kids) == 1:
            return self._morph(self._compile(kids[0]), nd, strict=False)
        states = [self._morph(self._compile(c), nd, strict=True) for c in kids]
        st = states[0]
        for other in states[1:]:
            st = self._join(st, other, bag)
        return st

    def run(self) -> BagProgram:
        st = self._compile(self.decomp.root_index)
        assert st.covered == frozenset(range(self.t.k)), st
        assert not (self.edges - st.applied), self.edges - st.applied
        if st.axes:
            self._forget_to(st, set())
        return BagProgram(template=self.t, decomposition=self.decomp, ops=tuple(self.ops))


@lru_cache(maxsize=None)
def build_bag_program(template: Template) -> BagProgram:
    """Compile a template's tree decomposition into a linear bag program.

    Works for any connected template; the counting pipeline uses it for
    non-trees (trees take the partition route, which this generalizes).
    """
    template.validate()
    decomp = build_tree_decomposition(template)
    return _BagCompiler(template, decomp).run()
