"""Tree templates, FASCIA-style partitioning, and automorphism counting.

A *template* is an unrooted tree on ``k`` vertices labeled ``0..k-1``.  The
color-coding dynamic program requires the template to be partitioned into a
binary recursion tree of *sub-templates* (paper §II-C / Fig 2):

* pick a root ``rho`` of ``T``;
* cut one edge ``(rho, tau)`` adjacent to the root — the child keeping ``rho``
  is the **active** child, the child rooted at ``tau`` is the **passive**
  child;
* recurse until every sub-template is a single vertex.

``partition_template`` returns the sub-templates in *topological order*
(children before parents) so the DP can run as a single forward pass.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import lru_cache
from math import factorial
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "Template",
    "SubTemplate",
    "TemplatePartition",
    "partition_template",
    "sub_template_canonical",
    "tree_automorphisms",
    "path_template",
    "star_template",
    "binary_tree_template",
    "random_tree_template",
    "PAPER_TEMPLATES",
    "get_template",
]


@dataclass(frozen=True)
class Template:
    """An unrooted tree template on ``k`` vertices."""

    name: str
    edges: Tuple[Tuple[int, int], ...]

    @property
    def k(self) -> int:
        return len(self.edges) + 1

    def adjacency(self) -> List[List[int]]:
        adj: List[List[int]] = [[] for _ in range(self.k)]
        for u, v in self.edges:
            adj[u].append(v)
            adj[v].append(u)
        return adj

    def validate(self) -> None:
        k = self.k
        seen = {u for e in self.edges for u in e}
        if self.edges and (max(seen) >= k or min(seen) < 0):
            raise ValueError(f"template {self.name}: vertex labels must be 0..{k-1}")
        # Connectivity + acyclicity follows from |E| = |V|-1 + connected.
        adj = self.adjacency()
        stack, visited = [0], {0}
        while stack:
            u = stack.pop()
            for v in adj[u]:
                if v not in visited:
                    visited.add(v)
                    stack.append(v)
        if len(visited) != k:
            raise ValueError(f"template {self.name}: not a connected tree")


@dataclass(frozen=True)
class SubTemplate:
    """One node of the partition recursion tree.

    ``vertices`` is the subset of template vertices covered; ``root`` the
    rooted vertex.  Non-leaf sub-templates reference their active / passive
    children by index into ``TemplatePartition.subs``.
    """

    vertices: Tuple[int, ...]
    root: int
    active: Optional[int]  # index into partition list, or None for leaves
    passive: Optional[int]

    @property
    def size(self) -> int:
        return len(self.vertices)

    @property
    def is_leaf(self) -> bool:
        return self.active is None


@dataclass(frozen=True)
class TemplatePartition:
    """Topologically-ordered sub-template list; ``subs[-1]`` is the full T."""

    template: Template
    subs: Tuple[SubTemplate, ...]

    @property
    def root_index(self) -> int:
        return len(self.subs) - 1

    def stage_sizes(self) -> List[Tuple[int, int, int]]:
        """(m, m_a, m_p) for every non-leaf sub-template, in DP order."""
        out = []
        for s in self.subs:
            if not s.is_leaf:
                a = self.subs[s.active]
                p = self.subs[s.passive]
                out.append((s.size, a.size, p.size))
        return out


def partition_template(template: Template, root: Optional[int] = None) -> TemplatePartition:
    """FASCIA-style single-edge-cut partition into a binary recursion tree.

    The root defaults to a maximum-degree vertex (keeps the active chain long
    and passive subtrees small, which minimizes the number of distinct
    ``(m, m_p)`` SpMM column counts).
    """
    template.validate()
    adj = template.adjacency()
    if root is None:
        root = int(np.argmax([len(a) for a in adj]))

    subs: List[SubTemplate] = []

    def subtree_vertices(start: int, blocked: int) -> Tuple[int, ...]:
        """Vertices reachable from ``start`` without crossing ``blocked``."""
        out, stack, seen = [], [start], {start, blocked}
        while stack:
            u = stack.pop()
            out.append(u)
            for v in adj[u]:
                if v not in seen:
                    seen.add(v)
                    stack.append(v)
        return tuple(sorted(out))

    def rec(vertices: Tuple[int, ...], rho: int) -> int:
        if len(vertices) == 1:
            subs.append(SubTemplate(vertices=vertices, root=rho, active=None, passive=None))
            return len(subs) - 1
        vset = set(vertices)
        # Cut the first root-adjacent edge (deterministic: smallest neighbor).
        taus = sorted(v for v in adj[rho] if v in vset)
        tau = taus[0]
        passive_vertices = tuple(v for v in subtree_vertices(tau, rho) if v in vset)
        active_vertices = tuple(sorted(vset - set(passive_vertices)))
        a_idx = rec(active_vertices, rho)
        p_idx = rec(passive_vertices, tau)
        subs.append(SubTemplate(vertices=vertices, root=rho, active=a_idx, passive=p_idx))
        return len(subs) - 1

    rec(tuple(sorted(range(template.k))), root)
    return TemplatePartition(template=template, subs=tuple(subs))


def sub_template_canonical(template: Template, vertices: Tuple[int, ...], root: int) -> str:
    """AHU canonical string of the rooted sub-template induced by ``vertices``.

    Two sub-templates with equal strings have identical count matrices
    ``M_s`` for every coloring — the key used by the engine backends to share
    DP state and SpMM products across templates (and across stages within one
    template).
    """
    allowed = set(vertices)
    adj: Dict[int, List[int]] = {v: [] for v in vertices}
    for u, v in template.edges:
        if u in allowed and v in allowed:
            adj[u].append(v)
            adj[v].append(u)

    def canon(node: int, parent: int) -> str:
        forms = sorted(canon(c, node) for c in adj[node] if c != parent)
        return "(" + "".join(forms) + ")"

    return canon(root, -1)


# ---------------------------------------------------------------------------
# Automorphism counting (AHU canonical forms).
# ---------------------------------------------------------------------------


def _rooted_canon_and_aut(adj: Sequence[Sequence[int]], root: int, parent: int) -> Tuple[str, int]:
    """AHU canonical string + automorphism count of the subtree at ``root``."""
    forms: List[str] = []
    aut = 1
    for child in adj[root]:
        if child == parent:
            continue
        f, a = _rooted_canon_and_aut(adj, child, root)
        forms.append(f)
        aut *= a
    forms.sort()
    counts: Dict[str, int] = {}
    for f in forms:
        counts[f] = counts.get(f, 0) + 1
    for c in counts.values():
        aut *= factorial(c)
    return "(" + "".join(forms) + ")", aut


def tree_automorphisms(template: Template) -> int:
    """|Aut(T)| of an unrooted tree via its center(s)."""
    template.validate()
    k = template.k
    if k == 1:
        return 1
    adj = [list(a) for a in template.adjacency()]
    # Peel leaves to find the 1- or 2-vertex center.
    degree = [len(a) for a in adj]
    remaining = k
    layer = [v for v in range(k) if degree[v] <= 1]
    removed = [False] * k
    while remaining > 2:
        nxt = []
        for v in layer:
            removed[v] = True
            remaining -= 1
            for u in adj[v]:
                if not removed[u]:
                    degree[u] -= 1
                    if degree[u] == 1:
                        nxt.append(u)
        layer = nxt
    centers = [v for v in range(k) if not removed[v]]
    if len(centers) == 1:
        _, aut = _rooted_canon_and_aut(adj, centers[0], -1)
        return aut
    c1, c2 = centers
    f1, a1 = _rooted_canon_and_aut(adj, c1, c2)
    f2, a2 = _rooted_canon_and_aut(adj, c2, c1)
    aut = a1 * a2
    if f1 == f2:
        aut *= 2  # the edge flip
    return aut


# ---------------------------------------------------------------------------
# Template constructors and the paper's template library.
# ---------------------------------------------------------------------------


def path_template(k: int, name: Optional[str] = None) -> Template:
    return Template(name or f"path{k}", tuple((i, i + 1) for i in range(k - 1)))


def star_template(k: int, name: Optional[str] = None) -> Template:
    return Template(name or f"star{k}", tuple((0, i) for i in range(1, k)))


def binary_tree_template(k: int, name: Optional[str] = None) -> Template:
    """Complete-ish binary tree on k vertices (heap numbering)."""
    return Template(name or f"bintree{k}", tuple(((i - 1) // 2, i) for i in range(1, k)))


def random_tree_template(k: int, seed: int, name: Optional[str] = None) -> Template:
    """Uniform random labeled tree from a Prüfer sequence (deterministic)."""
    rng = np.random.default_rng(seed)
    if k == 1:
        return Template(name or f"rand{k}", ())
    if k == 2:
        return Template(name or f"rand{k}", ((0, 1),))
    prufer = rng.integers(0, k, size=k - 2)
    degree = np.ones(k, dtype=np.int64)
    for x in prufer:
        degree[x] += 1
    edges = []
    import heapq

    leaves = [v for v in range(k) if degree[v] == 1]
    heapq.heapify(leaves)
    for x in prufer:
        leaf = heapq.heappop(leaves)
        edges.append((int(leaf), int(x)))
        degree[leaf] -= 1
        degree[x] -= 1
        if degree[x] == 1:
            heapq.heappush(leaves, int(x))
    u, v = [v for v in range(k) if degree[v] == 1][:2]
    edges.append((u, v))
    return Template(name or f"rand{k}", tuple(edges))


def _u5_2() -> Template:
    # 5-vertex "chair": path 0-1-2-3 with 4 hanging off 1.
    return Template("u5-2", ((0, 1), (1, 2), (2, 3), (1, 4)))


def _u7() -> Template:
    # FASCIA's u7: two cherries joined by a center path.
    return Template("u7", ((0, 1), (1, 2), (1, 3), (0, 4), (4, 5), (4, 6)))


def _u10() -> Template:
    return Template(
        "u10",
        ((0, 1), (1, 2), (2, 3), (1, 4), (4, 5), (0, 6), (6, 7), (6, 8), (8, 9)),
    )


def _u12() -> Template:
    # Paper Fig 6(b) family: balanced tree of depth ~3.
    return Template(
        "u12",
        (
            (0, 1), (0, 2), (1, 3), (1, 4), (2, 5), (2, 6),
            (3, 7), (4, 8), (5, 9), (6, 10), (10, 11),
        ),
    )


PAPER_TEMPLATES: Dict[str, Template] = {
    "u3": path_template(3, "u3"),
    "u5-1": path_template(5, "u5-1"),
    "u5-2": _u5_2(),
    "u6": binary_tree_template(6, "u6"),
    "u7": _u7(),
    "u10": _u10(),
    "u12": _u12(),
    "u13": random_tree_template(13, seed=13, name="u13"),
    "u14": random_tree_template(14, seed=14, name="u14"),
    "u15-1": random_tree_template(15, seed=151, name="u15-1"),
    "u15-2": random_tree_template(15, seed=152, name="u15-2"),
    "u16": random_tree_template(16, seed=16, name="u16"),
    "u17": random_tree_template(17, seed=17, name="u17"),
    "u18": random_tree_template(18, seed=18, name="u18"),
    "u20": random_tree_template(20, seed=20, name="u20"),
}


def get_template(name: str) -> Template:
    if name in PAPER_TEMPLATES:
        return PAPER_TEMPLATES[name]
    if name.startswith("path"):
        return path_template(int(name[4:]))
    if name.startswith("star"):
        return star_template(int(name[4:]))
    if name.startswith("bintree"):
        return binary_tree_template(int(name[7:]))
    raise KeyError(f"unknown template {name!r}; known: {sorted(PAPER_TEMPLATES)}")
