"""CountingEngine: batched multi-coloring, multi-template color-coding runs.

The estimator loop in early revisions dispatched ONE jit call per coloring —
re-entering Python, re-shipping split tables, and syncing a scalar back to
the host every iteration.  This module amortizes all static work across the
whole (epsilon, delta) estimation run, the way the paper's Algorithm 5
amortizes the neighbor reduction across color sets:

* **Plans and tables once** — ``CountingPlan``s are built per template and
  their split tables land on the device a single time, de-duplicated by
  ``(k, m, m_a)``.
* **Backend interface** — each execution strategy is an
  :class:`EngineBackend`: device-operand construction, the SpMM dispatch,
  the eMA step, and the per-coloring live-memory model all live behind one
  interface.  The local backends (``edges`` / ``ell`` / ``dense`` /
  ``blocked`` / ``custom``) run the fused DP on one device;
  :class:`MeshBackend` (``mesh``) runs the same DP under ``shard_map``
  across a device mesh with the column-batched all-gather SpMM and streamed
  eMA from :mod:`repro.core.distributed`.
* **Backend auto-selection** — the local SpMM kernel is picked from graph
  statistics (:func:`select_backend`): edge-list segment-sum for skewed
  degree distributions, padded ELL for flat ones, dense adjacency for tiny
  graphs, and the Pallas blocked-ELL kernel for large graphs on TPU.
  Passing ``mesh=`` selects the ``mesh`` backend.
* **Batched colorings** — a chunk of ``B`` colorings is fused into the
  *column* dimension of the DP state: every M matrix is ``(n, B, C)`` and
  each stage's SpMM is ONE wide neighbor reduction over ``B * C`` columns
  (``lax.map`` walks the chunks inside a single jit).  This is the paper's
  "batch more columns into one SpMM" principle applied across colorings —
  a plain ``vmap`` over the leading axis lowers to batched scatters that
  XLA:CPU executes far slower than one wide scatter.  On the mesh backend
  the same fusion means every all-gather collective serves all ``B``
  colorings at once.
* **Chunk-size picker** — the live M-matrix footprint per coloring is
  derived from the backend's memory model (resident M columns plus the
  per-stage gather transient — for the mesh backend, the per-shard gather
  scratch and the all-gather buffer) and the chunk size is chosen to keep
  ``chunk * footprint`` under a configurable VMEM/HBM budget.
* **Multi-template sharing** — several same-``k`` templates are counted per
  coloring; sub-template DP states and SpMM products are memoized by the
  rooted canonical form (AHU string) of the sub-template, so coinciding
  passive sub-templates (and the leaf one-hot + its neighbor sum, shared by
  *every* template) are computed once per coloring.
* **Dtype policy** — fp32 end-to-end, or bf16 storage/gather traffic with
  fp32 accumulation (paper §VI bf16 discussion).  On the mesh backend the
  storage dtype is also the all-gather wire dtype (plus an optional
  ``gather_dtype`` override for compressed collectives).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp

from .colorsets import binom, colorful_probability
from .counting import CountingPlan, _ema_apply_fused, build_counting_plan
from .graph import Graph
from .templates import Template, sub_template_canonical

__all__ = [
    "DtypePolicy",
    "EstimateResult",
    "CountingEngine",
    "EngineBackend",
    "select_backend",
    "pick_chunk_size",
    "sub_template_canonical",
    "ENGINE_BACKENDS",
    "DEFAULT_MEMORY_BUDGET_BYTES",
    "MAX_CHUNK_SIZE",
]

#: Default live-footprint budget for one chunk of colorings (bytes).  Sized
#: for the CPU/laptop case; on real TPUs pass the per-core VMEM/HBM figure.
DEFAULT_MEMORY_BUDGET_BYTES = 32 * 1024 * 1024

#: Hard cap on colorings fused into one chunk (diminishing returns beyond).
MAX_CHUNK_SIZE = 64

#: Graphs at or below this vertex count use the dense-adjacency backend.
DENSE_MAX_VERTICES = 256

#: ELL is chosen only when padding waste is bounded: ``n * max_deg`` must not
#: exceed this factor times the true directed edge count.
ELL_PAD_FACTOR = 1.5

#: On TPU, graphs at least this large route to the Pallas blocked-ELL kernel.
BLOCKED_MIN_VERTICES = 4096


@dataclass(frozen=True)
class DtypePolicy:
    """Storage vs accumulation dtypes for the DP state.

    ``store_dtype`` is what M matrices (and therefore the SpMM gather
    traffic — on the mesh backend, also the all-gather wire payload) are
    kept in; ``accum_dtype`` is what neighbor reductions and eMA FMAs
    accumulate in.  ``fp32`` keeps both at float32; ``bf16`` halves the
    storage/gather bytes while accumulating in float32 (paper §VI).
    """

    store_dtype: jnp.dtype
    accum_dtype: jnp.dtype

    @staticmethod
    def resolve(policy: Union[str, "DtypePolicy", jnp.dtype, None]) -> "DtypePolicy":
        """Coerce ``"fp32"`` | ``"bf16"`` | a dtype | a policy | None."""
        if policy is None:
            return DtypePolicy(jnp.float32, jnp.float32)
        if isinstance(policy, DtypePolicy):
            return policy
        if isinstance(policy, str):
            if policy in ("fp32", "float32"):
                return DtypePolicy(jnp.float32, jnp.float32)
            if policy in ("bf16", "bfloat16"):
                return DtypePolicy(jnp.bfloat16, jnp.float32)
            raise ValueError(f"unknown dtype policy {policy!r} (fp32 | bf16)")
        dt = jnp.dtype(policy)
        accum = jnp.float32 if dt in (jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.float16)) else dt
        return DtypePolicy(dt, accum)


@dataclass
class EstimateResult:
    """Per-template estimation summary (kept API-compatible with the old
    ``estimator.EstimateResult``)."""

    mean: float
    std: float
    per_iteration: np.ndarray
    iterations: int


def select_backend(graph: Graph, platform: Optional[str] = None) -> str:
    """Pick the local SpMM backend from graph statistics.

    * ``dense``   — tiny graphs: one (n, n) matmul beats gather/scatter.
    * ``blocked`` — large graphs on TPU: the Pallas blocked-ELL kernel.
    * ``ell``     — flat degree distributions where row padding is cheap.
    * ``edges``   — everything else (skewed / power-law graphs: a hub row
      would blow the ELL padding up to ``n * max_deg``).

    The ``mesh`` backend is never auto-selected from graph statistics — it
    is chosen by passing ``mesh=`` to :class:`CountingEngine`.
    """
    platform = platform or jax.default_backend()
    if graph.n <= DENSE_MAX_VERTICES:
        return "dense"
    if platform == "tpu" and graph.n >= BLOCKED_MIN_VERTICES:
        return "blocked"
    max_deg = graph.max_degree()
    if graph.n * max_deg <= ELL_PAD_FACTOR * max(graph.num_directed, 1):
        return "ell"
    return "edges"


def pick_chunk_size(
    bytes_per_coloring: int,
    memory_budget_bytes: int,
    max_chunk: int = MAX_CHUNK_SIZE,
) -> int:
    """Largest chunk whose live footprint stays under the budget (>= 1)."""
    if bytes_per_coloring <= 0:
        return max_chunk
    return max(1, min(max_chunk, int(memory_budget_bytes // bytes_per_coloring)))


# ---------------------------------------------------------------------------
# Backend interface
# ---------------------------------------------------------------------------


class EngineBackend:
    """One SpMM/eMA execution strategy behind :class:`CountingEngine`.

    A backend owns three things:

    * **operand construction** — its device-resident graph representation,
      built once in ``__init__`` (edge lists, ELL tables, dense adjacency,
      Pallas blocked operands, or the sharded edge partition + collective
      schedule for the mesh backend);
    * **the DP execution** — :meth:`counts_for_colors` maps a ``(B, n)``
      chunk of colorings to ``(B, T)`` raw colorful totals (local backends
      implement it via :meth:`LocalBackend.spmm` + the shared fused DP;
      the mesh backend delegates to the shard_map program built by
      :func:`repro.core.distributed.make_batched_count_fn`);
    * **the memory model** — :meth:`transient_elements` /
      :meth:`resident_elements` feed the engine's memory-budget chunk
      picker.
    """

    name: str = "abstract"

    def __init__(self, engine: "CountingEngine"):
        self.engine = engine

    # -- execution ----------------------------------------------------------

    def counts_for_colors(self, colors: jnp.ndarray) -> jnp.ndarray:
        """``(B, n)`` colorings -> ``(B, T)`` un-normalized colorful totals."""
        raise NotImplementedError

    def counts_for_keys_chunk(self, keys_chunk: jnp.ndarray) -> jnp.ndarray:
        """``(B, 2)`` PRNG keys -> ``(B, T)`` normalized estimates.

        The coloring draw is identical across backends (one ``randint`` per
        key over the *original* vertex ids), so the same keys produce the
        same colorings — and therefore fp-tolerance-comparable estimates —
        on every backend, mesh included.
        """
        eng = self.engine
        colors = jax.vmap(
            lambda key: jax.random.randint(key, (eng.graph.n,), 0, eng.k)
        )(keys_chunk)
        return self.counts_for_colors(colors) * eng._norm_factors[None, :]

    def make_run_fn(self) -> Callable:
        """One jit for the whole run: ``lax.map`` over key chunks."""
        return jax.jit(lambda keys: jax.lax.map(self.counts_for_keys_chunk, keys))

    # -- memory model --------------------------------------------------------

    def transient_elements(self) -> int:
        """Widest per-stage scratch one coloring needs, in store-dtype
        elements (gather intermediates, collective buffers)."""
        raise NotImplementedError

    def resident_elements(self) -> int:
        """Live M-matrix elements one coloring keeps resident."""
        return self.engine.graph.n * self.engine.peak_columns()

    def bytes_per_coloring(self) -> int:
        """Estimated live bytes one coloring contributes to a chunk."""
        itemsize = jnp.dtype(self.engine.policy.store_dtype).itemsize
        return (self.transient_elements() + self.resident_elements()) * itemsize


class LocalBackend(EngineBackend):
    """Shared single-device DP: subclasses only supply :meth:`spmm`.

    The fused multi-template DP walks every plan's stages with DP states and
    SpMM products memoized by rooted canonical form, all M matrices in the
    fused ``(n, B, C)`` layout.
    """

    def spmm(self, m: jnp.ndarray) -> jnp.ndarray:
        """One neighbor reduction over ALL fused columns; returns accum dtype."""
        raise NotImplementedError

    def ema(self, m_a, b_mat, idx_a, idx_p):
        """Vertex-local eMA on fused (n, B, C) state, fp accumulation."""
        pol = self.engine.policy
        n, bsz, _ = m_a.shape
        init = jnp.zeros((n, bsz, idx_a.shape[0]), pol.accum_dtype)
        return _ema_apply_fused(m_a, b_mat, idx_a, idx_p, init).astype(pol.store_dtype)

    def counts_for_colors(self, colors: jnp.ndarray) -> jnp.ndarray:
        """(B, n) colorings -> (B, T) un-normalized colorful totals.

        Sub-template states and SpMM products are memoized by canonical
        form, so templates sharing passive sub-templates (and every
        template's leaf stage) reuse one computation per coloring.
        """
        eng = self.engine
        pol = eng.policy
        leaf = jax.nn.one_hot(colors.T, eng.k, dtype=pol.store_dtype)  # (n, B, k)
        slots: Dict[str, jnp.ndarray] = {}
        prods: Dict[str, jnp.ndarray] = {}
        totals = []
        for p_idx, plan in enumerate(eng.plans):
            canons = eng._canons[p_idx]
            for i, sub in enumerate(plan.partition.subs):
                key = canons[i]
                if key in slots:
                    continue
                if sub.is_leaf:
                    slots[key] = leaf
                    continue
                p_key = canons[sub.passive]
                if p_key not in prods:
                    prods[p_key] = self.spmm(slots[p_key])
                idx_a, idx_p = eng._stage_tables[(p_idx, i)]
                slots[key] = self.ema(slots[canons[sub.active]], prods[p_key], idx_a, idx_p)
            root = slots[canons[plan.partition.root_index]].astype(pol.accum_dtype)
            # reduce color sets first, then vertices: the per-coloring order
            # is independent of the batch size (bit-exact across chunkings)
            totals.append(root.sum(axis=2).sum(axis=0).astype(jnp.float32))
        return jnp.stack(totals, axis=1)  # (B, T)

    def transient_elements(self) -> int:
        # default: the (n, C_p) gather intermediate of a dense-ish reduction
        return self.engine.graph.n * self.engine._max_passive_columns()


class EdgesBackend(LocalBackend):
    """Edge-list gather + segment-sum (the skew-robust default)."""

    name = "edges"

    def __init__(self, engine: "CountingEngine"):
        super().__init__(engine)
        g = engine.graph
        self._src = jnp.asarray(g.src)
        self._dst = jnp.asarray(g.dst)

    def spmm(self, m):
        return jax.ops.segment_sum(
            m[self._src].astype(self.engine.policy.accum_dtype),
            self._dst,
            num_segments=self.engine.graph.n,
            indices_are_sorted=True,
        )

    def transient_elements(self) -> int:
        # the (edges, C_p) message gather is the true high-water mark
        return self.engine.graph.num_directed * self.engine._max_passive_columns()


class EllBackend(LocalBackend):
    """Padded-row neighbor gather (flat degree distributions)."""

    name = "ell"

    def __init__(self, engine: "CountingEngine"):
        super().__init__(engine)
        nbr, mask = engine.graph.ell()
        self._nbr = jnp.asarray(nbr)
        self._ell_mask = jnp.asarray(mask)

    def spmm(self, m):
        pol = self.engine.policy
        gathered = m[self._nbr].astype(pol.accum_dtype)  # (n, max_deg, B, C)
        return jnp.einsum("ndbc,nd->nbc", gathered, self._ell_mask.astype(pol.accum_dtype))

    def transient_elements(self) -> int:
        g = self.engine.graph
        return g.n * max(g.max_degree(), 1) * self.engine._max_passive_columns()


class DenseBackend(LocalBackend):
    """Dense-adjacency matmul (tiny graphs)."""

    name = "dense"

    def __init__(self, engine: "CountingEngine"):
        super().__init__(engine)
        self._adj = jnp.asarray(engine.graph.dense_adjacency())

    def spmm(self, m):
        pol = self.engine.policy
        n, b, c = m.shape
        out = jnp.matmul(
            self._adj.astype(pol.store_dtype),
            m.reshape(n, b * c),
            preferred_element_type=pol.accum_dtype,
        )
        return out.reshape(n, b, c).astype(pol.accum_dtype)


class BlockedEllBackend(LocalBackend):
    """Pallas blocked-ELL kernel (large graphs on TPU)."""

    name = "blocked"

    def __init__(self, engine: "CountingEngine", block_size: int = 256):
        super().__init__(engine)
        from repro.kernels.spmm_blocked.ops import prepare_operand

        self._blocked_op = prepare_operand(engine.graph, block_size=block_size)

    def spmm(self, m):
        # kernel is 2-D (n, C) — fuse batch into columns
        from repro.kernels.spmm_blocked.ops import spmm_blocked

        n, b, c = m.shape
        out = spmm_blocked(
            self._blocked_op,
            m.reshape(n, b * c).astype(jnp.float32),
            interpret=self.engine.interpret,
        )
        return out.reshape(n, b, c).astype(self.engine.policy.accum_dtype)


class CustomBackend(LocalBackend):
    """Caller-supplied ``(n, C) -> (n, C)`` neighbor-sum kernel."""

    name = "custom"

    def __init__(self, engine: "CountingEngine", spmm_fn: Callable):
        super().__init__(engine)
        self._spmm_fn = spmm_fn

    def spmm(self, m):
        n, b, c = m.shape
        out = self._spmm_fn(m.reshape(n, b * c))
        return out.reshape(n, b, c).astype(self.engine.policy.accum_dtype)

    def transient_elements(self) -> int:
        # assume edge-list-like internals (the conservative choice)
        return self.engine.graph.num_directed * self.engine._max_passive_columns()


class MeshBackend(EngineBackend):
    """Distributed backend: the fused DP under ``shard_map`` on a device mesh.

    Wraps the column-batched all-gather SpMM and streamed eMA of
    :mod:`repro.core.distributed`: vertices are 1-D row-partitioned across
    every mesh axis, each DP stage all-gathers the passive M matrix in
    ``column_batch``-column slices (each collective serving all ``B``
    chunked colorings at once), and the eMA stays vertex-local.  Split
    tables are built once per plan at construction, de-duplicated by
    ``(k, m, m_a)``, and closure-captured by the shard_map program.

    Args (via ``CountingEngine(...)``):
      mesh: the ``jax.sharding.Mesh`` to run on (required).
      column_batch: passive columns per all-gather; ``None`` auto-sizes to
        ``min(128, max passive column count)``.
      ema_mode: ``"streamed"`` (default — fused per-batch SpMM->eMA, the B
        matrix never materializes) or ``"loop"`` (paper-faithful Algorithm
        5 with the SpMM product memoized per canonical passive form).
      gather_dtype: optional wire dtype for compressed all-gathers
        (e.g. ``jnp.bfloat16``); accumulation stays fp32.
      balance_degrees: relabel vertices round-robin by degree rank before
        sharding (spreads hub rows; colorings are permuted to follow, so
        counts are unchanged).
    """

    name = "mesh"

    def __init__(
        self,
        engine: "CountingEngine",
        mesh,
        *,
        column_batch: Optional[int] = None,
        ema_mode: str = "streamed",
        gather_dtype=None,
        balance_degrees: bool = False,
    ):
        super().__init__(engine)
        if mesh is None:
            raise ValueError("backend='mesh' needs a jax.sharding.Mesh (mesh=...)")
        from .distributed import make_batched_count_fn, mesh_peak_columns, shard_graph

        self.mesh = mesh
        self.ema_mode = ema_mode
        self.gather_dtype = gather_dtype
        n_shards = int(np.prod(mesh.devices.shape))
        self.sharded = shard_graph(engine.graph, n_shards, balance_degrees=balance_degrees)
        if column_batch is None:
            column_batch = min(128, max(engine._max_passive_columns(), engine.k))
        self.column_batch = int(column_batch)
        self._count_fn = make_batched_count_fn(
            engine.plans,
            mesh,
            self.sharded.n_padded,
            self.sharded.edges_per_shard,
            column_batch=self.column_batch,
            ema_mode=ema_mode,
            gather_dtype=gather_dtype,
            canons=engine._canons,
            store_dtype=engine.policy.store_dtype,
            accum_dtype=engine.policy.accum_dtype,
        )
        self._src = jnp.asarray(self.sharded.src)
        self._dst_local = jnp.asarray(self.sharded.dst_local)
        self._edge_mask = jnp.asarray(self.sharded.edge_mask)
        # colorings follow the degree-balancing relabel (scatter old -> new;
        # new ids range over [0, n_padded) with pad slots interleaved)
        self._perm = (
            jnp.asarray(self.sharded.perm) if self.sharded.perm is not None else None
        )
        self._peak_padded = mesh_peak_columns(
            engine.plans, engine._canons, ema_mode, self.column_batch
        )

    def counts_for_colors(self, colors: jnp.ndarray) -> jnp.ndarray:
        colors = jnp.asarray(colors)
        if self._perm is not None:
            padded = jnp.zeros((colors.shape[0], self.sharded.n_padded), colors.dtype)
            colors = padded.at[:, self._perm].set(colors)
        else:
            pad = self.sharded.n_padded - colors.shape[1]
            if pad:
                colors = jnp.pad(colors, ((0, 0), (0, pad)))
        return self._count_fn(colors, self._src, self._dst_local, self._edge_mask)

    # -- memory model (per shard!) -------------------------------------------

    def transient_elements(self) -> int:
        """Per-shard collective scratch: one all-gathered column batch
        (``n_padded * column_batch``) plus the per-shard edge message gather
        (``edges_per_shard * column_batch``)."""
        cb = self.column_batch
        return self.sharded.n_padded * cb + self.sharded.edges_per_shard * cb

    def resident_elements(self) -> int:
        """Per-shard live DP state: local rows times the liveness-aware
        peak of padded M columns under the shared multi-template schedule."""
        return self.sharded.rows_per_shard * self._peak_padded


ENGINE_BACKENDS = ("edges", "ell", "dense", "blocked", "mesh", "custom")


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------


class CountingEngine:
    """Batched color-coding counting runs over one graph.

    Args:
      graph: the network.
      templates: one :class:`Template` or a sequence of same-``k`` templates
        counted together per coloring (shared leaf one-hot / SpMM products).
      backend: ``auto`` | ``edges`` | ``ell`` | ``dense`` | ``blocked`` |
        ``mesh``.  ``auto`` resolves from graph statistics
        (:func:`select_backend`), or to ``mesh`` when ``mesh=`` is given.
        Ignored when ``spmm_fn`` is given.
      spmm_fn: optional custom ``(n, C) -> (n, C)`` neighbor-sum kernel.
      dtype_policy: ``fp32`` | ``bf16`` | a :class:`DtypePolicy` | a dtype.
      memory_budget_bytes: live-footprint budget steering the chunk picker
        (per device — for the mesh backend the model is per shard).
      chunk_size: explicit colorings-per-chunk override (skips the picker).
      plans: optional pre-built :class:`CountingPlan` per template.
      block_size / interpret: Pallas blocked-ELL kernel knobs.
      mesh / column_batch / ema_mode / gather_dtype / balance_degrees:
        mesh-backend knobs — see :class:`MeshBackend`.
    """

    def __init__(
        self,
        graph: Graph,
        templates: Union[Template, Sequence[Template]],
        *,
        backend: str = "auto",
        spmm_fn: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None,
        dtype_policy: Union[str, DtypePolicy, jnp.dtype, None] = "fp32",
        memory_budget_bytes: int = DEFAULT_MEMORY_BUDGET_BYTES,
        chunk_size: Optional[int] = None,
        plans: Optional[Sequence[CountingPlan]] = None,
        block_size: int = 256,
        interpret: bool = False,
        mesh=None,
        column_batch: Optional[int] = None,
        ema_mode: str = "streamed",
        gather_dtype=None,
        balance_degrees: bool = False,
    ):
        if isinstance(templates, Template):
            templates = [templates]
        if not templates:
            raise ValueError("CountingEngine needs at least one template")
        ks = {t.k for t in templates}
        if len(ks) != 1:
            raise ValueError(
                f"all templates must share one k to share colorings, got k={sorted(ks)}"
            )
        self.graph = graph
        self.templates: Tuple[Template, ...] = tuple(templates)
        self.k = ks.pop()
        self.policy = DtypePolicy.resolve(dtype_policy)
        self.memory_budget_bytes = int(memory_budget_bytes)
        self.interpret = interpret
        self.mesh = mesh

        if plans is None:
            self.plans: Tuple[CountingPlan, ...] = tuple(
                build_counting_plan(t) for t in self.templates
            )
        else:
            if len(plans) != len(self.templates):
                raise ValueError("plans must align with templates")
            self.plans = tuple(plans)

        # --- static schedule: canonical keys + de-duplicated device tables.
        self._canons: List[List[str]] = [
            [
                sub_template_canonical(plan.template, sub.vertices, sub.root)
                for sub in plan.partition.subs
            ]
            for plan in self.plans
        ]
        table_cache: Dict[Tuple[int, int, int], Tuple[jnp.ndarray, jnp.ndarray]] = {}
        self._stage_tables: Dict[Tuple[int, int], Tuple[jnp.ndarray, jnp.ndarray]] = {}
        for p_idx, plan in enumerate(self.plans):
            for i, table in enumerate(plan.tables):
                if table is None:
                    continue
                key = (table.k, table.m, table.m_a)
                if key not in table_cache:
                    table_cache[key] = (jnp.asarray(table.idx_a), jnp.asarray(table.idx_p))
                self._stage_tables[(p_idx, i)] = table_cache[key]

        norm = colorful_probability(self.k)
        self._norm_factors = jnp.asarray(
            [1.0 / (norm * plan.automorphisms) for plan in self.plans], jnp.float32
        )

        # --- backend resolution + construction (operands built once).
        if spmm_fn is not None:
            self.backend = "custom"
        elif backend == "auto":
            self.backend = "mesh" if mesh is not None else select_backend(graph)
        else:
            self.backend = backend
        self.backend_impl: EngineBackend = self._make_backend(
            spmm_fn=spmm_fn,
            block_size=block_size,
            column_batch=column_batch,
            ema_mode=ema_mode,
            gather_dtype=gather_dtype,
            balance_degrees=balance_degrees,
        )

        self.chunk_size = int(chunk_size) if chunk_size else pick_chunk_size(
            self.bytes_per_coloring(), self.memory_budget_bytes
        )

        self._run_fn = None  # built lazily (jit cache)

    def _make_backend(
        self, *, spmm_fn, block_size, column_batch, ema_mode, gather_dtype, balance_degrees
    ) -> EngineBackend:
        if self.backend == "custom":
            return CustomBackend(self, spmm_fn)
        if self.backend == "edges":
            return EdgesBackend(self)
        if self.backend == "ell":
            return EllBackend(self)
        if self.backend == "dense":
            return DenseBackend(self)
        if self.backend == "blocked":
            return BlockedEllBackend(self, block_size=block_size)
        if self.backend == "mesh":
            return MeshBackend(
                self,
                self.mesh,
                column_batch=column_batch,
                ema_mode=ema_mode,
                gather_dtype=gather_dtype,
                balance_degrees=balance_degrees,
            )
        raise ValueError(f"unknown backend {self.backend!r} (one of {ENGINE_BACKENDS})")

    # ------------------------------------------------------------------
    # Memory planning
    # ------------------------------------------------------------------

    def peak_columns(self) -> int:
        """Live M columns per coloring across the shared multi-template DP.

        With cross-template memoization every unique sub-template state and
        SpMM product stays resident for the whole coloring, so the figure is
        the sum over unique canonical forms — never less than the in-place
        single-template bound ``CountingPlan.peak_columns()``.
        """
        slot_cols: Dict[str, int] = {}
        prod_cols: Dict[str, int] = {}
        for p_idx, plan in enumerate(self.plans):
            for i, sub in enumerate(plan.partition.subs):
                slot_cols.setdefault(self._canons[p_idx][i], binom(self.k, sub.size))
                if not sub.is_leaf:
                    passive = plan.partition.subs[sub.passive]
                    prod_cols.setdefault(
                        self._canons[p_idx][sub.passive], binom(self.k, passive.size)
                    )
        unique_total = sum(slot_cols.values()) + sum(prod_cols.values())
        return max(unique_total, max(p.peak_columns() for p in self.plans))

    def _max_passive_columns(self) -> int:
        cp = 1
        for plan in self.plans:
            for sub in plan.partition.subs:
                if not sub.is_leaf:
                    passive = plan.partition.subs[sub.passive]
                    cp = max(cp, binom(self.k, passive.size))
        return cp

    def bytes_per_coloring(self) -> int:
        """Estimated live bytes one coloring contributes to a chunk.

        Delegates to the backend's memory model: resident M-matrix state
        plus the widest per-stage transient (edge/row gather scratch for the
        local backends; all-gather buffer + per-shard message gather for the
        mesh backend, where the figure is per shard).
        """
        return self.backend_impl.bytes_per_coloring()

    # ------------------------------------------------------------------
    # Public API
    # ------------------------------------------------------------------

    def raw_counts(self, colors) -> jnp.ndarray:
        """(n,) coloring -> (T,) raw colorful totals (test/inspection hook)."""
        colors = jnp.asarray(colors)
        return self.backend_impl.counts_for_colors(colors[None, :])[0]

    def _get_run_fn(self):
        if self._run_fn is None:
            self._run_fn = self.backend_impl.make_run_fn()
        return self._run_fn

    def count_keys(self, keys) -> np.ndarray:
        """Normalized per-iteration estimates for explicit PRNG keys.

        ``keys``: (iters, 2) uint32 PRNG keys (``jax.random.split`` output).
        Returns an (iters, T) float64 host array; all device work happens in
        one jit call (chunked ``lax.map`` over ``chunk_size``-wide batches).
        """
        keys = jnp.asarray(keys)
        iters = keys.shape[0]
        chunk = max(1, min(self.chunk_size, iters))
        n_chunks = -(-iters // chunk)
        pad = n_chunks * chunk - iters
        if pad:
            keys = jnp.concatenate([keys, keys[-1:].repeat(pad, axis=0)], axis=0)
        vals = self._get_run_fn()(keys.reshape(n_chunks, chunk, *keys.shape[1:]))
        flat = np.asarray(vals, dtype=np.float64).reshape(n_chunks * chunk, -1)
        return flat[:iters]

    def estimate(self, iterations: int = 32, seed: int = 0) -> List[EstimateResult]:
        """Run ``iterations`` random colorings; one :class:`EstimateResult`
        per template (paper Algorithm 1, batched)."""
        keys = jax.random.split(jax.random.PRNGKey(seed), iterations)
        vals = self.count_keys(keys)  # (iters, T)
        return [
            EstimateResult(
                mean=float(vals[:, t].mean()),
                std=float(vals[:, t].std()),
                per_iteration=vals[:, t],
                iterations=iterations,
            )
            for t in range(len(self.templates))
        ]
