"""CountingEngine: the thin façade over the plan -> cost -> exec pipeline.

The engine answers batched multi-coloring, multi-template color-coding
runs.  It is a *compiler driver*, not a monolith — one construction is
exactly::

    plan   = repro.plan.build_template_plan(templates)   # backend-agnostic IR
    cost   = repro.plan.cost.CostModel(plan, graph, ...) # calibrated budgets
    select_backend(graph)                                # graph statistics
    repro.exec.make_backend(engine)                      # bind plan to devices
    chunk  = cost.pick_chunk_size(...)                   # fit the budget

and every public surface — :meth:`CountingEngine.describe`,
:meth:`CountingEngine.cache_key`, the memory figures, the chunked launch
API — is derived from the bound :class:`~repro.plan.ir.TemplatePlan`.
``repro.plan`` owns the static schedule + the calibrated cost model,
``repro.exec`` owns the execution strategies and backend auto-selection;
this module keeps the dtype policy, the cache-key identity, and the
chunked launch API.  See ``docs/architecture.md`` / ``docs/planning.md``.

Execution-model invariants (unchanged from the fused PR 3/4 pipeline): the
aggregate product ``A_G @ M_p`` is never materialized; a chunk of ``B``
colorings rides the fused column dimension of every M matrix (one jit per
run); DP states are freed at their liveness-scheduled last read; and
estimates are bit-exact across chunk sizes."""

from __future__ import annotations

import logging
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp

# Submodule imports only (repro.exec/.plan's __init__ import repro.core
# right back); every re-exported compat name is listed in __all__.
from repro.exec.base import EngineBackend, StageTables, make_backend
from repro.exec.local import SELL_GROUP_SIZE
from repro.exec.mesh import MeshBackend
from repro.exec.select import (
    BACKEND_ENV_VAR,
    BLOCKED_MIN_VERTICES,
    DENSE_MAX_VERTICES,
    DENSE_WORK_ADVANTAGE,
    ELL_PAD_FACTOR,
    ENGINE_BACKENDS,
    SELL_MIN_SCATTER_WORK,
    resolve_backend_config,
    select_backend,
)
from repro.plan.cost import (
    DEFAULT_MEMORY_BUDGET_BYTES,
    LOCAL_COLUMN_BATCH,
    MAX_CHUNK_SIZE,
    CostModel,
    pick_chunk_size,
)
from repro.plan.ir import TemplatePlan, build_template_plan, template_set_canons
from repro.testing import faults as _faults

from .colorsets import colorful_probability
from .counting import CountingPlan
from .graph import Graph
from .templates import Template, sub_template_canonical

__all__ = [
    "DtypePolicy",
    "EstimateResult",
    "CountingEngine",
    "EngineBackend",
    "MeshBackend",
    "StageTables",
    "make_backend",
    "select_backend",
    "pick_chunk_size",
    "sub_template_canonical",
    "template_set_canons",
    "engine_cache_key",
    "CostModel",
    "ENGINE_BACKENDS",
    # re-exported tuning constants (homes: repro.plan.cost, repro.exec)
    "DEFAULT_MEMORY_BUDGET_BYTES", "MAX_CHUNK_SIZE", "LOCAL_COLUMN_BATCH",
    "BACKEND_ENV_VAR", "DENSE_MAX_VERTICES", "ELL_PAD_FACTOR",
    "BLOCKED_MIN_VERTICES", "SELL_MIN_SCATTER_WORK", "SELL_GROUP_SIZE",
    "DENSE_WORK_ADVANTAGE",
]

logger = logging.getLogger("repro.engine")


@dataclass(frozen=True)
class DtypePolicy:
    """Storage vs accumulation dtypes for the DP state.

    ``store_dtype`` is what M matrices (and therefore the SpMM gather
    traffic — on the mesh backend, also the all-gather wire payload) are
    kept in; ``accum_dtype`` is what neighbor reductions and eMA FMAs
    accumulate in.  ``fp32`` keeps both at float32; ``bf16`` halves the
    storage/gather bytes while accumulating in float32 (paper §VI).
    """

    store_dtype: jnp.dtype
    accum_dtype: jnp.dtype

    @staticmethod
    def resolve(policy: Union[str, "DtypePolicy", jnp.dtype, None]) -> "DtypePolicy":
        """Coerce ``"fp32"`` | ``"bf16"`` | a dtype | a policy | None."""
        if policy is None:
            return DtypePolicy(jnp.float32, jnp.float32)
        if isinstance(policy, DtypePolicy):
            return policy
        if isinstance(policy, str):
            if policy in ("fp32", "float32"):
                return DtypePolicy(jnp.float32, jnp.float32)
            if policy in ("bf16", "bfloat16"):
                return DtypePolicy(jnp.bfloat16, jnp.float32)
            raise ValueError(f"unknown dtype policy {policy!r} (fp32 | bf16)")
        dt = jnp.dtype(policy)
        accum = jnp.float32 if dt in (jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.float16)) else dt
        return DtypePolicy(dt, accum)


@dataclass
class EstimateResult:
    """Per-template estimation summary (API-compatible with the estimator's)."""

    mean: float
    std: float
    per_iteration: np.ndarray
    iterations: int


def _assemble_cache_key(
    signature: str,
    canons: Tuple[Tuple[str, ...], ...],
    backend: str,
    policy: "DtypePolicy",
    chunk_spec: Tuple,
    column_batch: Optional[int],
    tuning_fragment: Optional[Tuple] = None,
) -> Tuple:
    """The one place the cache-key tuple is laid out — shared by
    :func:`engine_cache_key` (pre-construction) and
    :meth:`CountingEngine.cache_key` (resolved values) so the two
    identities cannot drift.  The tuning fragment rides at the END so the
    positional consumers of the earlier elements (the serving layer's
    degradation ladder reads backend/chunk/column_batch at [3]/[6]/[7])
    keep their offsets."""
    return (
        "counting-engine",
        signature,
        canons,
        backend,
        str(jnp.dtype(policy.store_dtype)),
        str(jnp.dtype(policy.accum_dtype)),
        chunk_spec,
        None if column_batch is None else int(column_batch),
        tuning_fragment,
    )


def engine_cache_key(
    graph: Graph,
    templates: Sequence[Template],
    *,
    backend: str = "auto",
    dtype_policy: Union[str, "DtypePolicy", jnp.dtype, None] = "fp32",
    chunk_size: Optional[int] = None,
    memory_budget_bytes: Optional[int] = None,
    column_batch: Optional[int] = None,
    tuning=None,
) -> Tuple:
    """Hashable identity of a compiled :class:`CountingEngine`.

    Two constructions with equal keys trace and compile to the same
    programs, so a cache (``repro.serve.cache.EngineCache``) can hand back
    the warm engine and skip tracing entirely.  Anatomy::

        ("counting-engine",
         graph signature,           # content hash of (n, src, dst)
         template-set canons,       # DP-schedule identity, label-free
         resolved backend name,     # full resolution ladder folded in
         store dtype, accum dtype,  # dtype policy
         chunk spec,                # explicit chunk, or the budget that
                                    # deterministically picks one
         column_batch,              # fused-slice width override (or None)
         tuning fragment)           # TuningConfig.key_fragment(), or None

    Backend resolution runs the same ladder the constructor does
    (explicit > ``REPRO_ENGINE_BACKEND`` > tuned cache entry > analytic
    heuristic — :func:`repro.exec.select.resolve_backend_config`), and a
    tuned config's chunk/column-batch overrides are folded in exactly as
    construction would apply them, so the pre-construction key always
    matches the built engine's :meth:`CountingEngine.cache_key`.

    The template-set canons are exactly a ``TemplatePlan``'s schedule
    identity, so **plan equality implies cache-key equality** (pinned in
    ``tests/test_plan.py``).  The key is computable without constructing
    the engine (operands are only built on a cache miss)."""
    signature = graph.signature()
    canons = template_set_canons(templates)
    name, _source, _reason, cfg = resolve_backend_config(
        graph, backend=backend, canons=canons, tuning=tuning, signature=signature
    )
    if cfg is not None:
        if chunk_size is None and cfg.chunk_size is not None:
            chunk_size = cfg.chunk_size
        if column_batch is None and cfg.column_batch is not None:
            column_batch = cfg.column_batch
        if memory_budget_bytes is None and cfg.memory_budget_bytes is not None:
            memory_budget_bytes = cfg.memory_budget_bytes
    if memory_budget_bytes is None:
        memory_budget_bytes = DEFAULT_MEMORY_BUDGET_BYTES
    return _assemble_cache_key(
        signature,
        canons,
        name,
        DtypePolicy.resolve(dtype_policy),
        ("chunk", int(chunk_size)) if chunk_size else ("budget", int(memory_budget_bytes)),
        column_batch,
        None if cfg is None else cfg.key_fragment(),
    )


class CountingEngine:
    """Batched color-coding counting runs over one graph.

    Args:
      graph: the network.
      templates: one :class:`Template` or a sequence of same-``k`` templates
        counted together per coloring (shared leaf one-hot / DP states).
      backend: ``auto`` | ``edges`` | ``ell`` | ``sell`` | ``dense`` |
        ``blocked`` | ``mixed`` | ``mesh``.  ``auto`` runs the resolution
        ladder (:func:`repro.exec.select.resolve_backend_config`):
        ``REPRO_ENGINE_BACKEND`` env override, then a tuned config (passed
        as ``tuning=`` or found in the tuning cache under ``REPRO_TUNE``),
        then graph-statistics heuristics — or resolves to ``mesh`` when
        ``mesh=`` is given.  ``mixed`` requires ``tuning=``.  Ignored when
        ``spmm_fn`` is given.
      spmm_fn: optional custom ``(n, C) -> (n, C)`` neighbor-sum kernel.
      dtype_policy: ``fp32`` | ``bf16`` | a :class:`DtypePolicy` | a dtype.
      memory_budget_bytes: live-footprint budget steering the chunk picker
        (per device — for the mesh backend the model is per shard).
        ``None`` resolves to the tuned config's budget (the tuner sweeps
        it) when one binds, else ``DEFAULT_MEMORY_BUDGET_BYTES``.
      chunk_size: explicit colorings-per-chunk override (skips the picker).
      plans: optional pre-built :class:`CountingPlan` per template.
      block_size / interpret: fused Pallas kernel knobs (``blocked``).
      column_batch: passive columns aggregated per fused SpMM+eMA slice.
        ``None`` auto-sizes: ``min(16, max passive columns)`` on the local
        backends, ``min(128, max passive columns)`` on the mesh backend
        (where a batch is also one all-gather collective).
      mesh / ema_mode / gather_dtype / balance_degrees / mesh_comm:
        mesh-backend knobs — see :class:`repro.exec.mesh.MeshBackend`
        (``mesh_comm`` forces ``blocking`` | ``pipelined`` collectives;
        ``None`` lets ``REPRO_MESH_COMM`` or the cost model's
        ``comm_schedule`` decide; a tuned config may also carry it).
      tuning: optional :class:`repro.tune.config.TuningConfig` (what
        ``python -m repro.tune`` / ``svc.tune`` produce) — binds per-group
        backends and overrides ``column_batch``/``chunk_size`` wherever the
        caller left them ``None``.  Beaten by an explicit ``backend=`` or
        the env override; ``describe()["backend"]["source"]`` records who
        won.

    The bound plan is ``engine.plan_ir``, the resource model is
    ``engine.cost``, the execution strategy is ``engine.backend_impl``.
    """

    def __init__(
        self,
        graph: Graph,
        templates: Union[Template, Sequence[Template]],
        *,
        backend: str = "auto",
        spmm_fn: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None,
        dtype_policy: Union[str, DtypePolicy, jnp.dtype, None] = "fp32",
        memory_budget_bytes: Optional[int] = None,
        chunk_size: Optional[int] = None,
        plans: Optional[Sequence[CountingPlan]] = None,
        block_size: int = 256,
        interpret: bool = False,
        mesh=None,
        column_batch: Optional[int] = None,
        ema_mode: str = "streamed",
        gather_dtype=None,
        balance_degrees: bool = True,
        mesh_comm: Optional[str] = None,
        tuning=None,
    ):
        if isinstance(templates, Template):
            templates = [templates]
        if not templates:
            raise ValueError("CountingEngine needs at least one template")

        # fault-injection seam: construction is the first failure surface a
        # serving deployment meets (compile errors, operand OOMs) — the
        # chaos suite breaks it here, before any operand binds
        _faults.maybe_fail("engine_build", ctx=f"backend={backend}")

        # --- layer 1: the backend-agnostic plan (pure, graph-free).
        self.plan_ir: TemplatePlan = build_template_plan(templates, plans=plans)
        self.graph = graph
        self.templates: Tuple[Template, ...] = self.plan_ir.templates
        self.plans: Tuple[CountingPlan, ...] = self.plan_ir.counting_plans
        self.k = self.plan_ir.k
        self.policy = DtypePolicy.resolve(dtype_policy)
        self.interpret = interpret
        self.mesh = mesh

        # --- layer 2: the calibrated cost model.
        self.cost = CostModel(self.plan_ir, graph, self.policy.store_dtype)

        # --- backend resolution (operands bound once, below).  Runs before
        # the column-batch/chunk knobs are consumed: a tuned config may
        # override both, and only un-overridden (None) caller args yield.
        self._tuning = None
        if spmm_fn is not None:
            self.backend = "custom"
            self.backend_source = "custom"
            self.backend_reason = "caller-supplied spmm_fn"
        elif backend == "auto" and mesh is not None:
            self.backend = "mesh"
            self.backend_source = "mesh"
            self.backend_reason = "mesh= given"
        else:
            if backend != "auto" and backend not in ENGINE_BACKENDS:
                raise ValueError(
                    f"unknown backend {backend!r} (one of {ENGINE_BACKENDS})"
                )
            name, source, reason, cfg = resolve_backend_config(
                graph,
                backend=backend,
                canons=self.plan_ir.canons,
                tuning=tuning,
            )
            self.backend = name
            self.backend_source = source
            self.backend_reason = reason
            self._tuning = cfg
            if cfg is None and tuning is not None:
                # a config was offered but env/explicit resolution beat it —
                # surface that, an operator override silently eating a tuned
                # config is exactly the ambiguity the source field exists for
                logger.info(
                    "tuned config ignored: backend resolved by %s (%s)",
                    source,
                    reason,
                )
            if cfg is not None:
                if column_batch is None and cfg.column_batch is not None:
                    column_batch = cfg.column_batch
                if chunk_size is None and cfg.chunk_size is not None:
                    chunk_size = cfg.chunk_size
                if mesh_comm is None:
                    mesh_comm = getattr(cfg, "mesh_comm", None)

        # Budget resolution mirrors the other tuned knobs: an explicit
        # caller budget wins, else the budget the winning config was tuned
        # under, else the default — and it is part of the cache key, so
        # differently-budgeted engines never share compiled programs.
        if memory_budget_bytes is None and self._tuning is not None:
            memory_budget_bytes = self._tuning.memory_budget_bytes
        self.memory_budget_bytes = int(
            DEFAULT_MEMORY_BUDGET_BYTES
            if memory_budget_bytes is None
            else memory_budget_bytes
        )

        # Fused-slice width: local default keeps the per-batch edge gather
        # cache-sized; the mesh backend auto-sizes its own (one batch there
        # is also one all-gather collective).
        if column_batch:
            self.column_batch = int(column_batch)
        else:
            self.column_batch = self.cost.pick_local_column_batch()

        norm = colorful_probability(self.k)
        self._norm_factors = jnp.asarray(
            [1.0 / (norm * plan.automorphisms) for plan in self.plans], jnp.float32
        )

        # Observability counters, Python-level: ``trace_count`` bumps once
        # per jit trace (== compilation), ``passive_aggregations`` once per
        # traced aggregation launch — a warm engine replaying compiled
        # programs holds steady on both.
        self.trace_count = 0
        self.counters: Dict[str, int] = {"passive_aggregations": 0}

        # --- layer 3: bind the plan to devices.
        self.backend_impl: EngineBackend = make_backend(
            self,
            spmm_fn=spmm_fn,
            block_size=block_size,
            mesh=mesh,
            column_batch=column_batch,
            ema_mode=ema_mode,
            gather_dtype=gather_dtype,
            balance_degrees=balance_degrees,
            mesh_comm=mesh_comm,
            tuning=self._tuning if self._tuning is not None else tuning,
        )

        # remembered for the cache key: a None chunk means "picked from the
        # budget", which is itself deterministic given the budget
        self._chunk_explicit = bool(chunk_size)
        self._column_batch_arg = column_batch
        self.chunk_size = int(chunk_size) if chunk_size else self.cost.pick_chunk_size(
            self.bytes_per_coloring(), self.memory_budget_bytes
        )

        self._graph_signature: Optional[str] = None  # computed lazily
        if logger.isEnabledFor(logging.INFO):
            # describe() hashes the graph (O(|E|) host work) — only pay for
            # it when the line is actually emitted; services that want the
            # record call describe() themselves
            d = self.describe()
            logger.info(
                "CountingEngine backend=%s (%s: %s) n=%d edges=%d k=%d templates=%d "
                "column_batch=%d chunk=%d predicted transient=%.2f MiB "
                "resident=%.2f MiB per coloring",
                d["backend"]["name"],
                d["backend"]["source"],
                d["backend"]["reason"],
                d["n"],
                d["num_directed"],
                d["k"],
                len(self.templates),
                d["column_batch"],
                d["chunk_size"],
                d["memory"]["predicted_transient_bytes"] / 2**20,
                d["memory"]["predicted_resident_bytes"] / 2**20,
            )

        self._run_fn = None  # built lazily (jit cache)
        self._chunk_fn = None  # streaming per-chunk jit (serving path)

    # ------------------------------------------------------------------
    # Plan-derived views (compat names preserved for tests/benchmarks)
    # ------------------------------------------------------------------

    @property
    def _canons(self) -> Tuple[Tuple[str, ...], ...]:
        return self.plan_ir.canons

    @property
    def _free_at(self):
        return self.plan_ir.free_at

    @property
    def _exec_groups(self):
        return self.plan_ir.exec_groups

    @property
    def _stage_tables(self):
        """Device-bound split tables of the local backends (empty for mesh,
        which builds its own streamed tables at the all-gather width)."""
        return getattr(self.backend_impl, "stage_tables", {})

    def peak_columns(self) -> int:
        """Peak live M columns per coloring across the shared DP.

        Liveness-aware: states shared across templates by canonical form
        are freed at their last scheduled read, and the fused pipeline
        never holds an aggregate product, so the figure is the simulated
        peak of the schedule (for a single template it equals the in-place
        bound ``CountingPlan.peak_columns()``).
        """
        return self.plan_ir.peak_columns

    def _max_passive_columns(self) -> int:
        return self.plan_ir.max_passive_columns

    def _max_stage_columns(self) -> int:
        """Widest single stage: active + passive + output columns (the fused
        Pallas kernel's per-stage transposed staging footprint)."""
        return self.plan_ir.max_stage_columns

    # ------------------------------------------------------------------
    # Identity & observability (the serving layer builds on these)
    # ------------------------------------------------------------------

    def graph_signature(self) -> str:
        """Content hash of the graph (memoized; see :meth:`Graph.signature`)."""
        if self._graph_signature is None:
            self._graph_signature = self.graph.signature()
        return self._graph_signature

    def cache_key(self) -> Tuple:
        """This engine's :func:`engine_cache_key` (resolved values).

        Matches what a caller computes *before* construction with the same
        arguments, so ``CountingService`` can look up a warm engine without
        building one.  Only meaningful for the named local backends — a
        ``custom`` ``spmm_fn``'s identity is not captured by the key.
        """
        return _assemble_cache_key(
            self.graph_signature(),
            self.plan_ir.canons,
            self.backend,
            self.policy,
            ("chunk", self.chunk_size)
            if self._chunk_explicit
            else ("budget", self.memory_budget_bytes),
            self._column_batch_arg,
            None if self._tuning is None else self._tuning.key_fragment(),
        )

    def describe(self) -> Dict:
        """Structured construction/decision record: the backend decision
        and its reason, shapes, dtype policy, chunk plan, memory model,
        and the bound plan's summary — what the construction log line
        says, machine-readable (services attach it to cache entries)."""
        itemsize = jnp.dtype(self.policy.store_dtype).itemsize
        describe_comm = getattr(self.backend_impl, "describe_comm", None)
        return {
            # nested: which rung of the resolution ladder decided (explicit /
            # env / tuned / heuristic — plus custom / mesh), with the bound
            # TuningConfig's summary when one is live
            "backend": {
                "name": self.backend,
                "source": self.backend_source,
                "reason": self.backend_reason,
                "tuning": None if self._tuning is None else self._tuning.describe(),
            },
            "n": self.graph.n,
            "num_directed": self.graph.num_directed,
            "k": self.k,
            "templates": [t.name for t in self.templates],
            "dtype_policy": {
                "store": str(jnp.dtype(self.policy.store_dtype)),
                "accum": str(jnp.dtype(self.policy.accum_dtype)),
            },
            # the mesh backend aggregates at its own all-gather batch width
            "column_batch": getattr(self.backend_impl, "column_batch", self.column_batch),
            "chunk_size": self.chunk_size,
            # mesh backends: the resolved collective scheme + per-stage
            # comm schedule (None on local backends)
            "comm": describe_comm() if describe_comm is not None else None,
            "shared_passive_groups": sum(
                1 for m in self.plan_ir.exec_groups.values() if len(m) > 1
            ),
            "plan": self.plan_ir.describe(),
            "memory": {
                "budget_bytes": self.memory_budget_bytes,
                "fusion_slack": self.cost.fusion_slack,
                "predicted_transient_bytes": self.backend_impl.transient_elements()
                * itemsize,
                "predicted_resident_bytes": self.backend_impl.resident_elements()
                * itemsize,
                "bytes_per_coloring": self.bytes_per_coloring(),
            },
            "graph_signature": self.graph_signature(),
            "cache_key": self.cache_key(),
        }

    # ------------------------------------------------------------------
    # Memory planning (delegated to the cost model + backend geometry)
    # ------------------------------------------------------------------

    def bytes_per_coloring(self) -> int:
        """Calibrated live bytes one coloring contributes to a chunk.

        The cost model's formula fed with the bound backend's operand
        geometry: resident M-matrix state plus the widest per-stage
        transient (edge/row gather scratch for the local backends;
        all-gather buffer + per-shard message gather for the mesh backend,
        where the figure is per shard), corrected by the fusion-slack
        factor.
        """
        return self.backend_impl.bytes_per_coloring()

    def predicted_peak_bytes(self) -> int:
        """The chunk picker's live-footprint prediction for one chunk."""
        return self.chunk_size * self.bytes_per_coloring()

    def compiled_memory_analysis(self, iterations: Optional[int] = None) -> Dict[str, Optional[float]]:
        """Compile one run and compare XLA's measured temp allocation with
        the chunk picker's prediction (the fusion-slack calibration data:
        ``benchmarks/bench_counting`` commits the ratios as
        ``memory_model`` rows, which :func:`repro.plan.cost.
        load_fusion_slack` folds back into the picker).

        Returns ``{"predicted_bytes", "actual_temp_bytes", "ratio"}`` with
        ``actual_temp_bytes`` / ``ratio`` ``None`` when the backend does not
        expose ``memory_analysis()`` (it is optional in XLA).
        """
        iters = int(iterations) if iterations else self.chunk_size
        chunk = max(1, min(self.chunk_size, iters))
        n_chunks = -(-iters // chunk)
        keys = jnp.zeros((n_chunks, chunk, 2), jnp.uint32)
        predicted = float(self.predicted_peak_bytes())
        actual: Optional[float] = None
        try:
            compiled = self._get_run_fn().lower(keys).compile()
            analysis = compiled.memory_analysis()
            actual = float(analysis.temp_size_in_bytes)
        except (AttributeError, NotImplementedError, TypeError) as exc:  # pragma: no cover
            logger.info("memory_analysis unavailable on this backend: %s", exc)
        except Exception as exc:  # pragma: no cover - backend-specific failures
            logger.info("memory_analysis failed: %s", exc)
        return {
            "predicted_bytes": predicted,
            "actual_temp_bytes": actual,
            "ratio": (predicted / actual) if actual else None,
        }

    # ------------------------------------------------------------------
    # Public API
    # ------------------------------------------------------------------

    def raw_counts(self, colors) -> jnp.ndarray:
        """(n,) coloring -> (T,) raw colorful totals (test/inspection hook)."""
        colors = jnp.asarray(colors)
        return self.backend_impl.counts_for_colors(colors[None, :])[0]

    def _get_run_fn(self):
        if self._run_fn is None:
            self._run_fn = self.backend_impl.make_run_fn()
        return self._run_fn

    def _get_chunk_fn(self):
        if self._chunk_fn is None:
            impl = self.backend_impl

            def chunk_run(keys):
                self.trace_count += 1
                return impl.counts_for_keys_chunk(keys)

            self._chunk_fn = jax.jit(chunk_run)
        return self._chunk_fn

    def count_keys_chunk(self, keys) -> np.ndarray:
        """Streaming increment: one chunk-shaped launch, results back now.

        The serving path: callers stream iterations through repeated calls
        (adaptive stopping folds each increment into its running estimate)
        instead of fixing N upfront.  ``keys`` is ``(m, 2)`` with
        ``m <= chunk_size``; short increments are padded with the last key
        up to ``chunk_size`` so every call hits ONE compiled shape — a warm
        engine never re-traces, whatever increment sizes arrive
        (shape-bucketed padding).  Returns the ``(m, T)`` normalized
        estimates as a float64 host array.

        Fault seams (``repro.testing.faults``) fire HERE, at the Python
        launch boundary, not inside the backend's jitted body — an in-jit
        hook would only run at trace time, so a warm engine would never
        see it.  ``launch`` covers every backend; ``collective`` only the
        backends that declare it (``EngineBackend.fault_sites``).
        """
        keys = jnp.asarray(keys)
        m = int(keys.shape[0])
        if m == 0:
            return np.zeros((0, len(self.templates)), np.float64)
        if m > self.chunk_size:
            raise ValueError(
                f"increment of {m} keys exceeds chunk_size={self.chunk_size}; "
                "split it (count_keys handles multi-chunk runs)"
            )
        _faults.maybe_fail("launch", ctx=f"backend={self.backend}")
        if "collective" in getattr(self.backend_impl, "fault_sites", ()):
            # the pipelined mesh path crosses the collective seam once per
            # ring step (blocking: once per launch) — the injection site
            # fires with matching multiplicity so a seeded fault plan sees
            # every dispatch
            for step in range(getattr(self.backend_impl, "collective_dispatches", 1)):
                _faults.maybe_fail(
                    "collective", ctx=f"backend={self.backend} step={step}"
                )
        pad = self.chunk_size - m
        if pad:
            keys = jnp.concatenate([keys, keys[-1:].repeat(pad, axis=0)], axis=0)
        vals = self._get_chunk_fn()(keys)
        out = np.asarray(vals, dtype=np.float64)[:m]
        return _faults.corrupt_result("launch", out, ctx=f"backend={self.backend}")

    def count_keys(self, keys) -> np.ndarray:
        """Normalized per-iteration estimates for explicit PRNG keys.

        ``keys``: (iters, 2) uint32 PRNG keys (``jax.random.split`` output).
        Returns an (iters, T) float64 host array; all device work happens in
        one jit call (chunked ``lax.map`` over ``chunk_size``-wide batches).
        """
        keys = jnp.asarray(keys)
        iters = keys.shape[0]
        chunk = max(1, min(self.chunk_size, iters))
        n_chunks = -(-iters // chunk)
        pad = n_chunks * chunk - iters
        if pad:
            keys = jnp.concatenate([keys, keys[-1:].repeat(pad, axis=0)], axis=0)
        vals = self._get_run_fn()(keys.reshape(n_chunks, chunk, *keys.shape[1:]))
        flat = np.asarray(vals, dtype=np.float64).reshape(n_chunks * chunk, -1)
        return flat[:iters]

    def estimate(self, iterations: int = 32, seed: int = 0) -> List[EstimateResult]:
        """Run ``iterations`` random colorings; one :class:`EstimateResult`
        per template (paper Algorithm 1, batched)."""
        keys = jax.random.split(jax.random.PRNGKey(seed), iterations)
        vals = self.count_keys(keys)  # (iters, T)
        return [
            EstimateResult(
                mean=float(vals[:, t].mean()),
                std=float(vals[:, t].std()),
                per_iteration=vals[:, t],
                iterations=iterations,
            )
            for t in range(len(self.templates))
        ]
