"""CountingEngine: batched multi-coloring, multi-template color-coding runs.

The estimator loop in early revisions dispatched ONE jit call per coloring —
re-entering Python, re-shipping split tables, and syncing a scalar back to
the host every iteration.  This module amortizes all static work across the
whole (epsilon, delta) estimation run, the way the paper's Algorithm 5
amortizes the neighbor reduction across color sets:

* **Plans and tables once** — ``CountingPlan``s are built per template and
  their split tables land on the device a single time, de-duplicated by
  ``(k, m, m_a)``.
* **Backend auto-selection** — the SpMM kernel is picked from graph
  statistics (:func:`select_backend`): edge-list segment-sum for skewed
  degree distributions, padded ELL for flat ones, dense adjacency for tiny
  graphs, and the Pallas blocked-ELL kernel for large graphs on TPU.
* **Batched colorings** — a chunk of ``B`` colorings is fused into the
  *column* dimension of the DP state: every M matrix is ``(n, B, C)`` and
  each stage's SpMM is ONE wide neighbor reduction over ``B * C`` columns
  (``lax.map`` walks the chunks inside a single jit).  This is the paper's
  "batch more columns into one SpMM" principle applied across colorings —
  a plain ``vmap`` over the leading axis lowers to batched scatters that
  XLA:CPU executes far slower than one wide scatter.
* **Chunk-size picker** — the live M-matrix footprint per coloring is
  derived from ``CountingPlan.peak_columns()`` (plus the per-stage neighbor
  gather transient, the real high-water mark for the edge backend) and the
  chunk size is chosen to keep ``chunk * footprint`` under a configurable
  VMEM/HBM budget.
* **Multi-template sharing** — several same-``k`` templates are counted per
  coloring; sub-template DP states and SpMM products are memoized by the
  rooted canonical form (AHU string) of the sub-template, so coinciding
  passive sub-templates (and the leaf one-hot + its neighbor sum, shared by
  *every* template) are computed once per coloring.
* **Dtype policy** — fp32 end-to-end, or bf16 storage/gather traffic with
  fp32 accumulation (paper §VI bf16 discussion).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp

from .colorsets import binom, colorful_probability
from .counting import CountingPlan, build_counting_plan
from .graph import Graph
from .templates import Template

__all__ = [
    "DtypePolicy",
    "EstimateResult",
    "CountingEngine",
    "select_backend",
    "pick_chunk_size",
    "sub_template_canonical",
    "DEFAULT_MEMORY_BUDGET_BYTES",
    "MAX_CHUNK_SIZE",
]

#: Default live-footprint budget for one chunk of colorings (bytes).  Sized
#: for the CPU/laptop case; on real TPUs pass the per-core VMEM/HBM figure.
DEFAULT_MEMORY_BUDGET_BYTES = 32 * 1024 * 1024

#: Hard cap on colorings fused into one chunk (diminishing returns beyond).
MAX_CHUNK_SIZE = 64

#: Graphs at or below this vertex count use the dense-adjacency backend.
DENSE_MAX_VERTICES = 256

#: ELL is chosen only when padding waste is bounded: ``n * max_deg`` must not
#: exceed this factor times the true directed edge count.
ELL_PAD_FACTOR = 1.5

#: On TPU, graphs at least this large route to the Pallas blocked-ELL kernel.
BLOCKED_MIN_VERTICES = 4096


@dataclass(frozen=True)
class DtypePolicy:
    """Storage vs accumulation dtypes for the DP state.

    ``store_dtype`` is what M matrices (and therefore the SpMM gather
    traffic) are kept in; ``accum_dtype`` is what neighbor reductions and
    eMA FMAs accumulate in.  ``fp32`` keeps both at float32; ``bf16`` halves
    the storage/gather bytes while accumulating in float32 (paper §VI).
    """

    store_dtype: jnp.dtype
    accum_dtype: jnp.dtype

    @staticmethod
    def resolve(policy: Union[str, "DtypePolicy", jnp.dtype, None]) -> "DtypePolicy":
        if policy is None:
            return DtypePolicy(jnp.float32, jnp.float32)
        if isinstance(policy, DtypePolicy):
            return policy
        if isinstance(policy, str):
            if policy in ("fp32", "float32"):
                return DtypePolicy(jnp.float32, jnp.float32)
            if policy in ("bf16", "bfloat16"):
                return DtypePolicy(jnp.bfloat16, jnp.float32)
            raise ValueError(f"unknown dtype policy {policy!r} (fp32 | bf16)")
        dt = jnp.dtype(policy)
        accum = jnp.float32 if dt in (jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.float16)) else dt
        return DtypePolicy(dt, accum)


@dataclass
class EstimateResult:
    """Per-template estimation summary (kept API-compatible with the old
    ``estimator.EstimateResult``)."""

    mean: float
    std: float
    per_iteration: np.ndarray
    iterations: int


def select_backend(graph: Graph, platform: Optional[str] = None) -> str:
    """Pick the SpMM backend from graph statistics.

    * ``dense``   — tiny graphs: one (n, n) matmul beats gather/scatter.
    * ``blocked`` — large graphs on TPU: the Pallas blocked-ELL kernel.
    * ``ell``     — flat degree distributions where row padding is cheap.
    * ``edges``   — everything else (skewed / power-law graphs: a hub row
      would blow the ELL padding up to ``n * max_deg``).
    """
    platform = platform or jax.default_backend()
    if graph.n <= DENSE_MAX_VERTICES:
        return "dense"
    if platform == "tpu" and graph.n >= BLOCKED_MIN_VERTICES:
        return "blocked"
    max_deg = graph.max_degree()
    if graph.n * max_deg <= ELL_PAD_FACTOR * max(graph.num_directed, 1):
        return "ell"
    return "edges"


def pick_chunk_size(
    bytes_per_coloring: int,
    memory_budget_bytes: int,
    max_chunk: int = MAX_CHUNK_SIZE,
) -> int:
    """Largest chunk whose live footprint stays under the budget (>= 1)."""
    if bytes_per_coloring <= 0:
        return max_chunk
    return max(1, min(max_chunk, int(memory_budget_bytes // bytes_per_coloring)))


def sub_template_canonical(template: Template, vertices: Tuple[int, ...], root: int) -> str:
    """AHU canonical string of the rooted sub-template induced by ``vertices``.

    Two sub-templates with equal strings have identical count matrices
    ``M_s`` for every coloring — the key used to share DP state and SpMM
    products across templates (and across stages within one template).
    """
    allowed = set(vertices)
    adj: Dict[int, List[int]] = {v: [] for v in vertices}
    for u, v in template.edges:
        if u in allowed and v in allowed:
            adj[u].append(v)
            adj[v].append(u)

    def canon(node: int, parent: int) -> str:
        forms = sorted(canon(c, node) for c in adj[node] if c != parent)
        return "(" + "".join(forms) + ")"

    return canon(root, -1)


class CountingEngine:
    """Batched color-coding counting runs over one graph.

    Args:
      graph: the network.
      templates: one :class:`Template` or a sequence of same-``k`` templates
        counted together per coloring (shared leaf one-hot / SpMM products).
      backend: ``auto`` | ``edges`` | ``ell`` | ``dense`` | ``blocked``.
        Ignored when ``spmm_fn`` is given.
      spmm_fn: optional custom ``(n, C) -> (n, C)`` neighbor-sum kernel.
      dtype_policy: ``fp32`` | ``bf16`` | a :class:`DtypePolicy` | a dtype.
      memory_budget_bytes: live-footprint budget steering the chunk picker.
      chunk_size: explicit colorings-per-chunk override (skips the picker).
      plans: optional pre-built :class:`CountingPlan` per template.
      block_size / interpret: Pallas blocked-ELL kernel knobs.
    """

    def __init__(
        self,
        graph: Graph,
        templates: Union[Template, Sequence[Template]],
        *,
        backend: str = "auto",
        spmm_fn: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None,
        dtype_policy: Union[str, DtypePolicy, jnp.dtype, None] = "fp32",
        memory_budget_bytes: int = DEFAULT_MEMORY_BUDGET_BYTES,
        chunk_size: Optional[int] = None,
        plans: Optional[Sequence[CountingPlan]] = None,
        block_size: int = 256,
        interpret: bool = False,
    ):
        if isinstance(templates, Template):
            templates = [templates]
        if not templates:
            raise ValueError("CountingEngine needs at least one template")
        ks = {t.k for t in templates}
        if len(ks) != 1:
            raise ValueError(
                f"all templates must share one k to share colorings, got k={sorted(ks)}"
            )
        self.graph = graph
        self.templates: Tuple[Template, ...] = tuple(templates)
        self.k = ks.pop()
        self.policy = DtypePolicy.resolve(dtype_policy)
        self.memory_budget_bytes = int(memory_budget_bytes)
        self.interpret = interpret

        if plans is None:
            self.plans: Tuple[CountingPlan, ...] = tuple(
                build_counting_plan(t) for t in self.templates
            )
        else:
            if len(plans) != len(self.templates):
                raise ValueError("plans must align with templates")
            self.plans = tuple(plans)

        # --- static schedule: canonical keys + de-duplicated device tables.
        self._canons: List[List[str]] = [
            [
                sub_template_canonical(plan.template, sub.vertices, sub.root)
                for sub in plan.partition.subs
            ]
            for plan in self.plans
        ]
        table_cache: Dict[Tuple[int, int, int], Tuple[jnp.ndarray, jnp.ndarray]] = {}
        self._stage_tables: Dict[Tuple[int, int], Tuple[jnp.ndarray, jnp.ndarray]] = {}
        for p_idx, plan in enumerate(self.plans):
            for i, table in enumerate(plan.tables):
                if table is None:
                    continue
                key = (table.k, table.m, table.m_a)
                if key not in table_cache:
                    table_cache[key] = (jnp.asarray(table.idx_a), jnp.asarray(table.idx_p))
                self._stage_tables[(p_idx, i)] = table_cache[key]

        norm = colorful_probability(self.k)
        self._norm_factors = jnp.asarray(
            [1.0 / (norm * plan.automorphisms) for plan in self.plans], jnp.float32
        )

        # --- SpMM backend (device-resident operands built once).
        if spmm_fn is not None:
            self.backend = "custom"
            self._custom_spmm = spmm_fn
        else:
            self.backend = select_backend(graph) if backend == "auto" else backend
            self._custom_spmm = None
        self._build_spmm_operands(block_size)

        self.chunk_size = int(chunk_size) if chunk_size else pick_chunk_size(
            self.bytes_per_coloring(), self.memory_budget_bytes
        )

        self._run_fn = None  # built lazily (jit cache)

    # ------------------------------------------------------------------
    # Memory planning
    # ------------------------------------------------------------------

    def peak_columns(self) -> int:
        """Live M columns per coloring across the shared multi-template DP.

        With cross-template memoization every unique sub-template state and
        SpMM product stays resident for the whole coloring, so the figure is
        the sum over unique canonical forms — never less than the in-place
        single-template bound ``CountingPlan.peak_columns()``.
        """
        slot_cols: Dict[str, int] = {}
        prod_cols: Dict[str, int] = {}
        for p_idx, plan in enumerate(self.plans):
            for i, sub in enumerate(plan.partition.subs):
                slot_cols.setdefault(self._canons[p_idx][i], binom(self.k, sub.size))
                if not sub.is_leaf:
                    passive = plan.partition.subs[sub.passive]
                    prod_cols.setdefault(
                        self._canons[p_idx][sub.passive], binom(self.k, passive.size)
                    )
        unique_total = sum(slot_cols.values()) + sum(prod_cols.values())
        return max(unique_total, max(p.peak_columns() for p in self.plans))

    def _max_passive_columns(self) -> int:
        cp = 1
        for plan in self.plans:
            for sub in plan.partition.subs:
                if not sub.is_leaf:
                    passive = plan.partition.subs[sub.passive]
                    cp = max(cp, binom(self.k, passive.size))
        return cp

    def bytes_per_coloring(self) -> int:
        """Estimated live bytes one coloring contributes to a chunk.

        Resident term: ``n * peak_columns`` M-matrix floats.  Transient
        term: the widest per-stage neighbor gather — ``(edges, C_p)`` for
        the edge-list backend, ``(n * max_deg, C_p)`` for ELL — which is the
        true high-water mark on scatter/gather backends.
        """
        itemsize = jnp.dtype(self.policy.store_dtype).itemsize
        max_cp = self._max_passive_columns()
        if self.backend in ("edges", "custom"):
            transient = self.graph.num_directed * max_cp
        elif self.backend == "ell":
            transient = self.graph.n * max(self.graph.max_degree(), 1) * max_cp
        else:  # dense / blocked: no edge-wide gather intermediate
            transient = self.graph.n * max_cp
        resident = self.graph.n * self.peak_columns()
        return (transient + resident) * itemsize

    # ------------------------------------------------------------------
    # SpMM backends — all operate on the fused (n, B, C) layout
    # ------------------------------------------------------------------

    def _build_spmm_operands(self, block_size: int) -> None:
        g = self.graph
        if self.backend == "custom":
            pass  # the caller's spmm_fn owns its operands
        elif self.backend == "edges":
            self._src = jnp.asarray(g.src)
            self._dst = jnp.asarray(g.dst)
        elif self.backend == "ell":
            nbr, mask = g.ell()
            self._nbr = jnp.asarray(nbr)
            self._ell_mask = jnp.asarray(mask)
        elif self.backend == "dense":
            self._adj = jnp.asarray(g.dense_adjacency())
        elif self.backend == "blocked":
            from repro.kernels.spmm_blocked.ops import prepare_operand

            self._blocked_op = prepare_operand(g, block_size=block_size)
        else:
            raise ValueError(f"unknown backend {self.backend!r}")

    def _spmm(self, m: jnp.ndarray) -> jnp.ndarray:
        """One neighbor reduction over ALL fused columns; returns accum dtype."""
        g, pol = self.graph, self.policy
        n, b, c = m.shape
        if self.backend == "custom":
            out = self._custom_spmm(m.reshape(n, b * c))
            return out.reshape(n, b, c).astype(pol.accum_dtype)
        if self.backend == "edges":
            return jax.ops.segment_sum(
                m[self._src].astype(pol.accum_dtype),
                self._dst,
                num_segments=n,
                indices_are_sorted=True,
            )
        if self.backend == "ell":
            gathered = m[self._nbr].astype(pol.accum_dtype)  # (n, max_deg, B, C)
            return jnp.einsum("ndbc,nd->nbc", gathered, self._ell_mask.astype(pol.accum_dtype))
        if self.backend == "dense":
            out = jnp.matmul(
                self._adj.astype(pol.store_dtype),
                m.reshape(n, b * c),
                preferred_element_type=pol.accum_dtype,
            )
            return out.reshape(n, b, c).astype(pol.accum_dtype)
        # blocked (Pallas): kernel is 2-D (n, C) — fuse batch into columns.
        from repro.kernels.spmm_blocked.ops import spmm_blocked

        out = spmm_blocked(
            self._blocked_op, m.reshape(n, b * c).astype(jnp.float32), interpret=self.interpret
        )
        return out.reshape(n, b, c).astype(pol.accum_dtype)

    def _ema(self, m_a, b_mat, idx_a, idx_p):
        """Vertex-local eMA on fused (n, B, C) state, fp accumulation."""
        pol = self.policy
        n, bsz, _ = m_a.shape
        n_out, n_splits = idx_a.shape

        def body(t, acc):
            ga = jnp.take(m_a, idx_a[:, t], axis=2).astype(pol.accum_dtype)
            gp = jnp.take(b_mat, idx_p[:, t], axis=2).astype(pol.accum_dtype)
            return acc + ga * gp

        acc = jax.lax.fori_loop(
            0, n_splits, body, jnp.zeros((n, bsz, n_out), pol.accum_dtype)
        )
        return acc.astype(pol.store_dtype)

    # ------------------------------------------------------------------
    # The fused multi-template DP
    # ------------------------------------------------------------------

    def _raw_counts_batch(self, colors: jnp.ndarray) -> jnp.ndarray:
        """(B, n) colorings -> (B, T) un-normalized colorful totals.

        Sub-template states and SpMM products are memoized by canonical
        form, so templates sharing passive sub-templates (and every
        template's leaf stage) reuse one computation per coloring.
        """
        pol = self.policy
        leaf = jax.nn.one_hot(colors.T, self.k, dtype=pol.store_dtype)  # (n, B, k)
        slots: Dict[str, jnp.ndarray] = {}
        prods: Dict[str, jnp.ndarray] = {}
        totals = []
        for p_idx, plan in enumerate(self.plans):
            canons = self._canons[p_idx]
            for i, sub in enumerate(plan.partition.subs):
                key = canons[i]
                if key in slots:
                    continue
                if sub.is_leaf:
                    slots[key] = leaf
                    continue
                p_key = canons[sub.passive]
                if p_key not in prods:
                    prods[p_key] = self._spmm(slots[p_key])
                idx_a, idx_p = self._stage_tables[(p_idx, i)]
                slots[key] = self._ema(slots[canons[sub.active]], prods[p_key], idx_a, idx_p)
            root = slots[canons[plan.partition.root_index]].astype(pol.accum_dtype)
            # reduce color sets first, then vertices: the per-coloring order
            # is independent of the batch size (bit-exact across chunkings)
            totals.append(root.sum(axis=2).sum(axis=0).astype(jnp.float32))
        return jnp.stack(totals, axis=1)  # (B, T)

    def _counts_for_keys_chunk(self, keys_chunk: jnp.ndarray) -> jnp.ndarray:
        colors = jax.vmap(
            lambda key: jax.random.randint(key, (self.graph.n,), 0, self.k)
        )(keys_chunk)
        return self._raw_counts_batch(colors) * self._norm_factors[None, :]

    def _get_run_fn(self):
        if self._run_fn is None:
            self._run_fn = jax.jit(
                lambda keys: jax.lax.map(self._counts_for_keys_chunk, keys)
            )
        return self._run_fn

    # ------------------------------------------------------------------
    # Public API
    # ------------------------------------------------------------------

    def raw_counts(self, colors) -> jnp.ndarray:
        """(n,) coloring -> (T,) raw colorful totals (test/inspection hook)."""
        colors = jnp.asarray(colors)
        return self._raw_counts_batch(colors[None, :])[0]

    def count_keys(self, keys) -> np.ndarray:
        """Normalized per-iteration estimates for explicit PRNG keys.

        ``keys``: (iters, 2) uint32 PRNG keys (``jax.random.split`` output).
        Returns an (iters, T) float64 host array; all device work happens in
        one jit call (chunked ``lax.map`` over ``chunk_size``-wide batches).
        """
        keys = jnp.asarray(keys)
        iters = keys.shape[0]
        chunk = max(1, min(self.chunk_size, iters))
        n_chunks = -(-iters // chunk)
        pad = n_chunks * chunk - iters
        if pad:
            keys = jnp.concatenate([keys, keys[-1:].repeat(pad, axis=0)], axis=0)
        vals = self._get_run_fn()(keys.reshape(n_chunks, chunk, *keys.shape[1:]))
        flat = np.asarray(vals, dtype=np.float64).reshape(n_chunks * chunk, -1)
        return flat[:iters]

    def estimate(self, iterations: int = 32, seed: int = 0) -> List[EstimateResult]:
        """Run ``iterations`` random colorings; one :class:`EstimateResult`
        per template (paper Algorithm 1, batched)."""
        keys = jax.random.split(jax.random.PRNGKey(seed), iterations)
        vals = self.count_keys(keys)  # (iters, T)
        return [
            EstimateResult(
                mean=float(vals[:, t].mean()),
                std=float(vals[:, t].std()),
                per_iteration=vals[:, t],
                iterations=iterations,
            )
            for t in range(len(self.templates))
        ]
